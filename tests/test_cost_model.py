"""Postal-model tests: paper Eqs. 1-4 and the Figs. 7-8 qualitative claims."""
import pytest

from repro.core import cost_model as CM
from repro.core import schedules as S
from repro.core.topology import RegionMap


def test_locality_wins_small_messages_lassen():
    """Paper Fig. 7: locality-aware beats standard Bruck for small data,
    improvement grows with processes per region."""
    b = 4.0   # one 4-byte int per rank
    gains = []
    for pl in (4, 8, 16):
        p = pl * pl * pl
        std = CM.bruck_model(p, b, CM.LASSEN)
        loc = CM.locality_bruck_model(p, pl, b, CM.LASSEN)
        assert loc < std, f"locality should win at pl={pl}"
        gains.append(std / loc)
    assert gains[-1] > gains[0], "improvement should grow with ppn"


def test_datasize_insensitivity():
    """Paper Fig. 8: the relative improvement barely moves with data size."""
    p, pl = 1024 * 16, 16
    ratios = [CM.bruck_model(p, b, CM.LASSEN) /
              CM.locality_bruck_model(p, pl, b, CM.LASSEN)
              for b in (4, 16, 64, 256)]
    assert max(ratios) / min(ratios) < 3.0


def test_schedule_cost_matches_closed_form_order():
    """Round-mode evaluation of generated schedules preserves the ordering
    predicted by the closed forms."""
    p, pl = 64, 8
    region = RegionMap(p, pl)
    costs = {}
    for alg in ("bruck", "locality_bruck", "hierarchical", "multilane"):
        sched = S.ALGORITHMS[alg](p, pl)
        costs[alg] = CM.schedule_cost(sched, CM.LASSEN, 4.0, region)
    assert costs["locality_bruck"] < costs["bruck"]


def test_eager_rendezvous_split():
    pp = CM.LASSEN.nonlocal_
    small, big = pp.msg_cost(1000), pp.msg_cost(10000)
    assert big > small
    # crossing the 8192-byte boundary switches parameter sets
    assert pp.msg_cost(8191) != pytest.approx(
        pp.msg_cost(8192) * 8191 / 8192, rel=0.01)
