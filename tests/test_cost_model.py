"""Postal-model tests: paper Eqs. 1-4 and the Figs. 7-8 qualitative claims."""
import pytest

from repro.core import cost_model as CM
from repro.core import schedules as S
from repro.core.topology import RegionMap


def test_locality_wins_small_messages_lassen():
    """Paper Fig. 7: locality-aware beats standard Bruck for small data,
    improvement grows with processes per region."""
    b = 4.0   # one 4-byte int per rank
    gains = []
    for pl in (4, 8, 16):
        p = pl * pl * pl
        std = CM.bruck_model(p, b, CM.LASSEN)
        loc = CM.locality_bruck_model(p, pl, b, CM.LASSEN)
        assert loc < std, f"locality should win at pl={pl}"
        gains.append(std / loc)
    assert gains[-1] > gains[0], "improvement should grow with ppn"


def test_datasize_insensitivity():
    """Paper Fig. 8: the relative improvement barely moves with data size."""
    p, pl = 1024 * 16, 16
    ratios = [CM.bruck_model(p, b, CM.LASSEN) /
              CM.locality_bruck_model(p, pl, b, CM.LASSEN)
              for b in (4, 16, 64, 256)]
    assert max(ratios) / min(ratios) < 3.0


def test_schedule_cost_matches_closed_form_order():
    """Round-mode evaluation of generated schedules preserves the ordering
    predicted by the closed forms."""
    p, pl = 64, 8
    region = RegionMap(p, pl)
    costs = {}
    for alg in ("bruck", "locality_bruck", "hierarchical", "multilane"):
        sched = S.ALGORITHMS[alg](p, pl)
        costs[alg] = CM.schedule_cost(sched, CM.LASSEN, 4.0, region)
    assert costs["locality_bruck"] < costs["bruck"]


def test_locality_model_matches_oracle_nonpower():
    """The postal model's per-round non-local accounting must equal the
    oracle schedule's worst-rank blocks for non-power region counts — the
    allgatherv partial payload is priced, not the old full buffer."""
    for q, pl in ((3, 2), (5, 2), (6, 2), (3, 4), (5, 3), (6, 4), (4, 4)):
        p = q * pl
        region = RegionMap(p, pl)
        sched = S.ALGORITHMS["locality_bruck"](p, pl)
        blocks = 0
        group = 1
        while group < q:
            active = min(pl, -(-q // group))
            blocks += min(group, q - group) * pl
            group = min(group * active, q)
        assert sched.max_nonlocal_blocks(region) == blocks, (q, pl)


def test_nonpower_locality_cheaper_than_full_buffer():
    """For a wrapped region count the adapted model must price below an
    equivalent full-buffer accounting (recomputed inline) — the pre-PR
    cost, which over-charged the final DCN round."""
    m = CM.TPU_MULTIPOD
    b = 1 << 16
    # cases where the WORST lane's final round wraps (q − group < group);
    # layouts like (10, 4) keep a full-payload lane 1, so worst-rank cost
    # is unchanged there and only lane 2's bytes shrink
    for q, pl in ((5, 2), (6, 4), (3, 2)):
        p = q * pl
        new = CM.locality_bruck_model(p, pl, b, m)
        # full-buffer variant: s_nl uses group (not min(group, q-group))
        n_nl, s_nl = 0, 0.0
        from repro.core.topology import ceil_log
        s_l = b * (pl - 1)
        n_l = ceil_log(2, pl)
        group = 1
        while group < q:
            active = min(pl, -(-q // group))
            n_nl += 1
            s_nl += b * group * pl
            s_l += b * (active - 1) * group * pl
            n_l += ceil_log(2, pl)
            group = min(group * active, q)
        old = m.cost(n_local=n_l, s_local=s_l, n_nonlocal=n_nl,
                     s_nonlocal=s_nl)
        assert new < old, (q, pl, new, old)


def test_max_allreduce_model_nonpower_rounds():
    """Non-power tier sizes pay the fold/unfold rounds (log2(m) + 2), and
    the locality structure matches collectives._rd_allreduce's count."""
    from repro.core.topology import rd_rounds
    assert [rd_rounds(n) for n in (1, 2, 3, 4, 5, 6, 7, 8)] == \
        [0, 1, 3, 2, 4, 4, 4, 3]
    m = CM.TPU_MULTIPOD
    t3 = CM.max_allreduce_model(12, 4, 256.0, m, structure="locality")
    t4 = CM.max_allreduce_model(16, 4, 256.0, m, structure="locality")
    # 3 regions cost MORE rounds than 4 (fold/unfold): 3 nonlocal vs 2
    assert t3 > t4


def test_eager_rendezvous_split():
    pp = CM.LASSEN.nonlocal_
    small, big = pp.msg_cost(1000), pp.msg_cost(10000)
    assert big > small
    # crossing the 8192-byte boundary switches parameter sets
    assert pp.msg_cost(8191) != pytest.approx(
        pp.msg_cost(8192) * 8191 / 8192, rel=0.01)
