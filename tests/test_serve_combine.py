"""Locality-aware decode cache-combine (the executed §Perf serve hook).

Four layers of guarantees:
  * exact-match: the manual shard_map decode path ("locality") emits tokens
    identical to the GSPMD path ("xla") and the single-device reference,
    across sequence-sharded, batch-sharded, unsharded, TP-mixed, ring-cache
    (windowed) and encoder-decoder cache layouts;
  * compiled artifact: the locality decode HLO carries the explicit combine
    schedule (collective-permutes + reduce-scatters) and NO all-reduce of
    the attention-stat payload (no max-combiner all-reduce — the signature
    of GSPMD's implicit sharded-softmax combine);
  * resolution: resolve_cache_combine classifies every cache layout and
    prices the combine as the two-phase logsumexp collective;
  * primitives: allreduce(op=max/min) and locality_logsumexp_combine match
    lax ground truth on a two-region mesh.
"""
import json

import jax
import pytest

B_SEQ = 1          # sequence-parallel layouts decode a single long row

EXACT_MATCH_CODE = r"""
import dataclasses, json
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.models import encdec, transformer
from repro.serve.engine import Engine
from repro.serve.spec import ServeSpec

CL, NEW = 64, 10

def tokens_for(cfg, mesh, params, prompts, combine, extra=None):
    jax.set_mesh(mesh)
    eng = Engine(cfg, mesh, params, ServeSpec(batch=prompts.shape[0],
                                              cache_len=CL,
                                              combine=combine))
    toks = eng.generate(prompts, NEW, extra=extra)
    return eng, toks

def check_arch(arch, mesh8, mesh1, n_layers=2):
    cfg = dataclasses.replace(configs.get_smoke(arch), n_layers=n_layers,
                              dtype=jnp.float32)
    mod = encdec if cfg.family == "audio" else transformer
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (1, 8)).astype(np.int32)
    extra = None
    if cfg.family == "audio":
        extra = {"frames": jnp.asarray(
            rng.standard_normal((1, cfg.enc_seq, cfg.d_model), np.float32))}

    eng_loc, t_loc = tokens_for(cfg, mesh8, params, prompts, "locality", extra)
    assert eng_loc.combine.algorithm == "locality", (arch, eng_loc.combine)
    assert eng_loc.art.decode_fn_locality is not None
    _, t_xla = tokens_for(cfg, mesh8, params, prompts, "xla", extra)
    _, t_ref = tokens_for(cfg, mesh1, params, prompts, "auto", extra)
    assert np.array_equal(t_loc, t_xla), (arch, t_loc, t_xla)
    assert np.array_equal(t_loc, t_ref), (arch, t_loc, t_ref)
    st = eng_loc.stats()
    assert st["decode_steps"] == NEW and st["combine_steps"] == NEW
    assert eng_loc.art.decode_fn_locality is not None
    # combine traffic is sourced from the compiled decode HLO (CommReport),
    # not the analytic nbytes x layer-count estimate
    comm = st["comm"]
    per_step = comm["per_step"]["dp_bytes"]
    assert per_step > 0, comm
    assert st["combine_bytes"] == NEW * per_step, st
    rec = comm["reconcile"]
    assert rec["invocations"] == NEW and rec["match"], rec
    return t_ref

mesh8 = jax.make_mesh((8,), ("data",))
mesh1 = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("data",))

check_arch("llama3.2-3b", mesh8, mesh1)     # dense, full attention
check_arch("gemma2-9b", mesh8, mesh1)       # [window, full] plan: ring cache
check_arch("whisper-tiny", mesh8, mesh1)    # encoder-decoder self-attn cache

# mixed sequence x tensor parallelism: KV heads sharded over 'model'
mesh42 = jax.make_mesh((4, 2), ("data", "model"))
cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=2,
                          dtype=jnp.float32)
params = transformer.init_params(jax.random.PRNGKey(0), cfg)
prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (1, 8)).astype(np.int32)
eng, t_loc = tokens_for(cfg, mesh42, params, prompts, "locality")
assert eng.combine.p == 4, eng.combine
# per-RANK payload: KV heads sharded over the model axis halve the stats
assert eng.combine.nbytes == 1 * (cfg.n_heads // 2) * (cfg.head_dim_ + 1) * 4
_, t_xla = tokens_for(cfg, mesh42, params, prompts, "xla")
_, t_ref = tokens_for(cfg, mesh1, params, prompts, "auto")
assert np.array_equal(t_loc, t_xla), (t_loc, t_xla)
assert np.array_equal(t_loc, t_ref), (t_loc, t_ref)
print("EXACT_MATCH_OK")
"""


@pytest.mark.slow
def test_locality_decode_exact_match(subproc):
    assert "EXACT_MATCH_OK" in subproc(EXACT_MATCH_CODE, devices=8,
                                       timeout=1800)


HLO_CODE = r"""
import dataclasses, json, math
import jax, jax.numpy as jnp
from repro import configs
from repro.models import transformer
from repro.serve.engine import make_serve_fns
from repro.serve.spec import ServeSpec
from repro.core.hlo_analysis import (allreduce_combiners, collective_stats,
                                     op_payloads)

mesh = jax.make_mesh((8,), ("data",))
jax.set_mesh(mesh)
cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=2)
B, CL, n = 1, 64, 8
art = make_serve_fns(cfg, mesh, ServeSpec(batch=B, cache_len=CL,
                                          combine="locality"))
cache_sds = transformer.cache_specs(cfg, B, CL)
tok_sds = jax.ShapeDtypeStruct((B, 1), jnp.int32)

out = {}
for name, fn in (("locality", art.decode_fn_locality),
                 ("xla", art.decode_fn_xla)):
    hlo = fn.lower(art.abstract_params, cache_sds, tok_sds).compile().as_text()
    st = collective_stats(hlo)
    out[name] = {"counts": dict(st.counts),
                 "combiners": allreduce_combiners(hlo),
                 "ar_payloads": op_payloads(hlo, "all-reduce")}

loc, xla = out["locality"], out["xla"]
layers, lg = cfg.n_layers, int(math.log2(n))
# 1. the explicit schedule: one packed-sum reduce-scatter per attention
#    layer, plus max-phase recursive doubling and the Bruck allgather
assert loc["counts"].get("reduce-scatter", 0) >= layers, loc
assert loc["counts"].get("collective-permute", 0) >= 2 * layers * lg, loc
# 2. no all-reduce of the stat payload: GSPMD's implicit combine of a
#    softmax over the sharded axis needs a MAX-combiner all-reduce; the
#    manual path must have none (add-combiner all-reduces from sharded
#    projection matmuls are unrelated and allowed)
bad = [c for c in loc["combiners"] if c in ("maximum", "minimum")]
assert not bad, bad
# 2b. positive control for the detector itself: a plain GSPMD softmax over
#     a sharded axis MUST surface a maximum-combiner all-reduce (combiner
#     computations carry opaque names — the detector resolves root ops)
from jax.sharding import NamedSharding, PartitionSpec as P
sh = NamedSharding(mesh, P("data"))
ctrl = jax.jit(lambda x: jax.nn.softmax(x, axis=0), in_shardings=sh,
               out_shardings=sh)
ctrl_hlo = ctrl.lower(
    jax.ShapeDtypeStruct((64, 16), jnp.float32)).compile().as_text()
assert "maximum" in allreduce_combiners(ctrl_hlo), \
    allreduce_combiners(ctrl_hlo)
# 3. nor an all-reduce carrying the packed o+l stat payload itself
o_elems = B * cfg.n_heads * cfg.head_dim_
packed = (o_elems + B * cfg.n_heads) * 4
padded = -(-(o_elems + B * cfg.n_heads) // n) * n * 4
assert not [b for b in loc["ar_payloads"] if b in (packed, padded)], loc
# 4. the xla path is all-implicit: no explicit schedule leaked into it
assert not xla["counts"].get("reduce-scatter", 0), xla
assert not xla["counts"].get("collective-permute", 0), xla
print("HLO_OK" + json.dumps(out))
"""


@pytest.mark.slow
def test_locality_decode_hlo_has_no_stat_allreduce(subproc):
    assert "HLO_OK" in subproc(HLO_CODE, devices=8, timeout=1200)


COMBINE_PRIMITIVES_CODE = r"""
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import collectives as C

mesh = jax.make_mesh((2, 4), ("pod", "local"))
x = jnp.arange(8 * 5, dtype=jnp.float32).reshape(8, 5) * 0.7 - 11.0

def run(fn, arr, out_specs=None):
    f = jax.shard_map(fn, mesh=mesh, in_specs=P(("pod", "local")),
                      out_specs=out_specs or P(("pod", "local")),
                      check_vma=False)
    return jax.jit(f)(arr)

# generic reduction-op hook: locality max/min == lax ground truth
for op, lax_fn in (("max", jax.lax.pmax), ("min", jax.lax.pmin)):
    truth = run(lambda s, f=lax_fn: f(s, ("pod", "local")), x)
    for alg in ("locality", "xla"):
        out = run(lambda s, a=alg, o=op: C.allreduce(
            s, "pod", "local", algorithm=a, op=o), x)
        assert np.allclose(out, truth), (op, alg)

# logsumexp combine == softmax ground truth over the full axis
k, d = 6, 3
S = jax.random.normal(jax.random.PRNGKey(0), (8 * k,)) * 4.0
V = jax.random.normal(jax.random.PRNGKey(1), (8 * k, d))

def partial_stats(s, v):
    m = jnp.max(s)[None]                    # (1,)
    p = jnp.exp(s - m)
    return p[None, :] @ v, m, jnp.sum(p)[None]   # (1,d), (1,), (1,)

def combined(s, v, alg):
    o, m, l = partial_stats(s, v)
    o, l = C.locality_logsumexp_combine(o, m, l, "pod", "local",
                                        algorithm=alg)
    return (o / l[:, None])[0]

truth = jax.nn.softmax(S) @ V
for alg in ("locality", "xla"):
    f = jax.shard_map(lambda s, v, a=alg: combined(s, v, a), mesh=mesh,
                      in_specs=(P(("pod", "local")), P(("pod", "local"))),
                      out_specs=P(), check_vma=False)
    out = jax.jit(f)(S, V)
    assert np.allclose(np.asarray(out), np.asarray(truth), atol=1e-5), alg
print("PRIMITIVES_OK")
"""


@pytest.mark.slow
def test_logsumexp_combine_primitives(subproc):
    assert "PRIMITIVES_OK" in subproc(COMBINE_PRIMITIVES_CODE, devices=8)


RESOLVE_CODE = r"""
import dataclasses, json
import jax, numpy as np
from repro import configs
from repro.serve.engine import resolve_cache_combine

cfg = configs.get_smoke("llama3.2-3b")
mesh_d = jax.make_mesh((8,), ("data",))
mesh_m = jax.make_mesh((8,), ("model",))
out = {
    "batch_sharded": resolve_cache_combine(cfg, mesh_d, batch=8, cache_len=64),
    "seq_sharded": resolve_cache_combine(cfg, mesh_d, batch=1, cache_len=64),
    "no_data_axis": resolve_cache_combine(cfg, mesh_m, batch=1, cache_len=64),
    "indivisible": resolve_cache_combine(cfg, mesh_d, batch=1, cache_len=60),
    "forced_xla": resolve_cache_combine(cfg, mesh_d, batch=1, cache_len=64,
                                        override="xla"),
}
print("JSON" + json.dumps({k: dataclasses.asdict(v) for k, v in out.items()}))
"""


@pytest.fixture(scope="module")
def resolved_layouts(subproc):
    stdout = subproc(RESOLVE_CODE, devices=8)
    line = [l for l in stdout.splitlines() if l.startswith("JSON")][0]
    return json.loads(line[4:])


@pytest.mark.slow
@pytest.mark.parametrize("layout,expect", [
    ("batch_sharded", dict(algorithm="none", source="n/a", nbytes=0, p=1,
                           p_local=1)),
    ("seq_sharded", dict(nbytes=528, p=8, p_local=8)),
    ("no_data_axis", dict(algorithm="none", source="n/a", nbytes=0, p=1,
                          p_local=1)),
    ("indivisible", dict(algorithm="none", source="n/a", nbytes=0, p=1,
                         p_local=1)),
    ("forced_xla", dict(algorithm="xla", source="explicit", nbytes=528, p=8,
                        p_local=8)),
])
def test_resolve_cache_combine_layouts(resolved_layouts, layout, expect):
    got = resolved_layouts[layout]
    for k, v in expect.items():
        assert got[k] == v, (layout, k, got)
    if layout == "seq_sharded":
        assert got["algorithm"] in ("locality", "xla")
        assert got["source"] in ("model", "table")


# ---------------------------------------------------------------------------
# fast (single-device / deviceless) coverage — runs in --smoke mode
# ---------------------------------------------------------------------------
def test_policy_prices_logsumexp_combine():
    from repro.tuning.measure import simulate_logsumexp_combine
    from repro.tuning.policy import Policy
    pol = Policy(None, machine="lassen")
    sel = pol.select("logsumexp_combine", 16, 4, 528)
    assert sel.algorithm in ("locality", "xla") and sel.source == "model"
    assert sel.cost is not None and sel.cost > 0
    for alg in ("locality", "xla"):
        c = simulate_logsumexp_combine(alg, 16, 4, 65536, "lassen")
        assert c > 0
    # multi-region, bandwidth regime: the locality structure moves ~1/p_l of
    # the non-local bytes and must win under the postal model
    big = 4 << 20
    assert (simulate_logsumexp_combine("locality", 16, 4, big, "lassen")
            < simulate_logsumexp_combine("xla", 16, 4, big, "lassen"))


def test_reduce_op_hook_validates():
    from repro.core import collectives as C
    with pytest.raises(ValueError):
        C._binop("prod")
    assert set(C.REDUCE_BINOPS) == {"sum", "max", "min"}


def test_engine_stats_and_next_token_single_device():
    import dataclasses
    import jax.numpy as jnp
    import numpy as np
    from repro import configs
    from repro.serve.engine import Engine
    from repro.serve.spec import ServeSpec

    cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=2)
    mesh = jax.make_mesh((1,), ("data",))
    jax.set_mesh(mesh)
    from repro.models import transformer
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, mesh, params, ServeSpec(batch=2, cache_len=32))
    assert eng.combine.algorithm == "none"
    prompts = np.zeros((2, 4), np.int32)
    toks = eng.generate(prompts, 3)
    assert toks.shape == (2, 3)
    st = eng.stats()
    assert st["decode_steps"] == 3
    assert st["combine_steps"] == 0 and st["combine_bytes"] == 0
    assert "comm" not in st          # combine "none": telemetry stays off
    assert eng.comm_report is None
    # the sampling rule is the one helper: clamps padded-vocab ids
    big = jnp.zeros((2, 1, cfg.padded_vocab))
    big = big.at[:, :, cfg.padded_vocab - 1].set(9.0)
    tok = eng._next_token(big)
    assert int(tok.max()) <= cfg.vocab_size - 1
