import pytest
from _hypothesis_compat import given, strategies as st

pytestmark = pytest.mark.hypothesis

from repro.core.topology import RegionMap, ceil_log, is_power_of


@given(st.integers(1, 64), st.integers(1, 8))
def test_region_roundtrip(n_regions, p_local):
    rm = RegionMap(p=n_regions * p_local, p_local=p_local)
    for rank in range(rm.p):
        r, l = rm.region_of(rank), rm.local_rank_of(rank)
        assert rm.rank_of(r, l) == rank
        assert 0 <= r < rm.n_regions and 0 <= l < p_local


@given(st.integers(2, 10), st.integers(1, 10 ** 6))
def test_ceil_log(base, x):
    k = ceil_log(base, x)
    assert base ** k >= x
    assert k == 0 or base ** (k - 1) < x


def test_is_power_of():
    assert is_power_of(2, 8) and is_power_of(4, 16) and not is_power_of(4, 8)
    assert is_power_of(3, 27) and not is_power_of(3, 28)


def test_indivisible_raises():
    with pytest.raises(ValueError):
        RegionMap(p=10, p_local=4)
