import pytest
from _hypothesis_compat import given, strategies as st
from conftest import fake_mesh as _fake_mesh

pytestmark = pytest.mark.hypothesis

from repro.core.topology import (RegionMap, ceil_log, device_pod_map,
                                 is_power_of, mesh_region_map)


@given(st.integers(1, 64), st.integers(1, 8))
def test_region_roundtrip(n_regions, p_local):
    rm = RegionMap(p=n_regions * p_local, p_local=p_local)
    for rank in range(rm.p):
        r, l = rm.region_of(rank), rm.local_rank_of(rank)
        assert rm.rank_of(r, l) == rank
        assert 0 <= r < rm.n_regions and 0 <= l < p_local


@given(st.integers(2, 10), st.integers(1, 10 ** 6))
def test_ceil_log(base, x):
    k = ceil_log(base, x)
    assert base ** k >= x
    assert k == 0 or base ** (k - 1) < x


def test_is_power_of():
    assert is_power_of(2, 8) and is_power_of(4, 16) and not is_power_of(4, 8)
    assert is_power_of(3, 27) and not is_power_of(3, 28)


def test_rd_rounds():
    from repro.core.topology import rd_rounds
    # powers: log2(n); non-powers: fold + log2(m) core + unfold
    assert [rd_rounds(n) for n in range(1, 9)] == [0, 1, 3, 2, 4, 4, 4, 3]
    assert rd_rounds(16) == 4 and rd_rounds(17) == 6


def test_indivisible_raises():
    with pytest.raises(ValueError):
        RegionMap(p=10, p_local=4)


def test_device_pod_map_two_axis():
    mesh = _fake_mesh((2, 4), ("pod", "data"))
    pod = device_pod_map(mesh, ("pod",))
    # row-major enumeration: devices 0..3 in pod 0, 4..7 in pod 1
    assert pod == {i: i // 4 for i in range(8)}


def test_device_pod_map_three_axis_mesh():
    mesh = _fake_mesh((2, 4, 2), ("pod", "data", "model"))
    pod = device_pod_map(mesh, ("pod",))
    assert pod == {i: i // 8 for i in range(16)}
    # composite pod axes: ("pod", "data") as the region product
    both = device_pod_map(mesh, ("pod", "data"))
    assert both == {i: i // 2 for i in range(16)}
    # pod axis NOT leading: grouping follows the axis, not memory order
    mesh2 = _fake_mesh((4, 3, 2), ("data", "pod", "model"))
    pod2 = device_pod_map(mesh2, ("pod",))
    assert len(pod2) == 24 and set(pod2.values()) == {0, 1, 2}
    for i in range(24):
        assert pod2[i] == (i // 2) % 3       # row-major (data, pod, model)


def test_device_pod_map_non_power_of_two_pods():
    mesh = _fake_mesh((3, 4), ("pod", "data"))
    pod = device_pod_map(mesh, ("pod",))
    assert pod == {i: i // 4 for i in range(12)}
    rm = mesh_region_map(mesh, ("pod",), ("data",))
    assert rm.n_regions == 3 and rm.p_local == 4
    # the two maps agree on every rank's region
    for rank in range(12):
        assert rm.region_of(rank) == pod[rank]
