"""repro.tuning: cache round-trip/versioning, policy crossovers + hysteresis,
simulated-measurement vs closed-form model agreement, and algorithm="auto"
equivalence inside shard_map (subprocess)."""
import json
import os

import pytest

from repro.core import autotune
from repro.tuning import cache as tcache
from repro.tuning import measure as tmeasure
from repro.tuning import policy as tpolicy
from repro.tuning import sweep as tsweep
from repro.tuning.cache import Entry, SchemaVersionError, TuningCache, bucket_bytes

FP = "sim:lassen"


def _entry(bucket, costs, collective="allgather", p=16, pl=4):
    return Entry(collective=collective, p=p, p_local=pl, dtype="float32",
                 bucket=bucket, costs=costs, source="simulated")


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------
def test_cache_round_trip_atomic(tmp_path):
    cache = TuningCache()
    cache.put(FP, _entry(1024, {"bruck": 1e-5, "ring": 2e-5}))
    cache.put(FP, _entry(4096, {"bruck": 3e-5, "ring": 2.5e-5}))
    path = tmp_path / "table.json"
    cache.save(str(path))
    # atomic write leaves no temp droppings
    assert [p.name for p in tmp_path.iterdir()] == ["table.json"]
    loaded = TuningCache.load(str(path))
    assert len(loaded) == 2
    e = loaded.get(FP, 16, 4, "allgather", "float32", 4096)
    assert e is not None and e.best == "ring" and e.costs == {
        "bruck": 3e-5, "ring": 2.5e-5}
    # group returns buckets ascending
    assert [e.bucket for e in loaded.group(FP, 16, 4, "allgather", "float32")] \
        == [1024, 4096]


def test_cache_rejects_future_schema(tmp_path):
    path = tmp_path / "t.json"
    path.write_text(json.dumps({"schema_version": 99, "entries": {}}))
    with pytest.raises(SchemaVersionError):
        TuningCache.load(str(path))
    path.write_text(json.dumps({"entries": {}}))          # missing version
    with pytest.raises(SchemaVersionError):
        TuningCache.load(str(path))


def test_cache_migrates_v1(tmp_path):
    key = tcache.make_key(FP, 16, 4, "allgather", "float32", 1024)
    v1 = {"schema_version": 1,
          "entries": {key: {"collective": "allgather", "p": 16, "p_local": 4,
                            "dtype": "float32", "bucket": 1024,
                            "costs": {"bruck": 1e-5}}}}   # v1: no "source"
    path = tmp_path / "t.json"
    path.write_text(json.dumps(v1))
    loaded = TuningCache.load(str(path))
    assert loaded.entries[key].source == "measured"


def test_bucket_bytes():
    assert bucket_bytes(1) == 1
    assert bucket_bytes(1000) == 1024
    assert bucket_bytes(1024) == 1024
    assert bucket_bytes(1025) == 2048


# ---------------------------------------------------------------------------
# staleness (generation stamping, schema v3)
# ---------------------------------------------------------------------------
def test_cache_migrates_v2_adds_generation(tmp_path):
    key = tcache.make_key(FP, 16, 4, "allgather", "float32", 1024)
    v2 = {"schema_version": 2,
          "entries": {key: {"collective": "allgather", "p": 16, "p_local": 4,
                            "dtype": "float32", "bucket": 1024,
                            "costs": {"bruck": 1e-5},
                            "source": "simulated"}}}   # v2: no "generation"
    path = tmp_path / "t.json"
    path.write_text(json.dumps(v2))
    loaded = TuningCache.load(str(path))
    assert loaded.entries[key].generation == 0
    assert loaded.max_generation() == 0


def test_stale_keys_and_policy_surface():
    cache = TuningCache()
    for bucket, gen in ((1024, 1), (4096, 3), (16384, 5)):
        e = _entry(bucket, {"bruck": 1e-5, "ring": 2e-5})
        e.generation = gen
        cache.put(FP, e)
    assert cache.max_generation() == 5
    stale = cache.stale_keys(2)            # age >= 2 sweeps behind gen 5
    assert len(stale) == 2 and all("b1024" in k or "b4096" in k
                                   for k in stale)
    assert cache.stale_keys(10) == []
    with pytest.raises(ValueError):
        cache.stale_keys(0)
    pol = tpolicy.Policy(cache, fingerprint=FP)
    assert pol.stale_buckets(2) == stale
    assert tpolicy.Policy(None).stale_buckets(2) == []


def test_sweep_generation_stamp_and_stale_refresh():
    c1, r1 = tsweep.run_sweep(8, 2, sizes=(256,), collectives=("allgather",),
                              mode="simulated", machine="lassen")
    assert r1["generation"] == 1
    assert all(e.generation == 1 for e in c1)
    # everything fresh: a stale_after sweep measures nothing new
    c2, r2 = tsweep.run_sweep(8, 2, sizes=(256,), collectives=("allgather",),
                              mode="simulated", machine="lassen",
                              existing=c1, stale_after=3)
    assert len(c2) == 0 and r2["stale_skipped"] == 1 and r2["generation"] == 2
    # age the cell out: a later sweep pushed the table generation far ahead
    # (simulated by a fresh unrelated entry), so the same sweep re-measures
    fresh = _entry(1 << 30, {"bruck": 1e-5}, p=8, pl=2)
    fresh.generation = 6
    c1.put(FP, fresh)
    c3, r3 = tsweep.run_sweep(8, 2, sizes=(256,), collectives=("allgather",),
                              mode="simulated", machine="lassen",
                              existing=c1, stale_after=3)
    assert len(c3) == 1 and r3["stale_skipped"] == 0
    assert next(iter(c3)).generation == r3["generation"] == 7


def test_sweep_includes_overlap_cells(tmp_path):
    from repro.tuning.measure import OVERLAP_INTENSITY_OCTAVES
    cache, report = tsweep.run_sweep(
        16, 4, sizes=(4096,), collectives=("overlap",),
        mode="simulated", machine="lassen")
    colls = {e.collective for e in cache}
    assert colls == {f"overlap:i{k}" for k in OVERLAP_INTENSITY_OCTAVES}
    assert all(set(e.costs) == {"eager", "prefetch"} for e in cache)
    # the whole table (with overlap cells) round-trips the schema gate
    table = tmp_path / "tab.json"
    rep = tmp_path / "rep.json"
    tsweep.write_outputs(cache, report, table_path=str(table),
                         report_path=str(rep))
    import subprocess, sys, os
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run(
        [sys.executable, os.path.join(repo, "scripts",
                                      "check_tuning_schema.py"), str(table)],
        capture_output=True, text=True)
    assert out.returncode == 0, out.stdout + out.stderr
    # BENCH report carries the metadata stamp for the trend job
    meta = json.loads(rep.read_text())["meta"]
    assert {"jax_version", "backend", "device_count"} <= set(meta)


# ---------------------------------------------------------------------------
# policy
# ---------------------------------------------------------------------------
def test_policy_crossover_monotone_in_bytes():
    cache, _ = tsweep.run_sweep(16, 4, mode="simulated", machine="lassen")
    pol = tpolicy.Policy(cache, fingerprint=FP, machine="lassen")
    table = pol.crossover_table("allgather", 16, 4, "float32")
    assert table, "sweep produced no crossover table"
    buckets = [b for b, _, _ in table]
    assert buckets == sorted(buckets) and len(set(buckets)) == len(buckets)
    # selection is piecewise-constant: walking sizes upward, the chosen
    # algorithm changes only at bucket boundaries and matches the table
    prev_alg, changes = None, 0
    for nbytes in [2 ** k for k in range(4, 24)]:
        sel = pol.select("allgather", 16, 4, nbytes)
        assert sel.source == "table"
        if prev_alg is not None and sel.algorithm != prev_alg:
            changes += 1
        prev_alg = sel.algorithm
    assert changes <= len(set(a for _, a, _ in table))


def test_policy_hysteresis_suppresses_flapping():
    cache = TuningCache()
    # ring "wins" the middle bucket by only 5% — inside the 10% band the
    # incumbent (bruck) must be kept; at 2x it must switch.
    cache.put(FP, _entry(1024, {"bruck": 1.0e-5, "ring": 2.0e-5}))
    cache.put(FP, _entry(4096, {"bruck": 2.0e-5, "ring": 1.9e-5}))
    cache.put(FP, _entry(16384, {"bruck": 4.0e-5, "ring": 2.0e-5}))
    pol = tpolicy.Policy(cache, fingerprint=FP, hysteresis=0.10)
    assert pol.select("allgather", 16, 4, 1024).algorithm == "bruck"
    assert pol.select("allgather", 16, 4, 4096).algorithm == "bruck"   # held
    assert pol.select("allgather", 16, 4, 16384).algorithm == "ring"   # clear


def test_policy_model_fallback_matches_autotune():
    tpolicy.set_default_policy(tpolicy.Policy(None, machine="tpu_v5e"))
    try:
        for nbytes in (256, 1 << 16, 1 << 22):
            got = tpolicy.resolve("allgather", 16, 4, nbytes)
            want = autotune.pick_allgather(16, 4, nbytes, "tpu_v5e",
                                           use_table=False)
            assert got == want, (nbytes, got, want)
    finally:
        tpolicy.set_default_policy(None)


# ---------------------------------------------------------------------------
# measured (simulated executor) vs closed-form model
# ---------------------------------------------------------------------------
def test_simulated_measurement_tracks_model():
    """On the simulated machine the round-priced schedules must stay within
    a small factor of the closed forms (they differ by final-round effects,
    not orders of magnitude), and winner agreement must be high."""
    for nbytes in (256, 4096, 1 << 18):
        modeled = autotune.model_costs(16, 4, nbytes, "lassen")
        for alg in ("bruck", "ring"):
            sim = tmeasure.simulate("allgather", alg, 16, 4, nbytes, "lassen")
            ratio = sim / modeled[alg]
            assert 0.3 < ratio < 3.0, (alg, nbytes, ratio)
    _, report = tsweep.run_sweep(16, 4, mode="simulated", machine="lassen")
    assert report["winner_agreement"]["fraction"] >= 0.5


def test_sweep_outputs(tmp_path):
    cache, report = tsweep.run_sweep(
        8, 2, sizes=(256, 4096), collectives=("allgather",),
        mode="simulated", machine="quartz")
    table = tmp_path / "tab.json"
    rep = tmp_path / "rep.json"
    tsweep.write_outputs(cache, report, table_path=str(table),
                         report_path=str(rep))
    assert TuningCache.load(str(table)).entries
    r = json.loads(rep.read_text())
    assert r["fingerprint"] == "sim:quartz"
    assert r["topology"] == {"p": 8, "p_local": 2, "n_regions": 4}
    assert all(c["measured_winner"] in c["measured_s"] for c in r["cells"])


# ---------------------------------------------------------------------------
# wiring
# ---------------------------------------------------------------------------
def test_monitor_logs_algorithm_changes():
    from repro.runtime import StepMonitor
    m = StepMonitor(k=3.0, warmup=1)
    ev = m.record(1.0, algorithm="locality_bruck")
    assert any("locality_bruck" in e for e in ev)
    assert not m.record(1.0, algorithm="locality_bruck")   # unchanged: quiet
    ev = m.record(1.0, algorithm="ring")
    assert any("ring" in e for e in ev)


def test_serve_combine_resolution_single_device():
    import jax
    from repro import configs
    from repro.serve.engine import resolve_cache_combine
    mesh = jax.make_mesh((1,), ("data",))
    cfg = configs.get_smoke("llama3.2-3b")
    choice = resolve_cache_combine(cfg, mesh, batch=4, cache_len=64)
    assert choice.algorithm == "none"       # no sequence sharding on 1 chip


GRAD_SYNC_AUTO_CODE = r"""
import jax, dataclasses, shutil
from repro import configs
from repro.train import Trainer, TrainerConfig

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
jax.set_mesh(mesh)
shutil.rmtree("/tmp/repro_ckpt_auto", ignore_errors=True)
cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=2)
tcfg = TrainerConfig(steps=4, seq_len=32, global_batch=8, ckpt_every=100,
                     ckpt_dir="/tmp/repro_ckpt_auto", log_every=100,
                     grad_sync="auto")
logs = []
tr = Trainer(cfg, mesh, tcfg, log=logs.append)
assert tr.artifacts.grad_sync in ("locality", "flat_psum"), tr.artifacts
assert tr.artifacts.grad_algorithm in ("locality", "xla")
assert tr.artifacts.grad_sync_source in ("table", "model")
assert any("grad_sync=auto ->" in l for l in logs), logs
out = tr.run()
assert any(e.startswith("collective:") for e in tr.events), tr.events
assert out["steps"] == 4
print("GRAD_SYNC_AUTO_OK", tr.artifacts.grad_sync,
      tr.artifacts.grad_algorithm, tr.artifacts.grad_sync_source)
"""


@pytest.mark.slow
def test_trainer_grad_sync_auto(subproc):
    assert "GRAD_SYNC_AUTO_OK" in subproc(GRAD_SYNC_AUTO_CODE, devices=8)


AUTO_EQUIV_CODE = r"""
import os, tempfile
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import collectives as C
from repro.tuning import sweep
from repro.tuning.policy import default_policy, set_default_policy

tmp = tempfile.mkdtemp()
cache, _ = sweep.run_sweep(16, 4, mode="simulated", machine="lassen")
path = os.path.join(tmp, "table.json")
cache.save(path)
os.environ["REPRO_TUNING_TABLE"] = path
set_default_policy(None)                      # rediscover from env

pol = default_policy()
mesh = jax.make_mesh((4, 4), ("pod", "local"))
for n in (3, 16384):
    sel = pol.select("allgather", 16, 4, n * 4)
    assert sel.source == "table", sel
    x = jnp.arange(16 * n, dtype=jnp.float32).reshape(16, n)
    def run(fn):
        return jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=P(("pod","local")),
                                     out_specs=P(("pod","local"))))(x)
    auto = run(lambda s: C.allgather(s, "pod", "local", algorithm="auto",
                                     tiled=True))
    explicit = run(lambda s, a=sel.algorithm: C.allgather(
        s, "pod", "local", algorithm=a, tiled=True))
    truth = run(lambda s: jax.lax.all_gather(s, ("pod","local"), tiled=True))
    assert np.array_equal(np.asarray(auto), np.asarray(explicit)), sel
    assert np.allclose(np.asarray(auto), np.asarray(truth)), sel
ar = run = None
print("AUTO_EQUIV_OK")
"""


@pytest.mark.slow
def test_allgather_auto_equivalence_in_shard_map(subproc):
    assert "AUTO_EQUIV_OK" in subproc(AUTO_EQUIV_CODE, devices=16)
