"""Fallback shim for ``hypothesis`` so property tests degrade to skips.

Import the hypothesis API from here instead of ``hypothesis`` directly::

    from _hypothesis_compat import given, settings, assume, strategies as st

When hypothesis is installed (see requirements-dev.txt) the real library is
re-exported unchanged. When it is missing (the pinned CI container does not
ship it), ``@given`` replaces the test body with a ``pytest.skip`` so the
module still collects and the non-property tests in it still run.
"""
from __future__ import annotations

try:
    from hypothesis import assume, example, given, settings  # noqa: F401
    from hypothesis import strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Opaque strategy stub supporting the combinator surface we use."""

        def __init__(self, desc: str = "stub"):
            self.desc = desc

        def _derived(self, op: str) -> "_Strategy":
            return _Strategy(f"{self.desc}.{op}")

        def map(self, fn):
            return self._derived("map")

        def filter(self, fn):
            return self._derived("filter")

        def flatmap(self, fn):
            return self._derived("flatmap")

        def __repr__(self):
            return f"<stub strategy {self.desc}>"

    class _Strategies:
        def __getattr__(self, name):
            # integers / sampled_from / tuples / lists / floats / just / ...
            return lambda *a, **k: _Strategy(name)

    strategies = _Strategies()

    def given(*_args, **_kwargs):
        def decorate(fn):
            # NOTE: deliberately no functools.wraps — pytest must see the
            # (*a, **k) signature, not the original's hypothesis-injected
            # parameters (it would look for fixtures of those names).
            def skipper(*a, **k):
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            skipper.__module__ = fn.__module__
            return skipper

        return decorate

    def settings(*_args, **_kwargs):
        def decorate(fn):
            return fn

        return decorate

    def assume(condition):
        return True

    def example(*_args, **_kwargs):
        def decorate(fn):
            return fn

        return decorate
