"""DMA schedule compilation: table executor oracle + paper properties."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import schedules as S
from repro.core.topology import RegionMap, ceil_log
from repro.kernels.dma_allgather.schedule_compile import (
    compile_schedule, execute_table, locality_bruck_raw)

pytestmark = pytest.mark.hypothesis


def _check(dma):
    out = execute_table(dma)
    assert (out == np.arange(dma.p)[None, :]).all()


@settings(max_examples=25, deadline=None)
@given(st.sampled_from([2, 4, 8]), st.integers(1, 5))
def test_tables_correct(pl, k):
    p = pl * pl * k        # mixes power and non-power region counts
    for alg in ("bruck", "ring", "multilane"):
        _check(compile_schedule(S.ALGORITHMS[alg](p, pl)))
    _check(compile_schedule(locality_bruck_raw(p, pl)))


@settings(max_examples=20, deadline=None)
@given(st.sampled_from([2, 4, 8, 16]), st.sampled_from([1, 2, 3]))
def test_raw_locality_preserves_paper_traffic(pl, k):
    """The DMA-clean variant must not inflate non-local traffic vs Alg. 2."""
    from _hypothesis_compat import assume
    assume(pl ** (k + 1) <= 1024)        # tables are O(p²) host memory
    r = pl ** k
    p = r * pl
    region = RegionMap(p, pl)
    dma = compile_schedule(locality_bruck_raw(p, pl))
    nl_msgs, nl_blocks = dma.nonlocal_stats(region)
    assert nl_msgs == k                                # ceil(log_pl(r))
    assert nl_blocks == sum(pl ** (i + 1) for i in range(k))
    # capacity: no duplicate receives for power-of-pl region counts
    assert dma.capacity == p


def test_raw_locality_non_power_regions():
    """Non-power region counts still complete (wrapped exchanges allowed to
    duplicate; capacity grows accordingly)."""
    for (p, pl) in [(24, 4), (40, 4), (48, 8), (12, 2)]:
        dma = compile_schedule(locality_bruck_raw(p, pl))
        _check(dma)
        assert dma.capacity >= p


def test_hierarchical_rejected():
    from repro.kernels.dma_allgather.dma_ag import build_schedule
    with pytest.raises(NotImplementedError):
        build_schedule("hierarchical", 16, 4)
