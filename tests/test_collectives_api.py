"""The unified collective API surface (DESIGN.md §12).

Covers the string-keyed vocabulary tables (one enum shared by the family
functions, the tuning cells, and the comm-ledger labels), the
``collective()``/``Collective``/``finish()`` dispatch layer, and the
deprecation shims for the pre-redesign per-algorithm entry points.
Functional equivalence across all four spellings of the same collective
runs in a forced-multi-device subprocess.
"""
import dataclasses
import warnings

import jax.numpy as jnp
import pytest

from repro.core import collectives as C


# ---------------------------------------------------------------------------
# Vocabulary tables
# ---------------------------------------------------------------------------

def test_vocabulary_tables_consistent():
    assert set(C.ALGORITHMS_BY_KIND) == set(C.KINDS)
    assert set(C.DEFAULT_ALGORITHM) == set(C.KINDS)
    for kind, algs in C.ALGORITHMS_BY_KIND.items():
        assert len(set(algs)) == len(algs), kind
        assert C.DEFAULT_ALGORITHM[kind] in algs, kind


def test_tuning_vocab_is_the_api_vocab():
    from repro.tuning import measure
    assert set(measure.ALL_TO_ALL_ALGORITHMS) <= set(
        C.ALGORITHMS_BY_KIND["all_to_all"])
    assert set(measure.ALLGATHER_ALGORITHMS) <= set(
        C.ALGORITHMS_BY_KIND["allgather"])
    assert set(measure.ALLREDUCE_ALGORITHMS) <= set(
        C.ALGORITHMS_BY_KIND["allreduce"])
    assert set(measure.MIGRATE_ALGORITHMS) <= set(
        C.ALGORITHMS_BY_KIND["cache_migrate"])
    assert set(measure.LOGSUMEXP_ALGORITHMS) <= set(
        C.ALGORITHMS_BY_KIND["combine"])


def test_kind_alias_and_error_paths():
    assert C._norm_kind("logsumexp_combine") == "combine"
    with pytest.raises(ValueError, match="unknown collective kind"):
        C.collective("gathers", jnp.zeros(4), outer="pod")
    with pytest.raises(ValueError, match="unknown algorithm"):
        C.collective("allgather", jnp.zeros(4), outer="pod",
                     algorithm="nope")
    with pytest.raises(NotImplementedError, match="start/finish"):
        C.collective("reduce_scatter", jnp.zeros(4), outer="pod",
                     start=True)
    with pytest.raises(NotImplementedError, match="start/finish"):
        C.collective("cache_migrate", jnp.zeros(4), outer="pod",
                     algorithm="xla", start=True)


def test_collective_dataclass_normalizes_and_freezes():
    c = C.Collective("allgather", outer="pod", local="data")
    assert c.outer == ("pod",) and c.local == ("data",)
    assert C.Collective("combine", outer=("pod",)).local == ()
    with pytest.raises(ValueError, match="unknown collective kind"):
        C.Collective("nope")
    with pytest.raises(dataclasses.FrozenInstanceError):
        c.kind = "allreduce"


# ---------------------------------------------------------------------------
# Deprecated aliases: warn exactly once per process, then forward
# ---------------------------------------------------------------------------

ALIASES = [
    "bruck_allgather", "ring_allgather", "hierarchical_allgather",
    "multilane_allgather", "locality_bruck_allgather",
    "locality_bruck_allgather_start", "locality_bruck_allgather_finish",
    "locality_allreduce", "locality_logsumexp_combine",
    "locality_logsumexp_combine_start", "locality_logsumexp_combine_finish",
]


@pytest.mark.parametrize("name", ALIASES)
def test_deprecated_alias_warns_once(name):
    fn = getattr(C, name)
    C._WARNED.discard(name)     # isolate from other tests in this process
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        for _ in range(2):
            try:
                fn()            # warn fires before arg validation
            except Exception:
                pass
    dep = [r for r in rec if issubclass(r.category, DeprecationWarning)
           and name in str(r.message)]
    assert len(dep) == 1, [str(r.message) for r in rec]
    msg = str(dep[0].message)
    assert "DESIGN.md" in msg and ("collective(" in msg or "finish(" in msg)


# ---------------------------------------------------------------------------
# Functional equivalence of all spellings (subprocess: 4 forced devices)
# ---------------------------------------------------------------------------

API_ROUNDTRIP_CODE = r"""
import warnings
import repro  # noqa: F401
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
import repro.core.collectives as C

mesh = jax.make_mesh((2, 2), ("pod", "data"))
x = jnp.arange(4 * 3, dtype=jnp.float32).reshape(4, 3)
run = lambda f, a=x: jax.jit(jax.shard_map(f, mesh=mesh,
    in_specs=P(("pod", "data")), out_specs=P(("pod", "data"))))(a)

# allgather: family fn == collective() == Collective sugar == start/finish
# == deprecated alias, all equal to the lax ground truth
truth = run(lambda s: jax.lax.all_gather(s, ("pod", "data"), tiled=True))
cfgd = C.Collective("allgather", outer="pod", local="data",
                    algorithm="locality_bruck")
variants = {
    "family": lambda s: C.allgather(s, "pod", "data",
                                    algorithm="locality_bruck", tiled=True),
    "collective": lambda s: C.collective("allgather", s, outer="pod",
                                         local="data",
                                         algorithm="locality_bruck",
                                         tiled=True),
    "object": lambda s: cfgd(s, tiled=True),
    "split": lambda s: C.finish(cfgd.start(s, tiled=True)),
}
with warnings.catch_warnings():
    warnings.simplefilter("ignore", DeprecationWarning)
    variants["alias"] = lambda s: C.locality_bruck_allgather(
        s, "pod", "data", tiled=True)
for name, f in variants.items():
    out = run(f)
    assert np.array_equal(np.asarray(out), np.asarray(truth)), name

# allreduce default algorithm == psum ground truth through collective()
tr = run(lambda s: jax.lax.psum(s, ("pod", "data")))
ur = run(lambda s: C.collective("allreduce", s, outer="pod", local="data"))
assert np.allclose(np.asarray(ur), np.asarray(tr))

# all_to_all: locality (default) == flat xla through every spelling
xx = jnp.arange(4 * 4 * 2, dtype=jnp.float32).reshape(16, 2)
ax = run(lambda s: C.all_to_all(s, "pod", "data", algorithm="xla"), xx)
al = run(lambda s: C.collective("all_to_all", s, outer="pod",
                                local="data"), xx)
a2 = C.Collective("all_to_all", outer="pod", local="data")
asplit = run(lambda s: C.finish(a2.start(s)), xx)
assert np.array_equal(np.asarray(al), np.asarray(ax))
assert np.array_equal(np.asarray(asplit), np.asarray(ax))
print("API_ROUNDTRIP_OK")
"""


@pytest.mark.slow
def test_all_spellings_agree(subproc):
    assert "API_ROUNDTRIP_OK" in subproc(API_ROUNDTRIP_CODE, devices=4)
