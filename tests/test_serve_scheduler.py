"""Continuous-batching scheduler: paged-cache invariants, the request-level
API contract, and the deterministic replay guarantee.

Four layers:
  * accounting: ``PagedKVCache`` never aliases rows, never over-commits the
    page budget, honors home-pod affinity, and rejects impossible requests
    at submit time (property tests over random op sequences);
  * API redesign: ``ServeSpec`` is the one way to shape the engine — legacy
    kwargs still work one release behind a ``DeprecationWarning`` and
    produce the same artifacts; mixing spec and kwargs is a ``TypeError``;
    ``Engine.generate`` is deprecated but intact;
  * determinism: the same trace on a ``StepClock`` replays to identical
    tokens, timestamps, slots and migration decisions;
  * parity + locality: ``submit``/``drain`` emits tokens bitwise equal to
    the lockstep ``generate`` rows, every stamped comm label reconciles
    against its compiled HLO, and pod-local prefills move ZERO non-local
    bytes.
"""
import dataclasses
import warnings

import numpy as np
import pytest
from _hypothesis_compat import given, strategies as st

from repro.serve.paged import PagedKVCache
from repro.serve.spec import Request, ServeSpec


# ---------------------------------------------------------------------------
# PagedKVCache accounting (pure python, no devices)
# ---------------------------------------------------------------------------
def test_paged_reserve_release_roundtrip():
    paged = PagedKVCache(batch=4, cache_len=32, page_len=8, n_pods=2)
    rows = [paged.reserve(rid, 10, 6) for rid in range(4)]
    assert sorted(rows) == [0, 1, 2, 3]
    assert paged.reserve(99, 4, 4) is None          # full -> None, not raise
    paged.check_invariants()
    assert paged.release(2) == rows[2]
    assert paged.reserve(99, 4, 4) == rows[2]       # freed row is reusable
    paged.check_invariants()


def test_paged_home_pod_affinity():
    paged = PagedKVCache(batch=8, cache_len=32, page_len=8, n_pods=2)
    # pod 1 owns rows 4..7 (contiguous blocks, pod-major)
    assert [paged.pod_of_row(r) for r in range(8)] == [0] * 4 + [1] * 4
    r = paged.reserve(0, 8, 8, home_pod=1)
    assert paged.pod_of_row(r) == 1
    for rid in range(1, 4):                          # fill the rest of pod 1
        assert paged.pod_of_row(paged.reserve(rid, 8, 8, home_pod=1)) == 1
    # pod 1 full -> falls back to a pod-0 row (the migration case)
    assert paged.pod_of_row(paged.reserve(4, 8, 8, home_pod=1)) == 0


def test_paged_rejects_impossible_and_double_reserve():
    paged = PagedKVCache(batch=2, cache_len=16, page_len=8)
    assert not paged.fits(12, 8)                     # 20 tokens > 16 slots
    with pytest.raises(ValueError):
        paged.reserve(0, 12, 8)
    paged.reserve(0, 4, 4)
    with pytest.raises(ValueError):
        paged.reserve(0, 2, 2)                       # rid already holds a row
    with pytest.raises(ValueError):
        PagedKVCache(batch=2, cache_len=16, page_len=5)   # 5 !| 16


@pytest.mark.hypothesis
@given(st.lists(st.tuples(st.integers(0, 1), st.integers(0, 15)),
                max_size=80),
       st.sampled_from([1, 2, 4]))
def test_paged_random_ops_hold_invariants(ops, n_pods):
    paged = PagedKVCache(batch=8, cache_len=32, page_len=4, n_pods=n_pods)
    live, rid = [], 0
    for kind, x in ops:
        if kind == 0:
            row = paged.reserve(rid, 1 + x, 4, home_pod=x % n_pods)
            if row is not None:
                live.append(rid)
            rid += 1
        elif live:
            paged.release(live.pop(x % len(live)))
        paged.check_invariants()
    assert len(live) == len(set(live)) <= paged.batch


@pytest.mark.hypothesis
@given(st.integers(1, 64), st.integers(1, 8), st.integers(1, 64),
       st.integers(0, 64))
def test_paged_pages_needed_is_conservative(page_len, ppr, prompt, max_new):
    paged = PagedKVCache(batch=1, cache_len=page_len * ppr, page_len=page_len)
    pages = paged.pages_needed(prompt, max_new)
    assert pages * page_len >= prompt + max_new      # never under-reserves
    assert (pages - 1) * page_len < prompt + max_new  # by less than a page
    assert paged.fits(prompt, max_new) == (pages <= ppr)


# ---------------------------------------------------------------------------
# Request / ServeSpec validation (no devices)
# ---------------------------------------------------------------------------
def test_request_validates_prompt_and_budget():
    with pytest.raises(ValueError):
        Request(tokens=np.zeros((2, 3), np.int32), max_new=4)
    with pytest.raises(ValueError):
        Request(tokens=np.zeros((0,), np.int32), max_new=4)
    with pytest.raises(ValueError):
        Request(tokens=np.zeros((4,), np.int32), max_new=0)
    r = Request(tokens=[1, 2, 3], max_new=2)
    assert r.tokens.dtype == np.int32 and r.tokens.shape == (3,)


def test_spec_resolve_single_device():
    import jax
    from repro import configs
    cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=1)
    mesh = jax.make_mesh((1,), ("data",))
    res = ServeSpec(batch=2, cache_len=16).resolve(cfg, mesh)
    assert res.n_pods == 1 and res.p_local == 1
    assert res.combine.algorithm == "none"           # nothing to combine


# ---------------------------------------------------------------------------
# API redesign: the deprecation bridge (single device, tiny model)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def tiny():
    import jax
    import jax.numpy as jnp
    from repro import configs
    from repro.models import transformer
    cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=1,
                              dtype=jnp.float32)
    mesh = jax.make_mesh((1,), ("data",))
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, mesh, params


def test_legacy_kwargs_warn_and_match_spec(tiny):
    import jax
    from repro.serve.engine import make_serve_fns
    cfg, mesh, _ = tiny
    with jax.set_mesh(mesh):
        with pytest.warns(DeprecationWarning, match="ServeSpec"):
            legacy = make_serve_fns(cfg, mesh, batch=1, cache_len=16)
        spec = make_serve_fns(cfg, mesh, ServeSpec(batch=1, cache_len=16))
    assert legacy.combine.algorithm == spec.combine.algorithm
    assert legacy.fused_stats == spec.fused_stats


def test_spec_plus_legacy_kwargs_is_typeerror(tiny):
    import jax
    from repro.serve.engine import Engine, make_serve_fns
    cfg, mesh, params = tiny
    with jax.set_mesh(mesh):
        with pytest.raises(TypeError, match="both"):
            make_serve_fns(cfg, mesh, ServeSpec(batch=1, cache_len=16),
                           batch=1)
        with pytest.raises(TypeError, match="both"):
            Engine(cfg, mesh, params, ServeSpec(batch=1, cache_len=16),
                   cache_len=16)
        with pytest.raises(TypeError):
            make_serve_fns(cfg, mesh)                # neither spec nor kwargs


def test_generate_deprecated_but_intact(tiny):
    import jax
    from repro.serve.engine import Engine
    cfg, mesh, params = tiny
    with jax.set_mesh(mesh):
        eng = Engine(cfg, mesh, params, ServeSpec(batch=1, cache_len=16))
        prompts = np.arange(4, dtype=np.int32)[None, :]
        with pytest.warns(DeprecationWarning, match="submit"):
            toks = eng.generate(prompts, 3)
        # the request-level API decodes the same greedy continuation
        eng.submit(Request(tokens=prompts[0], max_new=3))
        res = eng.drain()
    (r,) = res.values()
    assert np.array_equal(r.tokens, toks[0]), (r.tokens, toks)
    assert r.finish_reason == "length" and r.n_tokens == 3


# ---------------------------------------------------------------------------
# determinism + parity + locality on the real 8-device batch path
# ---------------------------------------------------------------------------
TRACE_CODE = r"""
import dataclasses, warnings
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.models import transformer
from repro.serve import Engine, Request, ServeSpec, StepClock

mesh = jax.make_mesh((2, 4), ("pod", "data"))
jax.set_mesh(mesh)
cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=2,
                          dtype=jnp.float32)
params = transformer.init_params(jax.random.PRNGKey(0), cfg)
B, S, NEW = 8, 6, 4
spec = ServeSpec(batch=B, cache_len=32, page_len=8)
rng = np.random.default_rng(0)
prompts = rng.integers(0, cfg.vocab_size, (B, S), np.int32)
arrivals = np.sort(rng.uniform(0.0, 6.0, B))

def run_once(home_pods):
    eng = Engine(cfg, mesh, params, spec, clock=StepClock())
    rids = [eng.submit(Request(tokens=prompts[i], max_new=NEW,
                               home_pod=home_pods[i],
                               arrival_s=float(arrivals[i])))
            for i in range(B)]
    return eng, rids, eng.drain(), eng.scheduler.stats()

# 1. determinism: the same trace replays to the same everything
home = [i % 2 for i in range(B)]
eng1, rids1, res1, st1 = run_once(home)
eng2, rids2, res2, st2 = run_once(home)
assert rids1 == rids2
for rid in rids1:
    a, b = res1[rid], res2[rid]
    assert np.array_equal(a.tokens, b.tokens), (rid, a.tokens, b.tokens)
    assert a.token_times_s == b.token_times_s, rid
    assert (a.arrival_s, a.started_s, a.finished_s) == \
           (b.arrival_s, b.started_s, b.finished_s), rid
    assert (a.slot, a.migrated) == (b.slot, b.migrated), rid
assert st1["steps"] == st2["steps"]
assert st1["migrations"] == st2["migrations"]
print("DETERMINISM_OK")

# 2. every stamped comm label reconciles against its compiled HLO, and
#    pod-local prefills move ZERO non-local bytes
comm = st1["comm"]
assert comm, "comm telemetry missing"
for label, rec in comm.items():
    assert rec["match"], (label, rec)
    if label.startswith("serve/prefill:pod") and "podall" not in label:
        assert rec["actual_nonlocal_bytes"] == 0.0, (label, rec)
        assert rec["actual_nonlocal_msgs"] == 0.0, (label, rec)
print("LEDGER_OK")

# 3. parity: all-arrive-at-0, no home pod -> rows fill FCFS and every
#    request's tokens equal its lockstep generate row
eng3 = Engine(cfg, mesh, params, spec, clock=StepClock())
rids3 = [eng3.submit(Request(tokens=prompts[i], max_new=NEW, arrival_s=0.0))
         for i in range(B)]
res3 = eng3.drain()
with warnings.catch_warnings():
    warnings.simplefilter("ignore", DeprecationWarning)
    ref = eng3.generate(prompts, NEW)
for rid in rids3:
    r = res3[rid]
    assert np.array_equal(r.tokens, np.asarray(ref)[r.slot]), \
        (rid, r.slot, r.tokens, ref)
print("PARITY_OK")

# 4. layout guards: sequence-sharded layouts are one-request-at-a-time
cfg1 = dataclasses.replace(cfg, n_layers=1)
params1 = transformer.init_params(jax.random.PRNGKey(0), cfg1)
eng4 = Engine(cfg1, mesh, params1,
              ServeSpec(batch=2, cache_len=32, combine="locality"))
try:
    eng4.scheduler
except ValueError as e:
    assert "batch must be 1" in str(e), e
else:
    raise AssertionError("sequential scheduler accepted batch=2")
print("GUARD_OK")
"""


@pytest.mark.slow
def test_scheduler_trace_determinism_parity_locality(subproc):
    out = subproc(TRACE_CODE, devices=8, timeout=1800)
    for marker in ("DETERMINISM_OK", "LEDGER_OK", "PARITY_OK", "GUARD_OK"):
        assert marker in out, out
