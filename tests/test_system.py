"""End-to-end system behaviour: the public API wired together on one device.

(The multi-device variants live in test_distributed.py / test_train.py;
this file guards the single-host path users hit first.)
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import transformer
from repro.serve import Engine, ServeSpec


def test_end_to_end_tiny_train_then_serve(tmp_path):
    """Train a tiny model until loss drops, then serve it and check the
    generated continuations follow the learned affine token structure."""
    from repro.data import SyntheticLM
    from repro.optim import AdamW, TrainState
    from repro.train.step import make_loss_fn

    cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=2,
                              vocab_size=97, vocab_pad_multiple=1)
    rng = jax.random.PRNGKey(0)
    params = transformer.init_params(rng, cfg)
    data = SyntheticLM(vocab_size=97, seq_len=32, global_batch=8, seed=0,
                       noise=0.0)
    loss_fn = make_loss_fn(cfg)
    opt = AdamW(lr=5e-3)
    state = TrainState.create(params)
    shard = lambda x, _k: x

    @jax.jit
    def step(state, tokens, labels):
        (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params, {"tokens": tokens, "labels": labels}, shard)
        state, _ = opt.apply(state, g)
        return state, l

    losses = []
    for i in range(60):
        b = data.batch(i)
        state, l = step(state, jnp.asarray(b["tokens"]),
                        jnp.asarray(b["labels"]))
        losses.append(float(l))
    assert losses[-1] < losses[0] - 1.0, (losses[0], losses[-1])

    # serve greedily; verify continuation follows tokens[t+1] = a*t + c
    mesh = jax.make_mesh((1,), ("data",))
    jax.set_mesh(mesh)
    eng = Engine(cfg, mesh, state.params, ServeSpec(batch=4, cache_len=48))
    b = data.batch(1000)
    prompts = b["tokens"][:4, :16]
    toks = eng.generate(prompts, max_new=8)
    V, a = 97, 31337 % 97
    c = (b["labels"][0, 0] - a * b["tokens"][0, 0]) % V
    cur = prompts[:, -1].astype(np.int64)
    hits = total = 0
    for j in range(8):
        expect = (a * cur + c) % V
        hits += int((toks[:, j] == expect).sum())
        total += 4
        cur = toks[:, j].astype(np.int64)
    assert hits / total > 0.5, f"served continuations wrong ({hits}/{total})"
