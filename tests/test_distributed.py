"""Multi-device tests (subprocess: 8-16 forced host devices).

Covers: JAX collectives == lax ground truth, reduce-scatter transpose,
paper-mode grad sync == GSPMD grad sync, and the DMA allgather kernel under
the TPU interpret backend.
"""
import pytest

pytestmark = pytest.mark.slow      # multi-device subprocess suite

COLLECTIVES_CODE = r"""
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import collectives as C

mesh = jax.make_mesh((4, 4), ("pod", "local"))
x = jnp.arange(16 * 3, dtype=jnp.float32).reshape(16, 3)
def run(fn, arr=None):
    arr = x if arr is None else arr
    f = jax.shard_map(fn, mesh=mesh, in_specs=P(("pod","local")),
                      out_specs=P(("pod","local")))
    return jax.jit(f)(arr)

truth = run(lambda s: jax.lax.all_gather(s, ("pod","local"), tiled=True))
for name in ["bruck","ring","hierarchical","multilane","locality_bruck","xla"]:
    out = run(lambda s, n=name: C.allgather(s, "pod", "local", algorithm=n, tiled=True))
    assert np.allclose(out, truth), name

truthr = run(lambda s: jax.lax.psum(s, ("pod","local")))
for alg in [("locality","rhd"),("locality","rd"),("locality","psum")]:
    out = run(lambda s, a=alg: C.allreduce(s, "pod", "local", algorithm=a[0],
                                           outer_algorithm=a[1]))
    assert np.allclose(out, truthr), alg

xx = jnp.arange(16*32*2, dtype=jnp.float32).reshape(16*32, 2)
t2 = run(lambda s: jax.lax.psum_scatter(s, ("pod","local"),
                                        scatter_dimension=0, tiled=True), xx)
for name in ["bruck","locality_bruck","multilane","hierarchical","ring"]:
    out = run(lambda s, n=name: C.reduce_scatter(s, "pod", "local", algorithm=n), xx)
    assert np.allclose(out, t2), name

for alg in ["locality_bruck", "xla"]:
    def loss(s, a=alg):
        g = C.allgather(s, "pod", "local", algorithm=a, tiled=True)
        return (g ** 2).sum()
    g = run(jax.grad(loss))
    assert np.allclose(np.asarray(g), 32 * np.asarray(x)), alg
print("COLLECTIVES_OK")
"""

NONPOWER_COLLECTIVES_CODE = r"""
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import collectives as C
from repro.core.hlo_analysis import op_payloads

# q in {3, 5, 6} outer regions — Algorithm 2's allgatherv adaptation
# (partial final-round payloads) plus the non-power allreduce structures
# (Bruck-transpose RS for "rhd", fold/unfold for "rd" and max/min).
for r, pl in [(3, 2), (3, 4), (5, 2), (5, 3), (6, 2), (6, 4)]:
    p = r * pl
    devs = np.asarray(jax.devices()[:p]).reshape(r, pl)
    mesh = jax.sharding.Mesh(devs, ("pod", "local"))
    x = jnp.arange(p * 3, dtype=jnp.float32).reshape(p, 3) * 0.37 - 4.2

    def run(fn, arr=None):
        arr = x if arr is None else arr
        f = jax.shard_map(fn, mesh=mesh, in_specs=P(("pod", "local")),
                          out_specs=P(("pod", "local")), check_vma=False)
        return jax.jit(f)(arr)

    truth = run(lambda s: jax.lax.all_gather(s, ("pod", "local"), tiled=True))
    for name in ["bruck", "ring", "hierarchical", "multilane",
                 "locality_bruck", "xla"]:
        out = run(lambda s, n=name: C.allgather(s, "pod", "local",
                                                algorithm=n, tiled=True))
        assert np.allclose(out, truth), (name, r, pl)

    truthr = run(lambda s: jax.lax.psum(s, ("pod", "local")))
    for oa in ("rhd", "rd", "psum"):
        out = run(lambda s, a=oa: C.allreduce(s, "pod", "local",
                                              algorithm="locality",
                                              outer_algorithm=a))
        assert np.allclose(out, truthr, atol=1e-4), (oa, r, pl)
    for op, lref in (("max", jax.lax.pmax), ("min", jax.lax.pmin)):
        t = run(lambda s, f=lref: f(s, ("pod", "local")))
        o = run(lambda s, o_=op: C.allreduce(s, "pod", "local",
                                             algorithm="locality", op=o_))
        assert np.array_equal(np.asarray(o), np.asarray(t)), (op, r, pl)

    xx = jnp.arange(p * p * 2, dtype=jnp.float32).reshape(p * p, 2)
    t2 = run(lambda s: jax.lax.psum_scatter(s, ("pod", "local"),
                                            scatter_dimension=0, tiled=True),
             xx)
    out = run(lambda s: C.reduce_scatter(s, "pod", "local",
                                         algorithm="locality_bruck"), xx)
    assert np.allclose(out, t2, atol=1e-4), ("rs", r, pl)

    def loss(s):
        g = C.allgather(s, "pod", "local", algorithm="locality_bruck",
                        tiled=True)
        return (g ** 2).sum()
    g = run(jax.grad(loss))
    assert np.allclose(np.asarray(g), 2 * p * np.asarray(x)), (r, pl)

# the psum fallback is GONE: a non-power locality allreduce lowers to
# ppermutes/psum-scatters only — zero all-reduce ops in the compiled HLO
devs = np.asarray(jax.devices()[:6]).reshape(3, 2)
mesh = jax.sharding.Mesh(devs, ("pod", "local"))
x = jnp.zeros((24, 2), jnp.float32)
for kw in (dict(op="sum"), dict(op="max"), dict(op="sum",
                                                outer_algorithm="rd")):
    f = jax.jit(jax.shard_map(
        lambda s, k=kw: C.allreduce(s, "pod", "local", algorithm="locality",
                                    **k),
        mesh=mesh, in_specs=P(("pod", "local")),
        out_specs=P(("pod", "local")), check_vma=False))
    hlo = f.lower(x).compile().as_text()
    assert not op_payloads(hlo, "all-reduce"), (kw, "psum fallback resurfaced")
print("NONPOWER_OK")
"""


GRAD_SYNC_CODE = r"""
import jax, jax.numpy as jnp
import numpy as np, dataclasses
from repro import configs
from repro.train.step import make_train_step, init_state, custom_batch_specs
from repro.data import SyntheticLM

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
jax.set_mesh(mesh)
cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=2)
data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8, seed=0)
bspec = custom_batch_specs(cfg, 8, 32)
states, losses = {}, {}
for mode in ["xla", "locality", "flat_psum"]:
    art = make_train_step(cfg, mesh, grad_sync=mode, shape=bspec, donate=False)
    state = init_state(cfg, mesh, art)
    batch = {k: jax.device_put(v, art.batch_shardings[k])
             for k, v in data.batch(0).items()}
    state2, metrics = art.step_fn(state, batch)
    states[mode] = state2
    losses[mode] = float(metrics["loss"])
assert abs(losses["xla"] - losses["locality"]) < 1e-3, losses
p_x = jax.tree.leaves(states["xla"].params)
p_l = jax.tree.leaves(states["locality"].params)
err = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
          for a, b in zip(p_x, p_l))
assert err < 5e-3, err
print("GRAD_SYNC_OK", losses["xla"])
"""

DMA_KERNEL_CODE = r"""
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.kernels.dma_allgather.ops import dma_locality_allgather

mesh = jax.make_mesh((2, 4), ("r", "l"))
jax.set_mesh(mesh)
x = jnp.arange(8 * 6, dtype=jnp.float32).reshape(8, 6)
def run(fn):
    f = jax.shard_map(fn, mesh=mesh, in_specs=P(("r","l")),
                      out_specs=P(("r","l")), check_vma=False)
    return jax.jit(f)(x)
truth = run(lambda s: jax.lax.all_gather(s, ("r","l")))
for alg in ["bruck", "locality_bruck", "multilane", "ring"]:
    out = run(lambda s, a=alg: dma_locality_allgather(
        s, "r", "l", mesh, algorithm=a, interpret=True))
    assert np.allclose(np.asarray(out), np.asarray(truth)), alg
print("DMA_OK")
"""

SEQ_SHARD_CODE = r"""
import jax, jax.numpy as jnp
import numpy as np, dataclasses
from repro import configs
from repro.train.step import make_train_step, init_state, custom_batch_specs
from repro.data import SyntheticLM

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
jax.set_mesh(mesh)
cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=2)
data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8, seed=0)
bspec = custom_batch_specs(cfg, 8, 32)
losses = {}
for fsdp in (False, True):
    art = make_train_step(cfg, mesh, grad_sync="locality", fsdp=fsdp,
                          seq_shard=fsdp, shape=bspec, donate=False)
    state = init_state(cfg, mesh, art)
    batch = {k: jax.device_put(v, art.batch_shardings[k])
             for k, v in data.batch(0).items()}
    _, metrics = art.step_fn(state, batch)
    losses[fsdp] = float(metrics["loss"])
assert abs(losses[False] - losses[True]) < 1e-3, losses
print("FSDP_OK")
"""


def test_collectives_vs_ground_truth(subproc):
    assert "COLLECTIVES_OK" in subproc(COLLECTIVES_CODE, devices=16)


def test_collectives_nonpower_regions(subproc):
    """q ∈ {3, 5, 6} outer regions: every collective matches the lax ground
    truth and the non-power locality allreduce compiles without any
    all-reduce (the old silent psum fallback)."""
    assert "NONPOWER_OK" in subproc(NONPOWER_COLLECTIVES_CODE, devices=24,
                                    timeout=1800)


def test_grad_sync_modes_agree(subproc):
    assert "GRAD_SYNC_OK" in subproc(GRAD_SYNC_CODE, devices=8)


def _legacy_pallas_interpret() -> bool:
    from repro.kernels import _pallas_compat
    return _pallas_compat._InterpretParams is None


@pytest.mark.xfail(
    _legacy_pallas_interpret(),
    reason="pallas interpret-mode DMA discharge on this JAX version rejects "
           "meshes with more than one named dimension (dma_start_p "
           "NotImplementedError) — the cross-device DMA interpreter only "
           "exists in the newer TPU interpret backend",
    strict=False)
def test_dma_allgather_kernel(subproc):
    assert "DMA_OK" in subproc(DMA_KERNEL_CODE, devices=8)


def test_fsdp_seq_shard_agree(subproc):
    assert "FSDP_OK" in subproc(SEQ_SHARD_CODE, devices=8)
