"""Chaos soak: the fleet controller's convergence-to-healthy proof
(subprocess, 12 devices; DESIGN.md §11).

Leg A — numerics under disturbance (q=3 AND q=2 pod geometries):
a seeded random schedule of hard kills, graceful preemptions and
injected stragglers hits a ``grad_sync="flat_psum"`` run; the controller
must converge to ``complete`` with ZERO data loss (every episode resumes
exactly at the committed step — FleetDataLossError otherwise) and a
**bitwise-identical** per-step loss trajectory vs the undisturbed run
(flat_psum compiles to one psum over the concatenated axes, the data
pipeline is a pure function of the step, and no resize changes the
device count — so every replayed step recomputes the same bits).

Leg B — resize mechanics: a capacity revocation (12 -> 8) forces a
shrink onto the q=2 pod-aligned layout and the restored capacity grows
back to q=3 after the cooldown, all under ``grad_sync="locality"`` with
``comm_telemetry`` on: every post-resize mesh must show a locality
schedule in its compiled HLO (controller-asserted), the comm ledger must
reconcile across all three builds, and a serve engine is suspended /
resumed across both resizes, then drains to the exact tokens an
undisturbed engine produces.
"""
import os

import pytest

pytestmark = pytest.mark.slow


# ---------------------------------------------------------------------------
# leg A: bitwise convergence under kills/preemptions/stragglers
# ---------------------------------------------------------------------------
BITWISE_SOAK_CODE = r"""
import dataclasses, os
import jax, jax.numpy as jnp
from repro import configs, telemetry
from repro.fleet import (ACTION_COUNTERS, ChaosSchedule, ChaosSpec,
                         FleetController, FleetPolicy, PolicyConfig,
                         choose_layout, layout_mesh)
from repro.telemetry import MetricsRegistry, set_registry
from repro.train import Trainer, TrainerConfig

CKDIR = os.environ["FLEET_CKDIR"]
STEPS = 10
cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=2,
                          d_model=96, d_ff=192, vocab_size=384,
                          dtype=jnp.float32)

def tcfg(ckpt_dir):
    return TrainerConfig(steps=STEPS, seq_len=32, global_batch=24,
                         ckpt_every=2, keep_last=6, log_every=100,
                         grad_sync="flat_psum", fsdp=False, lr=3e-3,
                         comm_telemetry=False, ckpt_dir=ckpt_dir)

def run_leg(pod_size, seed):
    set_registry(MetricsRegistry())
    layout = choose_layout(12, pod_size)
    mesh = layout_mesh(layout)
    jax.set_mesh(mesh)
    # undisturbed baseline on the same layout
    base_tr = Trainer(cfg, mesh, tcfg(f"{CKDIR}/base{pod_size}"),
                      log=lambda s: None)
    out = base_tr.run()
    assert out["status"] == "complete", out["status"]
    base = {m["step"]: m["loss"] for m in base_tr.metrics_history}

    # disturbed run under the controller
    def make_trainer(mesh):
        return Trainer(cfg, mesh, tcfg(f"{CKDIR}/soak{pod_size}"),
                       log=lambda s: None)
    chaos = ChaosSchedule(ChaosSpec(steps=STEPS, seed=seed, kills=2,
                                    preempts=1, straggles=2, first_step=4,
                                    delay_s=0.4))
    print(f"CHAOS{pod_size}", chaos.describe())
    policy = FleetPolicy(PolicyConfig(max_retries=8, max_shrinks=0,
                                      straggler_high=99))
    fc = FleetController(make_trainer, pod_size=pod_size, devices=12,
                         chaos=chaos, policy=policy, log=lambda s: None)
    report = fc.run()
    assert report.status == "complete", report.status
    assert report.steps == STEPS, report.steps
    assert len(report.episodes) >= 4, report.episodes   # 2 kills + 1 preempt
    # every scheduled disturbance actually fired
    assert chaos.pending() == {"kills": [], "preempts": []}, chaos.pending()

    # ZERO data loss + bitwise trajectory: every step's loss, replays
    # folded in, equals the undisturbed run's bit for bit
    assert sorted(report.loss_by_step) == sorted(base)
    for s in sorted(base):
        bh, sh = float(base[s]).hex(), float(report.loss_by_step[s]).hex()
        assert bh == sh, (s, bh, sh)

    c = telemetry.get_registry().snapshot()["counters"]
    actions = sum(c.get(f"fleet/{v}", 0) for v in ACTION_COUNTERS.values())
    assert c["fleet/decisions"] == actions > 0, c
    assert c.get("fleet/retries", 0) >= 3, c            # 2 kills + 1 preempt
    assert c.get("fleet/shrinks", 0) == 0 and c.get("fleet/halts", 0) == 0, c
    stragglers = int(c.get("runtime/stragglers", 0))
    print(f"LEGA{pod_size}_STRAGGLERS", stragglers)
    print(f"LEGA{pod_size}_EPISODES", len(report.episodes))
    print(f"LEGA{pod_size}_OK")
    return stragglers

s3 = run_leg(4, seed=int(os.environ.get("FLEET_SEED", "0")))   # (3,4): q=3
s2 = run_leg(6, seed=int(os.environ.get("FLEET_SEED", "0")))   # (2,6): q=2
# the injected delays must actually register as straggler pressure in at
# least one geometry (an episode restart can reset the EWMA warmup right
# on top of a delay step; both geometries missing means the wiring broke)
assert s3 + s2 >= 1, (s3, s2)
print("LEGA_ALL_OK")
"""


# ---------------------------------------------------------------------------
# leg B: capacity shrink/grow with locality HLO asserts + serve migration
# ---------------------------------------------------------------------------
RESIZE_SOAK_CODE = r"""
import dataclasses, os
import jax, jax.numpy as jnp, numpy as np
from repro import configs, telemetry
from repro.fleet import (ChaosSchedule, ChaosSpec, FleetController,
                         FleetPolicy, PolicyConfig, Layout, layout_mesh)
from repro.models import transformer
from repro.serve import Engine, Request, ServeSpec, StepClock
from repro.telemetry import MetricsRegistry, set_registry
from repro.train import Trainer, TrainerConfig

CKDIR = os.environ["FLEET_CKDIR"]
STEPS = 10
set_registry(MetricsRegistry())

cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=2,
                          d_model=96, d_ff=192, vocab_size=384,
                          dtype=jnp.float32)
tcfg = TrainerConfig(steps=STEPS, seq_len=32, global_batch=24, ckpt_every=2,
                     keep_last=6, log_every=100, grad_sync="locality",
                     fsdp=True, lr=3e-3, comm_telemetry=True,
                     ckpt_dir=CKDIR + "/resize")

def make_trainer(mesh):
    return Trainer(cfg, mesh, tcfg, log=lambda s: None)

# serve tier riding along: 2 queued requests survive both resizes
# (sequence-sharded locality combine — the multi-pod decode layout —
# schedules one request at a time, hence batch=1)
scfg = dataclasses.replace(cfg, n_layers=1)
params = transformer.init_params(jax.random.PRNGKey(0), scfg)
spec = ServeSpec(batch=1, cache_len=32, combine="locality")
rng = np.random.default_rng(0)
prompts = rng.integers(0, scfg.vocab_size, (2, 6), np.int32)

def submit_two(eng):
    for i in range(2):
        eng.submit(Request(tokens=prompts[i], max_new=4, arrival_s=0.0))

_first = [True]
def engine_factory(mesh):
    eng = Engine(scfg, mesh, params, spec, clock=StepClock())
    if _first[0]:
        _first[0] = False
        submit_two(eng)
    return eng

# capacity revoked at step 4 (12 -> 8: one pod gone), restored at step 7
chaos = ChaosSchedule(ChaosSpec(steps=STEPS, kills=0, preempts=0,
                                straggles=0,
                                capacity=((4, 8), (7, 12))))
policy = FleetPolicy(PolicyConfig(cooldown_steps=2, straggler_high=99,
                                  max_retries=4, max_shrinks=2))
fc = FleetController(make_trainer, pod_size=4, devices=12, chaos=chaos,
                     capacity_fn=lambda s: chaos.capacity_at(s, 12),
                     policy=policy, assert_locality=True,
                     engine_factory=engine_factory,
                     serve_ckpt_dir=CKDIR + "/serve",
                     log=lambda s: None)
report = fc.run()
assert report.status == "complete", report.status
assert report.steps == STEPS
layouts = [tuple(e["layout"]) for e in report.episodes]
assert layouts == [(3, 4), (2, 4), (3, 4)], layouts    # q=3 -> q=2 -> q=3
assert report.final_layout == (3, 4)
for s, l in report.loss_by_step.items():
    assert np.isfinite(l), (s, l)

reg = telemetry.get_registry()
snap = reg.snapshot()
c = snap["counters"]
# every multi-pod build passed its compiled-HLO locality assertion
assert c.get("fleet/layout_asserts", 0) == 3, c
assert c.get("fleet/shrinks", 0) == 1 and c.get("fleet/grows", 0) == 1, c
assert c.get("fleet/serve_suspends", 0) == 2, c
assert c.get("fleet/serve_resumes", 0) == 2, c
# predicted-vs-actual comm reconciles across ALL three builds' epochs
for label, rec in reg.reconcile_all().items():
    assert rec["match"] is True, (label, rec)
print("LAYOUTS", layouts)
print("RESIZE_LOCALITY_OK")

# the twice-migrated serve queue drains to the undisturbed engine's tokens
res = fc.engine.drain()
ref_eng = Engine(scfg, layout_mesh(Layout(3, 4), jax.devices()[:12]),
                 params, spec, clock=StepClock())
submit_two(ref_eng)
ref = ref_eng.drain()
assert set(res) == set(ref) and len(ref) == 2, (set(res), set(ref))
for rid in ref:
    assert np.array_equal(res[rid].tokens, ref[rid].tokens), rid
print("SERVE_MIGRATION_OK")
"""


def test_chaos_soak_bitwise_convergence(subproc, tmp_path):
    """Seeded kills + preemptions + stragglers on q=3 and q=2 pod
    layouts: the controller converges to healthy with zero data loss and
    a bitwise loss trajectory vs the undisturbed run."""
    os.environ["FLEET_CKDIR"] = str(tmp_path)
    out = subproc(BITWISE_SOAK_CODE, devices=12, timeout=1800)
    assert "LEGA4_OK" in out, out
    assert "LEGA6_OK" in out, out
    assert "LEGA_ALL_OK" in out, out


def test_chaos_soak_resize_locality_and_serve(subproc, tmp_path):
    """Capacity revocation/restoration drives shrink->grow through
    pod-aligned layouts; every post-resize mesh keeps a locality HLO
    schedule, the comm ledger reconciles, and the serve engine migrates
    across both resizes losing nothing."""
    os.environ["FLEET_CKDIR"] = str(tmp_path)
    out = subproc(RESIZE_SOAK_CODE, devices=12, timeout=1800)
    assert "RESIZE_LOCALITY_OK" in out, out
    assert "SERVE_MIGRATION_OK" in out, out
