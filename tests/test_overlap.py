"""Overlap pipeline (DESIGN.md §5): split start/finish collectives, the
double-buffered FSDP train pipeline, and the issue-order audit.

Guarantee layers:
  * bit-identity: ``allgather_finish(allgather_start(x))`` equals the eager
    ``locality_bruck_allgather`` — forward AND vjp (the transposed
    reduce-scatter schedule) — across dense / non-power / TP-mixed mesh
    layouts (exact ``np.array_equal``, no tolerance);
  * pipeline exactness: eager (prefetch_depth=0) and prefetched (1, 2)
    train steps produce bitwise-identical losses and updated params on
    dense and windowed-ring plans; TP-mixed legacy meshes degrade to eager
    and stay exact;
  * issue order: in the lowered (trace-order) module, the prefetched
    variant shows the next gather's collective-permutes BEFORE the previous
    layer's consumer dot — the dataflow freedom XLA's latency-hiding
    scheduler needs;
  * serve: the fused-stats kernel path ("pallas_interpret") matches the jnp
    region path on a sequence-sharded decode step.
"""
import pytest

from _hypothesis_compat import given, settings, strategies as st

SPLIT_BIT_IDENTICAL_CODE = r"""
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import collectives as C

CASES = [((4, 4), ("pod", "local")),    # dense power-of-two regions
         ((2, 4), ("pod", "local")),
         ((8, 2), ("pod", "local")),    # many regions, small locality
         ((3, 4), ("pod", "local")),    # non-power regions: one partial-free
                                        # round (active = 3)
         ((5, 3), ("pod", "local")),    # wrapped final round, partial payload
         ((6, 2), ("pod", "local")),    # three rounds, final one partial
         ((2, 2, 4), ("pod", "data", "model"))]   # TP-mixed (gather on 2 axes)

for shape, names in CASES:
    n = 1
    for s in shape:
        n *= s
    devs = np.asarray(jax.devices()[:n]).reshape(shape)
    mesh = jax.sharding.Mesh(devs, names)
    ag_axes = names[:2] if len(names) > 2 else names
    p = 1
    for n, s in zip(names, shape):
        if n in ag_axes:
            p *= s
    in_spec = P(ag_axes)

    x = jnp.arange(p * 6, dtype=jnp.float32).reshape(p * 2, 3) * 0.37 - 4.2

    def run(fn, arr):
        f = jax.shard_map(fn, mesh=mesh, in_specs=P(ag_axes),
                          out_specs=P(ag_axes), check_vma=False,
                          axis_names=set(mesh.axis_names))
        return jax.jit(f)(arr)

    for tiled in (False, True):
        eager = run(lambda s, t=tiled: C.locality_bruck_allgather(
            s, ag_axes[0], ag_axes[1:], tiled=t), x)
        split = run(lambda s, t=tiled: C.allgather_finish(
            C.allgather_start(s, ag_axes[0], ag_axes[1:], tiled=t)), x)
        assert np.array_equal(np.asarray(eager), np.asarray(split)), \
            (shape, tiled)

    # the transposed (reduce-scatter) schedule: vjp outputs bit-identical
    big = jnp.arange(p * p * 2, dtype=jnp.float32).reshape(p * p, 2) * 0.11

    def rs(fn, arr):
        def g(s):
            primal = jnp.zeros((s.shape[0] // p,) + s.shape[1:], s.dtype) \
                + s.reshape(-1)[0] * 0
            _, vjp = jax.vjp(fn, primal)
            (out,) = vjp(s)
            return out
        f = jax.shard_map(g, mesh=mesh, in_specs=P(ag_axes),
                          out_specs=P(ag_axes), check_vma=False,
                          axis_names=set(mesh.axis_names))
        return jax.jit(f)(arr)

    t_eager = rs(lambda v: C.locality_bruck_allgather(
        v, ag_axes[0], ag_axes[1:], tiled=True), big)
    t_split = rs(lambda v: C.allgather_finish(C.allgather_start(
        v, ag_axes[0], ag_axes[1:], tiled=True)), big)
    assert np.array_equal(np.asarray(t_eager), np.asarray(t_split)), shape
    # and both match the lax ground truth
    truth = run(lambda s: jax.lax.psum_scatter(
        s, ag_axes, scatter_dimension=0, tiled=True), big)
    assert np.allclose(np.asarray(t_eager), np.asarray(truth)), shape

# single-axis degenerate split (the FSDP gather over 'data' only)
mesh = jax.make_mesh((8,), ("data",))
x = jnp.arange(8 * 5, dtype=jnp.float32).reshape(8, 5)
def run1(fn):
    f = jax.shard_map(fn, mesh=mesh, in_specs=P("data"),
                      out_specs=P("data"), check_vma=False)
    return jax.jit(f)(x)
eager = run1(lambda s: C.bruck_allgather(s, ("data",), tiled=True))
split = run1(lambda s: C.allgather_finish(C.allgather_start(
    s, (), ("data",), tiled=True)))
assert np.array_equal(np.asarray(eager), np.asarray(split))
print("SPLIT_BITWISE_OK")
"""


@pytest.mark.slow
def test_split_transpose_bit_identical(subproc):
    assert "SPLIT_BITWISE_OK" in subproc(SPLIT_BIT_IDENTICAL_CODE,
                                         devices=16)


PROPERTY_CODE_TMPL = r"""
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import collectives as C

r, pl, rows, cols = %d, %d, %d, %d
p = r * pl
mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:p]).reshape(r, pl),
                         ("pod", "local"))
x = (jnp.arange(p * rows * p * cols, dtype=jnp.float32)
     .reshape(p * rows * p, cols) * 0.173 - 7.0)

def rs(fn):
    def g(s):
        primal = jnp.zeros((s.shape[0] // p,) + s.shape[1:], s.dtype) \
            + s.reshape(-1)[0] * 0
        _, vjp = jax.vjp(fn, primal)
        (out,) = vjp(s)
        return out
    f = jax.shard_map(g, mesh=mesh, in_specs=P(("pod", "local")),
                      out_specs=P(("pod", "local")), check_vma=False)
    return jax.jit(f)(x)

t_eager = rs(lambda v: C.locality_bruck_allgather(v, "pod", "local",
                                                  tiled=True))
t_split = rs(lambda v: C.allgather_finish(
    C.allgather_start(v, "pod", "local", tiled=True)))
assert np.array_equal(np.asarray(t_eager), np.asarray(t_split))
print("PROP_OK")
"""


@pytest.mark.slow
@pytest.mark.hypothesis
@settings(max_examples=5, deadline=None)
@given(st.sampled_from([(2, 4), (4, 2), (2, 8), (4, 4), (8, 2),
                        (3, 2), (5, 2), (6, 2), (3, 4), (5, 3)]),
       st.integers(1, 3), st.integers(1, 4))
def test_split_transpose_property(subproc, layout, rows, cols):
    """Transposed split schedule == eager transpose for arbitrary payloads
    (non-power region counts q ∈ {3, 5, 6} included via the layout pool —
    the allgatherv adaptation's partial rounds transpose exactly)."""
    r, pl = layout
    code = PROPERTY_CODE_TMPL % (r, pl, rows, cols)
    assert "PROP_OK" in subproc(code, devices=16)


ISSUE_ORDER_CODE = r"""
import jax, jax.numpy as jnp
import re
from jax.sharding import PartitionSpec as P
from repro.core import collectives as C

mesh = jax.make_mesh((2, 4), ("pod", "data"))
p = 8

def prefetched(s0, s1, x):
    p0 = C.allgather_start(s0, "pod", "data", tiled=True)
    w0 = C.allgather_finish(p0)
    p1 = C.allgather_start(s1, "pod", "data", tiled=True)  # issued early
    y = jnp.tanh(x @ w0)                                   # layer-0 consumer
    w1 = C.allgather_finish(p1)
    return y @ w1

def eager(s0, s1, x):
    w0 = C.locality_bruck_allgather(s0, "pod", "data", tiled=True)
    y = jnp.tanh(x @ w0)
    w1 = C.locality_bruck_allgather(s1, "pod", "data", tiled=True)
    return y @ w1

def lowered(fn):
    f = jax.shard_map(fn, mesh=mesh,
                      in_specs=(P(("pod", "data")), P(("pod", "data")), P()),
                      out_specs=P(), check_vma=False)
    # per-shard (2, 16) -> gathered weights (16, 16); x (4, 16)
    s = jnp.zeros((p * 2, 16)); xx = jnp.zeros((4, p * 2))
    return jax.jit(f).lower(s, s, xx).as_text()

def permutes_before_first_dot(txt):
    perm = [m.start() for m in re.finditer(r"collective.permute", txt)]
    dots = [m.start() for m in re.finditer(r"\bdot", txt)]
    assert perm and dots, (len(perm), len(dots))
    return sum(1 for q in perm if q < dots[0]), len(perm)

pre_before, pre_total = permutes_before_first_dot(lowered(prefetched))
eag_before, eag_total = permutes_before_first_dot(lowered(eager))
# both variants run the same two gathers in total...
assert pre_total == eag_total, (pre_total, eag_total)
# ...but the prefetched trace issues the SECOND gather's non-local rounds
# before the first layer's consumer dot; the eager trace cannot
assert pre_before > eag_before, (pre_before, eag_before)
print("ORDER_OK", pre_before, eag_before, pre_total)
"""


@pytest.mark.slow
def test_prefetched_gather_issued_before_consumer(subproc):
    assert "ORDER_OK" in subproc(ISSUE_ORDER_CODE, devices=8)


TRAIN_EXACT_CODE = r"""
import jax, jax.numpy as jnp
import numpy as np, dataclasses
from repro import configs
from repro.train.step import make_train_step, init_state, custom_batch_specs
from repro.data import SyntheticLM

def one_step(cfg, mesh, depth):
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8,
                       seed=0)
    bspec = custom_batch_specs(cfg, 8, 32)
    art = make_train_step(cfg, mesh, grad_sync="locality", fsdp=True,
                          shape=bspec, donate=False, prefetch_depth=depth)
    state = init_state(cfg, mesh, art)
    batch = {k: jax.device_put(v, art.batch_shardings[k])
             for k, v in data.batch(0).items()}
    state2, metrics = art.step_fn(state, batch)
    return art, float(metrics["loss"]), state2

mesh = jax.make_mesh((2, 4), ("pod", "data"))
jax.set_mesh(mesh)
for arch in ("llama3.2-3b", "gemma2-9b"):       # dense + windowed-ring plan
    cfg = dataclasses.replace(configs.get_smoke(arch), n_layers=4)
    outs = {}
    for depth in (0, 1, 2):
        art, loss, st = one_step(cfg, mesh, depth)
        assert art.prefetch_depth == depth, (arch, depth, art)
        outs[depth] = (loss, st)
    for d in (1, 2):
        assert outs[0][0] == outs[d][0], (arch, d, outs[0][0], outs[d][0])
        pa = jax.tree.leaves(outs[0][1].params)
        pb = jax.tree.leaves(outs[d][1].params)
        assert all(np.array_equal(np.asarray(a), np.asarray(b))
                   for a, b in zip(pa, pb)), (arch, d)

# TP-mixed: on legacy partial-auto meshes the pipeline degrades to eager
# (StepArtifacts reports it) and stays exact
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
jax.set_mesh(mesh)
cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=2)
losses = {}
for depth in (0, 1):
    art, loss, _ = one_step(cfg, mesh, depth)
    losses[depth] = loss
    from repro import _jax_compat
    if _jax_compat.LEGACY_PARTIAL_AUTO:
        assert art.prefetch_depth == 0, art
assert losses[0] == losses[1], losses

# "auto" resolves through the tuning policy's overlap term
mesh = jax.make_mesh((2, 4), ("pod", "data"))
jax.set_mesh(mesh)
cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=2)
data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8,
                   seed=0)
art = make_train_step(cfg, mesh, grad_sync="locality", fsdp=True,
                      shape=custom_batch_specs(cfg, 8, 32), donate=False,
                      prefetch_depth="auto")
assert art.prefetch_source in ("model", "table", "dispatch"), art
assert art.prefetch_depth in (0, 1), art
# on the host-CPU harness there is no wire to hide: the measured-dispatch
# guard must resolve "auto" to the eager schedule (depth 0)
if jax.default_backend() == "cpu":
    assert art.prefetch_depth == 0, art
print("TRAIN_EXACT_OK")
"""


@pytest.mark.slow
def test_train_prefetch_exact(subproc):
    assert "TRAIN_EXACT_OK" in subproc(TRAIN_EXACT_CODE, devices=8,
                                       timeout=1800)


SERVE_FUSED_CODE = r"""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.models import transformer
from repro.serve.engine import make_serve_fns
from repro.serve.spec import ServeSpec

mesh = jax.make_mesh((8,), ("data",))
jax.set_mesh(mesh)
cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=2,
                          dtype=jnp.float32)
B, CL = 1, 64
params = transformer.init_params(jax.random.PRNGKey(0), cfg)
prompts = np.random.default_rng(0).integers(
    0, cfg.vocab_size, (B, 8)).astype(np.int32)

outs = {}
for impl in ("jnp", "pallas_interpret"):
    art = make_serve_fns(cfg, mesh, ServeSpec(batch=B, cache_len=CL,
                                          combine="locality",
                                          fused_stats=impl))
    assert art.fused_stats == impl, art.fused_stats
    logits, cache = art.prefill_fn(params, {"tokens": jnp.asarray(prompts)})
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    logits, _ = art.decode_fn(params, cache, tok)
    outs[impl] = np.asarray(logits)
np.testing.assert_allclose(outs["jnp"], outs["pallas_interpret"],
                           atol=1e-4, rtol=1e-4)
# "auto" resolves to jnp on CPU backends (the kernel would only interpret)
art = make_serve_fns(cfg, mesh, ServeSpec(batch=B, cache_len=CL,
                                          combine="locality"))
assert art.fused_stats == "jnp", art.fused_stats
print("SERVE_FUSED_OK")
"""


@pytest.mark.slow
def test_serve_fused_stats_matches_jnp(subproc):
    assert "SERVE_FUSED_OK" in subproc(SERVE_FUSED_CODE, devices=8,
                                       timeout=1800)


# ---------------------------------------------------------------------------
# fast (single-device) coverage — runs in --smoke mode
# ---------------------------------------------------------------------------
def test_overlap_cost_model_properties():
    from repro.core import cost_model as cm
    m = cm.MACHINES["lassen"]
    for p, pl in ((16, 4), (8, 2), (12, 4), (16, 1), (4, 4),
                  (6, 2), (10, 2), (15, 3), (24, 4)):
        for nbytes in (64, 4096, 1 << 20):
            t_sl, t_nl, t_fl = cm.locality_bruck_phase_split(p, pl, nbytes, m)
            assert t_sl >= 0 and t_nl >= 0 and t_fl >= 0
            for flops in (0.0, 1e9, 1e15):
                oc = cm.overlap_model(p, pl, nbytes, flops, m)
                # prefetch never exposes more than eager; hidden is bounded
                # by the start chain
                assert oc.exposed_prefetch <= oc.exposed_eager + 1e-18
                assert 0.0 <= oc.hidden <= t_sl + t_nl + 1e-18
                assert oc.exposed_nonlocal_prefetch <= \
                    oc.exposed_nonlocal_eager + 1e-18
            # a huge compute window hides the whole start chain
            oc = cm.overlap_model(p, pl, nbytes, 1e30, m)
            assert abs(oc.exposed_prefetch - t_fl) < 1e-18


def test_overlap_intensity_octaves():
    from repro.tuning.measure import overlap_collective, overlap_intensity
    assert overlap_collective(1.0) == "overlap:i0"
    assert overlap_collective(100.0) == "overlap:i7"
    assert overlap_collective(128.0) == "overlap:i7"
    assert overlap_collective(129.0) == "overlap:i8"
    assert overlap_intensity("overlap:i7") == 128.0


def test_policy_selects_overlap():
    from repro.tuning.policy import Policy
    pol = Policy(None, machine="tpu_v5e")
    # no compute window: nothing to hide -> eager (tie broken to eager)
    sel = pol.select_overlap(16, 4, 1 << 20, flops=0.0)
    assert sel.algorithm == "eager" and sel.source == "model"
    # a realistic FSDP layer window -> prefetch wins
    sel = pol.select_overlap(16, 4, 1 << 20, flops=1e12)
    assert sel.algorithm == "prefetch" and sel.source == "model"
    # single device: trivially eager
    assert pol.select_overlap(1, 1, 1024, flops=1e9).algorithm == "eager"


def test_pending_collective_is_pytree():
    import jax
    import jax.numpy as jnp
    from repro.core.collectives import PendingCollective, _SplitMeta
    pend = PendingCollective((jnp.ones(3), jnp.zeros(2)),
                             _SplitMeta("allgather", "pending", ("pod",),
                                        ("data",), True, (3,), 2, 2))
    leaves, treedef = jax.tree.flatten(pend)
    assert len(leaves) == 2
    back = jax.tree.unflatten(treedef, leaves)
    assert back.meta == pend.meta
    doubled = jax.tree.map(lambda t: t * 2, pend)
    assert float(doubled.arrays[0][0]) == 2.0
