"""The ('pod','data') sharding domain: unit + integration coverage.

Unit layer (single device, fake meshes): composite FSDP param specs and
their (outer, local) gather geometry, the serve cache's sequence-shard
candidate resolution, the combine geometry on multi-pod meshes, the
overlap policy's measured-dispatch guard, and the bench_trend
median-of-K baseline.

Integration layer (8-device subprocess, marked slow): pod-aware FSDP
train step vs the 'data'-only layout (loss bitwise-identical — the gather
is pure data movement), and the ('pod','data') sequence-sharded decode
(greedy tokens exactly equal across locality/XLA/legacy layouts).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest
from conftest import fake_mesh as _fake_mesh
from jax.sharding import PartitionSpec as P

from repro.train.sharding import (fsdp_dim, fsdp_leaf_axes,
                                  gather_outer_local, param_specs)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# train sharding geometry
# ---------------------------------------------------------------------------
def _abstract():
    import jax
    sds = jax.ShapeDtypeStruct
    f32 = np.float32
    return {
        "blocks": {"slot0": {"attn": {
            "wq": sds((3, 64, 32), f32),       # divisible by 8 -> composite
            "wo": sds((3, 32, 64), f32),
            "bias": sds((3, 64), f32),         # replicated by name rule
        }}},
        "embed": sds((512, 64), f32),
        "head": sds((12, 512), f32),           # 12 % 8 != 0, 12 % 4 == 0
    }


def test_param_specs_composite_fsdp_axes():
    mesh = _fake_mesh((2, 4), ("pod", "data"))
    specs = param_specs(_abstract(), mesh, fsdp=True)
    wq = specs["blocks"]["slot0"]["attn"]["wq"]
    assert wq == P(None, ("pod", "data"), None)
    assert fsdp_dim(wq) == 1
    assert fsdp_leaf_axes(wq) == "pod,data"
    # dim divisible intra-pod only: falls back to 'data' (pods replicate)
    head = specs["head"]
    assert head == P("data", None) or head == P(("data",), None), head
    assert fsdp_leaf_axes(head) == "data"
    # replicated-by-name leaves stay replicated
    bias = specs["blocks"]["slot0"]["attn"]["bias"]
    assert fsdp_dim(bias) == -1 and fsdp_leaf_axes(bias) == ""


def test_param_specs_forced_data_only():
    mesh = _fake_mesh((2, 4), ("pod", "data"))
    specs = param_specs(_abstract(), mesh, fsdp=True, fsdp_axes=("data",))
    wq = specs["blocks"]["slot0"]["attn"]["wq"]
    assert wq == P(None, "data", None)
    assert fsdp_leaf_axes(wq) == "data"


def test_string_axes_mean_one_axis():
    # a bare "data" must behave as ("data",), not be iterated char-by-char
    # (which would silently disable FSDP / the sequence sharding)
    mesh = _fake_mesh((2, 4), ("pod", "data"))
    specs = param_specs(_abstract(), mesh, fsdp=True, fsdp_axes="data")
    assert specs["blocks"]["slot0"]["attn"]["wq"] == P(None, "data", None)
    from repro.serve.engine import _cache_layout
    _, cand = _cache_layout(mesh, 1, seq_axes="data")
    assert cand == ("data",)


def test_gather_outer_local_split():
    assert gather_outer_local("pod,data") == (("pod",), ("data",))
    assert gather_outer_local("data") == ((), ("data",))
    assert gather_outer_local("") == ((), ())


def test_param_specs_three_pod_geometry():
    """q = 3 pods: per-leaf geometry when q ∤ a leaf dim — dims divisible by
    the full 3·p_data span shard composite, dims divisible only by p_data
    fall back to intra-pod 'data' (pods replicate that leaf)."""
    import jax
    sds = jax.ShapeDtypeStruct
    f32 = np.float32
    tree = {"blocks": {"slot0": {"attn": {
        "wq": sds((2, 96, 64), f32),      # 96 % 12 == 0 -> composite
        "wo": sds((2, 64, 96), f32),
    }}},
        "head": sds((64, 512), f32),      # 64 % 12 != 0, 64 % 4 == 0 -> data
    }
    mesh = _fake_mesh((3, 4), ("pod", "data"))
    specs = param_specs(tree, mesh, fsdp=True)
    wq = specs["blocks"]["slot0"]["attn"]["wq"]
    assert wq == P(None, ("pod", "data"), None)
    assert fsdp_leaf_axes(wq) == "pod,data"
    assert fsdp_leaf_axes(specs["head"]) == "data"


def test_three_pod_mesh_builders():
    from repro.launch.mesh import make_production_mesh  # noqa: F401 (sig)
    import inspect
    assert "pods" in inspect.signature(make_production_mesh).parameters


# ---------------------------------------------------------------------------
# serve cache layout + combine geometry
# ---------------------------------------------------------------------------
def test_seq_axes_resolution():
    from repro.serve.engine import _cache_layout, _seq_axes_for
    mesh = _fake_mesh((2, 4, 2), ("pod", "data", "model"))
    batch_sharded, cand = _cache_layout(mesh, 1)
    assert not batch_sharded and cand == ("pod", "data")
    assert _seq_axes_for(mesh, 32, cand) == ("pod", "data")   # 32 % 8 == 0
    assert _seq_axes_for(mesh, 12, cand) == ("data",)         # intra-pod only
    assert _seq_axes_for(mesh, 10, cand) is None
    # forcing the legacy layout narrows the candidates
    _, cand_d = _cache_layout(mesh, 1, seq_axes=("data",))
    assert cand_d == ("data",)
    assert _seq_axes_for(mesh, 32, cand_d) == ("data",)
    # single-pod mesh: unchanged behaviour
    mesh1 = _fake_mesh((8,), ("data",))
    _, cand1 = _cache_layout(mesh1, 1)
    assert cand1 == ("data",)


def test_resolve_cache_combine_multipod_geometry():
    import dataclasses
    from repro import configs
    from repro.serve.engine import resolve_cache_combine
    cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=2)
    mesh = _fake_mesh((2, 4), ("pod", "data"))
    ch = resolve_cache_combine(cfg, mesh, 1, 32, override="locality")
    assert (ch.p, ch.p_local) == (8, 4)
    ch_d = resolve_cache_combine(cfg, mesh, 1, 32, override="locality",
                                 seq_axes=("data",))
    assert (ch_d.p, ch_d.p_local) == (4, 4)
    # indivisible by the composite span but divisible intra-pod
    ch_n = resolve_cache_combine(cfg, mesh, 1, 12, override="locality")
    assert (ch_n.p, ch_n.p_local) == (4, 4)
    assert resolve_cache_combine(cfg, mesh, 1, 10).algorithm == "none"


def test_resolve_cache_combine_three_pods():
    """q = 3: the combine geometry resolves the (p, p_local) pair the
    hierarchical (fold/unfold max + Bruck-transpose sum) structure runs
    over; L ∤ 3·p_data falls back per layer to 'data'."""
    import dataclasses
    from repro import configs
    from repro.serve.engine import resolve_cache_combine
    cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=2)
    mesh = _fake_mesh((3, 4), ("pod", "data"))
    ch = resolve_cache_combine(cfg, mesh, 1, 48, override="locality")
    assert (ch.p, ch.p_local) == (12, 4)
    ch_n = resolve_cache_combine(cfg, mesh, 1, 32, override="locality")
    assert (ch_n.p, ch_n.p_local) == (4, 4)     # 32 % 12 != 0, 32 % 4 == 0


# ---------------------------------------------------------------------------
# overlap policy: measured dispatch overhead beats modeled hidden comm
# ---------------------------------------------------------------------------
def test_select_overlap_dispatch_guard():
    from repro.tuning.policy import Policy
    pol = Policy(None)
    nbytes, flops = 1 << 20, 1e12
    base = pol.select_overlap(16, 4, nbytes, flops)
    assert base.algorithm == "prefetch"          # big window hides the wire
    guarded = pol.select_overlap(16, 4, nbytes, flops,
                                 dispatch_overhead_s=10.0)
    assert guarded.algorithm == "eager" and guarded.source == "dispatch"
    # negligible measured overhead: the model's choice stands
    tiny = pol.select_overlap(16, 4, nbytes, flops,
                              dispatch_overhead_s=1e-12)
    assert tiny.algorithm == "prefetch"


def test_dispatch_overhead_is_measured_and_cached():
    from repro.tuning import measure
    t1 = measure.dispatch_overhead_s(refresh=True)
    assert t1 > 0.0
    assert measure.dispatch_overhead_s() == t1   # cached


# ---------------------------------------------------------------------------
# bench_trend: median-of-K baseline
# ---------------------------------------------------------------------------
def _run_trend(prev, cur, *extra):
    return subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts", "bench_trend.py"),
         "--prev", str(prev), "--cur", str(cur), *extra],
        capture_output=True, text=True)


def test_bench_trend_median_of_k(tmp_path):
    meta = {"jax_version": "1", "backend": "cpu", "device_count": 8,
            "device_kind": "cpu"}
    prev = tmp_path / "prev-bench"
    cur = tmp_path / "cur"
    cur.mkdir()

    def write(d, val, m=meta):
        d.mkdir(parents=True, exist_ok=True)
        (d / "BENCH_x.json").write_text(
            json.dumps({"cell": {"modeled_step_s": val}, "meta": m}))

    # three baseline runs: median 1.0 even though one run spiked to 5.0
    for i, v in enumerate((1.0, 5.0, 1.0)):
        write(prev / f"run{i}", v)
    write(cur, 1.05)
    r = _run_trend(prev, cur)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "3 baseline run(s)" in r.stdout
    # vs the single spiked run alone the same value would "improve"; vs the
    # median a real 30% regression is caught
    write(cur, 1.3)
    r = _run_trend(prev, cur)
    assert r.returncode == 1, r.stdout + r.stderr
    assert "median-of-3" in r.stderr + r.stdout
    # baseline runs with a foreign meta stamp are excluded from the median
    write(prev / "run3", 0.1, m={**meta, "jax_version": "2"})
    write(cur, 1.05)
    r = _run_trend(prev, cur)
    assert r.returncode == 0, r.stdout + r.stderr
    # single-run layout (artifacts directly in --prev) still works
    flat = tmp_path / "flat"
    write(flat, 1.0)
    write(cur, 1.3)
    r = _run_trend(flat, cur)
    assert r.returncode == 1, r.stdout + r.stderr


def test_bench_trend_plot_history(tmp_path, monkeypatch):
    """--plot renders the per-metric history: one SVG panel per tracked
    metric, a markdown table, and a $GITHUB_STEP_SUMMARY append."""
    meta = {"jax_version": "1", "backend": "cpu", "device_count": 8,
            "device_kind": "cpu"}
    prev = tmp_path / "prev-bench"
    cur = tmp_path / "cur"
    cur.mkdir()

    def write(d, val, m=meta):
        d.mkdir(parents=True, exist_ok=True)
        (d / "BENCH_x.json").write_text(json.dumps(
            {"cell": {"modeled_step_s": val, "tokens_per_s": val * 100},
             "meta": m}))

    for i, v in enumerate((1.0, 1.1, 0.9)):
        write(prev / f"run{i}", v)
    write(cur, 1.0)
    plot = tmp_path / "hist"
    summary = tmp_path / "summary.md"
    env = dict(os.environ, GITHUB_STEP_SUMMARY=str(summary))
    r = subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts", "bench_trend.py"),
         "--prev", str(prev), "--cur", str(cur), "--plot", str(plot)],
        capture_output=True, text=True, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    svg = (plot / "BENCH_x.svg").read_text()
    assert svg.count("<polyline") == 2          # one line per tracked metric
    assert "baseline 1/3: 1" in svg and "current" in svg
    md = (plot / "history.md").read_text()
    assert "cell.modeled_step_s" in md and "1 → 1.1 → 0.9" in md
    assert "cell.modeled_step_s" in summary.read_text()
    # --plot with NO baselines still renders the current point
    plot2 = tmp_path / "hist2"
    r = subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts", "bench_trend.py"),
         "--prev", str(tmp_path / "nope"), "--cur", str(cur),
         "--plot", str(plot2)],
        capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr
    assert (plot2 / "BENCH_x.svg").exists()


# ---------------------------------------------------------------------------
# integration: layouts agree (8-device subprocess)
# ---------------------------------------------------------------------------
EQUIV_CODE = r"""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.data import SyntheticLM
from repro.serve.engine import Engine
from repro.serve.spec import ServeSpec
from repro.train.step import custom_batch_specs, init_state, make_train_step

mesh = jax.make_mesh((2, 4), ("pod", "data"))
jax.set_mesh(mesh)
cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=2)
data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8,
                   seed=0)
bspec = custom_batch_specs(cfg, 8, 32)
losses = {}
for name, axes in (("pod_data", "auto"), ("data_only", ("data",))):
    art = make_train_step(cfg, mesh, grad_sync="locality", fsdp=True,
                          fsdp_axes=axes, shape=bspec, donate=False)
    state = init_state(cfg, mesh, art)
    batch = {k: jax.device_put(v, art.batch_shardings[k])
             for k, v in data.batch(0).items()}
    _, metrics = art.step_fn(state, batch)
    losses[name] = float(metrics["loss"])
    if name == "pod_data":
        assert art.fsdp_axes == ("pod", "data"), art.fsdp_axes
# the gather is pure data movement: identical forward on both layouts
assert losses["pod_data"] == losses["data_only"], losses

from repro.models import transformer
params = transformer.init_params(jax.random.PRNGKey(0), cfg)
prompts = np.array([[3, 5, 7, 2]], dtype=np.int32)
toks = {}
for name, kw in (("pod_loc", dict(combine="locality")),
                 ("pod_xla", dict(combine="xla")),
                 ("data_loc", dict(combine="locality", seq_axes=("data",)))):
    eng = Engine(cfg, mesh, params, ServeSpec(batch=1, cache_len=32, **kw))
    if name == "pod_loc":
        assert eng.combine.p == 8 and eng.combine.p_local == 4, eng.combine
        assert eng.art.decode_fn_locality is not None, eng.art
    toks[name] = eng.generate(prompts, 4)
assert np.array_equal(toks["pod_loc"], toks["pod_xla"]), toks
assert np.array_equal(toks["pod_loc"], toks["data_loc"]), toks
print("MULTIPOD_EQUIV_OK")
"""


@pytest.mark.slow
def test_multipod_layouts_agree(subproc):
    assert "MULTIPOD_EQUIV_OK" in subproc(EQUIV_CODE, devices=8,
                                          timeout=1800)


EQUIV3_CODE = r"""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.data import SyntheticLM
from repro.serve.engine import Engine
from repro.serve.spec import ServeSpec
from repro.train.step import custom_batch_specs, init_state, make_train_step

mesh = jax.make_mesh((3, 2), ("pod", "data"))
jax.set_mesh(mesh)
# dims divisible by the 3x2 composite span so the FSDP transpose really runs
# Algorithm 2's allgatherv rounds (the wrapped final round is PARTIAL here)
cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=2,
                          d_model=96, d_ff=192, vocab_size=384)
data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, global_batch=6,
                   seed=0)
bspec = custom_batch_specs(cfg, 6, 32)

# pod-aware vs data-only layout: forward is pure data movement -> bitwise
losses = {}
for name, axes in (("pod_data", "auto"), ("data_only", ("data",))):
    art = make_train_step(cfg, mesh, grad_sync="locality", fsdp=True,
                          fsdp_axes=axes, shape=bspec, donate=False)
    state = init_state(cfg, mesh, art)
    batch = {k: jax.device_put(v, art.batch_shardings[k])
             for k, v in data.batch(0).items()}
    _, metrics = art.step_fn(state, batch)
    losses[name] = float(metrics["loss"])
    if name == "pod_data":
        assert art.fsdp_axes == ("pod", "data"), art.fsdp_axes
assert losses["pod_data"] == losses["data_only"], losses

# prefetch-depth sweep on the 3-pod mesh: the double-buffered pipeline must
# stay bitwise-exact (loss AND params) when the deferred finish completes a
# PARTIAL final round — q=3, p_local=2 wraps at group 2
outs = {}
for depth in (0, 1, 2):
    art = make_train_step(cfg, mesh, grad_sync="locality", fsdp=True,
                          shape=bspec, donate=False, prefetch_depth=depth)
    assert art.prefetch_depth == depth, (depth, art)
    state = init_state(cfg, mesh, art)
    batch = {k: jax.device_put(v, art.batch_shardings[k])
             for k, v in data.batch(0).items()}
    st2, metrics = art.step_fn(state, batch)
    outs[depth] = (float(metrics["loss"]), st2)
for d in (1, 2):
    assert outs[0][0] == outs[d][0], (d, outs[0][0], outs[d][0])
    pa = jax.tree.leaves(outs[0][1].params)
    pb = jax.tree.leaves(outs[d][1].params)
    assert all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(pa, pb)), d

# decode: q=3 combine (fold/unfold max, Bruck-transpose sum) == XLA == legacy
from repro.models import transformer
params = transformer.init_params(jax.random.PRNGKey(0), cfg)
prompts = np.array([[3, 5, 7, 2]], dtype=np.int32)
toks = {}
for name, kw in (("pod_loc", dict(combine="locality")),
                 ("pod_xla", dict(combine="xla")),
                 ("data_loc", dict(combine="locality", seq_axes=("data",)))):
    eng = Engine(cfg, mesh, params, ServeSpec(batch=1, cache_len=48, **kw))
    if name == "pod_loc":
        assert eng.combine.algorithm == "locality", eng.combine
        assert eng.combine.p == 6 and eng.combine.p_local == 2, eng.combine
        assert eng.art.decode_fn_locality is not None, eng.art
    toks[name] = eng.generate(prompts, 4)
assert np.array_equal(toks["pod_loc"], toks["pod_xla"]), toks
assert np.array_equal(toks["pod_loc"], toks["data_loc"]), toks
print("MULTIPOD3_EQUIV_OK")
"""


@pytest.mark.slow
def test_three_pod_layouts_agree(subproc):
    """q = 3 pods (non-power region count): train loss bitwise across
    layouts, prefetch-depth sweep bitwise (loss + params), greedy decode
    tokens exactly equal across locality/XLA/legacy layouts."""
    assert "MULTIPOD3_EQUIV_OK" in subproc(EQUIV3_CODE, devices=6,
                                           timeout=1800)
