"""repro.telemetry: span tracer, metrics registry, comm reconciliation.

Fast units run in the parent process (tracer nesting + trace-event schema,
registry semantics, the comm predicted-vs-actual ledger, TelemetryEvent
string back-compat, StepMonitor edge cases, the check_metrics_schema CI
gate). The slow end-to-end test runs a 2-pod (2x4 ('pod','data')) train +
decode in an 8-device subprocess and asserts the acceptance contract: the
runtime-accumulated inter-pod bytes/msgs equal the compile-time
``collective_stats`` prediction EXACTLY for both the locality and the
flat-XLA paths, the locality artifacts carry the pod-crossing permute
schedule, and the run's trace dump is valid Perfetto trace-event JSON.
"""
import json
import os
import sys
import threading

import pytest

from conftest import fake_mesh

from repro.runtime import StepMonitor
from repro.telemetry import (CommReport, MetricsRegistry, TelemetryEvent,
                             Tracer, dp_group_map, validate_trace_events)

SCRIPTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "scripts")


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def test_tracer_nesting_and_valid_events():
    tr = Tracer(jax_annotations=False)
    with tr.span("outer", step=1):
        assert tr.current_span() == "outer"
        with tr.span("inner"):
            assert tr.current_span() == "inner"
            tr.instant("marker", note="x")
        assert tr.current_span() == "outer"
    assert tr.current_span() is None
    evs = tr.events()
    assert [e["ph"] for e in evs] == ["B", "B", "i", "E", "E"]
    assert evs[0]["name"] == "outer" and evs[0]["args"] == {"step": 1}
    # the inner span records its parent
    assert evs[1]["args"]["parent"] == "outer"
    assert validate_trace_events(evs) == []


def test_tracer_thread_lanes():
    tr = Tracer(jax_annotations=False)

    def worker():
        with tr.span("thread-span"):
            pass

    with tr.span("main-span"):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
    evs = tr.events()
    tids = {e["tid"] for e in evs}
    assert len(tids) == 2          # each OS thread gets its own lane
    assert validate_trace_events(evs) == []


def test_tracer_dump_is_chrome_trace_container(tmp_path):
    tr = Tracer(jax_annotations=False)
    with tr.span("a"):
        pass
    path = tmp_path / "trace.json"
    doc = tr.dump(str(path))
    on_disk = json.loads(path.read_text())
    assert on_disk == doc
    assert on_disk["displayTimeUnit"] == "ms"
    evs = on_disk["traceEvents"]
    assert evs[0]["ph"] == "M" and evs[0]["name"] == "process_name"
    assert validate_trace_events(evs) == []


def test_validate_trace_events_rejects_malformed():
    lane = {"pid": 1, "tid": 1}
    # unknown phase
    assert validate_trace_events([{"ph": "Z", "ts": 0, **lane}])
    # non-numeric ts
    assert validate_trace_events([{"ph": "B", "name": "a", "ts": "0", **lane}])
    # decreasing ts on one lane
    bad = [{"ph": "B", "name": "a", "ts": 5.0, **lane},
           {"ph": "E", "name": "a", "ts": 1.0, **lane}]
    assert any("decreases" in p or "E.ts" in p
               for p in validate_trace_events(bad))
    # E with no open B
    assert any("no open B" in p for p in validate_trace_events(
        [{"ph": "E", "name": "a", "ts": 0.0, **lane}]))
    # non-LIFO close
    bad = [{"ph": "B", "name": "a", "ts": 0.0, **lane},
           {"ph": "B", "name": "b", "ts": 1.0, **lane},
           {"ph": "E", "name": "a", "ts": 2.0, **lane},
           {"ph": "E", "name": "b", "ts": 3.0, **lane}]
    assert any("not LIFO" in p for p in validate_trace_events(bad))
    # unclosed span
    assert any("unclosed" in p for p in validate_trace_events(
        [{"ph": "B", "name": "a", "ts": 0.0, **lane}]))


def test_span_closes_on_exception():
    tr = Tracer(jax_annotations=False)
    with pytest.raises(RuntimeError):
        with tr.span("failing"):
            raise RuntimeError("boom")
    assert validate_trace_events(tr.events()) == []
    assert tr.current_span() is None


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_registry_counters_gauges_histograms():
    reg = MetricsRegistry()
    reg.count("steps")
    reg.count("steps", 2)
    assert reg.counter("steps").value == 3
    with pytest.raises(ValueError):
        reg.counter("steps").inc(-1)
    reg.gauge("loss").set(2.5)
    for v in [1.0, 2.0, 3.0, 4.0]:
        reg.observe("dt", v)
    snap = reg.snapshot()
    assert snap["counters"]["steps"] == 3
    assert snap["gauges"]["loss"] == 2.5
    h = snap["histograms"]["dt"]
    assert h["count"] == 4 and h["total"] == 10.0 and h["mean"] == 2.5
    assert h["min"] == 1.0 and h["max"] == 4.0
    # histogram means are mirrored as gauges for the trend gate
    assert snap["gauges"]["dt_mean"] == 2.5


def test_registry_dump_merges_sections(tmp_path):
    path = str(tmp_path / "metrics.json")
    r1 = MetricsRegistry()
    r1.gauge("a").set(1.0)
    r1.dump(path, meta={"backend": "cpu"})
    r2 = MetricsRegistry()
    r2.gauge("b").set(2.0)
    merged = r2.dump(path)            # merge=True default; meta preserved
    assert merged["gauges"]["a"] == 1.0 and merged["gauges"]["b"] == 2.0
    assert merged["meta"] == {"backend": "cpu"}
    on_disk = json.loads(open(path).read())
    assert on_disk == merged


def _report(nl_bytes=100.0, nl_msgs=4.0, **kw):
    return CommReport(label="t", nonlocal_bytes=nl_bytes,
                      nonlocal_msgs=nl_msgs, **kw)


def test_comm_ledger_reconciles_exactly():
    reg = MetricsRegistry()
    with pytest.raises(KeyError):
        reg.record_comm("t")          # unstamped path is the bug this catches
    reg.attach_comm_report("t", _report(permute_edges_nonlocal=2))
    for _ in range(5):
        reg.record_comm("t")
    rec = reg.reconcile("t")
    assert rec["invocations"] == 5 and rec["match"]
    assert rec["predicted_nonlocal_bytes"] == 500.0
    assert rec["actual_nonlocal_bytes"] == 500.0
    assert rec["predicted_nonlocal_msgs"] == 20.0
    snap = reg.snapshot()["comm"]["t"]
    assert snap["comm_nonlocal_bytes_per_step"] == 100.0
    assert snap["report"]["has_locality_schedule"] is True


def test_comm_ledger_detects_drift():
    reg = MetricsRegistry()
    reg.attach_comm_report("t", _report())
    reg.record_comm("t", 3)
    # simulate a step path that executed outside the accounting
    reg._comm["t"].actual_nonlocal_bytes += 100.0
    assert not reg.reconcile("t")["match"]


def test_comm_ledger_archives_on_reattach():
    reg = MetricsRegistry()
    reg.attach_comm_report("t", _report(100.0))
    reg.record_comm("t", 2)
    reg.attach_comm_report("t", _report(50.0))       # elastic rebuild
    reg.record_comm("t")
    snap = reg.snapshot()
    assert snap["comm"]["t"]["invocations"] == 1
    archived = snap["comm_archive"]["t"]
    assert len(archived) == 1 and archived[0]["invocations"] == 2
    assert archived[0]["actual_nonlocal_bytes"] == 200.0
    assert reg.reconcile_all()["t"]["match"]


# ---------------------------------------------------------------------------
# structured events: string back-compat
# ---------------------------------------------------------------------------

def test_telemetry_event_is_a_string():
    ev = TelemetryEvent("straggler: step took 9.000s", kind="straggler",
                        step=7, attrs={"dt": 9.0})
    assert isinstance(ev, str)
    assert "straggler" in ev                       # substring matching
    assert ev.startswith("straggler:")             # prefix matching
    assert ev == "straggler: step took 9.000s"     # equality with plain str
    assert ev.kind == "straggler" and ev.step == 7
    d = ev.asdict()
    assert d["message"] == str(ev) and d["attrs"] == {"dt": 9.0}
    assert d["t"] > 0
    assert "TelemetryEvent" in repr(ev)


# ---------------------------------------------------------------------------
# StepMonitor edge cases
# ---------------------------------------------------------------------------

def test_step_monitor_warmup_zero_does_not_flag_normal_steps():
    # historical bug: warmup=0 seeded the EWMA as alpha*dt, so every
    # subsequent NORMAL step satisfied dt > k*(alpha*dt) and was flagged
    m = StepMonitor(k=3.0, warmup=0)
    events = []
    for dt in [1.0, 1.0, 1.0, 1.0]:
        events.extend(m.record(dt))
    assert not any(e.kind == "straggler" for e in events)
    assert m.ewma == pytest.approx(1.0)
    # a genuine straggler is still caught
    events = m.record(10.0)
    assert sum(e.kind == "straggler" for e in events) == 1


def test_step_monitor_ewma_seeds_from_first_sample():
    m = StepMonitor(k=3.0, warmup=3, alpha=0.5)
    m.record(2.0)
    assert m.ewma == 2.0               # seeded, not blended against 0
    m.record(4.0)
    assert m.ewma == pytest.approx(3.0)


def test_step_monitor_collective_event_dedup():
    m = StepMonitor(k=3.0, warmup=0)
    evs = m.record(1.0, algorithm="locality")
    assert [e.kind for e in evs] == ["collective"]
    assert evs[0].attrs == {"algorithm": "locality", "previous": None}
    # repeats stay silent; a change (elastic re-resolution) re-fires
    assert m.record(1.0, algorithm="locality") == []
    evs = m.record(1.0, algorithm="flat_psum")
    assert [e.kind for e in evs] == ["collective"]
    assert evs[0].attrs["previous"] == "locality"


def test_step_monitor_returns_structured_string_events():
    m = StepMonitor(k=3.0, warmup=1)
    m.record(1.0)
    m.record(1.0)
    (ev,) = m.record(50.0)
    assert isinstance(ev, TelemetryEvent) and isinstance(ev, str)
    assert ev.kind == "straggler" and "straggler" in ev
    assert ev.attrs["dt"] == 50.0 and ev.attrs["k"] == 3.0


# ---------------------------------------------------------------------------
# dp_group_map (the DP-domain grouping behind CommReport.dp_bytes)
# ---------------------------------------------------------------------------

def test_dp_group_map_groups_tp_peers_together():
    mesh = fake_mesh((2, 2, 2), ("pod", "data", "model"))
    m = dp_group_map(mesh, ("pod", "data"))
    assert m is not None and len(m) == 8
    # devices 0 and 1 differ only in 'model' position: same DP coordinate
    assert m[0] == m[1]
    # device 2 sits at a different 'data' position, 4 at a different 'pod'
    assert m[0] != m[2] and m[0] != m[4]
    assert len(set(m.values())) == 4   # 2 pods x 2 data rows


def test_dp_group_map_none_when_no_dp_width():
    assert dp_group_map(fake_mesh((1, 1, 4), ("pod", "data", "model")),
                        ("pod", "data")) is None
    assert dp_group_map(fake_mesh((4,), ("model",)), ("data",)) is None


# ---------------------------------------------------------------------------
# check_metrics_schema (the CI gate script)
# ---------------------------------------------------------------------------

def _schema():
    sys.path.insert(0, SCRIPTS)
    try:
        import check_metrics_schema
    finally:
        sys.path.remove(SCRIPTS)
    return check_metrics_schema


def test_check_metrics_schema_accepts_real_artifacts(tmp_path):
    schema = _schema()
    reg = MetricsRegistry()
    reg.count("steps", 3)
    reg.observe("dt", 0.5)
    reg.attach_comm_report("t", _report())
    reg.record_comm("t", 3)
    mpath = str(tmp_path / "metrics.json")
    reg.dump(mpath)
    tr = Tracer(jax_annotations=False)
    with tr.span("a"):
        pass
    tpath = str(tmp_path / "trace_x.json")
    tr.dump(tpath)
    assert schema.main([mpath, tpath]) == 0


def test_check_metrics_schema_fails_on_comm_mismatch(tmp_path):
    schema = _schema()
    reg = MetricsRegistry()
    reg.attach_comm_report("t", _report())
    reg.record_comm("t", 2)
    reg._comm["t"].actual_nonlocal_msgs += 1.0      # drift
    mpath = str(tmp_path / "metrics.json")
    reg.dump(mpath)
    assert schema.main([mpath]) == 1


def test_check_metrics_schema_fails_on_bad_trace(tmp_path):
    schema = _schema()
    tpath = str(tmp_path / "trace_bad.json")
    with open(tpath, "w") as f:
        json.dump({"traceEvents": [
            {"ph": "B", "name": "a", "ts": 0.0, "pid": 1, "tid": 1}]}, f)
    assert schema.main([tpath]) == 1                # unclosed span
    empty = str(tmp_path / "trace_empty.json")
    with open(empty, "w") as f:
        json.dump({"traceEvents": []}, f)
    assert schema.main([empty]) == 1                # no spans at all
    missing = str(tmp_path / "nope.json")
    assert schema.main([missing]) == 1


# ---------------------------------------------------------------------------
# end-to-end: 2-pod train + decode with exact comm reconciliation
# ---------------------------------------------------------------------------

E2E_CODE = r"""
import dataclasses, json, os, shutil
import jax, jax.numpy as jnp, numpy as np
from repro import configs, telemetry
from repro.serve.engine import Engine
from repro.serve.spec import ServeSpec
from repro.train import Trainer, TrainerConfig
from repro.models import transformer

telemetry.set_tracer(telemetry.Tracer())
telemetry.set_registry(telemetry.MetricsRegistry())
tracer = telemetry.get_tracer()
registry = telemetry.get_registry()

mesh = jax.make_mesh((2, 4), ("pod", "data"))
jax.set_mesh(mesh)
cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=2)
shutil.rmtree("/tmp/repro_ckpt_telemetry", ignore_errors=True)

# --- train: locality FSDP (the paper path) ---------------------------------
tcfg = TrainerConfig(steps=4, seq_len=16, global_batch=8, ckpt_every=2,
                     ckpt_dir="/tmp/repro_ckpt_telemetry", log_every=100,
                     grad_sync="locality", fsdp=True)
tr = Trainer(cfg, mesh, tcfg, log=lambda s: None)
assert tr.comm_report is not None, "AOT comm stamping failed on locality path"
rep = tr.comm_report
assert rep.nonlocal_bytes > 0 and rep.nonlocal_msgs > 0, rep
assert rep.has_locality_schedule, (
    "locality train path lost its pod-crossing permute schedule", rep)
assert rep.dp_bytes > 0, rep
tr.run()
rec = registry.reconcile(tr.comm_label)
assert rec["invocations"] == 4, rec
assert rec["match"], ("train/locality reconciliation failed", rec)
assert rec["actual_nonlocal_bytes"] == 4 * rep.nonlocal_bytes, rec
assert rec["actual_nonlocal_msgs"] == 4 * rep.nonlocal_msgs, rec
assert registry.counter("train/steps").value == 4
assert registry.histogram("train/step_time_s").count == 4
assert registry.counter("checkpoint/saves").value >= 2

# --- train: flat XLA baseline (reconciliation must hold there too) ---------
tcfg_x = dataclasses.replace(tcfg, grad_sync="xla", fsdp=False,
                             ckpt_dir="/tmp/repro_ckpt_telemetry_x")
shutil.rmtree(tcfg_x.ckpt_dir, ignore_errors=True)
tr_x = Trainer(cfg, mesh, tcfg_x, log=lambda s: None)
assert tr_x.comm_report is not None, "AOT comm stamping failed on xla path"
assert tr_x.comm_label != tr.comm_label
tr_x.run()
rec_x = registry.reconcile(tr_x.comm_label)
assert rec_x["invocations"] == 4 and rec_x["match"], rec_x

# --- serve: locality vs flat-XLA decode combine over ('pod','data') --------
params = transformer.init_params(jax.random.PRNGKey(0), cfg)
prompts = np.random.default_rng(0).integers(
    0, cfg.vocab_size, (1, 8)).astype(np.int32)
NEW = 5
engines = {}
for alg in ("locality", "xla"):
    eng = Engine(cfg, mesh, params, ServeSpec(batch=1, cache_len=64,
                                              combine=alg))
    assert eng.comm_report is not None, f"decode comm stamping failed ({alg})"
    eng.generate(prompts, NEW)
    st = eng.stats()
    r = eng.comm_report
    assert st["decode_steps"] == NEW
    assert st["nonlocal_bytes"] == NEW * r.nonlocal_bytes, st
    assert st["nonlocal_msgs"] == NEW * r.nonlocal_msgs, st
    srec = st["comm"]["reconcile"]
    assert srec["invocations"] == NEW and srec["match"], (alg, srec)
    engines[alg] = eng
loc, xla = engines["locality"], engines["xla"]
assert loc.combine.algorithm == "locality"
assert loc.comm_report.has_locality_schedule, loc.comm_report
assert loc.comm_report.nonlocal_bytes > 0
assert loc.stats()["combine_bytes"] == NEW * loc.comm_report.dp_bytes
assert xla.stats()["combine_steps"] == 0

# --- artifacts: Perfetto trace + metrics snapshot --------------------------
os.makedirs("results", exist_ok=True)
doc = tracer.dump("results/trace_telemetry_e2e.json")
problems = telemetry.validate_trace_events(doc["traceEvents"])
assert problems == [], problems[:5]
names = {e.get("name") for e in doc["traceEvents"]}
for want in ("train/build", "train/compile", "train/step", "train/step_fn",
             "train/data", "checkpoint/save", "checkpoint/write",
             "serve/build", "serve/compile", "serve/prefill",
             "serve/decode_step"):
    assert want in names, (want, sorted(names))
snap = registry.dump("results/metrics.json")
assert all(rec["match"] for rec in registry.reconcile_all().values())
assert snap["gauges"]["train/compile_time_s"] > 0
assert snap["gauges"]["train/step_time_s_mean"] > 0
assert snap["gauges"]["serve/decode_step_s_mean"] > 0
print("TELEMETRY_E2E_OK",
      int(rep.nonlocal_bytes), int(loc.comm_report.nonlocal_bytes))
"""


@pytest.mark.slow
def test_telemetry_end_to_end_two_pods(subproc):
    out = subproc(E2E_CODE, devices=8, timeout=1800)
    assert "TELEMETRY_E2E_OK" in out
