"""Property tests for the paper's schedule generators (Algorithm 1 & 2)."""
import math

import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import schedules as S
from repro.core.topology import RegionMap, ceil_log

pytestmark = pytest.mark.hypothesis

ALGS = ["bruck", "ring", "hierarchical", "multilane", "locality_bruck"]


def region_cases():
    """(p, p_local) pairs incl. power and non-power region counts."""
    return st.tuples(st.sampled_from([2, 4, 8, 16]),
                     st.integers(1, 5)).map(lambda t: (t[0] * t[1], t[0]))


@settings(max_examples=30, deadline=None)
@given(region_cases(), st.sampled_from(ALGS))
def test_schedule_correct(case, alg):
    p, pl = case
    sched = S.ALGORITHMS[alg](p, pl)
    sched.validate()          # every rank ends with all p blocks, canonical


@settings(max_examples=30, deadline=None)
@given(region_cases())
def test_paper_eq3_bruck_counts(case):
    """Standard Bruck on a flat network: log2(p) msgs, p-1 blocks (Eq. 3)."""
    p, _ = case
    sched = S.ALGORITHMS["bruck"](p)          # no region: all msgs non-local
    assert sched.max_nonlocal_msgs() == ceil_log(2, p)
    assert sched.max_nonlocal_blocks() == p - 1


@settings(max_examples=30, deadline=None)
@given(st.sampled_from([2, 4, 8, 16]), st.sampled_from([1, 2, 3]))
def test_paper_eq4_locality_counts(pl, k):
    """Locality-aware Bruck with r = p_ℓ^k regions: ceil(log_pl(r)) non-local
    messages per rank; non-local blocks = sum_i pl^(i+1) (paper §4)."""
    from _hypothesis_compat import assume
    assume(pl ** (k + 1) <= 1024)        # generators are O(p²) host memory
    r = pl ** k
    p = r * pl
    sched = S.ALGORITHMS["locality_bruck"](p, pl)
    region = RegionMap(p, pl)
    assert sched.max_nonlocal_msgs(region) == k
    expect_blocks = sum(pl ** (i + 1) for i in range(k))
    assert sched.max_nonlocal_blocks(region) == expect_blocks


@settings(max_examples=20, deadline=None)
@given(region_cases())
def test_locality_beats_bruck_nonlocal(case):
    """The paper's core claim: fewer non-local messages AND blocks — for
    EVERY region count. The allgatherv adaptation (partial final-round
    payloads) removed the power-of-p_ℓ caveat: the wrapped final exchange
    no longer re-sends data the peer already holds."""
    p, pl = case
    if pl < 2 or p <= pl:
        return
    region = RegionMap(p, pl)
    loc = S.ALGORITHMS["locality_bruck"](p, pl)
    std = S.ALGORITHMS["bruck"](p, pl)
    assert loc.max_nonlocal_msgs(region) <= std.max_nonlocal_msgs(region)
    assert loc.max_nonlocal_blocks(region) <= std.max_nonlocal_blocks(region)


def test_allgatherv_partial_final_round():
    """Non-power region counts q ∈ {3, 5, 6}: round count is
    ceil(log_pl(q)) and the worst rank's non-local blocks follow the
    partial-payload recurrence Σ min(group, q−group)·p_ℓ — strictly below
    the full-buffer exchange wherever the final round wraps."""
    for q, pl in ((3, 2), (3, 4), (5, 2), (5, 3), (5, 4), (6, 2), (6, 4),
                  (10, 4), (7, 3)):
        p = q * pl
        region = RegionMap(p, pl)
        sched = S.ALGORITHMS["locality_bruck"](p, pl)
        sched.validate()
        assert sched.max_nonlocal_msgs(region) == ceil_log(pl, q), (q, pl)
        expect = full = 0
        group = 1
        while group < q:
            active = min(pl, -(-q // group))
            expect += min(group, q - group) * pl
            full += group * pl                  # the pre-adaptation payload
            group = min(group * active, q)
        assert sched.max_nonlocal_blocks(region) == expect, (q, pl)
        wraps = expect != full
        if wraps:
            assert sched.max_nonlocal_blocks(region) < full, (q, pl)


def test_example_2_1():
    """Paper Example 2.1: 16 ranks, 4 per region: 1 non-local message of 4
    values vs Bruck's 4 messages / 15 values."""
    region = RegionMap(16, 4)
    loc = S.ALGORITHMS["locality_bruck"](16, 4)
    std = S.ALGORITHMS["bruck"](16, 4)
    assert loc.max_nonlocal_msgs(region) == 1
    assert loc.max_nonlocal_blocks(region) == 4
    assert std.max_nonlocal_msgs(region) == 4
    assert std.max_nonlocal_blocks(region) == 15


def test_figure_6_64_ranks():
    """Paper Fig. 6: 64 ranks / 16 regions of 4 → 2 non-local rounds."""
    region = RegionMap(64, 4)
    loc = S.ALGORITHMS["locality_bruck"](64, 4)
    assert loc.max_nonlocal_msgs(region) == 2
