"""Ring-buffer KV caches for windowed/chunked attention (§Perf iteration 7):
prefill+decode with a W-slot ring must match the full teacher-forced
forward even after the ring wraps (S > W)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import transformer
from repro.models.transformer import cache_specs, ring_cache_len

B = 2


@pytest.mark.parametrize("arch,S", [("h2o-danube-3-4b", 96),
                                    ("gemma2-9b", 96),
                                    ("llama4-scout-17b-a16e", 80)])
def test_ring_wraps_match_full_forward(arch, S):
    cfg = configs.get_smoke(arch)         # reduced window/chunk = 64 < S
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)
    cfg = dataclasses.replace(cfg, n_layers=4)
    rng = jax.random.PRNGKey(0)
    params = transformer.init_params(rng, cfg)
    tokens = jax.random.randint(rng, (B, S + 3), 0, cfg.vocab_size)
    full, _, _ = transformer.forward(params, cfg, tokens, mode="train")
    lg, _, cache = transformer.forward(params, cfg, tokens[:, :S],
                                       mode="prefill", cache_len=S + 3)
    f32 = lambda t: t.astype(jnp.float32)
    assert float(jnp.max(jnp.abs(f32(full[:, S - 1:S]) - f32(lg)))) < 0.05
    for t in range(3):
        lg, _, cache = transformer.forward(params, cfg,
                                           tokens[:, S + t:S + t + 1],
                                           cache=cache)
        err = float(jnp.max(jnp.abs(f32(full[:, S + t:S + t + 1]) - f32(lg))))
        assert err < 0.05, f"decode step {t}: {err}"


def test_ring_cache_sizes():
    cfg = configs.get("h2o-danube-3-4b")
    specs = cache_specs(cfg, batch=1, cache_len=524_288)
    ls = {l.shape[-3] for l in jax.tree.leaves(specs)
          if hasattr(l, "shape") and len(l.shape) >= 4}
    assert ls == {cfg.window}, ls          # every layer windowed -> W slots

    g = configs.get("gemma2-9b")
    specs = cache_specs(g, batch=1, cache_len=32_768)
    ls = sorted({l.shape[-3] for l in jax.tree.leaves(specs)
                 if hasattr(l, "shape") and len(l.shape) >= 4})
    assert ls == [g.window, 32_768]        # alternating ring/full

    plan = configs.get("llama4-scout-17b-a16e").layer_plan()
    l4 = configs.get("llama4-scout-17b-a16e")
    assert ring_cache_len(l4, plan[0]) == l4.chunk
    assert ring_cache_len(l4, plan[3]) is None      # global-NoPE layer
