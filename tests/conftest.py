"""Shared test utilities.

NOTE: XLA_FLAGS / device-count forcing is deliberately NOT set here — smoke
tests run on the single real CPU device. Multi-device tests spawn
subprocesses (helpers below) that set --xla_force_host_platform_device_count
before importing jax.
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_subprocess(code: str, devices: int = 8, timeout: int = 900):
    """Run ``code`` in a fresh python with N host devices; returns stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode}):\n--- stdout ---\n"
            f"{proc.stdout[-4000:]}\n--- stderr ---\n{proc.stderr[-4000:]}")
    return proc.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_subprocess


class FakeDev:
    """Stand-in for a jax Device: the topology helpers only read ``.id``."""

    def __init__(self, id_):
        self.id = id_


def fake_mesh(shape, names):
    """Mesh stand-in (``axis_names`` + object ndarray of FakeDevs) for
    topology/sharding unit tests that never touch real devices."""
    import numpy as np
    n = int(np.prod(shape))
    devs = np.array([FakeDev(i) for i in range(n)],
                    dtype=object).reshape(shape)

    class _M:
        axis_names = names
        devices = devs

    return _M()
