"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and finiteness (assignment deliverable f)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import encdec, transformer
from repro.optim import AdamW, TrainState
from repro.train.step import make_loss_fn

B, S = 2, 32


def _inputs(cfg, rng):
    kw = {}
    if cfg.family == "audio":
        kw["frames"] = jax.random.normal(rng, (B, cfg.enc_seq, cfg.d_model),
                                         cfg.dtype)
    if cfg.family == "vlm":
        kw["img_embeds"] = jax.random.normal(
            rng, (B, cfg.n_img_tokens, cfg.d_model), cfg.dtype)
    return kw


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_forward_smoke(arch):
    cfg = configs.get_smoke(arch)
    mod = encdec if cfg.family == "audio" else transformer
    rng = jax.random.PRNGKey(0)
    params = mod.init_params(rng, cfg)
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    logits, aux, _ = mod.forward(params, cfg, tokens, mode="train",
                                 **_inputs(cfg, rng))
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert np.isfinite(float(aux["moe_aux"]))


@pytest.mark.parametrize("arch", ["llama3.2-3b", "qwen2-moe-a2.7b",
                                  "mamba2-780m", "zamba2-1.2b",
                                  "whisper-tiny"])
def test_train_step_smoke(arch):
    """One full grad+update step per family on the single CPU device."""
    cfg = configs.get_smoke(arch)
    cfg = dataclasses.replace(cfg, n_layers=min(cfg.n_layers, 2))
    mod = encdec if cfg.family == "audio" else transformer
    rng = jax.random.PRNGKey(0)
    params = mod.init_params(rng, cfg)
    loss_fn = make_loss_fn(cfg)
    batch = {
        "tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
    }
    batch.update(_inputs(cfg, rng))
    shard = lambda x, _k: x
    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, batch, shard)
    assert np.isfinite(float(loss))
    state = TrainState.create(params)
    new_state, om = AdamW(lr=1e-3).apply(state, grads)
    assert int(new_state.step) == 1
    assert np.isfinite(float(om["grad_norm"])) and float(om["grad_norm"]) > 0
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(new_state.params)))
    assert moved


def test_param_count_matches_init():
    for arch in configs.ARCHS:
        cfg = configs.get_smoke(arch)
        mod = encdec if cfg.family == "audio" else transformer
        a = jax.eval_shape(lambda k, c=cfg, m=mod: m.init_params(k, c),
                           jax.random.PRNGKey(0))
        n_init = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(a))
        n_count = cfg.param_count()
        if cfg.tie_embeddings:
            assert n_init == n_count, arch
        else:
            assert n_init == n_count, arch


def test_layer_plans():
    g = configs.get("gemma2-9b").layer_plan()
    assert [s.attn for s in g[:4]] == ["window", "full", "window", "full"]
    l4 = configs.get("llama4-scout-17b-a16e").layer_plan()
    assert [s.attn for s in l4[:4]] == ["chunked", "chunked", "chunked", "full"]
    assert l4[3].rope is False                       # NoPE global layer
    z = configs.get("zamba2-1.2b").layer_plan()
    assert sum(1 for s in z if s.mixer == "shared_attn") == 6
    assert sum(1 for s in z if s.mixer == "mamba2") == 38


def test_long_500k_applicability():
    runs = {a: configs.get(a).runs_long_500k for a in configs.ARCHS}
    assert runs["mamba2-780m"] and runs["zamba2-1.2b"]
    assert runs["h2o-danube-3-4b"] and runs["llama4-scout-17b-a16e"]
    for a in ("gemma2-9b", "llama3.2-3b", "yi-6b", "whisper-tiny",
              "internvl2-26b"):
        assert not runs[a], a
