"""HLO collective-scan unit tests on synthetic HLO text."""
from repro.core.hlo_analysis import (Roofline, collective_stats, _shape_bytes)

HLO = """
HloModule test
  %x = bf16[256,4096]{1,0} parameter(0)
  %ag = bf16[256,65536]{1,0} all-gather(bf16[256,4096]{1,0} %x), dimensions={1}
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %y), to_apply=%add
  %cp = f32[64,64]{1,0} collective-permute(f32[64,64]{1,0} %z), source_target_pairs={{0,1},{1,0},{2,3},{3,2}}
  %cps = (f32[8]{0}, f32[8]{0}) collective-permute-start(f32[8]{0} %w), source_target_pairs={{0,2},{2,0}}
  %cpd = f32[8]{0} collective-permute-done((f32[8]{0}, f32[8]{0}) %cps)
  %rs = bf16[16]{0} reduce-scatter(bf16[256]{0} %q), dimensions={0}
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[256,4096]") == 256 * 4096 * 2
    assert _shape_bytes("f32[1024]") == 4096
    assert _shape_bytes("(f32[8], f32[8])") == 64


def test_collective_scan_counts_and_bytes():
    st = collective_stats(HLO)
    assert st.counts["all-gather"] == 1
    assert st.counts["all-reduce"] == 1
    assert st.counts["collective-permute"] == 2    # plain + start (done skipped)
    assert st.counts["reduce-scatter"] == 1
    assert st.bytes_["all-gather"] == 256 * 65536 * 2
    assert st.bytes_["reduce-scatter"] == 32


def test_permute_locality_classification():
    pod = {0: 0, 1: 0, 2: 1, 3: 1}
    st = collective_stats(HLO, pod)
    # {0,1},{1,0} local; {2,3},{3,2} local; {0,2},{2,0} non-local
    assert st.permute_edges_local == 4
    assert st.permute_edges_nonlocal == 2


def test_roofline_terms():
    # all inputs PER-DEVICE except model_flops (global)
    r = Roofline(flops=197e12, hbm_bytes=819e9, collective_bytes=50e9,
                 n_chips=256, model_flops=197e12 * 128)
    assert abs(r.compute_s - 1.0) < 1e-9        # hlo == model/chip/2 < hlo
    assert abs(r.memory_s - 1.0) < 1e-9
    assert abs(r.collective_s - 1.0) < 1e-9
    assert abs(r.useful_fraction - 0.5) < 1e-9
    assert abs(r.roofline_fraction - 0.5) < 1e-9
    # compute floor kicks in when the scan-undercounted HLO flops are low
    r2 = Roofline(flops=1e9, hbm_bytes=819e9, collective_bytes=0,
                  n_chips=256, model_flops=197e12 * 256)
    assert abs(r2.compute_s - 1.0) < 1e-9
    assert r2.dominant in ("compute", "memory")
    assert abs(r2.useful_fraction - 1.0) < 1e-9


def test_autotune_prefers_locality_for_small_messages():
    from repro.core.autotune import model_costs, pick_allgather
    pick = pick_allgather(p=4096, p_local=16, nbytes_per_rank=8,
                          machine="lassen")
    costs = model_costs(4096, 16, 8, "lassen")
    assert costs["locality_bruck"] < costs["bruck"]
    assert pick in ("locality_bruck", "multilane")
