"""HLO collective-scan unit tests on synthetic HLO text."""
from repro.core.hlo_analysis import (Roofline, collective_stats, _shape_bytes)

HLO = """
HloModule test
  %x = bf16[256,4096]{1,0} parameter(0)
  %ag = bf16[256,65536]{1,0} all-gather(bf16[256,4096]{1,0} %x), dimensions={1}
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %y), to_apply=%add
  %cp = f32[64,64]{1,0} collective-permute(f32[64,64]{1,0} %z), source_target_pairs={{0,1},{1,0},{2,3},{3,2}}
  %cps = (f32[8]{0}, f32[8]{0}) collective-permute-start(f32[8]{0} %w), source_target_pairs={{0,2},{2,0}}
  %cpd = f32[8]{0} collective-permute-done((f32[8]{0}, f32[8]{0}) %cps)
  %rs = bf16[16]{0} reduce-scatter(bf16[256]{0} %q), dimensions={0}
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[256,4096]") == 256 * 4096 * 2
    assert _shape_bytes("f32[1024]") == 4096
    assert _shape_bytes("(f32[8], f32[8])") == 64


def test_collective_scan_counts_and_bytes():
    st = collective_stats(HLO)
    assert st.counts["all-gather"] == 1
    assert st.counts["all-reduce"] == 1
    assert st.counts["collective-permute"] == 2    # plain + start (done skipped)
    assert st.counts["reduce-scatter"] == 1
    assert st.bytes_["all-gather"] == 256 * 65536 * 2
    assert st.bytes_["reduce-scatter"] == 32


def test_permute_locality_classification():
    pod = {0: 0, 1: 0, 2: 1, 3: 1}
    st = collective_stats(HLO, pod)
    # {0,1},{1,0} local; {2,3},{3,2} local; {0,2},{2,0} non-local
    assert st.permute_edges_local == 4
    assert st.permute_edges_nonlocal == 2
    # per-EDGE payload accounting: each edge moves the op's bytes (the
    # async -start op's tuple type counts its send+recv buffers, 64 B)
    assert st.permute_bytes_local == 4 * 64 * 64 * 4
    assert st.permute_bytes_nonlocal == 2 * 64


GROUP_HLO = """
HloModule groups
  %arl = f32[64]{0} all-reduce(f32[64]{0} %a), replica_groups={{0,1},{2,3}}, to_apply=%add
  %arx = f32[64]{0} all-reduce(f32[64]{0} %b), replica_groups={{0,2},{1,3}}, to_apply=%add
  %ag = f32[128]{0} all-gather(f32[32]{0} %c), replica_groups={{0,1,2,3}}, dimensions={0}
  %rs = f32[16]{0} reduce-scatter(f32[64]{0} %d), replica_groups={{0,1},{2,3}}, dimensions={0}
  %a2a = f32[64]{0} all-to-all(f32[64]{0} %e), replica_groups={{0,1,2,3}}, dimensions={0}
"""


def test_group_collective_classification():
    pod = {0: 0, 1: 0, 2: 1, 3: 1}
    st = collective_stats(GROUP_HLO, pod)
    # %arl: both groups intra-pod -> ring msgs 2*(2-1)=2 per link, 2 links
    # per group, all local. %arx: both groups cross pods -> all nonlocal.
    # %ag over {0,1,2,3}: ring links (0,1)(1,2)(2,3)(3,0): 2 cross; each
    # link carries (n-1)=3 msgs of b/n.
    # %rs per-group n=2: 1 msg per link of the scattered shard (b=64B).
    # %a2a: ordered cross-pod pairs 8 of 12, b/n = 64B each.
    assert st.group_msgs_nonlocal == (2 * 2 * 2      # arx
                                      + 2 * 3        # ag crossing links
                                      + 8)           # a2a
    assert st.group_msgs_local == (2 * 2 * 2         # arl
                                   + 2 * 3           # ag local links
                                   + 2 * 2 * 1      # rs (2 groups, 2 links)
                                   + 4)              # a2a intra-pod pairs
    b_ag = 128 * 4
    assert st.group_bytes_nonlocal == (2 * 2 * 2 * (64 * 4 / 2)
                                       + 2 * 3 * (b_ag / 4)
                                       + 8 * (64 * 4 / 4))
    assert st.nonlocal_msgs == st.group_msgs_nonlocal   # no permutes here
    assert st.nonlocal_bytes == st.group_bytes_nonlocal


def test_group_classification_non_power_of_two_pods():
    # 3 pods of 2 ranks; one all-reduce spanning everything (iota form) and
    # one per-pod reduce-scatter (explicit)
    hlo = """
  %ar = f32[96]{0} all-reduce(f32[96]{0} %a), replica_groups=[1,6]<=[6], to_apply=%add
  %rs = f32[8]{0} reduce-scatter(f32[48]{0} %b), replica_groups={{0,1},{2,3},{4,5}}, dimensions={0}
"""
    pod = {i: i // 2 for i in range(6)}
    st = collective_stats(hlo, pod)
    # ring over [0..5]: links (1,2),(3,4),(5,0) cross pods -> 3 of 6;
    # all-reduce: 2*(6-1)=10 msgs per link of b/6
    assert st.group_msgs_nonlocal == 3 * 10
    assert st.group_msgs_local == 3 * 10 + 3 * 2 * 1   # + rs per-pod
    assert abs(st.group_bytes_nonlocal - 3 * 10 * (96 * 4 / 6)) < 1e-9


def test_uneven_replica_group_classification():
    """Groups of DIFFERENT sizes in one op (what GSPMD emits when a
    non-power pod count shards a dim its size doesn't divide evenly): each
    group is ring-decomposed with its own length."""
    hlo = """
  %ar = f32[96]{0} all-reduce(f32[96]{0} %a), replica_groups={{0,1,2,3},{4,5}}, to_apply=%add
"""
    pod = {0: 0, 1: 0, 2: 1, 3: 1, 4: 2, 5: 2}
    st = collective_stats(hlo, pod)
    # group {0,1,2,3}: ring links (0,1)L (1,2)N (2,3)L (3,0)N, 2*(4-1)=6
    # msgs/link of b/4; group {4,5}: both links local, 2*(2-1)=2 msgs/link
    assert st.group_msgs_nonlocal == 2 * 6
    assert st.group_msgs_local == 2 * 6 + 2 * 2
    assert abs(st.group_bytes_nonlocal - 2 * 6 * (96 * 4 / 4)) < 1e-9


def test_iota_prefix_subgroup():
    """An iota list covering only a prefix of the device grid (a subgroup
    collective on a mesh subset) parses to the prefix instead of failing
    the reshape."""
    from repro.core.hlo_analysis import _replica_groups
    pod = {i: i // 2 for i in range(8)}
    line = "x = f32[8] all-gather(f32[2] %a), replica_groups=[2,3]<=[8]"
    assert _replica_groups(line, pod) == [[0, 1, 2], [3, 4, 5]]


def test_iota_replica_group_parsing():
    from repro.core.hlo_analysis import _replica_groups
    pod = {i: 0 for i in range(8)}
    line = "x = f32[8] all-reduce(f32[8] %a), replica_groups=[2,4]<=[8]"
    assert _replica_groups(line, pod) == [[0, 1, 2, 3], [4, 5, 6, 7]]
    line = "x = f32[8] all-reduce(f32[8] %a), replica_groups=[2,4]<=[4,2]T(1,0)"
    assert _replica_groups(line, pod) == [[0, 2, 4, 6], [1, 3, 5, 7]]
    # empty groups attribute = one group of every known device
    line = "x = f32[8] all-reduce(f32[8] %a), replica_groups={}"
    assert _replica_groups(line, pod) == [sorted(pod)]
    # no attribute at all
    assert _replica_groups("x = f32[8] add(f32[8] %a)", pod) is None


def test_roofline_terms():
    # all inputs PER-DEVICE except model_flops (global)
    r = Roofline(flops=197e12, hbm_bytes=819e9, collective_bytes=50e9,
                 n_chips=256, model_flops=197e12 * 128)
    assert abs(r.compute_s - 1.0) < 1e-9        # hlo == model/chip/2 < hlo
    assert abs(r.memory_s - 1.0) < 1e-9
    assert abs(r.collective_s - 1.0) < 1e-9
    assert abs(r.useful_fraction - 0.5) < 1e-9
    assert abs(r.roofline_fraction - 0.5) < 1e-9
    # compute floor kicks in when the scan-undercounted HLO flops are low
    r2 = Roofline(flops=1e9, hbm_bytes=819e9, collective_bytes=0,
                  n_chips=256, model_flops=197e12 * 256)
    assert abs(r2.compute_s - 1.0) < 1e-9
    assert r2.dominant in ("compute", "memory")
    assert abs(r2.useful_fraction - 1.0) < 1e-9


def test_autotune_prefers_locality_for_small_messages():
    from repro.core.autotune import model_costs, pick_allgather
    pick = pick_allgather(p=4096, p_local=16, nbytes_per_rank=8,
                          machine="lassen")
    costs = model_costs(4096, 16, 8, "lassen")
    assert costs["locality_bruck"] < costs["bruck"]
    assert pick in ("locality_bruck", "multilane")
