"""Checkpoint store: atomic commit, GC, async manager, mismatch detection."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)

TREE = {"a": jnp.arange(12.0).reshape(3, 4), "b": [jnp.ones(5), jnp.zeros(2)],
        "c": {"d": jnp.asarray(3)}}


@pytest.fixture()
def ckdir(tmp_path):
    return str(tmp_path / "ck")


def test_save_restore_roundtrip(ckdir):
    save_checkpoint(ckdir, 7, TREE)
    assert latest_step(ckdir) == 7
    step, tree = restore_checkpoint(ckdir, TREE)
    assert step == 7
    for a, b in zip(jax.tree.leaves(TREE), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_gc_keeps_last_k(ckdir):
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(ckdir, s, TREE, keep_last=2)
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckdir)
                   if d.startswith("step_"))
    assert steps == [4, 5]


def test_incomplete_checkpoint_ignored(ckdir):
    save_checkpoint(ckdir, 1, TREE)
    # simulate a crash mid-write: directory without manifest
    os.makedirs(os.path.join(ckdir, "step_00000002"))
    assert latest_step(ckdir) == 1
    step, _ = restore_checkpoint(ckdir, TREE)
    assert step == 1


def test_leaf_count_mismatch_raises(ckdir):
    save_checkpoint(ckdir, 1, TREE)
    with pytest.raises(AssertionError, match="architecture mismatch"):
        restore_checkpoint(ckdir, {"only": jnp.ones(3)})


def test_async_manager(ckdir):
    mgr = CheckpointManager(ckdir, keep_last=3)
    for s in (10, 20):
        mgr.save(s, TREE)
    mgr.wait()
    assert latest_step(ckdir) == 20
    res = mgr.restore(TREE)
    assert res is not None and res[0] == 20


def test_restore_none_when_empty(ckdir):
    assert restore_checkpoint(ckdir, TREE) is None
