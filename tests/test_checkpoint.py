"""Checkpoint store v2: atomic commit, GC pinning, LATEST resolution, async
manager health, typed errors, sharded save + resharding restore."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import telemetry
from repro.checkpoint import (CheckpointError, CheckpointManager,
                              committed_step, latest_step, read_manifest,
                              restore_checkpoint, save_checkpoint)
from repro.faults import FaultHarness, FaultSpec

TREE = {"a": jnp.arange(12.0).reshape(3, 4), "b": [jnp.ones(5), jnp.zeros(2)],
        "c": {"d": jnp.asarray(3)}}


@pytest.fixture()
def ckdir(tmp_path):
    return str(tmp_path / "ck")


@pytest.fixture()
def registry():
    prev = telemetry.set_registry(telemetry.MetricsRegistry())
    yield telemetry.get_registry()
    telemetry.set_registry(prev)


def test_save_restore_roundtrip(ckdir):
    save_checkpoint(ckdir, 7, TREE)
    assert latest_step(ckdir) == 7
    step, tree = restore_checkpoint(ckdir, TREE)
    assert step == 7
    for a, b in zip(jax.tree.leaves(TREE), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_manifest_v2_schema(ckdir):
    save_checkpoint(ckdir, 3, TREE, extra={"note": "x"})
    step, manifest = read_manifest(ckdir)
    assert step == 3 and manifest["schema"] == 2
    assert manifest["n_leaves"] == len(jax.tree.leaves(TREE))
    paths = [l["path"] for l in manifest["leaves"]]
    assert "a" in paths and "c/d" in paths      # named leaves, not indices
    for leaf in manifest["leaves"]:
        for chunk in leaf["chunks"]:
            assert all(len(f["sha256"]) == 64 for f in chunk["files"])
    assert manifest["extra"] == {"note": "x"}


def test_gc_keeps_last_k(ckdir):
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(ckdir, s, TREE, keep_last=2)
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckdir)
                   if d.startswith("step_"))
    assert steps == [4, 5]


def test_gc_never_deletes_latest_target(ckdir):
    """Regression: after a rollback (recovery re-saves at a LOWER step than
    the on-disk tail), _gc kept the numerically-last steps and unlinked the
    one LATEST had just been pointed at — a dangling committed pointer."""
    for s in (5, 6, 7):
        save_checkpoint(ckdir, s, TREE, keep_last=3)
    save_checkpoint(ckdir, 4, TREE, keep_last=2)   # rollback save
    assert committed_step(ckdir) == 4
    step, _ = restore_checkpoint(ckdir, TREE)
    assert step == 4                               # pinned, not gc'd
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckdir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    assert 4 in steps


def test_incomplete_checkpoint_ignored(ckdir):
    save_checkpoint(ckdir, 1, TREE)
    # simulate a crash mid-write: directory without manifest
    os.makedirs(os.path.join(ckdir, "step_00000002"))
    assert latest_step(ckdir) == 1
    step, _ = restore_checkpoint(ckdir, TREE)
    assert step == 1


def test_leaf_count_mismatch_raises_typed(ckdir):
    save_checkpoint(ckdir, 1, TREE)
    with pytest.raises(CheckpointError, match="architecture mismatch"):
        restore_checkpoint(ckdir, {"only": jnp.ones(3)})


def test_shape_mismatch_names_leaf(ckdir):
    save_checkpoint(ckdir, 1, TREE)
    bad = dict(TREE)
    bad["a"] = jnp.ones((2, 2))
    with pytest.raises(CheckpointError, match="'a'"):
        restore_checkpoint(ckdir, bad)


def test_restore_prefers_committed_latest(ckdir, registry):
    save_checkpoint(ckdir, 1, TREE)
    save_checkpoint(ckdir, 2, TREE)
    # a crash between commit-rename and the LATEST replace leaves a newer
    # complete dir with a stale pointer: restore follows the POINTER
    with open(os.path.join(ckdir, "LATEST"), "w") as f:
        f.write("1")
    step, _ = restore_checkpoint(ckdir, TREE)
    assert step == 1
    assert registry.counter("checkpoint/latest_fallbacks").value == 0


def test_missing_latest_falls_back_to_scan(ckdir, registry):
    save_checkpoint(ckdir, 1, TREE)
    save_checkpoint(ckdir, 2, TREE)
    os.remove(os.path.join(ckdir, "LATEST"))
    step, _ = restore_checkpoint(ckdir, TREE)
    assert step == 2
    assert registry.counter("checkpoint/latest_fallbacks").value == 1


def test_dangling_latest_falls_back_to_scan(ckdir, registry):
    save_checkpoint(ckdir, 1, TREE)
    with open(os.path.join(ckdir, "LATEST"), "w") as f:
        f.write("9999")                       # gc'd / never-written target
    step, _ = restore_checkpoint(ckdir, TREE)
    assert step == 1
    assert registry.counter("checkpoint/latest_fallbacks").value == 1


def test_corrupt_manifest_falls_back_to_previous_step(ckdir, registry):
    save_checkpoint(ckdir, 1, TREE)
    save_checkpoint(ckdir, 2, TREE)
    with open(os.path.join(ckdir, "step_00000002", "manifest.json"),
              "w") as f:
        f.write('{"schema": 2, "n_lea')       # torn JSON
    step, tree = restore_checkpoint(ckdir, TREE)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(tree["a"]),
                                  np.asarray(TREE["a"]))
    assert registry.counter("checkpoint/manifest_fallbacks").value >= 1


def test_missing_chunk_falls_back_to_previous_step(ckdir, registry):
    save_checkpoint(ckdir, 1, TREE)
    save_checkpoint(ckdir, 2, TREE)
    d = os.path.join(ckdir, "step_00000002")
    for name in os.listdir(d):
        if name.startswith("leaf_0000"):
            os.remove(os.path.join(d, name))
    step, _ = restore_checkpoint(ckdir, TREE)
    assert step == 1
    assert registry.counter("checkpoint/manifest_fallbacks").value >= 1


def test_hash_mismatch_detected(ckdir, registry):
    save_checkpoint(ckdir, 1, TREE)
    d = os.path.join(ckdir, "step_00000001")
    victim = sorted(n for n in os.listdir(d) if n.startswith("leaf_"))[0]
    with open(os.path.join(d, victim), "r+b") as f:
        f.seek(-1, os.SEEK_END)
        f.write(b"\x00")
    # single replica on a pod-less tree: corruption is unrecoverable and
    # there is no previous step — typed error, not garbage data
    with pytest.raises(CheckpointError):
        restore_checkpoint(ckdir, TREE)
    assert registry.counter("checkpoint/hash_failures").value >= 1


def test_v1_manifest_back_compat(ckdir):
    """Pre-v2 run directories (leaf_<i>.npy + flat manifest) stay readable."""
    d = os.path.join(ckdir, "step_00000005")
    os.makedirs(d)
    leaves, treedef = jax.tree.flatten(TREE)
    for i, leaf in enumerate(leaves):
        np.save(os.path.join(d, f"leaf_{i}.npy"), np.asarray(leaf))
    manifest = {"step": 5, "n_leaves": len(leaves), "treedef": str(treedef),
                "dtypes": [str(np.asarray(l).dtype) for l in leaves],
                "shapes": [list(np.asarray(l).shape) for l in leaves],
                "extra": {}}
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    step, tree = restore_checkpoint(ckdir, TREE)
    assert step == 5
    for a, b in zip(jax.tree.leaves(TREE), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_manager(ckdir):
    mgr = CheckpointManager(ckdir, keep_last=3)
    for s in (10, 20):
        mgr.save(s, TREE)
    mgr.wait()
    assert latest_step(ckdir) == 20
    assert mgr.healthy() and mgr.health.state == "ok"
    assert mgr.health.last_saved_step == 20
    res = mgr.restore(TREE)
    assert res is not None and res[0] == 20


def test_manager_failure_does_not_lose_next_snapshot(ckdir, registry):
    """The satellite-1 regression: a pending writer error used to escape
    from inside the next save() (via self.wait()), aborting it before the
    new snapshot was enqueued."""
    faults = FaultHarness([FaultSpec(point="checkpoint/manifest_write",
                                     mode="io_error", at=0)])
    mgr = CheckpointManager(ckdir, retries=0, faults=faults)
    mgr.save(1, TREE)
    mgr._join()
    assert not mgr.healthy() and mgr.health.state == "failed"
    assert mgr.health.failures == 1
    mgr.save(2, TREE)              # must not raise, must not be lost
    mgr._join()
    assert latest_step(ckdir) == 2
    assert mgr.healthy() and mgr.health.state == "degraded"
    assert mgr.health.last_saved_step == 2
    assert registry.counter("checkpoint/save_failures").value == 1
    with pytest.raises(OSError):   # the end-of-run contract still surfaces
        mgr.wait()


def test_manager_retries_transient_io_error(ckdir, registry):
    # exactly one injected io_error: the first attempt fails, the retry
    # commits — no failure recorded, health degraded (a retry fired)
    faults = FaultHarness([FaultSpec(point="checkpoint/chunk_write",
                                     mode="io_error", at=0)])
    mgr = CheckpointManager(ckdir, retries=3, backoff_s=0.001, faults=faults)
    mgr.save(1, TREE, blocking=True)
    assert latest_step(ckdir) == 1
    assert mgr.healthy() and mgr.health.state == "degraded"
    assert mgr.health.retries == 1
    assert registry.counter("checkpoint/retries").value == 1
    assert registry.counter("checkpoint/save_failures").value == 0


def test_restore_none_when_empty(ckdir):
    assert restore_checkpoint(ckdir, TREE) is None
    assert committed_step(ckdir) is None


# ---------------------------------------------------------------------------
# sharded save + resharding restore (multi-device subprocesses)
# ---------------------------------------------------------------------------
SHARDED_CODE = r"""
import json, os
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro import telemetry
from repro.checkpoint import read_manifest, restore_checkpoint, save_checkpoint

reg = telemetry.set_registry(telemetry.MetricsRegistry()) and None
reg = telemetry.get_registry()
devs = np.array(jax.devices()[:8]).reshape(2, 4)
mesh = Mesh(devs, ("pod", "data"))
sh = NamedSharding(mesh, P(("pod", "data")))
rep = NamedSharding(mesh, P())
tree = {
    "w": jax.device_put(jnp.arange(16 * 6, dtype=jnp.float32).reshape(16, 6), sh),
    "b": jax.device_put(jnp.arange(8, dtype=jnp.float32), rep),
    "step": jax.device_put(jnp.asarray(3), rep),
}
ck = os.environ["CKDIR"]
save_checkpoint(ck, 1, tree)

step, manifest = read_manifest(ck)
w_meta = [l for l in manifest["leaves"] if l["path"] == "w"][0]
assert w_meta["sharded"] and len(w_meta["chunks"]) == 8, w_meta
assert manifest["replication"] == 2, manifest["replication"]
pods = set()
for chunk in w_meta["chunks"]:
    assert len(chunk["files"]) == 2                 # home + 1 replica
    assert chunk["files"][0]["pod"] != chunk["files"][1]["pod"]
    pods.add(chunk["files"][0]["pod"])
assert pods == {0, 1}, pods

# no host-gather: the largest host allocation during save is ONE shard of
# w — 16*6/8 floats — not the full 16*6 leaf
g = reg.snapshot()["gauges"]
shard_bytes = 16 * 6 * 4 // 8
assert g["checkpoint/max_chunk_bytes"] == shard_bytes, g
assert g["checkpoint/max_chunk_bytes"] < 16 * 6 * 4
assert g["checkpoint/replication"] == 2
assert g["checkpoint/replication_model_s"] > 0

# restore 1: same layout, values exact
_, t1 = restore_checkpoint(ck, tree, shardings={"w": sh, "b": rep, "step": rep})
np.testing.assert_array_equal(np.asarray(t1["w"]), np.asarray(tree["w"]))

# restore 2: RESHARD 2x4 -> flat(8) ('data',)
flat = Mesh(np.array(jax.devices()[:8]), ("data",))
fsh = NamedSharding(flat, P("data"))
frep = NamedSharding(flat, P())
_, t2 = restore_checkpoint(ck, tree, shardings={"w": fsh, "b": frep, "step": frep})
np.testing.assert_array_equal(np.asarray(t2["w"]), np.asarray(tree["w"]))
assert t2["w"].sharding.is_equivalent_to(fsh, 2)

# restore 3: RESHARD 2x4 -> 4x2 (different pod count, q=4)
mesh4 = Mesh(np.array(jax.devices()[:8]).reshape(4, 2), ("pod", "data"))
qsh = NamedSharding(mesh4, P(("pod", "data")))
qrep = NamedSharding(mesh4, P())
_, t3 = restore_checkpoint(ck, tree, shardings={"w": qsh, "b": qrep, "step": qrep})
np.testing.assert_array_equal(np.asarray(t3["w"]), np.asarray(tree["w"]))

# restore 4: LOST POD — delete every pod-0 home file; replicas recover it
d = os.path.join(ck, f"step_{1:08d}")
lost = 0
for leaf in manifest["leaves"]:
    for chunk in leaf["chunks"]:
        f0 = chunk["files"][0]
        if f0["pod"] == 0:
            os.remove(os.path.join(d, f0["file"]))
            lost += 1
assert lost > 0
_, t4 = restore_checkpoint(ck, tree, shardings={"w": sh, "b": rep, "step": rep})
np.testing.assert_array_equal(np.asarray(t4["w"]), np.asarray(tree["w"]))
assert reg.counter("checkpoint/replica_reads").value >= lost
print("SHARDED_OK")
"""


@pytest.mark.slow
def test_sharded_save_reshard_restore(subproc, tmp_path):
    env_code = f"import os; os.environ['CKDIR'] = {str(tmp_path / 'ck')!r}\n"
    out = subproc(env_code + SHARDED_CODE, devices=8)
    assert "SHARDED_OK" in out


NONPOW_CODE = r"""
import os
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.checkpoint import restore_checkpoint, save_checkpoint

# save on 2x6 (q=2), restore on 3x4 (q=3, non-power pod count) and 6x2:
# the restart matrix cell the allgatherv adaptation makes legal
devs = np.array(jax.devices()[:12])
mesh_a = Mesh(devs.reshape(2, 6), ("pod", "data"))
tree = {"w": jax.device_put(
    jnp.arange(24 * 5, dtype=jnp.float32).reshape(24, 5),
    NamedSharding(mesh_a, P(("pod", "data"))))}
ck = os.environ["CKDIR"]
save_checkpoint(ck, 1, tree)
for shape, q in (((3, 4), 3), ((6, 2), 6), ((12,), None)):
    names = ("pod", "data") if len(shape) == 2 else ("data",)
    m = Mesh(devs.reshape(shape), names)
    sh = NamedSharding(m, P("data" if len(shape) == 1 else ("pod", "data")))
    _, t = restore_checkpoint(ck, tree, shardings={"w": sh})
    np.testing.assert_array_equal(np.asarray(t["w"]), np.asarray(tree["w"]))
print("NONPOW_OK")
"""


@pytest.mark.slow
def test_restore_arbitrary_pod_counts(subproc, tmp_path):
    env_code = f"import os; os.environ['CKDIR'] = {str(tmp_path / 'ck')!r}\n"
    out = subproc(env_code + NONPOW_CODE, devices=12)
    assert "NONPOW_OK" in out
