"""Per-kernel validation: shape/dtype sweeps, interpret-mode Pallas vs the
pure-jnp oracle (assignment deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_stats.ops import resolve_impl
from repro.kernels.decode_stats.ref import decode_stats_accumulate_ref
from repro.kernels.decode_stats.stats import decode_stats_accumulate_pallas
from repro.kernels.flash_attention.flash import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.rmsnorm.rmsnorm import rmsnorm_pallas
from repro.kernels.ssd.ref import ssd_ref
from repro.kernels.ssd.ssd import ssd_pallas

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(8, 128), (3, 7, 256), (2, 64, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(shape, dtype):
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, shape, dtype)
    sc = (jax.random.normal(jax.random.PRNGKey(1), (shape[-1],)) * 0.2)
    out = rmsnorm_pallas(x, sc, interpret=True)
    ref = rmsnorm_ref(x, sc)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------
CASES = [
    dict(causal=True),
    dict(causal=False),
    dict(causal=True, window=32),
    dict(causal=True, chunk=32),
    dict(causal=True, cap=30.0),
    dict(causal=True, window=16, cap=50.0),
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_variants(case, dtype):
    B, S, H, KV, D = 2, 128, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, D), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, D), dtype)
    out = flash_attention(q, k, v, block_q=32, block_k=32, interpret=True,
                          **case)
    ref = attention_ref(q, k, v, **case)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=5 * TOL[dtype], rtol=5 * TOL[dtype])


@pytest.mark.parametrize("shape", [(1, 64, 2, 1, 32), (2, 256, 8, 8, 16),
                                   (1, 96, 6, 3, 64)])
def test_flash_shapes(shape):
    B, S, H, KV, D = shape
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))
    out = flash_attention(q, k, v, block_q=32, block_k=32, interpret=True)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


def test_flash_uneven_lengths_fall_back_single_block():
    B, S, H, KV, D = 1, 48, 2, 2, 32      # S not divisible by 32
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))
    out = flash_attention(q, k, v, block_q=32, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(attention_ref(q, k, v)),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# Fused decode partial-stat accumulation (the serve overlap region body)
# ---------------------------------------------------------------------------
DECODE_STATS_CASES = [
    dict(pos=17),                        # plain causal prefix
    dict(pos=100, window=32),            # sliding window
    dict(pos=63, chunk=32),              # chunked-local
    dict(pos=200, ring=True),            # ring cache (slot reuse)
    dict(pos=3, slot_offset=512, total_len=1024),   # fully-masked shard
]


@pytest.mark.parametrize("case", DECODE_STATS_CASES)
@pytest.mark.parametrize("dims", [(1, 8, 4, 64, 128), (2, 6, 2, 32, 96)])
def test_decode_stats_kernel(case, dims):
    from repro.models.attention import decode_stats_scores, decode_partial_stats
    B, H, KV, D, L = dims
    case = dict(case)
    pos = case.pop("pos")
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, 1, H, D))
    k = jax.random.normal(ks[1], (B, L, KV, D))
    v = jax.random.normal(ks[2], (B, L, KV, D))
    case.setdefault("total_len", L)
    s, mask = decode_stats_scores(q, k, pos, **case)
    m = jnp.max(s, axis=-1)
    o_ref, l_ref = decode_stats_accumulate_ref(s, m, v)
    o_pl, l_pl = decode_stats_accumulate_pallas(s, m, v, block_k=32,
                                                interpret=True)
    np.testing.assert_allclose(np.asarray(o_pl), np.asarray(o_ref),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(l_pl), np.asarray(l_ref),
                               atol=2e-5, rtol=2e-5)
    if case.get("slot_offset"):          # fully masked: exact zeros
        assert float(jnp.abs(o_pl).max()) == 0.0
        assert float(jnp.abs(l_pl).max()) == 0.0
        return
    # the composed jnp oracle (what the serve region computes without the
    # kernel) agrees too — one scoring/masking path, no drift
    o_j, _, l_j = decode_partial_stats(q, k, v, pos, **case)
    np.testing.assert_allclose(np.asarray(o_pl), np.asarray(o_j),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(l_pl), np.asarray(l_j),
                               atol=2e-5, rtol=2e-5)


def test_decode_stats_impl_resolution():
    assert resolve_impl("jnp") == "jnp"
    assert resolve_impl("pallas_interpret") == "pallas_interpret"
    assert resolve_impl("auto") in ("jnp", "pallas")   # pallas iff real TPU
    with pytest.raises(ValueError):
        resolve_impl("cuda")


# ---------------------------------------------------------------------------
# SSD (Mamba2)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dims", [(2, 128, 4, 16, 1, 32), (1, 64, 2, 8, 2, 16),
                                  (2, 96, 6, 32, 3, 8)])
def test_ssd_kernel(dims):
    Bt, S, H, P, G, N = dims
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (Bt, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bt, S, H)))
    A = -jnp.exp(jax.random.uniform(ks[2], (H,)))
    B = jax.random.normal(ks[3], (Bt, S, G, N)) * 0.5
    C = jax.random.normal(ks[4], (Bt, S, G, N)) * 0.5
    Q = 32
    y1, h1 = ssd_pallas(x, dt, A, B, C, Q=Q, interpret=True)
    y2, h2 = ssd_ref(x, dt, A, B, C, Q=Q)
    scale = float(jnp.max(jnp.abs(y2))) + 1e-6
    assert float(jnp.max(jnp.abs(y1 - y2))) / scale < 1e-5
    assert float(jnp.max(jnp.abs(h1 - h2))) < 1e-4


def test_ssd_chunk_invariance():
    """Same result whatever the chunk size (the state carry is exact)."""
    Bt, S, H, P, G, N = 1, 128, 2, 8, 1, 16
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    x = jax.random.normal(ks[0], (Bt, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bt, S, H)))
    A = -jnp.exp(jax.random.uniform(ks[2], (H,)))
    B = jax.random.normal(ks[3], (Bt, S, G, N)) * 0.5
    C = jax.random.normal(ks[4], (Bt, S, G, N)) * 0.5
    outs = [ssd_pallas(x, dt, A, B, C, Q=q, interpret=True)[0]
            for q in (16, 32, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=1e-4, rtol=1e-4)
