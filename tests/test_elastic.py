"""Elastic fleet operations, end to end (subprocess, 8/12 devices).

The contract under test (DESIGN.md §10):

* kill-and-resume: a hard mid-run kill (``ProcessKilled`` — BaseException,
  no recovery path may swallow it) loses at most the steps since the last
  commit; restarting on a *different* pod layout of the same DP size
  ((2,4) → (4,2) → flat) replays the remaining loss trajectory **bitwise**
  (``grad_sync="flat_psum"`` compiles to one psum over the concatenated
  axes, and every layout reshapes the same device order → identical
  replica groups);
* resharding restart across *pod counts*: the step-4 checkpoint written on
  (2,4)/fsdp=False restores onto (3,4)/fsdp=True — q=3, Algorithm-2
  territory — with **bitwise-identical state** (full-leaf digests match)
  and a loss trajectory that tracks the baseline (the DP=12 reduction
  order differs, so the tail is allclose, not bitwise);
* graceful preemption: the signal triggers one final blocking save and a
  clean drain (status "preempted"); the restart resumes exactly there and
  the joint trajectory is bitwise-identical to an uninterrupted run;
* serve drain/restore: ``Engine.drain(checkpoint_dir=...)`` suspends every
  in-flight request (KV state included) and a fresh engine's ``resume``
  replays them to the *same tokens* the uninterrupted engine produces —
  on both the batch-sharded and the sequence-sharded (locality-combine)
  layouts.
"""
import os
import re

import numpy as np
import pytest

pytestmark = pytest.mark.slow


# ---------------------------------------------------------------------------
# leg 1 (8 devices): baseline + kill/resume across layouts + preemption
# ---------------------------------------------------------------------------
BITWISE_CODE = r"""
import dataclasses, os
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.checkpoint import committed_step, restore_checkpoint
from repro.faults import ProcessKilled
from repro.runtime import FaultInjector, PreemptionSignal
from repro.train import Trainer, TrainerConfig

CKDIR = os.environ["ELASTIC_CKDIR"]
# dims divisible by every composite span used across the legs (8 and 12)
cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=2,
                          d_model=96, d_ff=192, vocab_size=384,
                          dtype=jnp.float32)
def tcfg(ckpt_dir, **kw):
    base = dict(steps=8, seq_len=32, global_batch=24, ckpt_every=2,
                keep_last=4, log_every=100, grad_sync="flat_psum",
                fsdp=False, lr=3e-3, comm_telemetry=False)
    base.update(kw)
    return TrainerConfig(ckpt_dir=ckpt_dir, **base)

def losses(tr):
    return [m["loss"] for m in tr.metrics_history]

def hexes(ls):
    return " ".join(float(l).hex() for l in ls)

def mesh(shape):
    m = jax.make_mesh(shape, ("pod", "data"))
    jax.set_mesh(m)
    return m

# --- baseline: uninterrupted (2,4) run --------------------------------
tr = Trainer(cfg, mesh((2, 4)), tcfg(CKDIR + "/base"), log=lambda s: None)
out = tr.run()
assert out["status"] == "complete", out["status"]
base = losses(tr)
print("BASE", hexes(base))

# --- hard kill at step 5 on (2,4): commits at 2 and 4 survive ---------
kdir = CKDIR + "/kill"
tr = Trainer(cfg, mesh((2, 4)), tcfg(kdir),
             fault_injector=FaultInjector(kill_at_steps=(5,)),
             log=lambda s: None)
try:
    tr.run()
except ProcessKilled as e:
    print("KILLED", tr.step, e)
else:
    raise AssertionError("kill did not fire")
tr.ckpt.wait()   # quiesce the async writer: the in-process "kill" leaves
                 # it alive, and committed_step below must not race it
assert committed_step(kdir) == 4, committed_step(kdir)

# --- resume the killed run on (4,2): auto-restore, bitwise tail -------
tr = Trainer(cfg, mesh((4, 2)), tcfg(kdir), log=lambda s: None)
assert tr.step == 4, tr.step
out = tr.fit(resume="auto")
assert out["status"] == "complete" and out["steps"] == 8, out
r42 = losses(tr)
assert hexes(r42) == hexes(base[4:]), (r42, base[4:])
print("RESUME42_BITWISE_OK")

# --- rollback-resume the same dir on flat(8): explicit step, bitwise --
tr = Trainer(cfg, mesh((1, 8)), tcfg(kdir), log=lambda s: None)
out = tr.fit(resume=4)
assert out["steps"] == 8, out
rflat = losses(tr)
assert hexes(rflat) == hexes(base[4:]), (rflat, base[4:])
print("RESUMEFLAT_BITWISE_OK")

# step 4 must still be on disk for the 12-device resharding leg, and its
# full-leaf digests are the cross-layout bitwise ground truth
import hashlib
m24 = mesh((2, 4))
s, tree = restore_checkpoint(kdir, tr.artifacts.abstract_state,
                             step=4, shardings=tr.artifacts.state_shardings)
assert s == 4
import jax.tree_util as jtu
for path, leaf in jtu.tree_flatten_with_path(tree)[0]:
    h = hashlib.sha256(np.ascontiguousarray(
        jax.device_get(leaf)).tobytes()).hexdigest()
    print("DIGEST4", jtu.keystr(path), h)

# --- graceful preemption at step 3, restart resumes exactly there -----
pdir = CKDIR + "/preempt"
tr = Trainer(cfg, mesh((2, 4)), tcfg(pdir),
             preemption=PreemptionSignal(at_steps=(3,)), log=lambda s: None)
out = tr.run()
assert out["status"] == "preempted" and out["steps"] == 3, out
assert any(e.kind == "preemption" for e in out["events"])
assert committed_step(pdir) == 3, committed_step(pdir)
pre = losses(tr)
tr = Trainer(cfg, mesh((2, 4)), tcfg(pdir), log=lambda s: None)
assert tr.step == 3, tr.step
out = tr.fit(resume="auto")
assert out["status"] == "complete" and out["steps"] == 8, out
assert hexes(pre + losses(tr)) == hexes(base), (pre, losses(tr), base)
print("PREEMPT_BITWISE_OK")
"""


# ---------------------------------------------------------------------------
# leg 2 (12 devices): reshard the step-4 checkpoint onto q=3 pods + FSDP
# ---------------------------------------------------------------------------
RESHARD_CODE = r"""
import dataclasses, hashlib, os
import jax, jax.numpy as jnp, numpy as np
import jax.tree_util as jtu
from repro import configs
from repro.train import Trainer, TrainerConfig

CKDIR = os.environ["ELASTIC_CKDIR"]
cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=2,
                          d_model=96, d_ff=192, vocab_size=384,
                          dtype=jnp.float32)
mesh = jax.make_mesh((3, 4), ("pod", "data"))
jax.set_mesh(mesh)
tcfg = TrainerConfig(steps=8, seq_len=32, global_batch=24, ckpt_every=100,
                     keep_last=4, log_every=100, grad_sync="locality",
                     fsdp=True, lr=3e-3, comm_telemetry=False,
                     ckpt_dir=CKDIR + "/kill")
tr = Trainer(cfg, mesh, tcfg, log=lambda s: None)
out = tr.fit(resume=4)           # explicit rollback to the killed commit
assert out["steps"] == 8, out

# the restored-then-resaved state is sharded (3,4)+FSDP now; digest the
# assembled full leaves of the ORIGINAL step-4 restore for the driver
from repro.checkpoint import restore_checkpoint
s, tree = restore_checkpoint(CKDIR + "/kill", tr.artifacts.abstract_state,
                             step=4, shardings=tr.artifacts.state_shardings)
assert s == 4
for path, leaf in jtu.tree_flatten_with_path(tree)[0]:
    assert leaf.sharding.mesh.shape.get("pod") == 3, leaf.sharding
    h = hashlib.sha256(np.ascontiguousarray(
        jax.device_get(leaf)).tobytes()).hexdigest()
    print("DIGEST4", jtu.keystr(path), h)
for m in tr.metrics_history:
    print("RLOSS", float(m["loss"]).hex())
print("RESHARD12_OK")
"""


# ---------------------------------------------------------------------------
# leg 3 (8 devices): serve graceful drain -> fresh-engine resume
# ---------------------------------------------------------------------------
SERVE_CODE = r"""
import dataclasses, os
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.checkpoint import read_manifest
from repro.serve import Engine, Request, ServeSpec, StepClock

CKDIR = os.environ["ELASTIC_CKDIR"]
mesh = jax.make_mesh((2, 4), ("pod", "data"))
jax.set_mesh(mesh)
cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=2,
                          dtype=jnp.float32)
from repro.models import transformer
params = transformer.init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)

# --- batch-sharded continuous batching --------------------------------
B, S = 8, 6
spec = ServeSpec(batch=B, cache_len=32, page_len=8)
prompts = rng.integers(0, cfg.vocab_size, (B, S), np.int32)
budgets = [2] + [6] * (B - 1)        # rid 0 finishes BEFORE the suspend

def submit_all(eng):
    return [eng.submit(Request(tokens=prompts[i], max_new=budgets[i],
                               arrival_s=0.0)) for i in range(B)]

eng0 = Engine(cfg, mesh, params, spec, clock=StepClock())
rids = submit_all(eng0)
ref = eng0.drain()

ckdir = CKDIR + "/serve_batch"
eng1 = Engine(cfg, mesh, params, spec, clock=StepClock())
submit_all(eng1)
eng1.step(); eng1.step()
partial = eng1.drain(checkpoint_dir=ckdir)
assert set(partial) == {0}, set(partial)      # only rid 0 already done
assert np.array_equal(partial[0].tokens, ref[0].tokens)

step, manifest = read_manifest(ckdir)
assert manifest["extra"]["kind"] == "serve_suspend"
assert len(manifest["extra"]["active"]) == B - 1

eng2 = Engine(cfg, mesh, params, spec, clock=StepClock())
assert eng2.resume(ckdir) == B - 1
res = eng2.drain()
for rid in rids[1:]:
    assert np.array_equal(ref[rid].tokens, res[rid].tokens), \
        (rid, ref[rid].tokens, res[rid].tokens)
print("SERVE_BATCH_RESUME_OK")

# --- sequence-sharded (locality combine): active + queued replay ------
cfg1 = dataclasses.replace(cfg, n_layers=1)
params1 = transformer.init_params(jax.random.PRNGKey(0), cfg1)
spec1 = ServeSpec(batch=1, cache_len=32, combine="locality")
p0 = rng.integers(0, cfg1.vocab_size, 6, np.int32)
p1 = rng.integers(0, cfg1.vocab_size, 5, np.int32)

def submit_two(eng):
    a = eng.submit(Request(tokens=p0, max_new=5, arrival_s=0.0))
    b = eng.submit(Request(tokens=p1, max_new=4, arrival_s=0.0))
    return a, b

eng0 = Engine(cfg1, mesh, params1, spec1, clock=StepClock())
r0, r1 = submit_two(eng0)
ref = eng0.drain()

ckdir = CKDIR + "/serve_seq"
eng1 = Engine(cfg1, mesh, params1, spec1, clock=StepClock())
submit_two(eng1)
eng1.step(); eng1.step()             # r0 mid-decode, r1 still queued
eng1.drain(checkpoint_dir=ckdir)
_, manifest = read_manifest(ckdir)
assert len(manifest["extra"]["active"]) == 1
assert len(manifest["extra"]["queued"]) == 1

eng2 = Engine(cfg1, mesh, params1, spec1, clock=StepClock())
assert eng2.resume(ckdir) == 2
res = eng2.drain()
for rid in (r0, r1):
    assert np.array_equal(ref[rid].tokens, res[rid].tokens), \
        (rid, ref[rid].tokens, res[rid].tokens)
print("SERVE_SEQ_RESUME_OK")
"""


def _hex_losses(out: str, tag: str) -> list[float]:
    for line in out.splitlines():
        if line.startswith(tag + " "):
            return [float.fromhex(h) for h in line.split()[1:]]
    raise AssertionError(f"no {tag} line in:\n{out}")


def _digests(out: str) -> dict[str, str]:
    return dict(re.findall(r"^DIGEST4 (\S+) ([0-9a-f]{64})$", out, re.M))


def test_kill_resume_reshard_bitwise(subproc, tmp_path):
    """The full elastic matrix: kill on (2,4) → bitwise resume on (4,2)
    and flat(8); preemption → bitwise resume; the same checkpoint
    resharded onto 12 devices / q=3 pods with bitwise state and a
    tracking loss tail."""
    os.environ["ELASTIC_CKDIR"] = str(tmp_path)
    out8 = subproc(BITWISE_CODE, devices=8, timeout=1800)
    for marker in ("KILLED 5", "RESUME42_BITWISE_OK",
                   "RESUMEFLAT_BITWISE_OK", "PREEMPT_BITWISE_OK"):
        assert marker in out8, out8

    out12 = subproc(RESHARD_CODE, devices=12, timeout=1800)
    assert "RESHARD12_OK" in out12, out12

    # bitwise state across pod counts: every restored leaf's full-array
    # digest matches between the (2,4) and the (3,4)+FSDP restore
    d8, d12 = _digests(out8), _digests(out12)
    assert d8 and set(d8) == set(d12), (set(d8) ^ set(d12))
    mismatch = {k for k in d8 if d8[k] != d12[k]}
    assert not mismatch, mismatch

    # the resumed q=3 trajectory tracks the baseline: first loss is the
    # same forward on bitwise-identical state (ulp-level difference from
    # the DP=12 reduction order), the tail stays close
    base = _hex_losses(out8, "BASE")
    rloss = [float.fromhex(m.group(1))
             for m in re.finditer(r"^RLOSS (\S+)$", out12, re.M)]
    assert len(rloss) == 4, rloss
    np.testing.assert_allclose(rloss[0], base[4], rtol=1e-5)
    np.testing.assert_allclose(rloss, base[4:], rtol=5e-3, atol=1e-3)


def test_serve_drain_checkpoint_resume(subproc, tmp_path):
    """Engine.drain(checkpoint_dir=...) + fresh-engine resume replays
    every unfinished request to the uninterrupted engine's exact tokens
    (batch-sharded and sequence-sharded layouts)."""
    os.environ["ELASTIC_CKDIR"] = str(tmp_path)
    out = subproc(SERVE_CODE, devices=8, timeout=1800)
    assert "SERVE_BATCH_RESUME_OK" in out, out
    assert "SERVE_SEQ_RESUME_OK" in out, out
