"""Trainer integration (subprocess, 8 devices): learning, checkpoint
restart, fault recovery, straggler detection."""
import pytest

from repro.runtime import StepMonitor

pytestmark = pytest.mark.slow      # multi-device subprocess suite

TRAINER_CODE = r"""
import jax, shutil, dataclasses
from repro import configs
from repro.train import Trainer, TrainerConfig
from repro.runtime import FaultInjector

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
jax.set_mesh(mesh)
shutil.rmtree("/tmp/repro_ckpt_pytest", ignore_errors=True)
cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=2)
tcfg = TrainerConfig(steps=40, seq_len=32, global_batch=8, ckpt_every=10,
                     ckpt_dir="/tmp/repro_ckpt_pytest", log_every=100,
                     grad_sync="locality", lr=3e-3)
tr = Trainer(cfg, mesh, tcfg, fault_injector=FaultInjector(fail_at_steps=(13,)),
             log=lambda s: None)
out = tr.run()
assert out["steps"] == 40
assert any("injected failure" in e for e in out["events"])
assert any("restored checkpoint at step 10" in e for e in out["events"])
first = tr.metrics_history[0]["loss"]; last = tr.metrics_history[-1]["loss"]
assert last < first - 0.5, (first, last)

# cold restart resumes from the newest checkpoint
tr2 = Trainer(cfg, mesh, dataclasses.replace(tcfg, steps=45),
              log=lambda s: None)
assert tr2.step == 40
out2 = tr2.run()
assert out2["steps"] == 45
print("TRAINER_OK", first, last)
"""

ELASTIC_CODE = r"""
import jax, shutil, dataclasses
from repro import configs
from repro.train import Trainer, TrainerConfig

shutil.rmtree("/tmp/repro_ckpt_elastic", ignore_errors=True)
cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=2)
mesh8 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
jax.set_mesh(mesh8)
tcfg = TrainerConfig(steps=10, seq_len=32, global_batch=8, ckpt_every=10,
                     ckpt_dir="/tmp/repro_ckpt_elastic", log_every=100,
                     grad_sync="locality")
tr = Trainer(cfg, mesh8, tcfg, log=lambda s: None)
tr.run()
l8 = tr.metrics_history[-1]["loss"]

# elastic restart on a SMALLER mesh (lost a pod: 8 -> 4 chips)
mesh4 = jax.make_mesh((2, 2), ("data", "model"))
jax.set_mesh(mesh4)
tr2 = Trainer(cfg, mesh4, dataclasses.replace(tcfg, steps=14),
              log=lambda s: None)
assert tr2.step == 10       # restored across mesh shapes
out = tr2.run()
assert out["steps"] == 14
print("ELASTIC_OK")
"""


def test_trainer_learning_and_recovery(subproc):
    assert "TRAINER_OK" in subproc(TRAINER_CODE, devices=8)


def test_elastic_restart_smaller_mesh(subproc):
    assert "ELASTIC_OK" in subproc(ELASTIC_CODE, devices=8)


def test_straggler_monitor_unit():
    m = StepMonitor(k=3.0, warmup=2)
    events = []
    for dt in [1.0, 1.0, 1.0, 1.0, 1.0, 10.0, 1.0]:
        events.extend(m.record(dt))
    assert any("straggler" in e for e in events)
    assert sum("straggler" in e for e in events) == 1
