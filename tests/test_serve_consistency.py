"""Serving invariant: prefill + decode == full teacher-forced forward.

MoE archs are run with a capacity factor high enough that no token is
dropped (capacity dropping differs inherently between teacher-forcing and
single-token decode)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import encdec, transformer

B, S = 2, 24


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_prefill_decode_matches_full(arch):
    cfg = configs.get_smoke(arch)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)
    cfg = dataclasses.replace(cfg, n_layers=min(cfg.n_layers, 3))
    mod = encdec if cfg.family == "audio" else transformer
    rng = jax.random.PRNGKey(0)
    params = mod.init_params(rng, cfg)
    tokens = jax.random.randint(rng, (B, S + 2), 0, cfg.vocab_size)
    kw = {}
    if cfg.family == "audio":
        kw["frames"] = jax.random.normal(rng, (B, cfg.enc_seq, cfg.d_model),
                                         cfg.dtype)
    if cfg.family == "vlm":
        kw["img_embeds"] = jax.random.normal(
            rng, (B, cfg.n_img_tokens, cfg.d_model), cfg.dtype)

    full, _, _ = mod.forward(params, cfg, tokens, mode="train", **kw)
    lg, _, cache = mod.forward(params, cfg, tokens[:, :S], mode="prefill",
                               cache_len=S + 2, **kw)
    f32 = lambda t: t.astype(jnp.float32)
    assert float(jnp.max(jnp.abs(f32(full[:, S - 1:S]) - f32(lg)))) < 0.05
    for t in range(2):
        lg, _, cache = mod.forward(params, cfg, tokens[:, S + t:S + t + 1],
                                   cache=cache)
        err = float(jnp.max(jnp.abs(f32(full[:, S + t:S + t + 1]) - f32(lg))))
        assert err < 0.05, f"decode step {t}: err {err}"
    assert int(cache["pos"]) == S + 2
