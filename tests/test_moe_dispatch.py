"""MoE dispatch invariants (hypothesis property tests)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro import configs
from repro.models.moe import _dispatch_tables, capacity, moe_apply, moe_init

pytestmark = pytest.mark.hypothesis


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 16), st.integers(1, 4), st.integers(4, 32),
       st.integers(0, 10_000))
def test_dispatch_tables_invariants(E, K, S, seed):
    K = min(K, E)
    rng = np.random.default_rng(seed)
    idx = jnp.asarray(rng.integers(0, E, (S, K)))
    gates = jnp.asarray(rng.random((S, K)), jnp.float32)
    C = max(int(S * K / E * 1.25), K)
    tok_idx, weight = _dispatch_tables(idx, gates, E, S, K, C)
    tok_idx = np.asarray(tok_idx).reshape(E, C)
    weight = np.asarray(weight).reshape(E, C)
    # sentinel slots carry zero weight
    assert (weight[tok_idx == S] == 0).all()
    # each (token, k) assignment appears at most once overall
    real = tok_idx[tok_idx < S]
    for e in range(E):
        toks_e = tok_idx[e][tok_idx[e] < S]
        assert len(set(toks_e.tolist())) == len(toks_e) or K > 1
    # capacity respected per expert
    assert ((tok_idx < S).sum(axis=1) <= C).all()
    # a token routed to expert e lands in e's rows only with its own gate
    for e in range(E):
        for c in range(C):
            t = tok_idx[e, c]
            if t < S:
                assert weight[e, c] in np.asarray(gates[t]), (e, c)


def test_no_drop_recovers_dense_mixture():
    """With huge capacity, combining expert outputs with weights ≈ averaging
    the routed experts — cross-check against a direct dense computation."""
    cfg = dataclasses.replace(configs.get_smoke("qwen2-moe-a2.7b"),
                              capacity_factor=64.0, n_shared_experts=0)
    rng = jax.random.PRNGKey(0)
    p = moe_init(rng, cfg)
    x = jax.random.normal(rng, (2, 8, cfg.d_model), jnp.float32)
    out, aux = moe_apply(p, x, cfg)

    # dense reference: run every expert on every token, weight by router
    logits = (x @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    g = jnp.einsum("bsd,edf->bsef", x, p["gate"])
    u = jnp.einsum("bsd,edf->bsef", x, p["up"])
    y_all = jnp.einsum("bsef,efd->bsed", jax.nn.silu(g) * u, p["down"])
    mask = jax.nn.one_hot(idx, cfg.n_experts) * gates[..., None]
    ref = jnp.einsum("bsed,bse->bsd", y_all, mask.sum(2))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4,
                               rtol=1e-4)


def test_capacity_dropping_actually_drops():
    cfg = dataclasses.replace(configs.get_smoke("qwen2-moe-a2.7b"),
                              capacity_factor=0.1, n_shared_experts=0)
    rng = jax.random.PRNGKey(0)
    p = moe_init(rng, cfg)
    x = jax.random.normal(rng, (1, 32, cfg.d_model), jnp.float32)
    out, _ = moe_apply(p, x, cfg)
    # some token rows must be exactly zero (dropped -> residual only)
    norms = np.asarray(jnp.linalg.norm(out[0], axis=-1))
    assert (norms == 0.0).any()


def test_aux_loss_balanced_is_small():
    cfg = configs.get_smoke("qwen2-moe-a2.7b")
    E = cfg.n_experts
    # perfectly uniform router -> aux ≈ AUX_W (its minimum)
    rng = jax.random.PRNGKey(1)
    p = moe_init(rng, cfg)
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"])
    x = jax.random.normal(rng, (2, 64, cfg.d_model), jnp.float32)
    _, aux = moe_apply(p, x, cfg)
    from repro.models.moe import AUX_LOSS_W
    assert float(aux) == pytest.approx(AUX_LOSS_W, rel=0.3)


# ---------------------------------------------------------------------------
# Expert-parallel dispatch (subprocess: forced host devices).
#
# The locality path must be *numerically indistinguishable* from the flat XLA
# dispatch — same loss bitwise, same router/expert/shared-expert parameters
# after an optimizer step — while compiling to only collective-permutes with
# strictly fewer inter-pod messages. Cross-transport comparisons (tokens vs
# slots) are bitwise for the last/sole MoE layer only: a downstream MoE's dx
# re-associates fp sums through the residual stream, so multi-layer runs pin
# both sides to the slots transport (top_k=1, capacity_factor=1.0). Global
# grad clipping couples every leaf through grad_norm, so bitwise per-leaf
# checks use AdamW(clip_norm=0.0).
# ---------------------------------------------------------------------------

_EP_PRELUDE = r"""
import dataclasses
import repro  # noqa: F401
import jax, jax.numpy as jnp
import numpy as np
from repro import configs
from repro.data import SyntheticLM
from repro.optim.adamw import AdamW
from repro.train.step import make_train_step, init_state
from repro.train.trainer import custom_batch_specs

OPT = AdamW(clip_norm=0.0)

def run(cfg, mesh, md, gb=8, fsdp=False):
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, global_batch=gb,
                       seed=0)
    bspec = custom_batch_specs(cfg, gb, 32)
    art = make_train_step(cfg, mesh, grad_sync="locality", shape=bspec,
                          donate=False, fsdp=fsdp, optimizer=OPT,
                          moe_dispatch=md)
    state = init_state(cfg, mesh, art)
    batch = {k: jax.device_put(v, art.batch_shardings[k])
             for k, v in data.batch(0).items()}
    s2, m = art.step_fn(state, batch)
    return art, s2, m

def leafset(params, names):
    out = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(params):
        keys = tuple(getattr(p, "key", getattr(p, "name", "")) for p in path)
        if any(n in keys for n in names):
            out[keys] = np.asarray(leaf)
    return out

MOE_LEAVES = ("router", "gate", "up", "down", "shared")
"""

EP_BITWISE_Q2_CODE = _EP_PRELUDE + r"""
base = configs.get_smoke("qwen2-moe-a2.7b")
mesh = jax.make_mesh((2, 4), ("pod", "data"))
jax.set_mesh(mesh)

# single MoE layer: tokens-vs-slots loss + router/expert grads bitwise
cfg = dataclasses.replace(base, n_layers=1)
res = {md: run(cfg, mesh, md) for md in ["none", "locality", "xla"]}
assert res["locality"][0].moe_transport == "tokens"
assert res["xla"][0].moe_transport == "slots"
assert np.array_equal(np.asarray(res["locality"][2]["loss"]),
                      np.asarray(res["xla"][2]["loss"]))
A = leafset(res["locality"][1].params, MOE_LEAVES)
B = leafset(res["xla"][1].params, MOE_LEAVES)
assert A.keys() == B.keys() and A
bad = [k for k in A if not np.array_equal(A[k], B[k])]
assert not bad, bad
assert abs(float(res["locality"][2]["loss"])
           - float(res["none"][2]["loss"])) < 1e-3

# 2 layers, slots transport both sides: FULL bitwise incl. every param leaf
cfg2 = dataclasses.replace(base, n_layers=2, top_k=1, capacity_factor=1.0)
r1 = {md: run(cfg2, mesh, md) for md in ["locality", "xla"]}
assert r1["locality"][0].moe_transport == "slots"
for k in r1["locality"][2]:
    assert np.array_equal(np.asarray(r1["locality"][2][k]),
                          np.asarray(r1["xla"][2][k])), k
for x, y in zip(jax.tree.leaves(r1["locality"][1].params),
                jax.tree.leaves(r1["xla"][1].params)):
    assert np.array_equal(np.asarray(x), np.asarray(y))
print("EP_BITWISE_Q2_OK")
"""

EP_BITWISE_Q3_CODE = _EP_PRELUDE + r"""
base = configs.get_smoke("qwen2-moe-a2.7b")
# q=3 exercises the non-power partial-round geometry; E=6 divides p=6
mesh3 = jax.make_mesh((3, 2), ("pod", "data"), devices=jax.devices()[:6])
jax.set_mesh(mesh3)
cfg3 = dataclasses.replace(base, n_layers=1, n_experts=6)
r3 = {md: run(cfg3, mesh3, md, gb=6, fsdp=True)
      for md in ["none", "locality", "xla"]}
assert np.array_equal(np.asarray(r3["locality"][2]["loss"]),
                      np.asarray(r3["xla"][2]["loss"]))
A = leafset(r3["locality"][1].params, MOE_LEAVES)
B = leafset(r3["xla"][1].params, MOE_LEAVES)
bad = [k for k in A if not np.array_equal(A[k], B[k])]
assert not bad and A, bad
assert abs(float(r3["locality"][2]["loss"])
           - float(r3["none"][2]["loss"])) < 1e-3

# ineligibility: xla grad-sync cannot host the EP grad bucket -> dispatch off
art = make_train_step(cfg3, mesh3, grad_sync="xla",
                      shape=custom_batch_specs(cfg3, 6, 32),
                      donate=False, optimizer=OPT, moe_dispatch="locality")
assert art.moe_dispatch == "none" and art.moe_dispatch_source == "n/a", art
print("EP_BITWISE_Q3_OK")
"""

A2A_HLO_CODE = r"""
import repro  # noqa: F401
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
import repro.core.collectives as C
from repro.core.hlo_analysis import collective_stats
from repro.core.topology import device_pod_map

q, pl = {q}, {pl}
p = q * pl
mesh = jax.make_mesh((q, pl), ("pod", "data"))
pod_map = device_pod_map(mesh, ("pod",))
x = jnp.arange(p * p * 3, dtype=jnp.float32).reshape(p * p, 3)

def loc(s):
    return C.all_to_all(s, "pod", "data", algorithm="locality")
def flat(s):
    return C.all_to_all(s, "pod", "data", algorithm="xla")

run = lambda f: jax.jit(jax.shard_map(f, mesh=mesh,
    in_specs=P(("pod", "data")), out_specs=P(("pod", "data"))))
yl, yf = run(loc)(x), run(flat)(x)
assert (np.asarray(yl) == np.asarray(yf)).all(), "fwd mismatch"
ct = jnp.cos(x)
gl = jax.jit(jax.grad(lambda s: (run(loc)(s) * ct).sum()))(x)
gf = jax.jit(jax.grad(lambda s: (run(flat)(s) * ct).sum()))(x)
assert (np.asarray(gl) == np.asarray(gf)).all(), "vjp mismatch"
ys = run(lambda s: C.finish(C.collective("all_to_all", s, outer="pod",
    local="data", start=True)))(x)
assert (np.asarray(ys) == np.asarray(yl)).all(), "split mismatch"
sl = collective_stats(run(loc).lower(x).compile().as_text(), pod_map)
sf = collective_stats(run(flat).lower(x).compile().as_text(), pod_map)
# locality lowers to collective-permutes only: no grouped all-to-all at
# all, and strictly fewer inter-pod messages (aggregation).  Raw a2a bytes
# are irreducible — every (src, dst) slab must cross — so the primitive is
# gated at <=; the strict byte win comes from the tokens transport at the
# MoE dispatch level (benchmarks/multipod.py moe cells).
assert sl.group_msgs_nonlocal == 0 and sl.group_msgs_local == 0
assert sl.nonlocal_msgs < sf.nonlocal_msgs, (sl.nonlocal_msgs, sf.nonlocal_msgs)
assert sl.nonlocal_bytes <= sf.nonlocal_bytes, (sl.nonlocal_bytes, sf.nonlocal_bytes)
print("A2A_HLO_OK")
"""

EP_LEDGER_CODE = r"""
import dataclasses, tempfile
import repro  # noqa: F401
import jax
from repro import configs, telemetry
from repro.train.trainer import Trainer, TrainerConfig

cfg = dataclasses.replace(configs.get_smoke("qwen2-moe-a2.7b"), n_layers=2)
mesh = jax.make_mesh((2, 4), ("pod", "data"))
jax.set_mesh(mesh)
reg = telemetry.MetricsRegistry()
t = TrainerConfig(steps=3, seq_len=32, global_batch=8,
                  ckpt_dir=tempfile.mkdtemp(), ckpt_every=100, log_every=1,
                  grad_sync="locality", moe_dispatch="locality")
tr = Trainer(cfg, mesh, t, registry=reg)
assert tr.moe_comm_label == "train/moe_dispatch:locality", tr.moe_comm_label
assert tr._moe_layers == 2, tr._moe_layers
rep = reg.comm_report(tr.moe_comm_label)
assert rep.has_locality_schedule and rep.nonlocal_bytes > 0
tr.run()
rec = reg.reconcile(tr.moe_comm_label)
assert rec["match"] and rec["invocations"] == 6, rec
rec2 = reg.reconcile(tr.comm_label)
assert rec2["match"], rec2
print("EP_LEDGER_OK")
"""


@pytest.mark.slow
def test_ep_train_bitwise_q2(subproc):
    assert "EP_BITWISE_Q2_OK" in subproc(EP_BITWISE_Q2_CODE, devices=8)


@pytest.mark.slow
def test_ep_train_bitwise_q3_fsdp(subproc):
    assert "EP_BITWISE_Q3_OK" in subproc(EP_BITWISE_Q3_CODE, devices=8)


@pytest.mark.slow
@pytest.mark.parametrize("q,pl", [(2, 4), (3, 2)])
def test_locality_a2a_hlo_gate(subproc, q, pl):
    code = A2A_HLO_CODE.format(q=q, pl=pl)
    assert "A2A_HLO_OK" in subproc(code, devices=q * pl)


@pytest.mark.slow
def test_ep_comm_ledger_reconciles_exactly(subproc):
    assert "EP_LEDGER_OK" in subproc(EP_LEDGER_CODE, devices=8)
