"""MoE dispatch invariants (hypothesis property tests)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro import configs
from repro.models.moe import _dispatch_tables, capacity, moe_apply, moe_init

pytestmark = pytest.mark.hypothesis


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 16), st.integers(1, 4), st.integers(4, 32),
       st.integers(0, 10_000))
def test_dispatch_tables_invariants(E, K, S, seed):
    K = min(K, E)
    rng = np.random.default_rng(seed)
    idx = jnp.asarray(rng.integers(0, E, (S, K)))
    gates = jnp.asarray(rng.random((S, K)), jnp.float32)
    C = max(int(S * K / E * 1.25), K)
    tok_idx, weight = _dispatch_tables(idx, gates, E, S, K, C)
    tok_idx = np.asarray(tok_idx).reshape(E, C)
    weight = np.asarray(weight).reshape(E, C)
    # sentinel slots carry zero weight
    assert (weight[tok_idx == S] == 0).all()
    # each (token, k) assignment appears at most once overall
    real = tok_idx[tok_idx < S]
    for e in range(E):
        toks_e = tok_idx[e][tok_idx[e] < S]
        assert len(set(toks_e.tolist())) == len(toks_e) or K > 1
    # capacity respected per expert
    assert ((tok_idx < S).sum(axis=1) <= C).all()
    # a token routed to expert e lands in e's rows only with its own gate
    for e in range(E):
        for c in range(C):
            t = tok_idx[e, c]
            if t < S:
                assert weight[e, c] in np.asarray(gates[t]), (e, c)


def test_no_drop_recovers_dense_mixture():
    """With huge capacity, combining expert outputs with weights ≈ averaging
    the routed experts — cross-check against a direct dense computation."""
    cfg = dataclasses.replace(configs.get_smoke("qwen2-moe-a2.7b"),
                              capacity_factor=64.0, n_shared_experts=0)
    rng = jax.random.PRNGKey(0)
    p = moe_init(rng, cfg)
    x = jax.random.normal(rng, (2, 8, cfg.d_model), jnp.float32)
    out, aux = moe_apply(p, x, cfg)

    # dense reference: run every expert on every token, weight by router
    logits = (x @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    g = jnp.einsum("bsd,edf->bsef", x, p["gate"])
    u = jnp.einsum("bsd,edf->bsef", x, p["up"])
    y_all = jnp.einsum("bsef,efd->bsed", jax.nn.silu(g) * u, p["down"])
    mask = jax.nn.one_hot(idx, cfg.n_experts) * gates[..., None]
    ref = jnp.einsum("bsed,bse->bsd", y_all, mask.sum(2))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4,
                               rtol=1e-4)


def test_capacity_dropping_actually_drops():
    cfg = dataclasses.replace(configs.get_smoke("qwen2-moe-a2.7b"),
                              capacity_factor=0.1, n_shared_experts=0)
    rng = jax.random.PRNGKey(0)
    p = moe_init(rng, cfg)
    x = jax.random.normal(rng, (1, 32, cfg.d_model), jnp.float32)
    out, _ = moe_apply(p, x, cfg)
    # some token rows must be exactly zero (dropped -> residual only)
    norms = np.asarray(jnp.linalg.norm(out[0], axis=-1))
    assert (norms == 0.0).any()


def test_aux_loss_balanced_is_small():
    cfg = configs.get_smoke("qwen2-moe-a2.7b")
    E = cfg.n_experts
    # perfectly uniform router -> aux ≈ AUX_W (its minimum)
    rng = jax.random.PRNGKey(1)
    p = moe_init(rng, cfg)
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"])
    x = jax.random.normal(rng, (2, 64, cfg.d_model), jnp.float32)
    _, aux = moe_apply(p, x, cfg)
    from repro.models.moe import AUX_LOSS_W
    assert float(aux) == pytest.approx(AUX_LOSS_W, rel=0.3)
