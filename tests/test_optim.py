"""AdamW + schedules unit tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import AdamW, TrainState, cosine_warmup, global_norm


def test_adamw_matches_reference_step():
    p = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    g = {"w": jnp.asarray([0.1, 0.2, -0.3])}
    opt = AdamW(lr=0.01, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                clip_norm=0.0)
    state = TrainState.create(p)
    new, _ = opt.apply(state, g)
    # reference: bias-corrected adam first step => update = lr * sign-ish
    gnp = np.asarray(g["w"])
    m = 0.1 * gnp / (1 - 0.9)
    v = 0.001 * gnp * gnp / (1 - 0.999)
    ref = np.asarray(p["w"]) - 0.01 * m / (np.sqrt(v) + 1e-8)
    np.testing.assert_allclose(np.asarray(new.params["w"]), ref, rtol=1e-5)


def test_weight_decay_only_on_matrices():
    p = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    g = {"w": jnp.zeros((2, 2)), "b": jnp.zeros((2,))}
    opt = AdamW(lr=0.1, weight_decay=0.5, clip_norm=0.0)
    new, _ = opt.apply(TrainState.create(p), g)
    assert float(jnp.max(jnp.abs(new.params["w"] - 1.0))) > 0   # decayed
    np.testing.assert_allclose(np.asarray(new.params["b"]), 1.0)  # not


def test_clipping():
    p = {"w": jnp.zeros(4)}
    g = {"w": jnp.full((4,), 100.0)}
    opt = AdamW(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    _, metrics = opt.apply(TrainState.create(p), g)
    assert float(metrics["grad_norm"]) == 200.0   # reported pre-clip


def test_cosine_warmup_shape():
    f = cosine_warmup(peak=1.0, warmup_steps=10, total_steps=100, floor=0.1)
    lrs = [float(f(jnp.asarray(s))) for s in (0, 5, 10, 50, 100, 200)]
    assert lrs[0] == 0.0
    assert lrs[1] == 0.5
    assert abs(lrs[2] - 1.0) < 1e-6
    assert lrs[3] < 1.0
    assert abs(lrs[4] - 0.1) < 1e-6
    assert abs(lrs[5] - 0.1) < 1e-6


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert abs(float(global_norm(t)) - 5.0) < 1e-6
