"""Fleet controller unit suite (fast, in-process).

* decision policy: the bounded escalation ladder, hysteresis/cooldown
  anti-oscillation (hypothesis properties), capacity-forced shrinks;
* pod-aligned layout selection priced by the postal cost model;
* StepMonitor.reset() across elastic rebuilds + the runtime/stragglers
  counter mirror;
* PreemptionSignal SIGTERM chaining + uninstall();
* FaultInjector straggler delays;
* ChaosSchedule determinism and re-arming;
* a 1-device FleetController end-to-end smoke with counter
  reconciliation (the multi-pod soak lives in test_fleet_chaos.py).
"""
import signal

import pytest

from _hypothesis_compat import given, settings, strategies as st
from repro.fleet import (ACTION_COUNTERS, ChaosSchedule, ChaosSpec,
                         FleetPolicy, FleetSignals, Layout, PolicyConfig,
                         choose_layout, layout_price_s, pod_aligned_layouts)
from repro.runtime import FaultInjector, PreemptionSignal, StepMonitor
from repro.telemetry import MetricsRegistry, set_registry


# ---------------------------------------------------------------------------
# policy: the deterministic ladder
# ---------------------------------------------------------------------------
def _kill(step=10, commit=8, devices=12, capacity=12):
    return FleetSignals(kind="kill", step=step, committed_step=commit,
                        devices=devices, capacity=capacity)


def _tick(step=10, commit=8, devices=12, capacity=12, **kw):
    return FleetSignals(kind="tick", step=step, committed_step=commit,
                        devices=devices, capacity=capacity, **kw)


def test_escalation_ladder_retry_shrink_halt():
    p = FleetPolicy(PolicyConfig(max_retries=2, max_shrinks=1))
    actions = [p.decide(_kill()).action for _ in range(6)]
    # retry x2 -> shrink (ladder restarts) -> retry x2 -> halt
    assert actions == ["retry", "retry", "shrink", "retry", "retry", "halt"]
    # halt is absorbing, whatever arrives next
    assert p.decide(_tick(capacity=24)).action == "halt"
    assert p.decide(FleetSignals(kind="preemption")).action == "halt"
    assert p.halted


def test_committed_progress_resets_retry_budget():
    p = FleetPolicy(PolicyConfig(max_retries=1, max_shrinks=1))
    assert p.decide(_kill(step=10, commit=8)).action == "retry"
    # progress since the incident opened: new incident, fresh budget
    assert p.decide(_kill(step=20, commit=18)).action == "retry"
    assert p.decide(_kill(step=21, commit=18)).action == "shrink"


def test_preemption_is_benign_retry():
    p = FleetPolicy(PolicyConfig(max_retries=1))
    for _ in range(5):
        d = p.decide(FleetSignals(kind="preemption", step=3))
        assert d.action == "retry"
    assert not p.halted


def test_capacity_revocation_forces_shrink_without_budget():
    p = FleetPolicy(PolicyConfig(max_shrinks=0, cooldown_steps=100))
    d = p.decide(_tick(step=5, devices=12, capacity=8))
    assert d.action == "shrink" and d.target_devices == 8
    assert p.shrinks == 0          # mandatory, not an escalation shrink
    # and cooldown does NOT gate it: again right away
    d = p.decide(_tick(step=6, devices=8, capacity=4))
    assert d.action == "shrink" and d.target_devices == 4


def test_capacity_below_minimum_halts():
    p = FleetPolicy(PolicyConfig(min_devices=4))
    assert p.decide(_tick(devices=12, capacity=2)).action == "halt"
    assert p.halted


def test_straggler_hysteresis_and_cooldown():
    cfg = PolicyConfig(straggler_window=8, straggler_high=2,
                       straggler_low=0, cooldown_steps=4, max_shrinks=1)
    p = FleetPolicy(cfg)
    # first signal anchors the counter baseline: no pressure yet
    assert p.decide(_tick(step=0, stragglers=5)).action == "none"
    # 2 new flags inside the window -> shrink
    d = p.decide(_tick(step=2, stragglers=7))
    assert d.action == "shrink" and p.shrinks == 1
    # grow blocked inside the cooldown even with spare capacity + calm
    assert p.decide(_tick(step=4, stragglers=7, devices=8,
                          capacity=12)).action == "none"
    # cooldown passed but pressure still above the low watermark: no grow
    # (and the shrink budget is spent, so no further shrink either)
    assert p.decide(_tick(step=7, stragglers=9, devices=8,
                          capacity=12)).action == "none"
    # cooldown passed AND window drained back to the low watermark: grow
    d = p.decide(_tick(step=20, stragglers=9, devices=8, capacity=12))
    assert d.action == "grow" and d.target_devices == 12


def test_queue_depth_gates_grow():
    cfg = PolicyConfig(queue_grow_depth=4, cooldown_steps=0,
                       straggler_window=1)
    p = FleetPolicy(cfg)
    assert p.decide(_tick(step=1, devices=8, capacity=12,
                          queue_depth=1)).action == "none"
    assert p.decide(_tick(step=2, devices=8, capacity=12,
                          queue_depth=4)).action == "grow"


def test_degraded_ckpt_blocks_grow_failed_ckpt_is_incident():
    p = FleetPolicy(PolicyConfig(cooldown_steps=0, max_retries=1))
    assert p.decide(_tick(step=1, devices=8, capacity=12,
                          ckpt_state="degraded")).action == "none"
    assert p.decide(_tick(step=2, devices=8, capacity=12,
                          ckpt_state="failed")).action == "retry"


def test_hysteresis_gap_must_not_invert():
    with pytest.raises(ValueError):
        PolicyConfig(straggler_high=1, straggler_low=1)


# ---------------------------------------------------------------------------
# policy: hypothesis properties
# ---------------------------------------------------------------------------
_signals_st = st.lists(
    st.builds(FleetSignals,
              kind=st.sampled_from(["tick", "kill", "fault", "preemption"]),
              step=st.integers(0, 200),
              committed_step=st.integers(0, 200),
              stragglers=st.integers(0, 50),
              queue_depth=st.integers(0, 20),
              ckpt_state=st.sampled_from(["ok", "degraded", "failed"]),
              devices=st.integers(1, 64),
              capacity=st.integers(0, 64)),
    min_size=1, max_size=60)


@pytest.mark.hypothesis
@settings(max_examples=200, deadline=None)
@given(seq=_signals_st, cooldown=st.integers(1, 20))
def test_no_grow_within_cooldown_of_a_shrink(seq, cooldown):
    """Anti-oscillation: under ANY signal sequence, a grow never lands
    within ``cooldown_steps`` trainer steps of any earlier shrink."""
    p = FleetPolicy(PolicyConfig(cooldown_steps=cooldown))
    hist = [p.decide(s) for s in seq]
    for i, di in enumerate(hist):
        if di.action != "shrink":
            continue
        for dj in hist[i + 1:]:
            if dj.action == "grow":
                assert dj.step - di.step >= cooldown, (di, dj)


@pytest.mark.hypothesis
@settings(max_examples=200, deadline=None)
@given(seq=_signals_st,
       max_retries=st.integers(0, 4), max_shrinks=st.integers(0, 3))
def test_escalation_bounded_and_halt_absorbing(seq, max_retries,
                                               max_shrinks):
    p = FleetPolicy(PolicyConfig(max_retries=max_retries,
                                 max_shrinks=max_shrinks))
    hist = [p.decide(s) for s in seq]
    halted = False
    for s, d in zip(seq, hist):
        if halted:
            assert d.action == "halt", (s, d)
        if d.action == "halt":
            halted = True
    # escalation shrinks (policy-counted) never exceed the budget
    assert p.shrinks <= max_shrinks


@pytest.mark.hypothesis
@settings(max_examples=100, deadline=None)
@given(n=st.integers(1, 30), max_retries=st.integers(0, 3),
       max_shrinks=st.integers(0, 2))
def test_crash_loop_escalation_is_monotone(n, max_retries, max_shrinks):
    """A pure crash loop (no progress ever) walks the ladder EXACTLY:
    (retry^max_retries shrink)^max_shrinks retry^max_retries halt*."""
    p = FleetPolicy(PolicyConfig(max_retries=max_retries,
                                 max_shrinks=max_shrinks))
    got = [p.decide(_kill(step=10, commit=8)).action for _ in range(n)]
    expect = (["retry"] * max_retries + ["shrink"]) * max_shrinks \
        + ["retry"] * max_retries
    expect = expect + ["halt"] * (n - len(expect))
    assert got == expect[:n]
    # and per-incident escalation ranks never decrease
    from repro.fleet import ESCALATION
    rank = 0
    for a in got:
        r = ESCALATION[a]
        if a == "shrink":          # a resize closes the incident
            rank = 0
            continue
        assert r >= rank, got
        rank = r


# ---------------------------------------------------------------------------
# layout selection
# ---------------------------------------------------------------------------
def test_pod_aligned_layouts_nest_rows_in_pods():
    for lay in pod_aligned_layouts(12, 4):
        if lay.per_pod < 4:
            assert 4 % lay.per_pod == 0, lay
        assert lay.total <= 12


def test_choose_layout_prefers_fewest_regions_at_equal_total():
    # three 4-chip pods: (3,4), (6,2) and (12,1) all use 12 devices, but
    # splitting pods multiplies the DCN round count — Eq. 4 rejects it
    assert choose_layout(12, 4) == Layout(3, 4)
    assert layout_price_s(Layout(3, 4)) < layout_price_s(Layout(6, 2))
    assert layout_price_s(Layout(6, 2)) < layout_price_s(Layout(12, 1))


def test_choose_layout_utilization_dominates_price():
    # (2,4)=8 devices beats the cheaper (1,4)=4: never idle a whole pod
    assert choose_layout(8, 4) == Layout(2, 4)
    # a ragged capacity drops the partial pod (pod-aligned), keeps both
    # whole ones
    assert choose_layout(10, 4) == Layout(2, 4)
    # q=2 wide pods (the soak's second geometry)
    assert choose_layout(12, 6) == Layout(2, 6)


def test_choose_layout_subpod_fallback():
    # capacity below one pod: the flat remnant is the only aligned shape
    assert choose_layout(3, 4) == Layout(1, 3)
    with pytest.raises(Exception):
        choose_layout(0, 4)


def test_layout_price_finite_on_nonpower_region_counts():
    # Algorithm-2 territory: q in {3, 5, 6, 7} must price finitely
    for q in (3, 5, 6, 7):
        p = layout_price_s(Layout(q, 4))
        assert p > 0 and p == p, (q, p)


# ---------------------------------------------------------------------------
# StepMonitor: reset across rebuilds + the counter mirror
# ---------------------------------------------------------------------------
def test_monitor_reset_prevents_false_flags_and_counts():
    reg = MetricsRegistry()
    old = set_registry(reg)
    try:
        m = StepMonitor(warmup=0)
        m.record(0.1)                        # seeds the EWMA
        assert m.record(0.11) == []
        evs = m.record(1.0)                  # 1.0 > 3 x ewma: flagged
        assert [e.kind for e in evs] == ["straggler"]
        assert m.stragglers == 1
        assert reg.snapshot()["counters"]["runtime/stragglers"] == 1

        # WITHOUT reset, the first step on a 100x-slower topology would
        # flag; reset() forgets the stale EWMA so it seeds cleanly instead
        m.reset()
        assert m.ewma == 0.0
        assert m.record(10.0) == []          # reseeded, no false straggler
        assert m.record(10.5) == []
        # cumulative count and the counter survive the reset
        assert m.stragglers == 1
        assert reg.snapshot()["counters"]["runtime/stragglers"] == 1

        # warmup is honored again after reset
        m2 = StepMonitor(warmup=2)
        m2.record(0.1), m2.record(0.1), m2.record(0.1)
        m2.reset()
        assert m2.record(50.0) == []         # warmup step, not a straggler
    finally:
        set_registry(old)


# ---------------------------------------------------------------------------
# PreemptionSignal: SIGTERM chaining + uninstall
# ---------------------------------------------------------------------------
def test_sigterm_chains_previous_handler_and_uninstalls():
    hits = []
    outer = signal.signal(signal.SIGTERM, lambda s, f: hits.append("outer"))
    try:
        ps = PreemptionSignal(install_sigterm=True)
        signal.raise_signal(signal.SIGTERM)
        assert ps.triggered()
        assert hits == ["outer"]            # the old handler still ran
        ps.uninstall()
        assert signal.getsignal(signal.SIGTERM) is not None
        signal.raise_signal(signal.SIGTERM)
        assert hits == ["outer", "outer"]   # restored exactly
        ps.uninstall()                      # idempotent
    finally:
        signal.signal(signal.SIGTERM, outer)


def test_sigterm_uninstall_restores_default_handler():
    prev = signal.signal(signal.SIGTERM, signal.SIG_DFL)
    try:
        ps = PreemptionSignal(install_sigterm=True)
        assert callable(signal.getsignal(signal.SIGTERM))
        ps.uninstall()
        assert signal.getsignal(signal.SIGTERM) == signal.SIG_DFL
        # no-install signals never touch the handler
        PreemptionSignal().uninstall()
        assert signal.getsignal(signal.SIGTERM) == signal.SIG_DFL
    finally:
        signal.signal(signal.SIGTERM, prev)


# ---------------------------------------------------------------------------
# FaultInjector delays + ChaosSchedule
# ---------------------------------------------------------------------------
def test_fault_injector_delay_once_and_scaled():
    fi = FaultInjector(delay_at_steps=(3,), delay_s=0.01)
    assert fi.delay(2) == 0.0
    assert fi.delay(3, floor_s=0.02) == 0.02     # floor wins over delay_s
    assert fi.delay(3) == 0.0                    # one-shot


def test_chaos_schedule_deterministic_and_rearming():
    a = ChaosSchedule(ChaosSpec(steps=12, seed=7, kills=2, preempts=2,
                                straggles=2))
    b = ChaosSchedule(ChaosSpec(steps=12, seed=7, kills=2, preempts=2,
                                straggles=2))
    assert a.describe() == b.describe()
    steps = a.kills + a.preempts + a.straggles
    assert len(set(steps)) == 6 and min(steps) >= 3
    a.observe_kill(a.kills[0])
    a.observe_preempt(a.preempts[1])
    fi = a.fault_injector()
    assert set(fi.kill_at_steps) == set(a.kills) - {a.kills[0]}
    assert set(fi.delay_at_steps) == set(a.straggles)
    ps = a.preemption_signal()
    assert not ps.should_stop(a.preempts[1])     # fired: not re-armed
    cap = ChaosSchedule(ChaosSpec(steps=12, capacity=((4, 8), (9, 12))))
    assert cap.capacity_at(0, 12) == 12
    assert cap.capacity_at(5, 12) == 8
    assert cap.capacity_at(9, 12) == 12


def test_chaos_schedule_rejects_overfull_draw():
    with pytest.raises(ValueError):
        ChaosSchedule(ChaosSpec(steps=5, kills=2, preempts=2, straggles=2,
                                first_step=3))


# ---------------------------------------------------------------------------
# controller end-to-end smoke (1 device, real Trainer)
# ---------------------------------------------------------------------------
def test_controller_converges_and_counters_reconcile(tmp_path):
    import dataclasses

    import jax.numpy as jnp

    from repro import configs
    from repro.fleet import FleetController
    from repro.train import Trainer, TrainerConfig

    cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=1,
                              d_model=32, d_ff=64, vocab_size=64,
                              n_heads=2, n_kv_heads=2, head_dim=16,
                              dtype=jnp.float32)
    steps = 6
    reg = MetricsRegistry()
    old = set_registry(reg)
    try:
        def make_trainer(mesh):
            tcfg = TrainerConfig(
                steps=steps, seq_len=8, global_batch=4, ckpt_every=2,
                keep_last=4, log_every=100, grad_sync="flat_psum",
                fsdp=False, lr=1e-3, comm_telemetry=False,
                ckpt_dir=str(tmp_path / "ck"))
            return Trainer(cfg, mesh, tcfg, log=lambda s: None,
                           registry=reg)

        chaos = ChaosSchedule(ChaosSpec(steps=steps, seed=3, kills=1,
                                        preempts=1, straggles=1,
                                        first_step=3, delay_s=0.05))
        fc = FleetController(make_trainer, pod_size=1, devices=1,
                             chaos=chaos, log=lambda s: None, registry=reg)
        report = fc.run()
    finally:
        set_registry(old)

    assert report.status == "complete"
    assert report.steps == steps
    # one episode per disturbance + the final complete one
    assert len(report.episodes) == 3, report.episodes
    assert report.episodes[-1]["outcome"] == "complete"
    # every restart resumed at the committed step (asserted in _build;
    # recorded here for the report's own story)
    for ep in report.episodes:
        assert ep["resumed_step"] <= ep["end_step"]
    # the loss trajectory covers every step exactly once after folding
    assert sorted(report.loss_by_step) == list(range(1, steps + 1))
    # fleet/* counter reconciliation — the same invariant
    # scripts/check_metrics_schema.py enforces in CI
    c = reg.snapshot()["counters"]
    actions = sum(c.get(f"fleet/{s}", 0) for s in ACTION_COUNTERS.values())
    assert c["fleet/decisions"] == actions > 0
    assert c["fleet/episodes"] == 3
    assert reg.snapshot()["gauges"]["fleet/healthy"] == 1.0
    assert c.get("fleet/halts", 0) == 0
