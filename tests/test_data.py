"""Data pipeline: determinism, host sharding, learnable structure."""
import numpy as np

from repro.data import SyntheticLM, host_shard


def test_determinism():
    d = SyntheticLM(vocab_size=100, seq_len=16, global_batch=4, seed=3)
    a, b = d.batch(5), d.batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = d.batch(6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_labels_are_shifted_tokens():
    d = SyntheticLM(vocab_size=100, seq_len=16, global_batch=2, seed=0)
    b = d.batch(0)
    # labels[t] is the next token of the same underlying sequence
    assert b["tokens"].shape == b["labels"].shape == (2, 16)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_structure_is_learnable():
    """tokens[t+1] is a fixed function of tokens[t] (up to noise)."""
    d = SyntheticLM(vocab_size=257, seq_len=64, global_batch=8, seed=1,
                    noise=0.0)
    b = d.batch(0)
    V = 257
    a = 31337 % V
    c_implied = (b["labels"].astype(np.int64) -
                 a * b["tokens"].astype(np.int64)) % V
    assert len(np.unique(c_implied)) == 1     # one global affine constant


def test_host_shard():
    d = SyntheticLM(vocab_size=100, seq_len=8, global_batch=8, seed=0)
    b = d.batch(0)
    parts = [host_shard(b, h, 4) for h in range(4)]
    recon = np.concatenate([p["tokens"] for p in parts], axis=0)
    np.testing.assert_array_equal(recon, b["tokens"])


def test_bounds():
    d = SyntheticLM(vocab_size=50, seq_len=32, global_batch=4, seed=0)
    b = d.batch(9)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 50
    assert b["labels"].min() >= 0 and b["labels"].max() < 50
