"""Crash-mid-write recovery: every fault point × mode must leave the store
restoring the last committed step with exact data — zero data loss.

"Committed" means the LATEST pointer replace finished. Faults at the
chunk/manifest/commit points leave no trace of the new step; faults at the
LATEST points leave a complete-but-unreferenced step dir, and restore
(which follows the committed pointer) still serves the previous commit —
consistent either way, and the next successful save heals the pointer.
"""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st
from repro.checkpoint import (FAULT_POINTS, committed_step,
                              restore_checkpoint, save_checkpoint)
from repro.faults import (FaultHarness, FaultSpec, ProcessKilled, guard,
                          write_bytes)


def make_tree(v: float):
    return {"a": jnp.full((3, 4), v), "b": [jnp.arange(5.0) + v],
            "c": {"d": jnp.asarray(int(v))}}


def assert_tree_equals(tree, v: float) -> None:
    ref = make_tree(v)
    import jax
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.fixture()
def ckdir(tmp_path):
    return str(tmp_path / "ck")


# ---------------------------------------------------------------------------
# harness unit behaviour
# ---------------------------------------------------------------------------
def test_harness_fires_at_exact_hit():
    h = FaultHarness([FaultSpec(point="p", mode="io_error", at=2)])
    assert [h.check("p") for _ in range(4)] == [None, None, "io_error", None]
    assert h.hits("p") == 4


def test_harness_glob_and_times():
    h = FaultHarness([FaultSpec(point="checkpoint/*", mode="kill",
                                rate=1.0, times=2)])
    fired = [h.check("checkpoint/chunk_write") for _ in range(5)]
    assert fired == ["kill", "kill", None, None, None]
    assert h.check("other/point") is None


def test_harness_seeded_rate_is_deterministic():
    def run(seed):
        h = FaultHarness([FaultSpec(point="p", mode="torn", rate=0.3,
                                    times=100)], seed=seed)
        return [h.check("p") for _ in range(50)]

    assert run(7) == run(7)
    assert run(7) != run(8)          # astronomically unlikely to collide


def test_write_bytes_torn_leaves_half(tmp_path):
    h = FaultHarness([FaultSpec(point="p", mode="torn", at=0)])
    path = str(tmp_path / "f.bin")
    with pytest.raises(ProcessKilled):
        write_bytes(path, b"0123456789", faults=h, point="p")
    assert os.path.getsize(path) == 5        # half landed, then the kill
    write_bytes(path, b"0123456789", faults=h, point="p")
    assert os.path.getsize(path) == 10


def test_guard_modes():
    h = FaultHarness([FaultSpec(point="r", mode="io_error", at=0),
                      FaultSpec(point="r", mode="kill", at=1)])
    with pytest.raises(OSError):
        guard("r", h)
    with pytest.raises(ProcessKilled):
        guard("r", h)
    guard("r", h)                            # disarmed
    guard("r", None)                         # no harness: no-op


# ---------------------------------------------------------------------------
# the zero-data-loss matrix: every point × every mode
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["torn", "kill", "io_error"])
@pytest.mark.parametrize("point", FAULT_POINTS)
def test_crash_at_every_point_restores_last_commit(ckdir, point, mode):
    save_checkpoint(ckdir, 1, make_tree(1.0))
    faults = FaultHarness([FaultSpec(point=point, mode=mode, at=0)])
    with pytest.raises((ProcessKilled, OSError)):
        save_checkpoint(ckdir, 2, make_tree(2.0), faults=faults)
    assert faults.log, f"fault at {point} never fired"
    # the last committed step restores, bit-exact
    step, tree = restore_checkpoint(ckdir, make_tree(0.0))
    assert step == 1
    assert_tree_equals(tree, 1.0)
    # and the store heals: the next save commits and restores normally
    save_checkpoint(ckdir, 3, make_tree(3.0))
    step, tree = restore_checkpoint(ckdir, make_tree(0.0))
    assert step == 3
    assert_tree_equals(tree, 3.0)


@pytest.mark.parametrize("point", FAULT_POINTS)
def test_torn_write_mid_sequence(ckdir, point):
    """A torn write inside a save *sequence* never rolls back past the
    previous commit and never serves a torn step."""
    committed = None
    faults = FaultHarness([FaultSpec(point=point, mode="torn", at=3)])
    for s in range(1, 6):
        try:
            save_checkpoint(ckdir, s, make_tree(float(s)), faults=faults)
            committed = s
        except (ProcessKilled, OSError):
            pass
        step, tree = restore_checkpoint(ckdir, make_tree(0.0))
        assert step == committed
        assert_tree_equals(tree, float(committed))
    assert faults.log, f"fault at {point} never fired"


# ---------------------------------------------------------------------------
# property test: random kill points over a save sequence
# ---------------------------------------------------------------------------
@pytest.mark.hypothesis
@given(seed=st.integers(min_value=0, max_value=10**6))
@settings(max_examples=30, deadline=None)
def test_random_kill_points_never_lose_data(seed, tmp_path_factory):
    ckdir = str(tmp_path_factory.mktemp("faults") / f"ck_{seed}")
    rng = np.random.default_rng(seed)
    mode = ["torn", "kill", "io_error"][int(rng.integers(3))]
    faults = FaultHarness(
        [FaultSpec(point="checkpoint/*", mode=mode,
                   rate=float(rng.uniform(0.02, 0.25)), times=4)],
        seed=seed)
    save_checkpoint(ckdir, 0, make_tree(0.0))      # fault-free baseline
    committed = 0
    for s in range(1, 9):
        try:
            save_checkpoint(ckdir, s, make_tree(float(s)), faults=faults)
            committed = s
        except (ProcessKilled, OSError):
            pass
        step, tree = restore_checkpoint(ckdir, make_tree(0.0))
        assert step == committed, (
            f"seed={seed} mode={mode} log={faults.log}: restored {step}, "
            f"last commit {committed}")
        assert_tree_equals(tree, float(committed))


def test_committed_step_tracks_pointer_not_dirs(ckdir):
    """A kill between commit-rename and the pointer replace leaves a newer
    complete dir; the committed pointer — not the scan — wins."""
    save_checkpoint(ckdir, 1, make_tree(1.0))
    faults = FaultHarness([FaultSpec(point="checkpoint/latest_rename",
                                     mode="kill", at=0)])
    with pytest.raises(ProcessKilled):
        save_checkpoint(ckdir, 2, make_tree(2.0), faults=faults)
    assert os.path.isdir(os.path.join(ckdir, "step_00000002"))
    assert committed_step(ckdir) == 1
