"""End-to-end driver: train a ~100M-parameter llama-family model for a few
hundred steps on a multi-device host mesh, with the paper's locality-aware
gradient sync, checkpoints, and straggler monitoring.

    PYTHONPATH=src python examples/train_100m.py --steps 300

(CPU-bound: ~1-3 s/step. Use --steps 30 for a quick look; the loss curve is
written to results/train_100m_loss.csv either way.)

With ``--preemptible`` the run goes through the fleet controller
(DESIGN.md §11): SIGTERM becomes a graceful drain-and-commit instead of
lost work (send ``kill -TERM <pid>`` while it trains and watch the
resume), the ('pod','data') mesh is chosen pod-aligned by the cost
model, and a hard kill restarts from the committed step with the
bounded retry -> shrink -> halt escalation.
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--grad-sync", default="locality")
    ap.add_argument("--preemptible", action="store_true",
                    help="run under the FleetController: SIGTERM drains "
                         "gracefully, kills resume from the committed step")
    ap.add_argument("--pod-size", type=int, default=4,
                    help="physical pod width for --preemptible layout "
                         "selection")
    args = ap.parse_args()

    os.environ.setdefault(
        "XLA_FLAGS",
        f"--xla_force_host_platform_device_count={args.devices}")
    import dataclasses

    import jax
    import numpy as np

    from repro import configs
    from repro.models import transformer
    from repro.train import Trainer, TrainerConfig

    # ~100M params: 12L, d=768, heads 12, ff 3072, vocab 32k (GPT-2-small-ish
    # dims in the llama3 family).
    cfg = dataclasses.replace(
        configs.get("llama3.2-3b"), name="llama-100m",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
        d_ff=3072, vocab_size=32_000)
    a = jax.eval_shape(lambda k: transformer.init_params(k, cfg),
                       jax.random.PRNGKey(0))
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(a))
    print(f"[train_100m] {n/1e6:.1f}M params, {args.devices} devices, "
          f"grad_sync={args.grad_sync}")

    tcfg = TrainerConfig(steps=args.steps, seq_len=256, global_batch=8,
                         ckpt_dir="/tmp/repro_100m_ckpt", ckpt_every=100,
                         log_every=10, grad_sync=args.grad_sync, lr=3e-4)

    if args.preemptible:
        # fleet-controller path: pod-aligned ('pod','data') layout from
        # the cost model; SIGTERM chains into a graceful drain-and-commit
        # and the controller restarts any killed episode from the
        # committed step (ctrl-C still interrupts: SIGINT is untouched).
        from repro.fleet import FleetController
        from repro.runtime import PreemptionSignal

        def make_trainer(mesh):
            return Trainer(cfg, mesh, tcfg,
                           preemption=PreemptionSignal(install_sigterm=True))

        fc = FleetController(make_trainer, pod_size=args.pod_size,
                             devices=args.devices)
        report = fc.run()
        metrics = sorted(report.loss_by_step)
        rows = [(s, report.loss_by_step[s], 0.0) for s in metrics]
        final = report.loss_by_step[metrics[-1]] if metrics else float("nan")
        print(f"[train_100m] fleet run {report.status}: "
              f"{len(report.episodes)} episode(s), final layout "
              f"{report.final_layout}")
    else:
        mesh = jax.make_mesh((2, args.devices // 4, 2),
                             ("pod", "data", "model"))
        jax.set_mesh(mesh)
        tr = Trainer(cfg, mesh, tcfg)
        out = tr.run()
        rows = [(m["step"], m["loss"], m["dt"]) for m in tr.metrics_history]
        final = out["final_loss"]

    os.makedirs("results", exist_ok=True)
    with open("results/train_100m_loss.csv", "w") as f:
        f.write("step,loss,dt\n")
        for step, loss, dt in rows:
            f.write(f"{step},{loss:.4f},{dt:.3f}\n")
    print(f"[train_100m] done: {final:.4f} "
          f"(loss curve -> results/train_100m_loss.csv)")


if __name__ == "__main__":
    main()
