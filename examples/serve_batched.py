"""Serve a small model with batched requests: prefill once, decode in a
batch, report per-token latency.

    PYTHONPATH=src python examples/serve_batched.py --arch mamba2-780m
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args()

    os.environ.setdefault(
        "XLA_FLAGS",
        f"--xla_force_host_platform_device_count={args.devices}")
    import time

    import jax
    import numpy as np

    import warnings

    from repro import configs
    from repro.models import transformer
    from repro.serve import Engine, ServeSpec

    mesh = jax.make_mesh((args.devices // 2, 2), ("data", "model"))
    jax.set_mesh(mesh)
    cfg = configs.get_smoke(args.arch)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, mesh, params,
                 ServeSpec(batch=args.batch,
                           cache_len=args.prompt_len + args.max_new))

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len), dtype=np.int32)
    t0 = time.perf_counter()
    # the lockstep wave is exactly what this example measures (whole-batch
    # per-token latency), so it keeps the deprecated generate loop on purpose
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        toks = eng.generate(prompts, max_new=args.max_new)
    dt = time.perf_counter() - t0
    n_tok = args.batch * args.max_new
    print(f"[serve] {cfg.name}: {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s, {dt/args.max_new*1e3:.1f} ms/decode-step)")
    print("sample:", toks[0][:16])


if __name__ == "__main__":
    main()
