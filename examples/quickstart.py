"""Quickstart: the whole stack in one minute on one CPU device.

    PYTHONPATH=src python examples/quickstart.py

1. Build a tiny llama-family model from the config registry.
2. Train it on the synthetic affine-token stream until loss visibly drops.
3. Serve it: prefill + greedy decode with a KV cache.
4. Compare allgather algorithms with the paper's cost model.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.autotune import model_costs
from repro.data import SyntheticLM
from repro.models import transformer
from repro.optim import AdamW, TrainState
from repro.serve import Engine, Request, ServeSpec
from repro.train.step import make_loss_fn


def main():
    # --- 1. model -----------------------------------------------------------
    cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=2,
                              vocab_size=97, vocab_pad_multiple=1)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"model: {cfg.name}, {n/1e6:.2f}M params")

    # --- 2. train -----------------------------------------------------------
    data = SyntheticLM(vocab_size=97, seq_len=64, global_batch=8, noise=0.02)
    loss_fn = make_loss_fn(cfg)
    opt = AdamW(lr=5e-3)
    state = TrainState.create(params)

    @jax.jit
    def step(state, tokens, labels):
        (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params, {"tokens": tokens, "labels": labels},
            lambda x, _k: x)
        state, _ = opt.apply(state, g)
        return state, l

    for i in range(50):
        b = data.batch(i)
        state, l = step(state, jnp.asarray(b["tokens"]), jnp.asarray(b["labels"]))
        if i % 10 == 0 or i == 49:
            print(f"  step {i:3d} loss {float(l):.3f}")

    # --- 3. serve -----------------------------------------------------------
    mesh = jax.make_mesh((1,), ("data",))
    jax.set_mesh(mesh)
    eng = Engine(cfg, mesh, state.params, ServeSpec(batch=4, cache_len=48))
    prompts = data.batch(999)["tokens"][:4, :16]
    for i in range(4):
        eng.submit(Request(tokens=np.asarray(prompts[i]), max_new=8))
    results = eng.drain()
    print("generated continuations:", results[0].tokens)

    # --- 4. the paper's trade-off, in numbers --------------------------------
    print("\nmodeled allgather cost on 4096 ranks, 16/region, 8B msgs (Lassen):")
    for name, cost in sorted(model_costs(4096, 16, 8.0, "lassen").items(),
                             key=lambda kv: kv[1]):
        print(f"  {name:16s} {cost*1e6:9.1f} us")


if __name__ == "__main__":
    main()
