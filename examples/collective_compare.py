"""The paper, hands-on: run all five allgather algorithms on a (regions ×
local) host mesh, check bit-exactness against XLA, measure wall time, count
the schedule's non-local traffic, and show the postal-model projection for a
real TPU pod boundary.

    PYTHONPATH=src python examples/collective_compare.py --regions 2 --local 4
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--regions", type=int, default=2)
    ap.add_argument("--local", type=int, default=4)
    ap.add_argument("--kib", type=float, default=4.0, help="payload per rank")
    args = ap.parse_args()

    p = args.regions * args.local
    os.environ.setdefault("XLA_FLAGS",
                          f"--xla_force_host_platform_device_count={p}")
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.core import collectives as C
    from repro.core import schedules as S
    from repro.core.autotune import model_costs
    from repro.core.topology import RegionMap

    mesh = jax.make_mesh((args.regions, args.local), ("r", "l"))
    jax.set_mesh(mesh)
    n = int(args.kib * 1024 / 4)
    x = jnp.arange(p * n, dtype=jnp.float32).reshape(p, n)

    def run(fn):
        return jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=P(("r", "l")),
                                     out_specs=P(("r", "l"))))

    truth_fn = run(lambda s: jax.lax.all_gather(s, ("r", "l"), tiled=True))
    truth = truth_fn(x)
    region = RegionMap(p, args.local)

    print(f"allgather of {args.kib:.0f} KiB/rank over {p} ranks "
          f"({args.regions} regions x {args.local}):\n")
    print(f"{'algorithm':16s} {'wall us':>9s} {'nl msgs':>8s} {'nl blocks':>10s}")
    for alg in ["xla", "bruck", "ring", "hierarchical", "multilane",
                "locality_bruck"]:
        f = run(lambda s, a=alg: C.allgather(s, "r", "l", algorithm=a,
                                             tiled=True))
        out = f(x)
        assert np.allclose(np.asarray(out), np.asarray(truth)), alg
        t0 = time.perf_counter()
        for _ in range(20):
            out = f(x)
        out.block_until_ready()
        us = (time.perf_counter() - t0) / 20 * 1e6
        if alg == "xla":
            nl = blocks = "-"
        else:
            sched = (S.ALGORITHMS[alg](p, args.local)
                     if alg in ("hierarchical", "multilane", "locality_bruck")
                     else S.ALGORITHMS[alg](p, args.local))
            nl = sched.max_nonlocal_msgs(region)
            blocks = sched.max_nonlocal_blocks(region)
        print(f"{alg:16s} {us:9.1f} {nl!s:>8s} {blocks!s:>10s}")

    print("\npostal-model projection, 1024 regions x 16 (pod boundary = DCN):")
    for name, cost in sorted(
            model_costs(1024 * 16, 16, args.kib * 1024, "tpu_v5e").items(),
            key=lambda kv: kv[1]):
        print(f"  {name:16s} {cost*1e6:9.1f} us")


if __name__ == "__main__":
    main()
