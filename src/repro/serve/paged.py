"""Paged KV-cache accounting for the continuous-batching scheduler.

Pure-Python bookkeeping over the physical cache the engine compiled: the
(B, cache_len, ...) cache is viewed as B *rows* (one request each) of
``cache_len // page_len`` *pages*.  Admission reserves the request's whole
worst case — ceil((prompt_len + max_new) / page_len) pages in one free row
— up front, so:

* **rows never alias**: a row belongs to at most one in-flight request
  (``reserve`` refuses a row that is taken; ``release`` is the only way
  back to the free pool);
* **no admission ever deadlocks or starves**: the queue is served strictly
  FCFS — a request is admitted only if the *head* of the queue is, so a
  small late request can never overtake (and thereby starve) a large early
  one; a request that can never fit (needs more pages than a row has)
  is rejected at submit time, not queued forever.

Row→pod affinity mirrors the batch-sharded layout (contiguous row blocks,
pod-major): ``reserve`` prefers a free row inside the request's home pod
and falls back to any pod — the scheduler then pays a cross-pod cache
migration for the fallback, which is exactly the traffic the
``cache_migrate`` collective cell prices.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class RowState:
    rid: int                  # owning request id
    pages: int                # pages reserved (the worst-case footprint)
    home_pod: int             # pod the request asked for
    pod: int                  # pod the row actually lives in


class PagedKVCache:
    """Slot/page accounting; holds no device arrays."""

    def __init__(self, batch: int, cache_len: int, page_len: int,
                 n_pods: int = 1):
        if page_len < 1 or cache_len % page_len != 0:
            raise ValueError(f"page_len {page_len} must divide "
                             f"cache_len {cache_len}")
        self.batch = batch
        self.cache_len = cache_len
        self.page_len = page_len
        self.n_pods = max(1, n_pods)
        self.pages_per_row = cache_len // page_len
        self.rows: dict[int, RowState] = {}          # row -> owner
        self._by_rid: dict[int, int] = {}            # rid -> row

    # ------------------------------------------------------------------
    def pod_of_row(self, row: int) -> int:
        return (row * self.n_pods) // self.batch

    def pages_needed(self, prompt_len: int, max_new: int) -> int:
        total = prompt_len + max_new
        return -(-total // self.page_len)

    def fits(self, prompt_len: int, max_new: int) -> bool:
        """Whether the request can EVER be admitted (rejecting oversized
        requests at submit keeps the FCFS queue starvation-free)."""
        return self.pages_needed(prompt_len, max_new) <= self.pages_per_row

    @property
    def free_rows(self) -> list[int]:
        return [r for r in range(self.batch) if r not in self.rows]

    @property
    def used_pages(self) -> int:
        return sum(s.pages for s in self.rows.values())

    @property
    def page_budget(self) -> int:
        return self.batch * self.pages_per_row

    # ------------------------------------------------------------------
    def reserve(self, rid: int, prompt_len: int, max_new: int,
                home_pod: int | None = None) -> int | None:
        """Reserve a row for ``rid``; returns the row or None when full.

        Prefers a free row whose pod matches ``home_pod`` (no migration);
        otherwise takes the lowest free row anywhere (the caller pays a
        cross-pod migration). Raises if ``rid`` already holds a row or the
        request cannot fit in any row.
        """
        if rid in self._by_rid:
            raise ValueError(f"request {rid} already holds row "
                             f"{self._by_rid[rid]}")
        pages = self.pages_needed(prompt_len, max_new)
        if pages > self.pages_per_row:
            raise ValueError(
                f"request {rid} needs {pages} pages "
                f"({prompt_len}+{max_new} tokens) but a row holds only "
                f"{self.pages_per_row} (cache_len {self.cache_len})")
        free = self.free_rows
        if not free:
            return None
        row = None
        if home_pod is not None:
            for r in free:
                if self.pod_of_row(r) == home_pod:
                    row = r
                    break
        if row is None:
            row = free[0]
        self.rows[row] = RowState(rid=rid, pages=pages,
                                  home_pod=home_pod if home_pod is not None
                                  else self.pod_of_row(row),
                                  pod=self.pod_of_row(row))
        self._by_rid[rid] = row
        return row

    def release(self, rid: int) -> int:
        """Free ``rid``'s row; returns the row index."""
        row = self._by_rid.pop(rid)
        del self.rows[row]
        return row

    def row_of(self, rid: int) -> int | None:
        return self._by_rid.get(rid)

    def check_invariants(self) -> None:
        """Assert the no-alias invariants (used by the property tests)."""
        rows = list(self._by_rid.values())
        assert len(rows) == len(set(rows)), f"aliased rows: {rows}"
        for rid, row in self._by_rid.items():
            assert self.rows[row].rid == rid
            assert 0 <= row < self.batch
        assert self.used_pages <= self.page_budget
