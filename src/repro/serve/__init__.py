from .engine import Engine, cache_shardings, make_serve_fns
from .paged import PagedKVCache
from .scheduler import Scheduler, StepClock, WallClock
from .spec import Request, RequestResult, ServeSpec

__all__ = ["Engine", "PagedKVCache", "Request", "RequestResult",
           "Scheduler", "ServeSpec", "StepClock", "WallClock",
           "cache_shardings", "make_serve_fns"]
