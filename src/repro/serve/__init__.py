from .engine import Engine, cache_shardings, make_serve_fns
