"""Continuous-batching request scheduler over the compiled serve steps.

The lockstep ``Engine.generate`` loop admits a whole batch, decodes every
row to the same budget, and returns — production traffic never looks like
that. The scheduler runs the decode loop *continuously*: requests are
admitted into free cache rows between steps (FCFS against the paged
accounting of :mod:`repro.serve.paged`), each admitted request is prefilled
at B=1 **inside its home pod** (a submesh jit over that pod's devices — the
prefill's collectives cannot cross the DCN by construction), its cache row
is inserted into the live batch cache, and rows free the moment their
request finishes. Decode carries a per-row ``(B,)`` position vector (the
scalar lockstep path is untouched — see ``models/attention.py``).

Cross-pod cache migration: when the only free row lives in another pod,
the prefilled KV slab moves through ``core.collectives.cache_migrate`` —
a gatherv-shaped replication over ('pod','data') executed with the
locality-Bruck family, priced by the ``cache_migrate`` tuning cell, and
classified by ``telemetry.comm.comm_report`` so the comm ledger reconciles
migration traffic exactly like decode traffic (labels ``serve/migrate:*``,
``serve/prefill:*``, ``serve/decode:cont``).

Sequence-sharded layouts (B=1 long-context, the locality decode-combine's
domain) schedule too: admission degenerates to one request at a time with
the engine's own scalar-pos decode fn, so batch-sharded and
sequence-sharded requests run under one scheduler API.

Clocks are injectable: :class:`WallClock` for real latency numbers,
:class:`StepClock` for deterministic replay (same trace → identical
admission order, tokens, and stamps — the property the determinism test
pins).
"""
from __future__ import annotations

import bisect
import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import collectives as C
from repro.models import transformer
from repro.train.sharding import make_shard_fn, param_specs
from .paged import PagedKVCache
from .spec import Request, RequestResult, ResolvedServeSpec


# ---------------------------------------------------------------------------
# clocks
# ---------------------------------------------------------------------------
class WallClock:
    """Real time; ``idle_until`` naps toward the next arrival."""

    def now(self) -> float:
        return time.perf_counter()

    def advance(self, kind: str) -> None:   # wall time advances itself
        pass

    def idle_until(self, t: float) -> None:
        dt = t - self.now()
        if dt > 0:
            time.sleep(min(dt, 0.05))


class StepClock:
    """Deterministic virtual clock: each decode step / prefill advances
    time by a fixed cost. Latencies become exact functions of the trace and
    the schedule — replayable, noise-free (what the determinism test and
    the trace benchmark's continuous-vs-waves comparison key on)."""

    def __init__(self, decode_cost: float = 1.0, prefill_cost: float = 1.0):
        self.t = 0.0
        self.decode_cost = decode_cost
        self.prefill_cost = prefill_cost

    def now(self) -> float:
        return self.t

    def advance(self, kind: str) -> None:
        self.t += self.prefill_cost if kind == "prefill" else self.decode_cost

    def idle_until(self, t: float) -> None:
        self.t = max(self.t, t)


# ---------------------------------------------------------------------------
# cache-leaf geometry (mirrors cache_shardings' name-keyed placement)
# ---------------------------------------------------------------------------
def _leaf_name(path) -> str:
    keys = tuple(str(getattr(p, "key", getattr(p, "idx", ""))) for p in path)
    return keys[-1] if keys else ""


def _leaf_batch_dim(path, leaf) -> int | None:
    """Batch dim of a cache leaf (stacked leaves carry leading dims);
    None for the pos leaf."""
    name = _leaf_name(path)
    nd = leaf.ndim
    if name in ("k", "v"):
        return nd - 4
    if name == "conv":
        return nd - 3
    if name == "h":
        return nd - 4
    if name == "pos":
        return None
    raise ValueError(f"unknown cache leaf {name!r}")


def _seq_axes_of_spec(spec) -> tuple[int, tuple[str, ...]] | None:
    """(dim, axes) of the sequence-sharded dim in a donor PartitionSpec —
    the dim carrying 'pod'/'data' — or None for unsharded-seq leaves."""
    for d, entry in enumerate(spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        if "pod" in axes or "data" in axes:
            return d, tuple(axes)
    return None


# ---------------------------------------------------------------------------
# compiled helpers: insert / migrate-insert
# ---------------------------------------------------------------------------
def _row_mask_insert(cache, req, row, batch):
    """Masked row insert: elementwise ``where`` on the batch dim only, so
    GSPMD keeps every update device-local on a batch-sharded cache (a
    dynamic_update_slice at a *dynamic row index* on the sharded dim would
    make it gather the whole cache)."""
    onehot = jnp.arange(batch) == row

    def visit(path, leaf, req_leaf):
        b = _leaf_batch_dim(path, leaf)
        if b is None:                      # pos: scalar -> the row's entry
            return jnp.where(onehot, req_leaf.astype(leaf.dtype), leaf)
        m = onehot.reshape([batch if i == b else 1 for i in range(leaf.ndim)])
        return jnp.where(m, req_leaf.astype(leaf.dtype), leaf)

    return jax.tree_util.tree_map_with_path(visit, cache, req)


def make_insert_fn(mesh, batch: int, cache_sh, req_sh):
    """jit((cache, req_cache, row) -> cache): donated masked row insert."""
    fn = jax.jit(lambda cache, req, row: _row_mask_insert(cache, req, row,
                                                          batch),
                 in_shardings=(cache_sh, req_sh, None),
                 donate_argnums=(0,), out_shardings=cache_sh)
    return fn


def make_migrate_insert_fn(mesh, batch: int, cache_sh, donor_specs,
                           donor_sh, algorithm: str):
    """jit((cache, req_cache, row) -> cache) where the request cache
    arrives in the DONOR layout (KV slabs sequence-sharded over
    ('pod','data') per cache_shardings at B=1) and is replicated by the
    explicit ``cache_migrate`` collective — one fully-manual shard_map per
    sharded leaf — before the masked row insert. ``algorithm=None``/"gspmd"
    skips the explicit collective: GSPMD reshards the same donor-layout
    input with its flat all-gather (the baseline the multipod benchmark
    compares against)."""
    axis_names = set(mesh.axis_names)

    def gather_leaf(path, leaf, spec):
        sharded = _seq_axes_of_spec(spec)
        if sharded is None or algorithm in (None, "gspmd"):
            return leaf
        dim, axes = sharded
        if "pod" in axes:
            outer = ("pod",)
            local = tuple(a for a in axes if a != "pod")
        else:
            outer = axes
            local = ()
        out_entries = [None if d == dim else e for d, e in enumerate(spec)]

        def region(x):
            y = jnp.moveaxis(x, dim, 0)
            shp = y.shape
            g = C.cache_migrate(y.reshape(-1), outer, local,
                                algorithm=algorithm, tiled=True)
            g = g.reshape((-1,) + shp[1:])
            return jnp.moveaxis(g, 0, dim)

        return jax.shard_map(region, mesh=mesh, in_specs=spec,
                             out_specs=P(*out_entries),
                             axis_names=axis_names, check_vma=False)(leaf)

    def migrate_insert(cache, req, row):
        req_full = jax.tree_util.tree_map_with_path(gather_leaf, req,
                                                    donor_specs)
        return _row_mask_insert(cache, req_full, row, batch)

    return jax.jit(migrate_insert,
                   in_shardings=(cache_sh, donor_sh, None),
                   donate_argnums=(0,), out_shardings=cache_sh)


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _Active:
    req: Request
    row: int
    started_s: float
    migrated: bool
    tokens: list = dataclasses.field(default_factory=list)
    times: list = dataclasses.field(default_factory=list)


class Scheduler:
    """Continuous-batching loop over an Engine's compiled steps.

    Use through ``Engine.submit / Engine.step / Engine.drain`` — the
    engine constructs one lazily and forwards. ``step()`` performs: admit
    (FCFS while the paged cache has rows and the queue head has arrived) →
    one decode step over the live batch → harvest finished rows.
    """

    def __init__(self, engine, *, clock=None, comm_telemetry: bool = True):
        cfg = engine.cfg
        if cfg.family == "audio":
            raise NotImplementedError(
                "the continuous scheduler serves decoder-only families; "
                "enc-dec audio keeps Engine.generate")
        self.engine = engine
        self.cfg = cfg
        self.mesh = engine.mesh
        self.resolved: ResolvedServeSpec = engine.resolved
        self.spec = self.resolved.spec
        self.clock = clock or WallClock()
        self.comm_telemetry = comm_telemetry
        self.tracer = engine.tracer
        self.registry = engine.registry
        self.sequential = self.resolved.combine.algorithm != "none"
        if self.sequential and self.spec.batch != 1:
            raise ValueError(
                "sequence-sharded layouts schedule one request at a time: "
                f"batch must be 1, got {self.spec.batch}")
        self.paged = PagedKVCache(self.spec.batch, self.spec.cache_len,
                                  self.spec.page_len,
                                  n_pods=self.resolved.n_pods
                                  if self.resolved.batch_sharded else 1)
        self.queue: list[Request] = []       # sorted by (arrival_s, rid)
        self.active: dict[int, _Active] = {}
        self.results: dict[int, RequestResult] = {}
        self._next_rid = 0
        self._tok = np.zeros((self.spec.batch, 1), np.int32)
        self._prefills: dict[tuple, tuple] = {}   # (pod, S) -> compiled
        self._pod_params: dict[int, Any] = {}
        self._migrations = 0
        self._steps = 0
        self._insert_fn = None
        self._migrate_fn = None
        self._migrate_compiled = None
        self._migrate_label = None
        self._extract_fn = None
        self._build_decode()
        self._build_insert()

    # -- compiled-step construction ------------------------------------
    def _build_decode(self) -> None:
        """The continuous decode step: the engine's forward with a per-row
        (B,) position vector (batch mode), or the engine's own scalar-pos
        decode fn (sequential mode)."""
        art = self.engine.art
        if self.sequential:
            self._decode = self.engine._decode_callable
            self._decode_label = self.engine.comm_label
            self._cache = None            # sequential: cache per request
            self.cache_sh = art.cache_shardings_
            return
        cfg, mesh = self.cfg, self.mesh
        shard = make_shard_fn(mesh)
        B, L = self.spec.batch, self.spec.cache_len
        self.cache_sh = art.cache_shardings_
        self.abstract_cache = transformer.cache_specs(cfg, B, L,
                                                      vector_pos=True)

        def decode(params, cache, tokens):
            logits, _, cache = transformer.forward(params, cfg, tokens,
                                                   cache=cache, shard=shard)
            return logits, cache

        fn = jax.jit(decode,
                     in_shardings=(art.param_shardings, self.cache_sh,
                                   art.tok_sharding),
                     donate_argnums=(1,), out_shardings=(None, self.cache_sh))
        self._decode = fn
        self._decode_label = "serve/decode:cont"
        if self.comm_telemetry:
            try:
                a_tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
                compiled = fn.lower(art.abstract_params, self.abstract_cache,
                                    a_tok).compile()
                from repro import telemetry
                rep = telemetry.comm_report(compiled.as_text(), mesh,
                                            label=self._decode_label)
                self.registry.attach_comm_report(self._decode_label, rep)
                self._decode = compiled
            except Exception:             # pragma: no cover - backend quirks
                self.comm_telemetry = False
        init = jax.jit(lambda: jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), self.abstract_cache),
            out_shardings=self.cache_sh)
        self._cache = init()

    def _build_insert(self) -> None:
        if self.sequential:
            return
        from .engine import cache_shardings
        cfg, mesh = self.cfg, self.mesh
        B, L = self.spec.batch, self.spec.cache_len
        # donor layout: a B=1 prefill cache as cache_shardings places it —
        # KV slabs sequence-sharded over ('pod','data') where divisible
        self.donor_specs = cache_shardings(cfg, mesh, 1, L,
                                           self.spec.seq_axes)
        self.donor_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                     self.donor_specs)
        rep_specs = jax.tree.map(lambda _: P(), self.donor_specs)
        self.rep_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                   rep_specs)
        self._insert_fn = make_insert_fn(mesh, B, self.cache_sh, self.rep_sh)
        self._migrate_fn = None
        self._migrate_label = None
        if self.resolved.n_pods > 1 and self.resolved.batch_sharded:
            alg = self.spec.migrate
            if alg == "auto":
                slab = self._slab_bytes()
                from repro.tuning.policy import default_policy
                p = self.resolved.n_pods * self.resolved.p_local
                alg = default_policy().select(
                    "cache_migrate", p, self.resolved.p_local,
                    slab).algorithm
            self._migrate_alg = alg
            self._migrate_fn = make_migrate_insert_fn(
                mesh, B, self.cache_sh, self.donor_specs, self.donor_sh, alg)
            self._migrate_label = f"serve/migrate:{alg}"
            if self.comm_telemetry:
                self._stamp_migrate()

    def _slab_bytes(self) -> int:
        """Per-rank bytes of one request's KV slab (the migrate payload)."""
        total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(
                transformer.cache_specs(self.cfg, 1, self.spec.cache_len))[0]:
            if _leaf_name(path) in ("k", "v"):
                total += int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        p = self.resolved.n_pods * self.resolved.p_local
        return max(1, total // max(p, 1))

    def _stamp_migrate(self) -> None:
        try:
            a_cache = jax.tree.map(
                lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                   sharding=sh),
                self.abstract_cache, self.cache_sh)
            a_req = jax.tree.map(
                lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                   sharding=sh),
                transformer.cache_specs(self.cfg, 1, self.spec.cache_len),
                self.donor_sh)
            a_row = jax.ShapeDtypeStruct((), jnp.int32)
            compiled = self._migrate_fn.lower(a_cache, a_req,
                                              a_row).compile()
            from repro import telemetry
            rep = telemetry.comm_report(compiled.as_text(), self.mesh,
                                        label=self._migrate_label)
            self.registry.attach_comm_report(self._migrate_label, rep)
            self._migrate_compiled = compiled
        except Exception:                 # pragma: no cover - backend quirks
            self._migrate_compiled = None

    # -- pod-local prefill ---------------------------------------------
    def _pod_mesh(self, pod: int | None):
        """The home pod's submesh (axes minus 'pod') — prefill jitted over
        it provably cannot emit a DCN-crossing collective. None = the full
        mesh (single-pod topologies, sequential mode)."""
        if pod is None:
            return self.mesh
        names = list(self.mesh.axis_names)
        devs = np.asarray(self.mesh.devices)
        sub = np.take(devs, pod, axis=names.index("pod"))
        return Mesh(sub, tuple(n for n in names if n != "pod"))

    def _prefill_for(self, pod: int | None, S: int):
        """(compiled_prefill, params, tok_sharding, label) for one home pod
        and prompt length — built lazily, cached per (pod, S)."""
        key = (pod, S)
        hit = self._prefills.get(key)
        if hit is not None:
            return hit
        cfg = self.cfg
        mesh = self._pod_mesh(pod)
        from .engine import cache_shardings
        shard = make_shard_fn(mesh)

        def prefill(params, tokens):
            logits, _, cache = transformer.forward(
                params, cfg, tokens, mode="prefill",
                cache_len=self.spec.cache_len, shard=shard)
            return logits, cache

        a_params = self.engine.art.abstract_params
        pspecs = param_specs(a_params, mesh, fsdp=False)
        p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
        if pod is None:
            params = self.engine.params
            if mesh is not self.mesh:   # pragma: no cover
                params = jax.device_put(params, p_sh)
        else:
            params = self._pod_params.get(pod)
            if params is None:
                # serve params are replicated over the DP axes (fsdp=False),
                # so the pod's devices already hold every value — this pins
                # a pod-local copy the submesh jit can consume
                params = jax.device_put(self.engine.params, p_sh)
                self._pod_params[pod] = params
        c_specs = cache_shardings(cfg, mesh, 1, self.spec.cache_len,
                                  self.spec.seq_axes)
        c_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), c_specs)
        tok_sh = NamedSharding(mesh, P())
        fn = jax.jit(prefill, in_shardings=(p_sh, tok_sh),
                     out_shardings=(None, c_sh))
        label = f"serve/prefill:pod{pod if pod is not None else 'all'}:s{S}"
        if self.comm_telemetry:
            try:
                a_tok = jax.ShapeDtypeStruct((1, S), jnp.int32)
                # trace under the submesh: the forward's bare-P sharding
                # constraints (model axis) must resolve on the pod's
                # devices, not the ambient full mesh (Mesh's own context
                # manager nests and restores, unlike jax.set_mesh)
                with mesh:
                    compiled = fn.lower(a_params, a_tok).compile()
                from repro import telemetry
                rep = telemetry.comm_report(compiled.as_text(), mesh,
                                            label=label)
                self.registry.attach_comm_report(label, rep)
                fn = compiled
            except Exception:             # pragma: no cover
                pass
        entry = (fn, params, tok_sh, label, mesh)
        self._prefills[key] = entry
        return entry

    # -- public API -----------------------------------------------------
    def submit(self, req: Request) -> int:
        """Enqueue; returns the request id (the handle)."""
        if not self.paged.fits(req.tokens.size, req.max_new):
            raise ValueError(
                f"request of {req.tokens.size}+{req.max_new} tokens can "
                f"never fit a {self.spec.cache_len}-slot row")
        rid = self._next_rid
        self._next_rid += 1
        arrival = req.arrival_s if req.arrival_s is not None \
            else self.clock.now()
        req = dataclasses.replace(req, rid=rid, arrival_s=arrival)
        bisect.insort(self.queue, req,
                      key=lambda r: (r.arrival_s, r.rid))
        return rid

    def cancel(self, rid: int) -> bool:
        """Evict a queued or running request (finish_reason "evicted")."""
        for i, req in enumerate(self.queue):
            if req.rid == rid:
                self.queue.pop(i)
                self._finish_meta(rid, req, None, "evicted")
                return True
        st = self.active.pop(rid, None)
        if st is not None:
            self.paged.release(rid)
            self._finish_meta(rid, st.req, st, "evicted")
            return True
        return False

    def step(self) -> list[RequestResult]:
        """Admit what fits, run one decode step, harvest finished rows."""
        self._admit()
        if not self.active:
            if self.queue:
                self.clock.idle_until(self.queue[0].arrival_s)
                self._admit()
            if not self.active:
                return []
        if self.sequential:
            return self._step_sequential()
        toks = jnp.asarray(self._tok)
        if self.comm_telemetry:
            toks = jax.device_put(toks, self.engine.art.tok_sharding)
        with self.tracer.span("serve/decode_step"):
            logits, self._cache = self._decode(self.engine.params,
                                               self._cache, toks)
            nxt = np.asarray(self._next_token(logits))
        self.clock.advance("decode")
        self._steps += 1
        if self.comm_telemetry:
            self.registry.record_comm(self._decode_label)
        return self._harvest(nxt)

    def drain(self) -> dict[int, RequestResult]:
        """Run until queue and batch are empty; all results by rid."""
        while self.queue or self.active:
            self.step()
        return dict(self.results)

    # -- suspend / resume (graceful drain for restarts, DESIGN.md §10) --
    def _make_extract_fn(self):
        """jit((cache, row) -> B=1 slab in the donor/replicated layout) —
        the transpose of ``make_insert_fn``: the suspended request's row
        leaves the live batch cache the same shape the insert/migration
        machinery puts it back with on resume."""
        def extract(cache, row):
            def visit(path, leaf):
                b = _leaf_batch_dim(path, leaf)
                if b is None:              # (B,) pos vector -> donor scalar
                    return leaf[row]
                return jax.lax.dynamic_slice_in_dim(leaf, row, 1, b)
            return jax.tree_util.tree_map_with_path(visit, cache)

        return jax.jit(extract, in_shardings=(self.cache_sh, None),
                       out_shardings=self.rep_sh)

    def _req_meta(self, st: _Active) -> dict:
        return {"rid": st.req.rid,
                "prompt": np.asarray(st.req.tokens).tolist(),
                "max_new": int(st.req.max_new),
                "arrival_s": st.req.arrival_s,
                "home_pod": st.req.home_pod,
                "generated": [int(t) for t in st.tokens],
                "times": [float(t) for t in st.times],
                "started_s": float(st.started_s),
                "migrated": bool(st.migrated)}

    def suspend(self, ckpt_dir: str) -> str:
        """Checkpoint every in-flight request — per-row KV slab (extracted
        through the insert machinery's transpose) plus token/queue state —
        through the v2 store (atomic commit, replication, sharded chunks in
        sequential mode). A restarted engine's :meth:`resume` replays them;
        nothing is dropped. The scheduler itself is left untouched."""
        from repro.checkpoint import save_checkpoint
        tree: dict[str, Any] = {}
        meta_active = []
        for rid, st in sorted(self.active.items()):
            if self.sequential:
                tree[f"r{rid}"] = self._cache     # B=1: cache IS the slab
            else:
                if self._extract_fn is None:
                    self._extract_fn = self._make_extract_fn()
                tree[f"r{rid}"] = self._extract_fn(
                    self._cache, jnp.asarray(st.row, jnp.int32))
            meta_active.append(self._req_meta(st))
        queued = [{"rid": r.rid, "prompt": np.asarray(r.tokens).tolist(),
                   "max_new": int(r.max_new), "arrival_s": r.arrival_s,
                   "home_pod": r.home_pod} for r in self.queue]
        extra = {"kind": "serve_suspend", "active": meta_active,
                 "queued": queued, "next_rid": self._next_rid,
                 "now": float(self.clock.now()), "steps": self._steps,
                 "batch": self.spec.batch}
        with self.tracer.span("serve/suspend", active=len(meta_active),
                              queued=len(queued)):
            path = save_checkpoint(ckpt_dir, self._steps, tree, extra=extra)
        self.registry.count("serve/suspends")
        return path

    def resume(self, ckpt_dir: str) -> int:
        """Reload a :meth:`suspend` checkpoint into this (fresh) scheduler:
        re-reserve rows, re-insert each KV slab via the same insert path a
        migrated prefill takes, rebuild the queue — restart replays rather
        than drops. Returns the number of requests brought back."""
        from repro.checkpoint import (CheckpointError, read_manifest,
                                      restore_checkpoint)
        if self.active or self.queue:
            raise RuntimeError("resume() requires a fresh scheduler")
        rm = read_manifest(ckpt_dir)
        if rm is None:
            raise CheckpointError(f"no serve checkpoint under {ckpt_dir}")
        step, manifest = rm
        extra = manifest.get("extra", {})
        if extra.get("kind") != "serve_suspend":
            raise CheckpointError("not a serve suspend checkpoint",
                                  step=step)
        like, shardings = {}, {}
        for m in extra["active"]:
            key = f"r{m['rid']}"
            if self.sequential:
                like[key] = self.engine.art.abstract_cache
                shardings[key] = self.cache_sh
            else:
                like[key] = transformer.cache_specs(self.cfg, 1,
                                                    self.spec.cache_len)
                shardings[key] = self.rep_sh
        slabs = {}
        if like:
            _, slabs = restore_checkpoint(ckpt_dir, like, step=step,
                                          shardings=shardings)
        with self.tracer.span("serve/resume", active=len(extra["active"]),
                              queued=len(extra["queued"])):
            for m in extra["active"]:
                rid = m["rid"]
                req = Request(tokens=np.asarray(m["prompt"], np.int32),
                              max_new=m["max_new"],
                              arrival_s=m["arrival_s"],
                              home_pod=m["home_pod"], rid=rid)
                row = self.paged.reserve(rid, req.tokens.size, req.max_new,
                                         home_pod=req.home_pod)
                if row is None:
                    raise RuntimeError(
                        f"resume: no free row for suspended request {rid}")
                slab = slabs[f"r{rid}"]
                if self.sequential:
                    self._cache = slab
                else:
                    self._cache = self._insert_fn(
                        self._cache, slab, jnp.asarray(row, jnp.int32))
                st = _Active(req=req, row=row, started_s=m["started_s"],
                             migrated=m["migrated"],
                             tokens=list(m["generated"]),
                             times=list(m["times"]))
                self.active[rid] = st
                if not self.sequential:
                    self._tok[row, 0] = st.tokens[-1]
            for qm in extra["queued"]:
                req = Request(tokens=np.asarray(qm["prompt"], np.int32),
                              max_new=qm["max_new"],
                              arrival_s=qm["arrival_s"],
                              home_pod=qm["home_pod"], rid=qm["rid"])
                bisect.insort(self.queue, req,
                              key=lambda r: (r.arrival_s, r.rid))
        self._next_rid = max(self._next_rid, extra["next_rid"])
        self._steps = extra["steps"]
        if not isinstance(self.clock, WallClock):
            # StepClock replay: resumed stamps continue from the suspend
            # point; WallClock perf_counters don't compare across processes
            self.clock.idle_until(extra["now"])
        self.registry.count("serve/resumes")
        return len(extra["active"]) + len(extra["queued"])

    def result(self, rid: int) -> RequestResult | None:
        return self.results.get(rid)

    def stats(self) -> dict:
        out = {"steps": self._steps, "migrations": self._migrations,
               "active": len(self.active), "queued": len(self.queue),
               "finished": len(self.results)}
        if self.comm_telemetry:
            out["comm"] = {label: self.registry.reconcile(label)
                           for label in self._stamped_labels()}
        return out

    def _stamped_labels(self) -> list[str]:
        labels = [self._decode_label]
        labels += [entry[3] for entry in self._prefills.values()]
        if self._migrate_label is not None and self._migrations:
            labels.append(self._migrate_label)
        return [l for l in labels
                if self.registry.comm_report(l) is not None]

    # -- internals ------------------------------------------------------
    def _next_token(self, logits) -> jax.Array:
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        return jnp.minimum(tok, self.cfg.vocab_size - 1)

    def _admit(self) -> None:
        now = self.clock.now()
        while self.queue:
            req = self.queue[0]
            if req.arrival_s > now:
                break                      # not arrived yet
            if self.sequential and self.active:
                break                      # one request at a time
            row = self.paged.reserve(req.rid, req.tokens.size, req.max_new,
                                     home_pod=req.home_pod)
            if row is None:
                break                      # FCFS: the head waits, nobody
            self.queue.pop(0)              # overtakes (starvation-free)
            self._start(req, row)
            now = self.clock.now()

    def _start(self, req: Request, row: int) -> None:
        S = int(req.tokens.size)
        home = req.home_pod
        use_pod_prefill = (self.resolved.n_pods > 1
                           and self.resolved.batch_sharded
                           and not self.sequential)
        pod = (home if home is not None
               else self.paged.pod_of_row(row)) if use_pod_prefill else None
        fn, params, tok_sh, label, mesh_sub = self._prefill_for(pod, S)
        toks = jax.device_put(jnp.asarray(req.tokens)[None, :], tok_sh)
        with self.tracer.span("serve/prefill", rid=req.rid, prompt_len=S):
            with mesh_sub:                  # non-AOT path traces here
                logits, req_cache = fn(params, toks)
            tok0 = np.asarray(self._next_token(logits))
        self.clock.advance("prefill")
        if self.comm_telemetry \
                and self.registry.comm_report(label) is not None:
            self.registry.record_comm(label)

        migrated = False
        if self.sequential:
            # B=1: the request cache IS the serving cache (the device_put
            # is the donor→serving reshard)
            self._cache = jax.device_put(req_cache, self.cache_sh)
        else:
            row_pod = self.paged.pod_of_row(row)
            if (self._migrate_fn is not None and pod is not None
                    and row_pod != pod):
                # home pod's slab must cross the DCN to the owning rows
                migrated = True
                self._migrations += 1
                req_cache = jax.device_put(req_cache, self.donor_sh)
                with self.tracer.span("serve/migrate", rid=req.rid,
                                      src_pod=pod, dst_pod=row_pod):
                    mfn = (self._migrate_compiled
                           if self.comm_telemetry
                           and self._migrate_compiled is not None
                           else self._migrate_fn)
                    self._cache = mfn(self._cache, req_cache,
                                      jnp.asarray(row, jnp.int32))
                if self.comm_telemetry and self.registry.comm_report(
                        self._migrate_label) is not None:
                    self.registry.record_comm(self._migrate_label)
            else:
                req_cache = jax.device_put(req_cache, self.rep_sh)
                self._cache = self._insert_fn(self._cache, req_cache,
                                              jnp.asarray(row, jnp.int32))
        t = self.clock.now()
        st = _Active(req=req, row=row, started_s=t, migrated=migrated)
        st.tokens.append(int(tok0[0, 0]))
        st.times.append(t)
        self._tok[row, 0] = st.tokens[-1]
        self.active[req.rid] = st
        if len(st.tokens) >= req.max_new:
            self._finish(req.rid, "length")

    def _harvest(self, nxt: np.ndarray) -> list[RequestResult]:
        t = self.clock.now()
        done = []
        for rid in list(self.active):
            st = self.active[rid]
            st.tokens.append(int(nxt[st.row, 0]))
            st.times.append(t)
            self._tok[st.row, 0] = st.tokens[-1]
            if len(st.tokens) >= st.req.max_new:
                done.append(self._finish(rid, "length"))
        return done

    def _step_sequential(self) -> list[RequestResult]:
        (rid, st), = self.active.items()
        tok = jnp.asarray([[st.tokens[-1]]], jnp.int32)
        if self.engine.comm_report is not None:
            tok = jax.device_put(tok, self.engine.art.tok_sharding)
        with self.tracer.span("serve/decode_step"):
            logits, self._cache = self._decode(self.engine.params,
                                               self._cache, tok)
            nxt = np.asarray(self._next_token(logits))
        self.clock.advance("decode")
        self._steps += 1
        if self.engine.comm_report is not None:
            self.registry.record_comm(self._decode_label)
        t = self.clock.now()
        st.tokens.append(int(nxt[0, 0]))
        st.times.append(t)
        if len(st.tokens) >= st.req.max_new:
            return [self._finish(rid, "length")]
        return []

    def _finish(self, rid: int, reason: str) -> RequestResult:
        st = self.active.pop(rid)
        self.paged.release(rid)
        self.registry.count("serve/tokens", len(st.tokens))
        return self._finish_meta(rid, st.req, st, reason)

    def _finish_meta(self, rid: int, req: Request, st, reason: str
                     ) -> RequestResult:
        res = RequestResult(
            rid=rid,
            tokens=np.asarray(st.tokens if st else [], np.int32),
            finish_reason=reason,
            arrival_s=req.arrival_s or 0.0,
            started_s=st.started_s if st else self.clock.now(),
            finished_s=self.clock.now(),
            token_times_s=list(st.times) if st else [],
            home_pod=req.home_pod or 0,
            slot=st.row if st else -1,
            migrated=st.migrated if st else False)
        self.results[rid] = res
        return res
