"""Request-level serving API: the three dataclasses of the serve surface.

The old surface was a kwarg sprawl — ``make_serve_fns(cfg, mesh, *, batch,
cache_len, combine, fused_stats, seq_axes, ...)`` with ``Engine.__init__``
repeating every knob.  The scheduler cannot bolt onto that, so the surface
is three small dataclasses instead:

* :class:`ServeSpec`      — static compile-time geometry (batch, cache_len,
  the combine / fused_stats / seq_axes policies, paging granularity),
  resolved once against a concrete ``(cfg, mesh)`` via
  :meth:`ServeSpec.resolve`;
* :class:`Request`        — one user request: prompt tokens, decode budget,
  arrival metadata, home pod;
* :class:`RequestResult`  — the finished request: generated tokens,
  per-token completion stamps, finish reason.

``Engine(cfg, mesh, params, spec)`` plus ``submit(request) -> handle`` /
``step()`` / ``drain()`` is the new API; the old keyword constructors keep
working one release behind a ``DeprecationWarning`` (see engine.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np


@dataclasses.dataclass(frozen=True)
class ServeSpec:
    """Static serving geometry — everything that shapes the compiled steps.

    batch:       decode batch rows (the paged cache's slot count).
    cache_len:   KV slots per row (prompt + decode budget ceiling).
    combine:     decode cache-combine policy — "auto" (tuning policy),
                 "xla", or "locality".
    fused_stats: partial-stat impl inside the locality combine region —
                 "auto" / "jnp" / "pallas" / "pallas_interpret".
    seq_axes:    sequence-parallel cache domain — "auto" spans every DP
                 axis (('pod','data') on multi-pod meshes), ("data",)
                 forces the legacy intra-pod layout.
    page_len:    paging granularity in KV slots: admission reserves
                 ceil((prompt + max_new) / page_len) pages in the
                 request's row (conservative — a request can never
                 outgrow its reservation, so eviction is policy, not
                 necessity).
    migrate:     cross-pod cache-migration collective — "auto" resolves
                 through the ``cache_migrate`` tuning cell, or one of
                 ``core.collectives.MIGRATE_ALGORITHMS``.
    """

    batch: int
    cache_len: int
    prefill_len: int | None = None
    combine: str = "auto"
    fused_stats: str = "auto"
    seq_axes: str | tuple[str, ...] = "auto"
    page_len: int = 16
    migrate: str = "auto"

    def resolve(self, cfg, mesh) -> "ResolvedServeSpec":
        """Bind the spec to a concrete (cfg, mesh): one place computes the
        layout decision (batch- vs sequence-sharded), the combine choice,
        and the pod geometry, so the engine, the scheduler, and the
        benchmarks cannot drift on any of them."""
        from .engine import (_axsize, _cache_layout, _seq_axes_for,
                             resolve_cache_combine)
        batch_sharded, seq_cand = _cache_layout(mesh, self.batch,
                                                self.seq_axes)
        choice = resolve_cache_combine(
            cfg, mesh, self.batch, self.cache_len,
            override=None if self.combine == "auto" else self.combine,
            seq_axes=self.seq_axes)
        n_pods = _axsize(mesh, "pod")
        p_local = _axsize(mesh, "data")
        seq_span = _seq_axes_for(mesh, self.cache_len, seq_cand)
        return ResolvedServeSpec(
            spec=self, batch_sharded=batch_sharded, seq_cand=seq_cand,
            seq_span=seq_span, combine=choice, n_pods=n_pods,
            p_local=p_local)


@dataclasses.dataclass(frozen=True)
class ResolvedServeSpec:
    """A ServeSpec bound to (cfg, mesh): the derived geometry.

    seq_cand: the DP axes a sequence-parallel cache may shard over
              (layout candidates, per-layer narrowing via _seq_axes_for).
    seq_span: the span a full-length cache actually shards over (None for
              batch-sharded / replicated layouts).
    """

    spec: ServeSpec
    batch_sharded: bool
    seq_cand: tuple[str, ...] | None
    seq_span: tuple[str, ...] | None
    combine: Any
    n_pods: int
    p_local: int

    @property
    def batch(self) -> int:
        return self.spec.batch

    @property
    def cache_len(self) -> int:
        return self.spec.cache_len

    def pod_of_row(self, row: int) -> int:
        """Home pod of batch row ``row`` under the batch-sharded layout:
        P(('pod','data')) on the batch dim places contiguous row blocks
        pod-major, so row r lives in pod r·n_pods // batch."""
        if self.n_pods <= 1 or not self.batch_sharded:
            return 0
        return (row * self.n_pods) // self.batch


@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request. ``tokens`` is the (S,) int32 prompt; ``max_new``
    the decode budget; ``home_pod`` the pod whose HBM should absorb the
    prefill (None = wherever a slot frees first); ``arrival_s`` the arrival
    stamp on the submitting clock (the scheduler's clock if unset)."""

    tokens: np.ndarray
    max_new: int
    home_pod: int | None = None
    arrival_s: float | None = None
    rid: int | None = None        # assigned by Engine.submit

    def __post_init__(self):
        t = np.asarray(self.tokens, dtype=np.int32)
        if t.ndim != 1 or t.size == 0:
            raise ValueError(f"Request.tokens must be a non-empty 1-D "
                             f"prompt, got shape {t.shape}")
        object.__setattr__(self, "tokens", t)
        if self.max_new < 1:
            raise ValueError("Request.max_new must be >= 1")


@dataclasses.dataclass
class RequestResult:
    """A finished request.

    finish_reason: "length" (decode budget exhausted), "evicted"
    (cancelled by the scheduler), or "error".
    token_times_s: completion stamp of each generated token on the
    scheduler's clock — per-token latency is ``t - arrival_s``.
    """

    rid: int
    tokens: np.ndarray
    finish_reason: str
    arrival_s: float
    started_s: float
    finished_s: float
    token_times_s: list[float] = dataclasses.field(default_factory=list)
    home_pod: int = 0
    slot: int = -1
    migrated: bool = False

    @property
    def n_tokens(self) -> int:
        return int(np.asarray(self.tokens).size)
