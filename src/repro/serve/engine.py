"""Serving: jitted prefill/decode steps, cache sharding, batched engine.

Cache placement policy (per leaf):
  * KV caches (…, B, L, KV, D): batch over the DP axes when divisible
    (decode_32k: 128 rows over 16/32 chips); otherwise the *sequence* dim
    is sharded over the DP axes — ('pod','data') on a multi-pod mesh when
    the layer's cache length divides the full span (long_500k: B=1, 512k
    context split across BOTH pods; the decode combine then crosses the
    DCN), falling back to 'data' alone (pods replicate) otherwise —
    sequence-parallel decode. KV heads shard over 'model' when divisible.
  * SSM caches: batch over DP, heads over 'model'.

Decode is compiled twice when the tuning policy picks a non-XLA combine for
the sequence-parallel cache reduction:
  * "xla"      — single jit; XLA turns the position-masked attention over
    the sequence-sharded cache into partial reductions + its own implicit
    combine (an all-reduce of the full per-step stat payload).
  * "locality" — the same forward, but every decode-attention layer runs
    inside a FULLY-manual ``shard_map`` region (all mesh axes manual — the
    legacy partitioner cannot place manual-axis collectives in partial-auto
    regions, see DESIGN.md §3): per-shard flash-style partial stats
    (o-accumulator, running max, sumexp) from
    ``models.attention.decode_partial_stats``, combined with the explicit
    ``core.collectives.logsumexp_combine``
    (max-allreduce → rescale → packed sum-allreduce). The cache write lands
    on the owning shard via a masked device-local dynamic_update_slice —
    no gather of the sharded cache, and no all-reduce of the stat payload
    in the compiled HLO.
``Engine`` dispatches on the resolved ``CombineChoice`` and surfaces
per-step combine traffic in ``Engine.stats()``.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import collectives as C
from repro.kernels.decode_stats import ops as stats_ops
from repro.models import encdec, transformer
from repro.models.attention import decode_stats_scores
from repro.train.sharding import (dp_axes, make_shard_fn, normalize_axes,
                                  param_specs)
from .spec import Request, RequestResult, ServeSpec


def _axsize(mesh, name) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.devices.shape[list(mesh.axis_names).index(name)]


def cache_specs(cfg, batch: int, cache_len: int, *, vector_pos: bool = False):
    mod = encdec if cfg.family == "audio" else transformer
    if vector_pos:                 # continuous batching: per-row positions
        return mod.cache_specs(cfg, batch, cache_len, vector_pos=True)
    return mod.cache_specs(cfg, batch, cache_len)


def _coerce_spec(spec, batch, cache_len, prefill_len, combine, fused_stats,
                 seq_axes, caller: str) -> ServeSpec:
    """Normalize the serve surface to a ServeSpec.

    New API: ``caller(cfg, mesh, ServeSpec(...))``. The legacy keyword
    surface (``batch=``, ``cache_len=``, ...) keeps working one release
    behind a DeprecationWarning; mixing both is an error."""
    legacy = {k: v for k, v in dict(batch=batch, cache_len=cache_len,
                                    prefill_len=prefill_len, combine=combine,
                                    fused_stats=fused_stats,
                                    seq_axes=seq_axes).items()
              if v is not None}
    if spec is not None:
        if legacy:
            raise TypeError(
                f"{caller}: pass either a ServeSpec or the legacy keywords, "
                f"not both (got {sorted(legacy)})")
        return spec
    if batch is None or cache_len is None:
        raise TypeError(f"{caller} requires a ServeSpec (or, deprecated, "
                        "the batch=/cache_len= keywords)")
    warnings.warn(
        f"{caller}(..., batch=, cache_len=, ...) is deprecated; pass "
        f"{caller}(cfg, mesh, ServeSpec(batch=..., cache_len=..., ...)) "
        "(removal one release out, see DESIGN.md §9)",
        DeprecationWarning, stacklevel=3)
    return ServeSpec(
        batch=batch, cache_len=cache_len, prefill_len=prefill_len,
        combine=combine if combine is not None else "auto",
        fused_stats=fused_stats if fused_stats is not None else "auto",
        seq_axes=seq_axes if seq_axes is not None else "auto")


def _cache_layout(mesh, batch: int,
                  seq_axes: str | tuple[str, ...] = "auto"
                  ) -> tuple[bool, tuple[str, ...] | None]:
    """(batch_sharded, seq_axes_candidates): the one placement decision both
    the cache shardings and the combine resolution key off — kept in one
    place so they cannot drift.

    The candidates are the DP axes a sequence-parallel cache may shard
    over, outer-major: ``('pod','data')`` on a multi-pod mesh (the decode
    combine then genuinely crosses the DCN boundary) and ``('data',)``
    otherwise. Per-layer divisibility narrows them via
    :func:`_seq_axes_for`. ``seq_axes=("data",)`` forces the legacy
    intra-pod layout (pods replicate the cache — the flat baseline the
    multipod benchmark compares against)."""
    dp = dp_axes(mesh)
    dp_size = max(1, int(np.prod([_axsize(mesh, a) for a in dp])))
    batch_sharded = bool(dp) and batch % dp_size == 0 and batch >= dp_size
    if "data" not in mesh.axis_names:
        cand = None
    elif seq_axes == "auto":
        cand = dp
    else:
        cand = tuple(a for a in normalize_axes(seq_axes)
                     if a in mesh.axis_names) or None
    return batch_sharded, cand


def _seq_axes_for(mesh, L: int, cand: tuple[str, ...] | None
                  ) -> tuple[str, ...] | None:
    """The widest span a cache of ``L`` slots actually shards over: the full
    composite when divisible, the intra-pod ('data',) slice otherwise, None
    when neither divides (that layer keeps a replicated cache)."""
    if not cand:
        return None
    full = int(np.prod([_axsize(mesh, a) for a in cand]))
    if full > 1 and L % full == 0:
        return cand
    if "data" in cand:
        d = _axsize(mesh, "data")
        if d > 1 and L % d == 0:
            return ("data",)
    return None


def cache_shardings(cfg, mesh, batch: int, cache_len: int,
                    seq_axes: str | tuple[str, ...] = "auto"):
    """PartitionSpec pytree matching cache_specs."""
    dp = dp_axes(mesh)
    m = _axsize(mesh, "model")

    def on_model(dim: int) -> bool:    # shardable over a real 'model' axis?
        return m > 1 and dim % m == 0

    batch_sharded, seq_cand = _cache_layout(mesh, batch, seq_axes)

    def visit(path, leaf):
        keys = tuple(str(getattr(p, "key", getattr(p, "idx", ""))) for p in path)
        shape = leaf.shape
        name = keys[-1] if keys else ""
        # find batch dim: stacked leaves carry leading (reps/L,) dims
        if name in ("k", "v") or (len(keys) >= 2 and keys[-2] == "cross"):
            nd = len(shape)
            b_dim = nd - 4
            L_dim, kv_dim, d_dim = b_dim + 1, b_dim + 2, b_dim + 3
            spec = [None] * nd
            if batch_sharded:
                spec[b_dim] = dp
                # model axis: prefer KV heads; else head_dim (a dynamic
                # update on a sharded *sequence* dim makes GSPMD gather the
                # whole cache); else the sequence dim as last resort.
                if on_model(shape[kv_dim]):
                    spec[kv_dim] = "model"
                elif on_model(shape[d_dim]):
                    spec[d_dim] = "model"
                elif on_model(shape[L_dim]):
                    spec[L_dim] = "model"
            else:
                # B=1 long-context: sequence-parallel cache over the DP
                # axes (('pod','data') on multi-pod when divisible — the
                # locality combine's domain), plus KV-heads/head_dim over
                # 'model' when divisible.
                ax = _seq_axes_for(mesh, shape[L_dim], seq_cand)
                if ax:
                    spec[L_dim] = ax if len(ax) > 1 else ax[0]
                if on_model(shape[kv_dim]):
                    spec[kv_dim] = "model"
                elif on_model(shape[d_dim]):
                    spec[d_dim] = "model"
            return P(*spec)
        if name == "conv":
            nd = len(shape)
            spec = [None] * nd
            if batch_sharded:
                spec[nd - 3] = dp
            if on_model(shape[nd - 1]):
                spec[nd - 1] = "model"
            return P(*spec)
        if name == "h":
            nd = len(shape)
            spec = [None] * nd
            if batch_sharded:
                spec[nd - 4] = dp
            if on_model(shape[nd - 3]):
                spec[nd - 3] = "model"
            return P(*spec)
        return P()                                 # pos scalar

    return jax.tree_util.tree_map_with_path(visit, cache_specs(cfg, batch, cache_len))


@dataclasses.dataclass(frozen=True)
class ServeArtifacts:
    prefill_fn: Callable      # (params, batch) -> (logits, cache)
    decode_fn: Callable       # (params, cache, tokens) -> (logits, cache)
    param_shardings: Any
    cache_shardings_: Any
    abstract_params: Any
    combine: Any = None       # CombineChoice for the decode cache-combine
    decode_fn_xla: Callable | None = None       # always-compiled GSPMD path
    decode_fn_locality: Callable | None = None  # manual combine path (or None)
    fused_stats: str = "jnp"  # resolved partial-stat impl ("jnp"/"pallas"/...)
    seq_axes: Any = None      # sequence-shard candidates (('pod','data')/...)
    tok_sharding: Any = None  # decode-token sharding (AOT calls don't reshard)
    abstract_cache: Any = None  # ShapeDtypeStruct pytree for decode lowering


@dataclasses.dataclass(frozen=True)
class CombineChoice:
    """Resolved collective for the sequence-parallel decode combine.

    When the KV cache is sequence-sharded over 'data' (B=1 long-context),
    every decode step reduces per-shard partial attention stats — o (B,1,H,D)
    plus the logsumexp accumulator (B,1,H) in fp32 — across the sequence
    shards. ``algorithm`` is what the tuning policy picks for an allreduce
    of that payload on this topology; "xla" keeps GSPMD's own combine,
    "locality" routes it through the paper-structured allreduce.
    """

    algorithm: str            # "xla" | "locality" | "none" (no seq sharding)
    source: str               # "table" | "model" | "n/a"
    nbytes: int               # per-step combine payload in bytes
    p: int                    # ranks participating in the combine
    p_local: int


def resolve_cache_combine(cfg, mesh, batch: int, cache_len: int,
                          override: str | None = None,
                          seq_axes: str | tuple[str, ...] = "auto"
                          ) -> CombineChoice:
    """Resolve the decode cache-combine collective through repro.tuning.

    The combine is priced as the two-phase ``logsumexp_combine`` collective
    (max-allreduce of the running max, then the packed o+l sum-allreduce) —
    not as a single sum allreduce, which is what it replaces.
    ``override`` ("xla"/"locality") forces the algorithm, keeping the
    resolved geometry (source becomes "explicit"); the layout still decides
    whether there is anything to combine at all.

    On a multi-pod mesh with the cache sequence-sharded over
    ``('pod','data')`` the combine spans both tiers: ``p`` is the full
    shard count and ``p_local`` the intra-pod 'data' slice, so the policy
    prices the hierarchical (intra-pod, then inter-pod) structure against
    GSPMD's flat combine. The pod count q = p/p_local may be ANY integer —
    non-power counts price (and execute) the fold/unfold max phase and the
    Bruck-transpose sum phase of DESIGN.md §7 rather than falling back to
    a flat psum. ``seq_axes=("data",)`` forces the legacy intra-pod domain.
    """
    if override is not None and override not in ("xla", "locality"):
        raise ValueError(f"unknown combine override {override!r}")
    batch_sharded, seq_cand = _cache_layout(mesh, batch, seq_axes)
    ax = None if batch_sharded else _seq_axes_for(mesh, cache_len, seq_cand)
    if ax is None:
        return CombineChoice("none", "n/a", 0, 1, 1)
    H = getattr(cfg, "n_heads", 1)
    D = getattr(cfg, "head_dim_", getattr(cfg, "d_model", 0) // max(H, 1))
    # per-RANK stat payload: when cache_shardings puts KV heads on 'model'
    # the combine moves H/m heads per rank, not H — pricing with the full
    # head count would overstate the payload by the TP factor
    m = _axsize(mesh, "model")
    if m > 1 and getattr(cfg, "n_kv_heads", H) % m == 0:
        H //= m
    nbytes = batch * H * (D + 1) * 4          # fp32 o + logsumexp per step
    p = int(np.prod([_axsize(mesh, a) for a in ax]))
    p_local = _axsize(mesh, "data") if "pod" in ax else p
    if override is not None:
        return CombineChoice(override, "explicit", nbytes, p, p_local)
    from repro.tuning.policy import default_policy
    sel = default_policy().select("logsumexp_combine", p, p_local, nbytes)
    return CombineChoice(sel.algorithm, sel.source, nbytes, p, p_local)


def _combine_eligible(cfg, mesh, cache_len: int,
                      seq_cand: tuple[str, ...] | None) -> bool:
    """Whether ANY decode-attention layer will take the locality hook —
    mirrors the per-layer fallbacks of ``_make_locality_decode_combine``
    (ring/chunk cache lengths indivisible by the shard count, head_dim
    model-sharded caches), so a layout where every layer would fall back
    never compiles a manual path that executes nothing. Per-step combine
    traffic is read off the compiled HLO's CommReport, never an analytic
    layer count."""
    if not seq_cand:
        return False
    m = _axsize(mesh, "model")
    kv = getattr(cfg, "n_kv_heads", 1)
    kv_sharded = m > 1 and kv % m == 0
    if m > 1 and not kv_sharded and cfg.head_dim_ % m == 0:
        return False                   # head_dim-sharded caches: xla path
    if cfg.family == "audio":
        return bool(_seq_axes_for(mesh, cache_len, seq_cand))
    for spec in cfg.layer_plan():
        if spec.mixer not in ("attn", "shared_attn"):
            continue
        rl = transformer.ring_cache_len(cfg, spec)
        L = cache_len if rl is None else min(cache_len, rl)
        if _seq_axes_for(mesh, L, seq_cand):
            return True
    return False


def _make_locality_decode_combine(cfg, mesh, seq_cand: tuple[str, ...],
                                  stats_impl: str = "jnp"):
    """Build the per-layer ``decode_combine`` hook for sequence-sharded caches.

    Returns a callable matching ``models.attention.attention``'s
    ``decode_combine`` protocol. Per layer it traces ONE fully-manual
    ``shard_map`` region (manual over every mesh axis — required on the
    legacy partitioner, and it keeps the whole cache update + partial-stat
    attention device-local) that:

      1. writes the new token's K/V into the owning sequence shard
         (masked device-local dynamic_update_slice — slot ``pos`` lives on
         shard ``pos // L_loc`` of the region-major (pod-major) flat rank;
         ring caches use slot ``pos % L``);
      2. computes the masked scores + running max over the local cache
         slice and IMMEDIATELY issues the combine's max-allreduce
         (``logsumexp_combine_start`` — split halves of
         core/collectives). On a ``('pod','data')``-sharded cache the max
         runs HIERARCHICALLY: intra-pod recursive doubling first, then the
         inter-pod exchange — rd_rounds(q) tiny DCN messages for ANY pod
         count q (non-power counts fold/unfold, DESIGN.md §7) instead of
         GSPMD's flat tree over all shards;
      3. accumulates the flash-style o/l partials (``stats_impl`` picks the
         jnp ops or the fused Pallas kernel of ``kernels/decode_stats``) —
         the real compute the in-flight max-allreduce hides behind;
      4. finishes the combine (rescale + packed sum-allreduce: intra-pod
         psum-scatter, per-lane inter-pod exchange of 1/p_ℓ of the bytes —
         each of the p_ℓ lanes reduce-scatters + allgathers its slice
         across all q pods, Bruck-transpose schedule on non-power q —
         local allgather) and normalizes.

    Falls back (returns None → the layer keeps the GSPMD path) when the
    layer's cache length is not divisible by any candidate shard span, or
    when ``cache_shardings`` would put 'model' on the head_dim (the q·k
    contraction would then need a model-axis reduction inside the region).
    A layer divisible intra-pod but not by the full composite span shards
    over ('data',) alone — its combine stays all-ICI, pods replicate.
    """
    m = _axsize(mesh, "model")
    axis_names = set(mesh.axis_names)        # fully manual region

    def combine(q, k_new, v_new, k_cache, v_cache, pos, meta):
        B, L, KV, D = k_cache.shape
        ax = _seq_axes_for(mesh, L, seq_cand)
        if ax is None:
            return None
        sizes = [_axsize(mesh, a) for a in ax]
        n = int(np.prod(sizes))
        if n == 1:
            return None
        kv_m = "model" if (m > 1 and KV % m == 0) else None
        if m > 1 and kv_m is None and D % m == 0:
            return None       # head_dim model-sharded cache: xla path
        outer = tuple(a for a in ax if a == "pod")
        local = tuple(a for a in ax if a != "pod")
        L_loc = L // n
        ring = meta["ring"]
        cache_spec = P(None, ax if len(ax) > 1 else ax[0], kv_m, None)
        new_spec = P(None, None, kv_m, None)
        q_spec = P(None, None, kv_m, None)   # H sharded iff KV heads are

        def region(q_, k_n, v_n, k_c, v_c, pos_):
            # flat shard index, region-major over (outer, local) — matches
            # GSPMD's row-major composite-axis enumeration of cache_spec
            i = lax.axis_index(ax[0])
            for a, sz in zip(ax[1:], sizes[1:]):
                i = i * sz + lax.axis_index(a)
            offset = i * L_loc
            slot_g = pos_ % L if ring else pos_
            slot_l = slot_g - offset
            owns = (slot_l >= 0) & (slot_l < L_loc)
            idx = jnp.clip(slot_l, 0, L_loc - 1)
            k_u = lax.dynamic_update_slice(k_c, k_n.astype(k_c.dtype),
                                           (0, idx, 0, 0))
            v_u = lax.dynamic_update_slice(v_c, v_n.astype(v_c.dtype),
                                           (0, idx, 0, 0))
            k_c = jnp.where(owns, k_u, k_c)
            v_c = jnp.where(owns, v_u, v_c)
            s, smask = decode_stats_scores(
                q_, k_c, pos_, slot_offset=offset, total_len=L,
                window=meta["window"], chunk=meta["chunk"], cap=meta["cap"],
                ring=ring)
            mx = jnp.max(s, axis=-1)                 # (B, KV/m, G)
            B_, KV_, G_ = mx.shape
            pend = C.logsumexp_combine_start(
                mx.reshape(B_, 1, KV_ * G_), outer, local)
            o, l = stats_ops.accumulate(s, smask, mx, v_c, impl=stats_impl)
            o, l = C.logsumexp_combine_finish(o, l, pend)
            out = (o / l[..., None]).astype(v_c.dtype)
            return out, k_c, v_c

        fn = jax.shard_map(region, mesh=mesh,
                           in_specs=(q_spec, new_spec, new_spec,
                                     cache_spec, cache_spec, P()),
                           out_specs=(q_spec, cache_spec, cache_spec),
                           axis_names=axis_names, check_vma=False)
        return fn(q, k_new, v_new, k_cache, v_cache, pos)

    return combine


def make_serve_fns(cfg, mesh, spec: ServeSpec | None = None, *,
                   batch: int | None = None, cache_len: int | None = None,
                   prefill_len: int | None = None,
                   combine: str | None = None,
                   fused_stats: str | None = None,
                   seq_axes: str | tuple[str, ...] | None = None
                   ) -> ServeArtifacts:
    """Compile the serving steps for a :class:`ServeSpec`.

    ``make_serve_fns(cfg, mesh, ServeSpec(batch=..., cache_len=...))`` is
    the API; the spread keywords are the deprecated legacy surface (see
    ``_coerce_spec``). Spec fields: ``combine`` "auto" resolves through
    repro.tuning, "xla"/"locality" force the decode cache-combine algorithm
    (explicit benchmark/test dispatch); ``fused_stats`` picks the
    partial-stat accumulation inside the locality combine region — "auto"
    (Pallas kernel on TPU, jnp elsewhere), "jnp", "pallas", or
    "pallas_interpret" (kernel-path testing on CPU); ``seq_axes`` sets the
    sequence-parallel cache domain — "auto" spans every DP axis
    (('pod','data') on multi-pod meshes: the combine crosses the DCN),
    ("data",) forces the legacy intra-pod layout (pods replicate)."""
    spec = _coerce_spec(spec, batch, cache_len, prefill_len, combine,
                        fused_stats, seq_axes, "make_serve_fns")
    batch, cache_len = spec.batch, spec.cache_len
    combine, fused_stats = spec.combine, spec.fused_stats
    seq_axes = spec.seq_axes
    mod = encdec if cfg.family == "audio" else transformer
    a_params = jax.eval_shape(
        lambda k: mod.init_params(k, cfg), jax.random.PRNGKey(0))
    # serving weights live in bf16 (no optimizer → no fp32 master copy):
    # halves the resident params (llama4-scout: 25 GiB → 12.6 GiB per chip)
    a_params = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, cfg.dtype if s.dtype == jnp.float32 else s.dtype),
        a_params)
    pspecs = param_specs(a_params, mesh, fsdp=False)
    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    c_specs = cache_shardings(cfg, mesh, batch, cache_len, seq_axes)
    c_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), c_specs)
    dp = dp_axes(mesh)
    shard = make_shard_fn(mesh)

    def prefill(params, batch_in):
        kw = {}
        if cfg.family == "audio":
            kw["frames"] = batch_in["frames"]
        if cfg.family == "vlm" and "img_embeds" in batch_in:
            kw["img_embeds"] = batch_in["img_embeds"]
        logits, _, cache = mod.forward(params, cfg, batch_in["tokens"],
                                       mode="prefill", cache_len=cache_len,
                                       shard=shard, **kw)
        return logits, cache

    def decode(params, cache, tokens):
        logits, _, cache = mod.forward(params, cfg, tokens, cache=cache,
                                       shard=shard)
        return logits, cache

    choice = resolve_cache_combine(
        cfg, mesh, batch, cache_len,
        override=None if combine == "auto" else combine, seq_axes=seq_axes)
    _, seq_cand = _cache_layout(mesh, batch, seq_axes)
    if choice.algorithm == "locality" and not _combine_eligible(
            cfg, mesh, cache_len, seq_cand):
        # every layer would take the per-layer fallback — don't compile
        # a manual path that executes nothing
        choice = dataclasses.replace(choice, algorithm="xla")

    stats_impl = stats_ops.resolve_impl(fused_stats)

    def decode_locality(params, cache, tokens):
        hook = _make_locality_decode_combine(cfg, mesh, seq_cand,
                                             stats_impl=stats_impl)
        logits, _, cache = mod.forward(params, cfg, tokens, cache=cache,
                                       shard=shard, decode_combine=hook)
        return logits, cache

    dp_size = max(1, int(np.prod([_axsize(mesh, a) for a in dp])))
    row_spec = P(dp, None) if (dp and batch % dp_size == 0) else P()
    tok_sh = NamedSharding(mesh, row_spec)

    def in_sh(ndim):
        if dp and batch % dp_size == 0:
            return NamedSharding(mesh, P(dp, *([None] * (ndim - 1))))
        return NamedSharding(mesh, P())

    batch_in_sh = {"tokens": tok_sh}
    if cfg.family == "audio":
        batch_in_sh["frames"] = in_sh(3)
    if cfg.family == "vlm":
        batch_in_sh["img_embeds"] = in_sh(3)
    prefill_fn = jax.jit(prefill, in_shardings=(p_sh, batch_in_sh),
                         out_shardings=(None, c_sh))
    decode_jit_kw: dict[str, Any] = dict(in_shardings=(p_sh, c_sh, tok_sh),
                                         donate_argnums=(1,),
                                         out_shardings=(None, c_sh))
    decode_fn_xla = jax.jit(decode, **decode_jit_kw)
    decode_fn_locality = None
    if choice.algorithm == "locality":
        decode_fn_locality = jax.jit(decode_locality, **decode_jit_kw)
    # dispatch: the CombineChoice picks which compiled decode serves traffic
    decode_fn = decode_fn_locality or decode_fn_xla
    return ServeArtifacts(prefill_fn=prefill_fn, decode_fn=decode_fn,
                          param_shardings=p_sh, cache_shardings_=c_sh,
                          abstract_params=a_params, combine=choice,
                          decode_fn_xla=decode_fn_xla,
                          decode_fn_locality=decode_fn_locality,
                          fused_stats=stats_impl, seq_axes=seq_cand,
                          tok_sharding=tok_sh,
                          abstract_cache=cache_specs(cfg, batch, cache_len))


class Engine:
    """Minimal batched greedy-decoding engine over the jitted steps.

    Telemetry (DESIGN.md §8): when the decode path has a cache combine at
    all (``comm_telemetry="auto"``), the active decode fn is AOT-compiled at
    construction — the compiled executable serves the decode loop (same
    compile the first decode call would have paid) and its HLO yields the
    :class:`~repro.telemetry.CommReport` stamped under ``"serve/decode"``:
    per-step combine traffic in ``stats()`` is read off the compiled
    artifact's DP-crossing bytes, not a hand-maintained layer count, and
    each executed step is accounted against the prediction
    (``registry.reconcile(engine.comm_label)``). The label is qualified by
    the combine algorithm (``serve/decode:locality`` / ``serve/decode:xla``)
    so side-by-side A/B engines in one process keep separate ledgers."""

    def __init__(self, cfg, mesh, params, spec: ServeSpec | None = None, *,
                 batch: int | None = None, cache_len: int | None = None,
                 combine: str | None = None, fused_stats: str | None = None,
                 seq_axes: str | tuple[str, ...] | None = None,
                 log: Callable[[str], None] | None = None,
                 comm_telemetry: bool | str = "auto",
                 tracer=None, registry=None, clock=None):
        from repro import telemetry
        spec = _coerce_spec(spec, batch, cache_len, None, combine,
                            fused_stats, seq_axes, "Engine")
        self.cfg = cfg
        self.mesh = mesh
        self.spec = spec
        self.resolved = spec.resolve(cfg, mesh)
        self.tracer = tracer or telemetry.get_tracer()
        self.registry = registry or telemetry.get_registry()
        with self.tracer.span("serve/build"):
            self.art = make_serve_fns(cfg, mesh, spec)
        params = jax.tree.map(
            lambda p: p.astype(cfg.dtype) if p.dtype == jnp.float32 else p,
            params)
        self.params = jax.device_put(params, self.art.param_shardings)
        self.batch = spec.batch
        self.cache_len = spec.cache_len
        self.combine = self.art.combine
        self._comm_requested = comm_telemetry
        self._clock = clock
        self._scheduler = None
        self._stats = {"decode_steps": 0, "combine_steps": 0,
                       "combine_bytes": 0.0, "nonlocal_bytes": 0.0,
                       "nonlocal_msgs": 0.0}
        self._decode_callable = self.art.decode_fn
        self.comm_report = None
        self.comm_label = f"serve/decode:{self.combine.algorithm}"
        if comm_telemetry == "auto":
            comm_telemetry = self.combine.algorithm != "none"
        if comm_telemetry:
            self._stamp_comm(log)
        if log and self.combine.algorithm != "none":
            log(f"[engine] cache-combine: {self.combine.algorithm} "
                f"({self.combine.source}, {self.combine.nbytes} B/step, "
                f"p={self.combine.p} p_local={self.combine.p_local})")

    def _stamp_comm(self, log=None) -> None:
        """AOT-compile the active decode fn; stamp its CommReport."""
        from repro import telemetry
        import time as _time
        try:
            with self.tracer.span("serve/compile"):
                t0 = _time.perf_counter()
                a_tok = jax.ShapeDtypeStruct((self.batch, 1), jnp.int32)
                lowered = self.art.decode_fn.lower(
                    self.art.abstract_params, self.art.abstract_cache, a_tok)
                compiled = lowered.compile()
                compile_s = _time.perf_counter() - t0
            report = telemetry.comm_report(compiled.as_text(), self.mesh,
                                           label=self.comm_label)
            self._decode_callable = compiled
            self.comm_report = report
            self.registry.gauge("serve/compile_time_s").set(compile_s)
            self.registry.attach_comm_report(self.comm_label, report)
        except Exception as e:            # pragma: no cover - backend quirks
            if log:
                log(f"[engine] comm telemetry unavailable: "
                    f"{type(e).__name__}: {e}")

    def _next_token(self, logits) -> jax.Array:
        """Greedy sampling rule, shared by prefill and decode so it cannot
        drift: argmax over the last position, clamped below the padded-vocab
        ids (vocab is padded to a multiple; padding logits must never be
        emitted as tokens)."""
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        return jnp.minimum(tok, self.cfg.vocab_size - 1)

    def stats(self) -> dict:
        """Cumulative serving counters: decode steps and the per-step
        combine traffic they generated. ``combine_bytes`` is sourced from
        the compiled artifact's CommReport (DP-domain-crossing bytes of the
        decode HLO × steps) when comm telemetry is on — the ground truth,
        not an analytic layer count — and stays 0 without it. ``nonlocal_*``
        are the
        inter-pod (DCN) accumulations; a ``comm`` entry carries the
        per-step report and its runtime reconciliation when stamped."""
        out = dict(self._stats)
        if self.comm_report is not None:
            out["comm"] = {
                "per_step": self.comm_report.asdict(),
                "reconcile": self.registry.reconcile(self.comm_label),
            }
        return out

    # -- request-level API (DESIGN.md §9) -------------------------------
    @property
    def scheduler(self):
        """The continuous-batching scheduler over this engine's compiled
        steps — built lazily on the first ``submit``."""
        if self._scheduler is None:
            from .scheduler import Scheduler
            self._scheduler = Scheduler(
                self, clock=self._clock,
                comm_telemetry=self._comm_requested is not False)
        return self._scheduler

    def submit(self, request: Request) -> int:
        """Enqueue one request; returns its handle (the request id)."""
        return self.scheduler.submit(request)

    def step(self) -> list[RequestResult]:
        """Admit what fits, decode one step; the requests that finished."""
        return self.scheduler.step()

    def drain(self, *, checkpoint_dir: str | None = None
              ) -> dict[int, RequestResult]:
        """Run until every submitted request finished; results by handle.

        ``checkpoint_dir`` turns the drain into a *graceful preemption
        drain*: instead of decoding the backlog to completion, every
        in-flight request (KV state and all) is checkpointed via
        :meth:`suspend` and only the already-finished results return — a
        restarted engine's :meth:`resume` replays the rest."""
        if checkpoint_dir is not None:
            self.suspend(checkpoint_dir)
            return dict(self.scheduler.results)
        return self.scheduler.drain()

    def suspend(self, checkpoint_dir: str) -> str:
        """Checkpoint all in-flight/queued request state (DESIGN.md §10)."""
        return self.scheduler.suspend(checkpoint_dir)

    def resume(self, checkpoint_dir: str) -> int:
        """Reload a suspend checkpoint into this (fresh) engine; returns
        the number of requests replayed back in."""
        return self.scheduler.resume(checkpoint_dir)

    def cancel(self, rid: int) -> bool:
        return self.scheduler.cancel(rid)

    def result(self, rid: int) -> RequestResult | None:
        return self.scheduler.result(rid)

    def generate(self, prompts: np.ndarray, max_new: int,
                 extra: dict | None = None) -> np.ndarray:
        """prompts: (B, S) int32. Returns (B, max_new) greedy tokens.

        Legacy lockstep loop: the whole batch prefills together and decodes
        to the same budget. Kept one release behind a DeprecationWarning —
        ``submit()``/``step()``/``drain()`` is the serving API."""
        import time as _time
        warnings.warn(
            "Engine.generate is the legacy lockstep loop; use "
            "Engine.submit/step/drain (DESIGN.md §9)",
            DeprecationWarning, stacklevel=2)
        batch_in = {"tokens": jnp.asarray(prompts)}
        batch_in.update(extra or {})
        with self.tracer.span("serve/prefill", prompt_len=int(prompts.shape[-1])):
            logits, cache = self.art.prefill_fn(self.params, batch_in)
        out = []
        tok = self._next_token(logits)
        combining = self.combine.algorithm == "locality"
        rep = self.comm_report
        reg = self.registry
        for _ in range(max_new):
            out.append(np.asarray(tok))
            if rep is not None:
                # the AOT-compiled executable does not reshard inputs
                tok = jax.device_put(tok, self.art.tok_sharding)
            with self.tracer.span("serve/decode_step"):
                t0 = _time.perf_counter()
                logits, cache = self._decode_callable(self.params, cache, tok)
                tok = self._next_token(logits)
            reg.observe("serve/decode_step_s", _time.perf_counter() - t0)
            self._stats["decode_steps"] += 1
            if rep is not None:
                self._stats["nonlocal_bytes"] += rep.nonlocal_bytes
                self._stats["nonlocal_msgs"] += rep.nonlocal_msgs
                reg.record_comm(self.comm_label)
            if combining:
                self._stats["combine_steps"] += 1
                if rep is not None:
                    # ground truth only: the compiled HLO's DP-crossing
                    # bytes — without telemetry the counter stays 0 rather
                    # than reporting an analytic guess as traffic
                    self._stats["combine_bytes"] += rep.dp_bytes
        reg.count("serve/tokens", max_new * prompts.shape[0])
        return np.concatenate(out, axis=1)
