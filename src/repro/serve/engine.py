"""Serving: jitted prefill/decode steps, cache sharding, batched engine.

Cache placement policy (per leaf):
  * KV caches (…, B, L, KV, D): batch over the DP axes when divisible
    (decode_32k: 128 rows over 16/32 chips); otherwise the *sequence* dim is
    sharded over 'data' (long_500k: B=1, 512k context split across the pod)
    — sequence-parallel decode. KV heads shard over 'model' when divisible.
  * SSM caches: batch over DP, heads over 'model'.
The decode step is a single jit; XLA turns the position-masked attention
over a sequence-sharded cache into partial reductions + a combine, which the
§Perf pass replaces with the explicit locality-aware logsumexp combine.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import encdec, transformer
from repro.train.sharding import dp_axes, make_shard_fn, param_specs


def _axsize(mesh, name) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.devices.shape[list(mesh.axis_names).index(name)]


def cache_specs(cfg, batch: int, cache_len: int):
    mod = encdec if cfg.family == "audio" else transformer
    return mod.cache_specs(cfg, batch, cache_len)


def _cache_layout(mesh, batch: int) -> tuple[bool, str | None]:
    """(batch_sharded, seq_axis): the one placement decision both the cache
    shardings and the combine resolution key off — kept in one place so
    they cannot drift."""
    dp = dp_axes(mesh)
    dp_size = max(1, int(np.prod([_axsize(mesh, a) for a in dp])))
    batch_sharded = bool(dp) and batch % dp_size == 0 and batch >= dp_size
    seq_ax = "data" if "data" in mesh.axis_names else None
    return batch_sharded, seq_ax


def cache_shardings(cfg, mesh, batch: int, cache_len: int):
    """PartitionSpec pytree matching cache_specs."""
    dp = dp_axes(mesh)
    m = _axsize(mesh, "model")

    def on_model(dim: int) -> bool:    # shardable over a real 'model' axis?
        return m > 1 and dim % m == 0

    batch_sharded, seq_ax = _cache_layout(mesh, batch)

    def visit(path, leaf):
        keys = tuple(str(getattr(p, "key", getattr(p, "idx", ""))) for p in path)
        shape = leaf.shape
        name = keys[-1] if keys else ""
        # find batch dim: stacked leaves carry leading (reps/L,) dims
        if name in ("k", "v") or (len(keys) >= 2 and keys[-2] == "cross"):
            nd = len(shape)
            b_dim = nd - 4
            L_dim, kv_dim, d_dim = b_dim + 1, b_dim + 2, b_dim + 3
            spec = [None] * nd
            if batch_sharded:
                spec[b_dim] = dp
                # model axis: prefer KV heads; else head_dim (a dynamic
                # update on a sharded *sequence* dim makes GSPMD gather the
                # whole cache); else the sequence dim as last resort.
                if on_model(shape[kv_dim]):
                    spec[kv_dim] = "model"
                elif on_model(shape[d_dim]):
                    spec[d_dim] = "model"
                elif on_model(shape[L_dim]):
                    spec[L_dim] = "model"
            else:
                # B=1 long-context: sequence-parallel cache over 'data',
                # plus KV-heads/head_dim over 'model' when divisible.
                if seq_ax and shape[L_dim] % _axsize(mesh, seq_ax) == 0:
                    spec[L_dim] = seq_ax
                if on_model(shape[kv_dim]):
                    spec[kv_dim] = "model"
                elif on_model(shape[d_dim]):
                    spec[d_dim] = "model"
            return P(*spec)
        if name == "conv":
            nd = len(shape)
            spec = [None] * nd
            if batch_sharded:
                spec[nd - 3] = dp
            if on_model(shape[nd - 1]):
                spec[nd - 1] = "model"
            return P(*spec)
        if name == "h":
            nd = len(shape)
            spec = [None] * nd
            if batch_sharded:
                spec[nd - 4] = dp
            if on_model(shape[nd - 3]):
                spec[nd - 3] = "model"
            return P(*spec)
        return P()                                 # pos scalar

    return jax.tree_util.tree_map_with_path(visit, cache_specs(cfg, batch, cache_len))


@dataclasses.dataclass(frozen=True)
class ServeArtifacts:
    prefill_fn: Callable      # (params, batch) -> (logits, cache)
    decode_fn: Callable       # (params, cache, tokens) -> (logits, cache)
    param_shardings: Any
    cache_shardings_: Any
    abstract_params: Any
    combine: Any = None       # CombineChoice for the decode cache-combine


@dataclasses.dataclass(frozen=True)
class CombineChoice:
    """Resolved collective for the sequence-parallel decode combine.

    When the KV cache is sequence-sharded over 'data' (B=1 long-context),
    every decode step reduces per-shard partial attention stats — o (B,1,H,D)
    plus the logsumexp accumulator (B,1,H) in fp32 — across the sequence
    shards. ``algorithm`` is what the tuning policy picks for an allreduce
    of that payload on this topology; "xla" keeps GSPMD's own combine,
    "locality" routes it through the paper-structured allreduce.
    """

    algorithm: str            # "xla" | "locality" | "none" (no seq sharding)
    source: str               # "table" | "model" | "n/a"
    nbytes: int               # per-step combine payload in bytes
    p: int                    # ranks participating in the combine
    p_local: int


def resolve_cache_combine(cfg, mesh, batch: int, cache_len: int) -> CombineChoice:
    """Resolve the decode cache-combine collective through repro.tuning."""
    batch_sharded, seq_ax = _cache_layout(mesh, batch)
    seq_sharded = (not batch_sharded and seq_ax is not None
                   and _axsize(mesh, seq_ax) > 1
                   and cache_len % _axsize(mesh, seq_ax) == 0)
    if not seq_sharded:
        return CombineChoice("none", "n/a", 0, 1, 1)
    H = getattr(cfg, "n_heads", 1)
    D = getattr(cfg, "head_dim_", getattr(cfg, "d_model", 0) // max(H, 1))
    nbytes = batch * H * (D + 1) * 4          # fp32 o + logsumexp per step
    # the cache L dim is sharded over 'data' ONLY (pods hold replicas), so
    # the combine spans exactly the 'data' ranks — one region, all ICI
    p = p_local = _axsize(mesh, seq_ax)
    from repro.tuning.policy import default_policy
    sel = default_policy().select("allreduce", p, p_local, nbytes)
    return CombineChoice(sel.algorithm, sel.source, nbytes, p, p_local)


def make_serve_fns(cfg, mesh, *, batch: int, cache_len: int,
                   prefill_len: int | None = None) -> ServeArtifacts:
    mod = encdec if cfg.family == "audio" else transformer
    a_params = jax.eval_shape(
        lambda k: mod.init_params(k, cfg), jax.random.PRNGKey(0))
    # serving weights live in bf16 (no optimizer → no fp32 master copy):
    # halves the resident params (llama4-scout: 25 GiB → 12.6 GiB per chip)
    a_params = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, cfg.dtype if s.dtype == jnp.float32 else s.dtype),
        a_params)
    pspecs = param_specs(a_params, mesh, fsdp=False)
    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    c_specs = cache_shardings(cfg, mesh, batch, cache_len)
    c_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), c_specs)
    dp = dp_axes(mesh)
    shard = make_shard_fn(mesh)

    def prefill(params, batch_in):
        kw = {}
        if cfg.family == "audio":
            kw["frames"] = batch_in["frames"]
        if cfg.family == "vlm" and "img_embeds" in batch_in:
            kw["img_embeds"] = batch_in["img_embeds"]
        logits, _, cache = mod.forward(params, cfg, batch_in["tokens"],
                                       mode="prefill", cache_len=cache_len,
                                       shard=shard, **kw)
        return logits, cache

    def decode(params, cache, tokens):
        logits, _, cache = mod.forward(params, cfg, tokens, cache=cache,
                                       shard=shard)
        return logits, cache

    dp_size = max(1, int(np.prod([_axsize(mesh, a) for a in dp])))
    row_spec = P(dp, None) if (dp and batch % dp_size == 0) else P()
    tok_sh = NamedSharding(mesh, row_spec)

    def in_sh(ndim):
        if dp and batch % dp_size == 0:
            return NamedSharding(mesh, P(dp, *([None] * (ndim - 1))))
        return NamedSharding(mesh, P())

    batch_in_sh = {"tokens": tok_sh}
    if cfg.family == "audio":
        batch_in_sh["frames"] = in_sh(3)
    if cfg.family == "vlm":
        batch_in_sh["img_embeds"] = in_sh(3)
    prefill_fn = jax.jit(prefill, in_shardings=(p_sh, batch_in_sh),
                         out_shardings=(None, c_sh))
    decode_fn = jax.jit(decode, in_shardings=(p_sh, c_sh, tok_sh),
                        donate_argnums=(1,), out_shardings=(None, c_sh))
    return ServeArtifacts(prefill_fn=prefill_fn, decode_fn=decode_fn,
                          param_shardings=p_sh, cache_shardings_=c_sh,
                          abstract_params=a_params,
                          combine=resolve_cache_combine(cfg, mesh, batch,
                                                        cache_len))


class Engine:
    """Minimal batched greedy-decoding engine over the jitted steps."""

    def __init__(self, cfg, mesh, params, *, batch: int, cache_len: int,
                 log: Callable[[str], None] | None = None):
        self.cfg = cfg
        self.art = make_serve_fns(cfg, mesh, batch=batch, cache_len=cache_len)
        params = jax.tree.map(
            lambda p: p.astype(cfg.dtype) if p.dtype == jnp.float32 else p,
            params)
        self.params = jax.device_put(params, self.art.param_shardings)
        self.cache_len = cache_len
        self.combine = self.art.combine
        if log and self.combine.algorithm != "none":
            log(f"[engine] cache-combine: {self.combine.algorithm} "
                f"({self.combine.source}, {self.combine.nbytes} B/step, "
                f"p={self.combine.p} p_local={self.combine.p_local})")

    def generate(self, prompts: np.ndarray, max_new: int,
                 extra: dict | None = None) -> np.ndarray:
        """prompts: (B, S) int32. Returns (B, max_new) greedy tokens."""
        batch_in = {"tokens": jnp.asarray(prompts)}
        batch_in.update(extra or {})
        logits, cache = self.art.prefill_fn(self.params, batch_in)
        out = []
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        # never emit padding ids (vocab padded to a multiple)
        tok = jnp.minimum(tok, self.cfg.vocab_size - 1)
        for _ in range(max_new):
            out.append(np.asarray(tok))
            logits, cache = self.art.decode_fn(self.params, cache, tok)
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            tok = jnp.minimum(tok, self.cfg.vocab_size - 1)
        return np.concatenate(out, axis=1)
