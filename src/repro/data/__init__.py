from .pipeline import SyntheticLM, host_shard
