"""Deterministic synthetic LM data pipeline.

Sequences follow a noisy affine recurrence (tokens[t+1] ≈ (a·tokens[t] + c)
mod V with ε-noise), so a model can actually reduce loss — the end-to-end
examples demonstrate real learning, not noise-fitting. Batches are a pure
function of (seed, step): restarts resume mid-stream with no state to
checkpoint beyond the step counter, and every host can independently
materialize exactly its shard (host_shard) — no data service needed at
1000-node scale.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.05       # fraction of positions replaced by uniform noise

    def batch(self, step: int) -> dict[str, np.ndarray]:
        """Full global batch for ``step`` (tokens, labels), both (B, S)."""
        B, S, V = self.global_batch, self.seq_len, self.vocab_size
        rng = np.random.Generator(np.random.Philox(key=self.seed + (step << 20)))
        a = 31337 % V or 7
        # c fixed per stream (seed), so tokens[t+1] is a fixed learnable
        # function of tokens[t]; per-sequence x0 + noise provide variety.
        c = np.random.Generator(np.random.Philox(key=self.seed)).integers(
            1, V, dtype=np.int64)
        c = np.full((B, 1), c, dtype=np.int64)
        x0 = rng.integers(0, V, size=(B, 1), dtype=np.int64)
        seqs = np.empty((B, S + 1), dtype=np.int64)
        seqs[:, 0] = x0[:, 0]
        for i in range(1, S + 1):
            seqs[:, i] = (a * seqs[:, i - 1] + c[:, 0]) % V
        noise_mask = rng.random((B, S + 1)) < self.noise
        noise_vals = rng.integers(0, V, size=(B, S + 1), dtype=np.int64)
        seqs = np.where(noise_mask, noise_vals, seqs)
        return {"tokens": seqs[:, :-1].astype(np.int32),
                "labels": seqs[:, 1:].astype(np.int32)}

    def batches(self, start_step: int = 0):
        step = start_step
        while True:
            yield self.batch(step)
            step += 1


def host_shard(batch: dict[str, np.ndarray], host_id: int,
               n_hosts: int) -> dict[str, np.ndarray]:
    """The rows of the global batch owned by ``host_id`` (contiguous split)."""
    out = {}
    for k, v in batch.items():
        per = v.shape[0] // n_hosts
        out[k] = v[host_id * per:(host_id + 1) * per]
    return out
