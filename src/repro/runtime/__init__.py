from .monitor import SimulatedFault, FaultInjector, StepMonitor
