from .monitor import (FaultInjector, PreemptionSignal, SimulatedFault,
                      StepMonitor)
