"""Runtime health: straggler detection and fault injection.

On a real fleet the heartbeat/restart daemon lives outside the process
(borg/k8s/xmanager); in this repo the Trainer demonstrates the *in-process*
half of the contract: detect stragglers from step-time statistics, survive
injected chip failures by restoring the latest complete checkpoint, and
(elastically) rebuild the step function for a smaller mesh. The CPU
container simulates failures via ``FaultInjector``.
"""
from __future__ import annotations

import dataclasses
import signal
import threading
import time

from repro.faults import ProcessKilled
from repro.telemetry import TelemetryEvent, get_registry


class SimulatedFault(RuntimeError):
    """Raised by FaultInjector to emulate a chip/host loss mid-run."""


@dataclasses.dataclass
class FaultInjector:
    """Raises SimulatedFault at ``fail_at_steps`` (once each) — the
    *recoverable* failure class the Trainer restores through — and
    :class:`repro.faults.ProcessKilled` at ``kill_at_steps``: a hard kill
    that no recovery path may catch (BaseException), so the process dies
    and the kill-and-resume tests restart it from the committed
    checkpoint."""

    fail_at_steps: tuple[int, ...] = ()
    kill_at_steps: tuple[int, ...] = ()
    #: steps at which the injector SLEEPS inside the timed step region —
    #: the deterministic straggler the chaos soak drives StepMonitor with
    delay_at_steps: tuple[int, ...] = ()
    delay_s: float = 0.25

    def __post_init__(self):
        self._pending = set(self.fail_at_steps)
        self._kills = set(self.kill_at_steps)
        self._delays = set(self.delay_at_steps)

    def check(self, step: int) -> None:
        if step in self._kills:
            self._kills.discard(step)
            raise ProcessKilled(f"injected kill at step {step}")
        if step in self._pending:
            self._pending.discard(step)
            raise SimulatedFault(f"injected failure at step {step}")

    def delay(self, step: int, *, floor_s: float = 0.0) -> float:
        """Injected straggler (once per armed step): sleep long enough that
        the step lands above the monitor's flagging threshold. ``floor_s``
        lets the caller scale the sleep to the live EWMA (a fixed delay can
        sit under ``k×ewma`` once real steps are slow); the larger of the
        two is used. Returns the seconds slept (0.0 when unarmed)."""
        if step not in self._delays:
            return 0.0
        self._delays.discard(step)
        d = max(self.delay_s, floor_s)
        time.sleep(d)
        return d


class PreemptionSignal:
    """Graceful-preemption latch: the fleet scheduler's "you have N seconds"
    notice. The Trainer polls :meth:`should_stop` each step and, when set,
    runs one final *blocking* save and drains cleanly instead of dying with
    up to ``ckpt_every`` steps of progress uncommitted.

    Trigger paths: :meth:`trigger` (tests, embedding runtimes),
    ``at_steps`` (deterministic test schedules), or a real SIGTERM when
    constructed with ``install_sigterm=True`` (opt-in: library code must
    not steal the host process's handlers by default). The installed
    handler CHAINS to whatever handler was registered before it — an
    embedding runtime's own SIGTERM logic keeps running — and
    :meth:`uninstall` restores the previous handler exactly."""

    def __init__(self, at_steps: tuple[int, ...] = (), *,
                 install_sigterm: bool = False):
        self._event = threading.Event()
        self._at = set(at_steps)
        self._prev_handler = None
        self._installed = False
        if install_sigterm:
            def _handler(signum, frame):
                self.trigger()
                prev = self._prev_handler
                if callable(prev):        # SIG_DFL/SIG_IGN are ints: skip
                    prev(signum, frame)
            self._prev_handler = signal.signal(signal.SIGTERM, _handler)
            self._installed = True

    def uninstall(self) -> None:
        """Restore the SIGTERM handler that was active before this signal
        installed its own (no-op unless ``install_sigterm=True``)."""
        if self._installed:
            prev = self._prev_handler
            signal.signal(signal.SIGTERM,
                          prev if prev is not None else signal.SIG_DFL)
            self._prev_handler = None
            self._installed = False

    def trigger(self) -> None:
        self._event.set()

    def triggered(self) -> bool:
        return self._event.is_set()

    def should_stop(self, step: int) -> bool:
        if step in self._at:
            self.trigger()
        return self._event.is_set()


@dataclasses.dataclass
class StepMonitor:
    """EWMA step-time tracker; flags steps ``k×`` slower than the average.

    At fleet scale the same statistic (exported per host) is what lets the
    controller identify the slow host; here it feeds the Trainer's event log
    and the straggler tests.
    """

    k: float = 3.0
    alpha: float = 0.1
    warmup: int = 3

    _ewma: float = 0.0
    _n: int = 0
    _last_algorithm: str | None = None
    _stragglers: int = 0

    def reset(self) -> None:
        """Forget the timing statistics (EWMA + warmup), keeping the
        cumulative straggler count and the last-seen algorithm.

        Call on every step-function rebuild: after an elastic restart the
        EWMA still describes the OLD topology, so the first steps on a
        smaller/slower mesh would be falsely flagged as stragglers (and a
        faster mesh would mask real ones). The algorithm survives so the
        collective-change event still fires only on an actual change."""
        self._ewma = 0.0
        self._n = 0

    def record(self, dt: float,
               algorithm: str | None = None) -> list[TelemetryEvent]:
        """Record one step time; ``algorithm`` is the collective algorithm
        the step ran with (from the tuning policy / grad_sync resolution).
        An event is emitted on the first step and whenever it changes —
        e.g. after an elastic restart onto a different topology re-resolves
        ``grad_sync="auto"`` to a different schedule (the change event is
        deduplicated: repeats of the current algorithm stay silent).

        Returns structured :class:`TelemetryEvent`s (str subclasses — every
        legacy substring consumer keeps working)."""
        events: list[TelemetryEvent] = []
        if algorithm is not None and algorithm != self._last_algorithm:
            events.append(TelemetryEvent(
                f"collective: {algorithm}", kind="collective",
                attrs={"algorithm": algorithm,
                       "previous": self._last_algorithm}))
            self._last_algorithm = algorithm
        self._n += 1
        if self._n <= self.warmup:          # ignore compile-dominated steps
            self._ewma = dt if self._ewma == 0 else (
                self.alpha * dt + (1 - self.alpha) * self._ewma)
            return events
        if self._ewma == 0:
            # warmup=0 (or all-zero warmup samples): seed the EWMA from the
            # first measured step instead of blending against 0 — an
            # α-scaled seed would flag every subsequent NORMAL step as a
            # straggler (dt > k·α·dt for the default k=3, α=0.1).
            self._ewma = dt
            return events
        if dt > self.k * self._ewma:
            # mirrored into a counter so the fleet controller (and the CI
            # schema gate) can read the straggler pressure without
            # scraping the event stream
            self._stragglers += 1
            get_registry().count("runtime/stragglers")
            events.append(TelemetryEvent(
                f"straggler: step took {dt:.3f}s "
                f"(ewma {self._ewma:.3f}s, k={self.k})",
                kind="straggler",
                attrs={"dt": dt, "ewma": self._ewma, "k": self.k}))
        self._ewma = self.alpha * dt + (1 - self.alpha) * self._ewma
        return events

    @property
    def ewma(self) -> float:
        return self._ewma

    @property
    def stragglers(self) -> int:
        """Cumulative flagged-straggler count (survives :meth:`reset`)."""
        return self._stragglers
