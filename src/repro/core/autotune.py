"""Algorithm selection from the postal model — paper §4 as a runtime policy.

Given (p, p_local, message bytes, machine), evaluate the modeled cost of
every allgather algorithm and return the cheapest. The train step's
``grad_sync="auto"`` resolves through this with the TPU parameter set; the
benchmarks sweep it across the paper's (Lassen/Quartz) parameter sets to
reproduce Figs. 7–8.

When a persisted tuning table exists (``repro.tuning``), the measured
crossover tables take precedence over the closed forms — the paper's own
Fig. 9 shows the model mispredicts crossovers on real networks, so
measurements win whenever we have them. The table is consulted only for
the *deployment* selection (``machine`` left unset): passing an explicit
machine parameter set asks for that machine's closed forms (the figure
benchmarks do), which a table measured elsewhere must not override.
``use_table=False`` additionally forces pure-model behaviour.
"""
from __future__ import annotations

from .cost_model import MACHINES, MODELS, MachineParams


def pick_allgather(p: int, p_local: int, nbytes_per_rank: float,
                   machine: MachineParams | str | None = None, *,
                   dtype: str = "float32", use_table: bool = True) -> str:
    if machine is None:
        machine = "tpu_v5e"
        if use_table:
            from repro.tuning.policy import default_policy
            sel = default_policy().select("allgather", p, p_local,
                                          nbytes_per_rank, dtype)
            if sel.source == "table":
                return sel.algorithm
    if isinstance(machine, str):
        machine = MACHINES[machine]
    if p_local <= 1 or p <= p_local:
        return "bruck"
    block = nbytes_per_rank
    costs = {name: fn(p, p_local, block, machine)
             for name, fn in MODELS.items()}
    return min(costs, key=costs.get)


def model_costs(p: int, p_local: int, nbytes_per_rank: float,
                machine: MachineParams | str = "tpu_v5e") -> dict[str, float]:
    if isinstance(machine, str):
        machine = MACHINES[machine]
    return {name: fn(p, p_local, nbytes_per_rank, machine)
            for name, fn in MODELS.items()}
