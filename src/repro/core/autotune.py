"""Algorithm selection from the postal model — paper §4 as a runtime policy.

Given (p, p_local, message bytes, machine), evaluate the modeled cost of
every allgather algorithm and return the cheapest. The train step's
``grad_sync="auto"`` resolves through this with the TPU parameter set; the
benchmarks sweep it across the paper's (Lassen/Quartz) parameter sets to
reproduce Figs. 7–8.
"""
from __future__ import annotations

from .cost_model import MACHINES, MODELS, MachineParams


def pick_allgather(p: int, p_local: int, nbytes_per_rank: float,
                   machine: MachineParams | str = "tpu_v5e") -> str:
    if isinstance(machine, str):
        machine = MACHINES[machine]
    if p_local <= 1 or p <= p_local:
        return "bruck"
    block = nbytes_per_rank
    costs = {name: fn(p, p_local, block, machine)
             for name, fn in MODELS.items()}
    return min(costs, key=costs.get)


def model_costs(p: int, p_local: int, nbytes_per_rank: float,
                machine: MachineParams | str = "tpu_v5e") -> dict[str, float]:
    if isinstance(machine, str):
        machine = MACHINES[machine]
    return {name: fn(p, p_local, nbytes_per_rank, machine)
            for name, fn in MODELS.items()}
