"""The paper's collective algorithms as JAX collectives, behind one API.

Every algorithm here is a *pure function of per-device shards*, usable inside
``jax.shard_map`` over any subset of mesh axes. Point-to-point MPI sends map
onto ``jax.lax.ppermute`` (XLA ``collective-permute`` with explicit
``source_target_pairs``) — one ppermute per communication round. Locality is
expressed through the (outer_axes, local_axes) split: ``outer`` axes cross the
expensive boundary (inter-pod DCN), ``local`` axes stay inside it (intra-pod
ICI). The flat rank over ``outer + local`` is region-major, matching
``topology.RegionMap``.

Because each algorithm is a composition of linear ops (ppermute / concat /
roll / slice), JAX autodiff transposes an allgather into the matching
reduce-scatter with the *reversed schedule* for free — used by the FSDP
parameter gathering in ``train/`` and the expert-parallel return leg in
``models/moe.py``.

Public surface (DESIGN.md §12) — one family function per collective *kind*,
each taking ``(operands..., outer, local, algorithm=..., **kw)``:

  allgather          kinds of gather: ``bruck`` (Algorithm 1 [Bruck '97]),
                     ``ring`` [Chan '07], ``hierarchical`` [Träff '06],
                     ``multilane`` [Träff & Hunold '20], and
                     ``locality_bruck`` — Algorithm 2, THE paper's
                     contribution. Same five as ``core/schedules.py``,
                     which is the oracle the runtime is reconciled against.
  reduce_scatter     linear transpose of any allgather (reversed schedule)
  allreduce          ``locality``: local RS → per-lane outer allreduce →
                     local AG (generic over sum / max / min), or ``psum``
  all_to_all         ``locality``: two-tier expert dispatch — intra-pod
                     exchange + one minimized inter-pod phase shipping
                     per-destination-pod aggregates (reuses Algorithm 2's
                     partial-round geometry); ``xla``: flat lax.all_to_all
  logsumexp_combine  numerically-safe combine of flash-style partial
                     softmax stats: max-allreduce → rescale → packed
                     sum-allreduce (the serve decode cache-combine)
  cache_migrate      serve-time KV-cache resharding (serve/scheduler.py)

Each family has ``_start``/``_finish`` split halves for the overlap pipeline
(DESIGN.md §5): the non-local ``outer`` rounds issue in ``start``; the local
redistribution completes in ``finish`` at the consumer, so calling start for
layer i+1 before layer i's compute takes the wire time off the critical path.

``collective(kind, *operands, outer=..., local=..., algorithm=...)`` is the
uniform string-keyed entry point over the same table (``KINDS`` /
``ALGORITHMS_BY_KIND`` / ``DEFAULT_ALGORITHM``); ``algorithm="auto"``
defers to the tuning policy (``tuning/policy.py``). The pre-redesign names
(``bruck_allgather``, ``locality_bruck_allgather``, ``locality_allreduce``,
``locality_logsumexp_combine``, ...) remain as deprecated aliases that warn
once per process and forward to the family functions.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import warnings
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

Axes = str | Sequence[str]


def _tup(axes: Axes) -> tuple[str, ...]:
    return (axes,) if isinstance(axes, str) else tuple(axes)


def _varying(x: jax.Array, axes: tuple[str, ...]) -> jax.Array:
    """Mark ``x`` device-varying over ``axes`` (no-op if already varying).

    shard_map's vma tracking treats unvarying inputs as replicated values;
    collectives on them transpose into psums. All algorithms here assume a
    genuinely per-device shard, so we normalize at entry.
    """
    missing = tuple(a for a in axes if a not in jax.typeof(x).vma)
    return lax.pcast(x, missing, to="varying") if missing else x


def _size(axes: tuple[str, ...]) -> int:
    return math.prod(lax.axis_size(a) for a in axes)


def _stack_to_tiled(buf: jax.Array, x_shape: tuple[int, ...]) -> jax.Array:
    """[p, *x_shape] -> concatenation along axis 0 (lax.all_gather tiled=True)."""
    p = buf.shape[0]
    if not x_shape:
        return buf
    return buf.reshape((p * x_shape[0],) + x_shape[1:])


def _out(buf: jax.Array, tiled: bool, x_shape: tuple[int, ...]) -> jax.Array:
    return _stack_to_tiled(buf, x_shape) if tiled else buf


# =============================================================================
# Algorithm 1 — standard Bruck allgather: log2(p) rounds, doubling buffers.
# =============================================================================
def _bruck_allgather(x: jax.Array, axes: Axes, *, tiled: bool = False,
                    assume_varying: bool = False) -> jax.Array:
    """Bruck allgather over ``axes``. Returns [p, *x.shape] (or tiled concat).

    Round i (distance d=2^i): every rank sends its entire current buffer
    (first min(d, p-d) blocks) to rank id-d and receives from id+d; a final
    rotation by ``axis_index`` restores canonical block order.

    assume_varying: skip the vma normalization — required when the gather is
    *differentiated* inside a ``check_vma=False`` region (the inserted pcast
    would transpose into an invalid psum); the caller asserts the input is
    genuinely per-device.
    """
    axes = _tup(axes)
    p = _size(axes)
    if not assume_varying:
        x = _varying(x, axes)
    if p == 1:
        return _out(x[None], tiled, x.shape)
    idx = lax.axis_index(axes)
    with jax.named_scope(f"bruck_ag_p{p}"):
        buf = x[None]                       # buf[k] = block (idx + k) mod p
        d = 1
        while d < p:
            cnt = min(d, p - d)
            perm = [(s, (s - d) % p) for s in range(p)]
            recv = lax.ppermute(buf[:cnt], axes, perm)
            buf = jnp.concatenate([buf, recv], axis=0)
            d *= 2
        buf = jnp.roll(buf, idx, axis=0)    # out[j] = block j
    return _out(buf, tiled, x.shape)


# =============================================================================
# Ring allgather: p-1 neighbor rounds (bandwidth-optimal, locality-friendly).
# =============================================================================
def _ring_allgather(x: jax.Array, axes: Axes, *, tiled: bool = False) -> jax.Array:
    axes = _tup(axes)
    p = _size(axes)
    x = _varying(x, axes)
    if p == 1:
        return _out(x[None], tiled, x.shape)
    idx = lax.axis_index(axes)
    perm = [(s, (s - 1) % p) for s in range(p)]
    with jax.named_scope(f"ring_ag_p{p}"):
        def body(cur, _):
            nxt = lax.ppermute(cur, axes, perm)
            return nxt, nxt

        _, rest = lax.scan(body, x, None, length=p - 1)
        buf = jnp.concatenate([x[None], rest], axis=0)  # buf[k] = block idx+k
        buf = jnp.roll(buf, idx, axis=0)
    return _out(buf, tiled, x.shape)


# =============================================================================
# Hierarchical allgather [Träff '06]: binomial gather to a master per region,
# Bruck among masters, binomial broadcast. Non-masters idle during phase 2.
# =============================================================================
def _hierarchical_allgather(x: jax.Array, outer: Axes, local: Axes, *,
                           tiled: bool = False) -> jax.Array:
    outer, local = _tup(outer), _tup(local)
    r, pl = _size(outer), _size(local)
    x = _varying(x, outer + local)
    if pl == 1:
        return _bruck_allgather(x, outer + local, tiled=tiled)
    R = lax.axis_index(outer)
    l = lax.axis_index(local)
    flat = lambda Rg, lg: Rg * pl + lg
    zeros = lambda shape: jnp.zeros(shape, x.dtype) + x.reshape(-1)[0] * 0

    with jax.named_scope(f"hier_ag_r{r}_pl{pl}"):
        # --- Phase 1: binomial gather to lane-0 master --------------------------
        # B[k] = block of lane k of own region (zeros where unknown). Slots
        # are padded to the next power of two so a sender's subtree slice
        # [l, l+d) and a receiver's write at l+d are always in bounds — the
        # min() clamps never bind (for a non-power p_ℓ the old pl-sized
        # buffer made the clamp grab the wrong subtree and the final
        # partial sender overwrite slots it didn't own).
        pl2 = 1 << (pl - 1).bit_length()
        B = lax.dynamic_update_slice(
            zeros((pl2,) + x.shape), x[None], (l,) + (0,) * x.ndim)
        d = 1
        while d < pl:
            # lanes with l % 2d == d send their subtree slots [l, l+d) to lane l-d
            pairs = [(flat(Rg, lg), flat(Rg, lg - d))
                     for Rg in range(r) for lg in range(d, pl, 2 * d)]
            payload = lax.dynamic_slice(
                B, (jnp.minimum(l, pl2 - d),) + (0,) * x.ndim, (d,) + x.shape)
            recv = lax.ppermute(payload, outer + local, pairs)
            is_recv = (l % (2 * d) == 0) & (l + d < pl)
            upd = lax.dynamic_update_slice(
                B, recv, (jnp.minimum(l + d, pl2 - d),) + (0,) * x.ndim)
            B = jnp.where(is_recv, upd, B)
            d *= 2
        B = B[:pl]                      # drop the power-of-two padding

        # --- Phase 2: Bruck allgather among masters (lane 0) over regions -------
        buf = B[None]                       # [chunks, pl, ...]; chunk k = region R+k
        d = 1
        while d < r:
            cnt = min(d, r - d)
            pairs = [(flat(Rg, 0), flat((Rg - d) % r, 0)) for Rg in range(r)]
            recv = lax.ppermute(buf[:cnt], outer + local, pairs)
            buf = jnp.concatenate([buf, recv], axis=0)
            d *= 2
        buf = jnp.roll(buf, R, axis=0)      # canonical region order (masters)

        # --- Phase 3: binomial broadcast of the full buffer within each region --
        have = 1
        while have < pl:
            pairs = [(flat(Rg, lg), flat(Rg, lg + have))
                     for Rg in range(r) for lg in range(min(have, pl - have))]
            recv = lax.ppermute(buf, outer + local, pairs)
            is_recv = (l >= have) & (l < 2 * have)
            buf = jnp.where(is_recv, recv, buf)
            have *= 2

        buf = buf.reshape((r * pl,) + x.shape)
    return _out(buf, tiled, x.shape)


# =============================================================================
# Multi-lane allgather [Träff & Hunold '20]: every lane runs a Bruck over the
# regions concurrently (its own block only), then one local allgather combines
# the lanes. Non-local bytes drop by p_local; message count unchanged.
# =============================================================================
def _multilane_allgather(x: jax.Array, outer: Axes, local: Axes, *,
                        tiled: bool = False) -> jax.Array:
    outer, local = _tup(outer), _tup(local)
    r, pl = _size(outer), _size(local)
    x = _varying(x, outer + local)
    with jax.named_scope(f"multilane_ag_r{r}_pl{pl}"):
        lane = _bruck_allgather(x, outer)      # [r, ...] canonical region order
        allb = _bruck_allgather(lane, local)   # [pl, r, ...] lane-major
        buf = jnp.moveaxis(allb, 1, 0)        # [r, pl, ...] region-major
        buf = buf.reshape((r * pl,) + x.shape)
    return _out(buf, tiled, x.shape)


# =============================================================================
# Algorithm 2 — locality-aware Bruck allgather (the paper's contribution).
# =============================================================================
def _nonlocal_round_geometry(r: int, pl: int, group: int
                             ) -> tuple[int, int, int]:
    """Static geometry of one Algorithm-2 non-local round.

    With ``group`` region chunks held per rank, returns ``(active, span,
    rem)``: the lanes that exchange this round (offsets 0..active-1 name
    distinct peer regions), the chunks held after the round (``span =
    min(active·group, r)``), and the chunk count the LAST active lane's peer
    is actually missing (``rem ∈ (0, group]``; ``rem < group`` only on the
    wrapped final round of a non-power region count — the allgatherv case).
    """
    n_groups = -(-r // group)                 # distinct groups remaining
    active = min(pl, n_groups)
    span = min(active * group, r)
    rem = span - (active - 1) * group
    return active, span, rem


def _nonlocal_exchange(buf: jax.Array, axes: tuple[str, ...], r: int, pl: int,
                       group: int, active: int, rem: int, l: jax.Array,
                       step: int) -> jax.Array:
    """One Algorithm-2 non-local round, allgatherv-adapted (paper §3).

    Lane ℓ ∈ [1, active) sends to region R - ℓ·group (same lane) and
    receives from R + ℓ·group. Lanes 1..active-2 need their peer's full
    ``group``-chunk buffer; the last active lane's peer is missing only
    ``rem`` chunks, so on a wrapped final round (``rem < group``) that lane
    sends exactly the ``rem``-chunk prefix — the partial final-round payload
    that replaces the paper's MPI_Allgatherv for non-power region counts
    (previously the full buffer went over the DCN and the duplicate chunks
    were discarded after the fact). The partial receive is zero-padded back
    to ``group`` chunks so the local redistribution stays one uniform Bruck
    allgather; the caller's ``span`` trim drops the padding statically.
    Message count is unchanged: the two ppermutes carry disjoint edge sets,
    one send per active lane per round.
    """
    flat = lambda Rg, lg: Rg * pl + lg
    last = active - 1
    full_pairs = [(flat(Rg, lg), flat((Rg - lg * group) % r, lg))
                  for Rg in range(r) for lg in range(1, last)]
    last_pairs = [(flat(Rg, last), flat((Rg - last * group) % r, last))
                  for Rg in range(r)]
    with jax.named_scope(f"nonlocal_step{step}"):
        if rem == group:                      # uniform round: one ppermute
            return lax.ppermute(buf, axes, full_pairs + last_pairs)
        part = lax.ppermute(buf[: rem * pl], axes, last_pairs)
        pad = [(0, (group - rem) * pl)] + [(0, 0)] * (buf.ndim - 1)
        part = jnp.pad(part, pad)
        if not full_pairs:                    # active == 2: only the partial
            return part
        recv = lax.ppermute(buf, axes, full_pairs)
        return jnp.where(l == last, part, recv)


def _locality_bruck_allgather(x: jax.Array, outer: Axes, local: Axes, *,
                             tiled: bool = False,
                             assume_varying: bool = False) -> jax.Array:
    """Paper Algorithm 2 over mesh axes — ANY outer region count.

    1. Local Bruck allgather inside each region (``local`` axes).
    2. ceil(log_{p_ℓ}(r)) non-local rounds: with ``group`` regions' data held,
       lane ℓ ∈ [1, active) sends its buffer to region R - ℓ·group (same
       lane) and receives from R + ℓ·group — one non-local message per rank
       per round, each pair of regions exchanging disjoint data. Lane 0
       stays idle (paper §3) and re-contributes its own buffer.
    3. A local allgather of the received buffers redistributes them in-region.

    Allgatherv adaptation (DESIGN.md §7): where the paper uses
    MPI_Allgatherv for non-power region counts, the wrapped final round
    sends only the partial payload its peer is missing
    (:func:`_nonlocal_exchange`), the uniform local allgather runs on
    zero-padded units, and the ``pl - active`` empty units plus the padding
    are discarded statically — strictly fewer non-local bytes than the
    full-buffer exchange, identical message count, slightly padded local
    traffic.

    assume_varying: as for :func:`_bruck_allgather` — required when this
    gather is differentiated inside a ``check_vma=False`` region (the
    two-tier FSDP param gather of train/step.py).
    """
    outer, local = _tup(outer), _tup(local)
    r, pl = _size(outer), _size(local)
    if not assume_varying:
        x = _varying(x, outer + local)
    if pl == 1:
        return _bruck_allgather(x, outer + local, tiled=tiled,
                               assume_varying=True)
    R = lax.axis_index(outer)
    l = lax.axis_index(local)

    with jax.named_scope(f"loc_bruck_ag_r{r}_pl{pl}"):
        # Step 0 (Alg. 2 line 1): local allgather of initial values.
        buf = _bruck_allgather(x, local, assume_varying=True)
        # Invariant: buf = region chunks [R, R+group) (mod r), chunk = pl blocks.
        group = 1
        step = 0
        while group < r:
            active, span, rem = _nonlocal_round_geometry(r, pl, group)
            recv = _nonlocal_exchange(buf, outer + local, r, pl, group,
                                      active, rem, l, step)
            # Lane 0 re-contributes its current buffer; lanes >= active carry
            # no new data (their unit is discarded below).
            unit = jnp.where(l == 0, buf, recv)
            with jax.named_scope(f"redistribute_step{step}"):
                stacked = _bruck_allgather(unit, local,  # [pl, group*pl, ...]
                                          assume_varying=True)
            stacked = stacked[:active]
            buf = stacked.reshape((active * group * pl,) + x.shape)
            buf = buf[: span * pl]             # drop final-round padding
            group = span
            step += 1

        chunks = buf.reshape((r, pl) + x.shape)
        chunks = jnp.roll(chunks, R, axis=0)   # canonical region order
        buf = chunks.reshape((r * pl,) + x.shape)
    return _out(buf, tiled, x.shape)


# =============================================================================
# Dispatcher
# =============================================================================
ALLGATHERS = {
    "bruck": lambda x, outer, local, tiled: _bruck_allgather(
        x, _tup(outer) + _tup(local), tiled=tiled),
    "ring": lambda x, outer, local, tiled: _ring_allgather(
        x, _tup(outer) + _tup(local), tiled=tiled),
    "hierarchical": lambda x, outer, local, tiled: _hierarchical_allgather(
        x, outer, local, tiled=tiled),
    "multilane": lambda x, outer, local, tiled: _multilane_allgather(
        x, outer, local, tiled=tiled),
    "locality_bruck": lambda x, outer, local, tiled: _locality_bruck_allgather(
        x, outer, local, tiled=tiled),
    "xla": lambda x, outer, local, tiled: lax.all_gather(
        x, _tup(outer) + _tup(local), tiled=tiled),
}


def _resolve_auto(collective: str, x: jax.Array, outer: tuple[str, ...],
                  local: tuple[str, ...]) -> str:
    """Trace-time resolution of ``algorithm="auto"`` through repro.tuning.

    Axis sizes and the shard's byte count are Python ints during tracing, so
    the choice is static: the jitted program contains exactly the selected
    schedule (resolve again to re-tune, e.g. after a sweep).
    """
    from repro.tuning.policy import resolve
    p_local = _size(local) if local else 1
    p = _size(outer + local)
    nbytes = x.size * x.dtype.itemsize
    return resolve(collective, p, p_local, nbytes, str(x.dtype))


def allgather(x: jax.Array, outer: Axes, local: Axes = (), *,
              algorithm: str = "locality_bruck", tiled: bool = False,
              assume_varying: bool = False) -> jax.Array:
    """Gather ``x`` shards over ``outer + local`` mesh axes (region-major).

    ``algorithm="auto"`` selects via the tuning policy: the persisted
    measured crossover table when one exists, the postal model otherwise.

    assume_varying: skip the vma normalization (see
    :func:`_bruck_allgather`) — only the Bruck schedules support being
    differentiated inside a ``check_vma=False`` region.
    """
    if algorithm == "auto":
        algorithm = _resolve_auto("allgather", x, _tup(outer), _tup(local))
    if not _tup(local):
        algorithm = "bruck" if algorithm in ("locality_bruck", "hierarchical",
                                             "multilane") else algorithm
    if assume_varying:
        if algorithm == "bruck":
            return _bruck_allgather(x, _tup(outer) + _tup(local), tiled=tiled,
                                    assume_varying=True)
        if algorithm == "locality_bruck":
            return _locality_bruck_allgather(x, outer, local, tiled=tiled,
                                             assume_varying=True)
        raise ValueError(f"assume_varying is only supported for the Bruck "
                         f"schedules, not algorithm={algorithm!r}")
    return ALLGATHERS[algorithm](x, outer, local, tiled)


# Algorithms eligible for KV-cache migration (see ``cache_migrate``): the
# locality schedule minimizes inter-pod messages, multilane minimizes
# per-rank inter-pod bytes, and flat XLA is the ring-decomposed baseline.
MIGRATE_ALGORITHMS = ("locality_bruck", "multilane", "xla")


def cache_migrate(x: jax.Array, outer: Axes, local: Axes = (), *,
                  algorithm: str = "auto", tiled: bool = True) -> jax.Array:
    """Replicate a sequence-sharded KV-cache slab over ``outer + local``.

    The serve scheduler calls this when a request's cache must move across
    the pod (DCN) boundary: the donor layout shards the slab's sequence dim
    over every rank, and the destination insert needs the full slab on the
    owning ranks — a gatherv-shaped replication where the Algorithm-2
    machinery applies directly (uneven tails ride the allgatherv adaptation
    inside :func:`_locality_bruck_allgather`). Priced as its own tuning cell
    (``"cache_migrate"``) because the slab-sized payloads sit in a different
    α/β regime than activation allgathers.
    """
    if algorithm == "auto":
        algorithm = _resolve_auto("cache_migrate", x, _tup(outer), _tup(local))
    if algorithm not in MIGRATE_ALGORITHMS:
        raise ValueError(f"cache_migrate algorithm {algorithm!r} not in "
                         f"{MIGRATE_ALGORITHMS}")
    if not _tup(local):
        algorithm = "bruck" if algorithm != "xla" else "xla"
    with jax.named_scope(f"cache_migrate_{algorithm}"):
        return ALLGATHERS[algorithm](x, outer, local, tiled)


# =============================================================================
# Split (start/finish) collectives — the overlap pipeline's communication half
# =============================================================================
# ``allgather_finish(allgather_start(x, ...)) == allgather(x, ...)`` — the
# same op sequence, divided so the expensive non-local rounds run in start
# and only the cheap local redistribution remains at the consumer. A caller
# that issues start(layer i+1) before layer i's compute makes the non-local
# ppermutes data-independent of that compute, which is exactly what XLA's
# latency-hiding scheduler needs to overlap them (it splits collectives into
# -start/-done pairs and slides independent work between).

#: Default lookahead of the double-buffered pipelines (layers of params
#: gathered ahead of the consumer). 1 = classic double buffering; 0 = eager.
PREFETCH_DEPTH_DEFAULT = 1


@dataclasses.dataclass(frozen=True)
class _SplitMeta:
    """Static half of a PendingCollective (hashable: safe under jit/scan)."""

    op: str                        # "allgather" | "allreduce" | "logsumexp"
    kind: str                      # phase tag, see the start functions
    outer: tuple[str, ...] = ()
    local: tuple[str, ...] = ()
    tiled: bool = False
    x_shape: tuple[int, ...] = ()
    group: int = 1                 # locality_bruck: chunks held pre-finish
    active: int = 1                # locality_bruck: lanes live in last round
    rem: int = 0                   # chunks the last active lane really
                                   # carried in the final round — always
                                   # set on "pending" metas (rem < group on
                                   # the allgatherv wrapped round); unused
                                   # by the other kinds


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PendingCollective:
    """An in-flight split collective.

    Registered as a pytree so it can ride a ``lax.scan`` carry (the
    double-buffered pipelines keep one pending gather per lookahead slot).
    """

    arrays: tuple
    meta: _SplitMeta

    def tree_flatten(self):
        return tuple(self.arrays), self.meta

    @classmethod
    def tree_unflatten(cls, meta, arrays):
        return cls(tuple(arrays), meta)


def _locality_bruck_allgather_start(x: jax.Array, outer: Axes, local: Axes, *,
                                   tiled: bool = False,
                                   assume_varying: bool = False
                                   ) -> PendingCollective:
    """Algorithm 2, split: everything through the LAST non-local ppermute.

    Intermediate rounds keep their local redistribution (the next non-local
    round consumes it), so only the final local allgather + canonical
    reordering — pure ICI traffic — is deferred to ``finish``. All DCN bytes
    are on the wire when start returns. On a wrapped final round (non-power
    region counts) the partial payload is already zero-padded back to
    ``group`` chunks here, so the PendingCollective's arrays stay the
    uniform ``(buf, recv)`` pair and the meta's ``(group, active, rem)``
    record the uneven geometry — the prefetch pipeline and the FSDP
    transpose carry it without caring about the region count.
    """
    outer, local = _tup(outer), _tup(local)
    r, pl = _size(outer), _size(local)
    if not assume_varying:
        x = _varying(x, outer + local)
    if pl == 1:
        full = _bruck_allgather(x, outer + local, tiled=tiled,
                               assume_varying=True)
        return PendingCollective((full,), _SplitMeta("allgather", "done"))
    l = lax.axis_index(local)

    with jax.named_scope(f"loc_bruck_ag_start_r{r}_pl{pl}"):
        buf = _bruck_allgather(x, local, assume_varying=True)
        if r == 1:
            return PendingCollective(
                (buf,), _SplitMeta("allgather", "local_done", outer, local,
                                   tiled, x.shape, group=1, active=1))
        group = 1
        step = 0
        while True:
            active, span, rem = _nonlocal_round_geometry(r, pl, group)
            recv = _nonlocal_exchange(buf, outer + local, r, pl, group,
                                      active, rem, l, step)
            if span >= r:                  # last round: defer redistribution
                return PendingCollective(
                    (buf, recv), _SplitMeta("allgather", "pending", outer,
                                            local, tiled, x.shape,
                                            group=group, active=active,
                                            rem=rem))
            unit = jnp.where(l == 0, buf, recv)
            with jax.named_scope(f"redistribute_step{step}"):
                stacked = _bruck_allgather(unit, local, assume_varying=True)
            stacked = stacked[:active]
            buf = stacked.reshape((active * group * pl,) + x.shape)
            group = span
            step += 1


def _locality_bruck_allgather_finish(pending: PendingCollective) -> jax.Array:
    """Complete a split Algorithm 2: final local redistribution + reorder."""
    meta = pending.meta
    if meta.kind == "done":
        return pending.arrays[0]
    outer, local = meta.outer, meta.local
    r, pl = _size(outer) if outer else 1, _size(local)
    x_shape = meta.x_shape
    with jax.named_scope(f"loc_bruck_ag_finish_r{r}_pl{pl}"):
        if meta.kind == "local_done":
            (buf,) = pending.arrays
        else:
            buf, recv = pending.arrays
            l = lax.axis_index(local)
            unit = jnp.where(l == 0, buf, recv)
            with jax.named_scope("redistribute_final"):
                stacked = _bruck_allgather(unit, local, assume_varying=True)
            stacked = stacked[:meta.active]
            buf = stacked.reshape((meta.active * meta.group * pl,) + x_shape)
            # the uneven geometry recorded at start: the last lane carried
            # only `rem` real chunks — drop its zero padding (and with it
            # any wrap past region r)
            valid = (meta.active - 1) * meta.group + meta.rem
            assert valid == r, (meta, r)
            buf = buf[: valid * pl]
        chunks = buf.reshape((r, pl) + x_shape)
        if outer:                          # canonical region order
            chunks = jnp.roll(chunks, lax.axis_index(outer), axis=0)
        buf = chunks.reshape((r * pl,) + x_shape)
    return _out(buf, meta.tiled, x_shape)


def allgather_start(x: jax.Array, outer: Axes, local: Axes = (), *,
                    algorithm: str = "locality_bruck", tiled: bool = False,
                    assume_varying: bool = False) -> PendingCollective:
    """Issue an allgather; complete it with :func:`allgather_finish`.

    For ``locality_bruck`` the non-local rounds genuinely complete in start
    (_locality_bruck_allgather_start); every other algorithm has no local
    tail to defer, so start runs the whole gather and the split is a
    program-order hook — still the mechanism that lets a double-buffered
    caller issue it before independent compute.
    """
    if algorithm == "auto":
        algorithm = _resolve_auto("allgather", x, _tup(outer), _tup(local))
    if not _tup(local):
        algorithm = "bruck" if algorithm in ("locality_bruck", "hierarchical",
                                             "multilane") else algorithm
    if algorithm == "locality_bruck":
        return _locality_bruck_allgather_start(
            x, outer, local, tiled=tiled, assume_varying=assume_varying)
    if algorithm == "bruck":
        full = _bruck_allgather(x, _tup(outer) + _tup(local), tiled=tiled,
                               assume_varying=assume_varying)
    else:
        full = ALLGATHERS[algorithm](x, outer, local, tiled)
    return PendingCollective((full,), _SplitMeta("allgather", "done"))


def allgather_finish(pending: PendingCollective) -> jax.Array:
    """Complete an :func:`allgather_start`; bit-identical to the eager path."""
    assert pending.meta.op == "allgather", pending.meta
    return _locality_bruck_allgather_finish(pending)


# =============================================================================
# Locality-aware all-to-all — the MoE expert-dispatch collective family.
# =============================================================================
# The paper's two-tier decomposition applied to personalized exchange: block
# (i → j) must cross the DCN at most once, and every inter-pod message is the
# AGGREGATE of a whole pod-pair's blocks instead of a rank-pair's.  Three
# phases, all ppermute (the compiled HLO carries explicit source_target_pairs,
# so collective_stats classifies every edge exactly):
#
#   1. intra-pod collect   — offsets o ∈ [1, q) to the q-1 other pods are
#      assigned round-robin to the p_ℓ lanes (offset o → lane (o-1) mod p_ℓ,
#      round (o-1) div p_ℓ — the same modular lane assignment as Algorithm
#      2's non-local rounds); a local all-to-all hands lane ℓ every local
#      rank's blocks destined to ℓ's pods.
#   2. inter-pod rounds    — ceil((q-1)/p_ℓ) rounds; in round t, active lane
#      ℓ ships ONE aggregated (p_ℓ × p_ℓ)-block slab to pod R + (t·p_ℓ+ℓ+1)
#      and receives the mirror slab.  The last round runs with only
#      (q-1) - (nrounds-1)·p_ℓ active lanes — the non-power-q partial-round
#      geometry of `_nonlocal_round_geometry`, here with group = 1 (no
#      doubling: every block already knows its destination).  q-1 aggregated
#      DCN messages per pod total vs p_ℓ²·(q-1) for the flat pairwise
#      exchange.
#   3. intra-pod deliver   — a second local all-to-all fans the received
#      slabs' columns out to their destination lanes (own-pod blocks ride
#      the same ppermutes), and a static reassembly restores canonical
#      source-rank order.
#
# Linear throughout (roll / reshape / pad / ppermute), so jax.vjp transposes
# the whole exchange into the reversed all-to-all for free — the MoE return
# leg and the router-gradient path reuse the same machinery.

#: Canonical algorithm names for the all_to_all family.
ALL_TO_ALL_ALGORITHMS = ("locality", "xla")


def _a2a_rounds(q: int, pl: int) -> int:
    """Inter-pod round count of the two-tier all-to-all: offsets 1..q-1
    spread over p_ℓ lanes."""
    return -(-(q - 1) // pl) if q > 1 else 0


def _a2a_active(q: int, pl: int, t: int) -> int:
    """Active lanes in inter-pod round ``t`` (partial on the last round of a
    non-power q, mirroring `_nonlocal_round_geometry`'s ``active``)."""
    return max(0, min(pl, (q - 1) - t * pl))


def _local_exchange(struct: jax.Array, axes: tuple[str, ...], q: int, pl: int,
                    l: jax.Array, tag: str) -> jax.Array:
    """Local all-to-all of ``struct`` (leading dim p_ℓ: entry λ is the
    payload for local rank λ).  Returns the mirrored structure: entry m is
    the payload local rank m addressed to us.  p_ℓ - 1 intra-pod ppermutes
    (offset k pairs lane m with lane m+k), plus the rank's own entry.
    """
    flat = lambda Rg, lg: Rg * pl + lg
    sends = jnp.roll(struct, -l, axis=0)          # sends[k] -> lane (l+k)%pl
    arr = [sends[0]]                              # k = 0: own payload
    with jax.named_scope(tag):
        for k in range(1, pl):
            pairs = [(flat(Rg, m), flat(Rg, (m + k) % pl))
                     for Rg in range(q) for m in range(pl)]
            arr.append(lax.ppermute(sends[k], axes, pairs))
    # arr[k] came from lane (l-k)%pl; reindex to source-lane order.
    return jnp.roll(jnp.stack(arr)[::-1], l + 1, axis=0)


def locality_all_to_all_start(x: jax.Array, outer: Axes, local: Axes = (), *,
                              tiled: bool = False,
                              assume_varying: bool = False
                              ) -> PendingCollective:
    """Two-tier all-to-all, split: the intra-pod collect and ALL inter-pod
    rounds run here — every DCN byte is on the wire when start returns; only
    the intra-pod delivery + static reassembly remain in finish."""
    outer, local = _tup(outer), _tup(local)
    q, pl = _size(outer), _size(local)
    p = q * pl
    if not assume_varying:
        x = _varying(x, outer + local)
    assert x.shape[0] % p == 0, \
        f"all_to_all leading dim {x.shape[0]} not divisible by p={p}"
    blk = (x.shape[0] // p,) + x.shape[1:]
    xb = x.reshape((q, pl) + blk)                 # [dest_pod][dest_lane]
    if p == 1:
        return PendingCollective((x,), _SplitMeta("all_to_all", "done"))
    axes = outer + local
    l = lax.axis_index(local) if pl > 1 else jnp.int32(0)
    nrounds = _a2a_rounds(q, pl)

    with jax.named_scope(f"loc_a2a_start_q{q}_pl{pl}"):
        if q > 1:
            R = lax.axis_index(outer)
            # xs[s] = block-slab destined to pod (R+1+s)%q; xs[q-1] = own pod.
            xs = jnp.roll(xb, -(R + 1), axis=0)
            own = xs[q - 1]                       # (pl_dst, *blk)
            rs = xs[: q - 1]
            pad = [(0, nrounds * pl - (q - 1))] + [(0, 0)] * (rs.ndim - 1)
            rs = jnp.pad(rs, pad)                 # zero slots: inactive lanes
            # offset slot s = t·pl + λ  →  send-structure [λ][t][dest_lane]
            sendst = jnp.moveaxis(
                rs.reshape((nrounds, pl, pl) + blk), 1, 0)
            # Phase 1: lane λ collects every local rank's slabs for λ's pods.
            coll = _local_exchange(sendst, axes, q, pl, l, "a2a_collect")
            # coll: (pl_src, nrounds, pl_dst, *blk) → per-round slabs
            A = jnp.moveaxis(coll, 1, 0)          # (nrounds, pl_src, pl_dst, ...)
            # Phase 2: one aggregated DCN message per active lane per round.
            recvs = []
            for t in range(nrounds):
                active = _a2a_active(q, pl, t)
                pairs = [(Rg * pl + lg,
                          ((Rg + t * pl + lg + 1) % q) * pl + lg)
                         for lg in range(active) for Rg in range(q)]
                with jax.named_scope(f"a2a_nonlocal_round{t}"):
                    recvs.append(lax.ppermute(A[t], axes, pairs))
            slabs = jnp.stack(recvs)              # (nrounds, pl_src, pl_dst, ...)
            return PendingCollective(
                (slabs, own), _SplitMeta("all_to_all", "pending", outer,
                                         local, tiled, blk, group=nrounds,
                                         active=_a2a_active(q, pl,
                                                            nrounds - 1)))
        # q == 1: nothing crosses the pod boundary; delivery happens in finish.
        own = xb[0]                               # (pl_dst, *blk)
        return PendingCollective(
            (own,), _SplitMeta("all_to_all", "local_only", outer, local,
                               tiled, blk))


def locality_all_to_all_finish(pending: PendingCollective) -> jax.Array:
    """Complete a split two-tier all-to-all: intra-pod delivery of the
    received slab columns (+ own-pod blocks) and canonical reordering."""
    meta = pending.meta
    assert meta.op == "all_to_all", meta
    if meta.kind == "done":
        return pending.arrays[0]
    outer, local, blk = meta.outer, meta.local, meta.x_shape
    q = _size(outer) if outer else 1
    pl = _size(local) if local else 1
    p = q * pl
    axes = outer + local
    l = lax.axis_index(local) if pl > 1 else jnp.int32(0)
    nrounds = meta.group if meta.kind == "pending" else 0

    with jax.named_scope(f"loc_a2a_finish_q{q}_pl{pl}"):
        if meta.kind == "pending":
            slabs, own = pending.arrays
            # Phase 3 payload for dest lane m: the m-columns of every
            # received slab, then the own-pod block — one structure so the
            # own-pod blocks ride the same p_ℓ-1 local ppermutes.
            cols = jnp.moveaxis(slabs, 2, 0)      # (pl_dst, nrounds, pl_src, ...)
            cols = cols.reshape((pl, nrounds * pl) + blk)
            struct = jnp.concatenate([cols, own[:, None]], axis=1)
        else:
            (own,) = pending.arrays
            struct = own[:, None]                 # (pl_dst, 1, *blk)
        got = _local_exchange(struct, axes, q, pl, l, "a2a_deliver")
        # got[λ][s] for s < nrounds·pl: block from pod (R - (t·pl+λ+1))%q,
        # src lane s%pl; got[λ][-1]: own-pod block from lane λ.
        own_blocks = got[:, -1]                   # (pl_src, *blk)
        if q > 1:
            rem = jnp.moveaxis(
                got[:, :-1].reshape((pl, nrounds, pl) + blk), 1, 0)
            rem = rem.reshape((nrounds * pl, pl) + blk)[: q - 1]
            stacked = jnp.concatenate([own_blocks[None], rem], axis=0)
            # stacked[o] = blocks from pod (R-o)%q → canonical pod order.
            R = lax.axis_index(outer)
            canon = jnp.roll(stacked[::-1], R + 1, axis=0)
        else:
            canon = own_blocks[None]
        buf = canon.reshape((p,) + blk)
    # unlike allgather, the exchange preserves shape: block i of the output
    # (same leading-dim split as the input) came from rank i
    return buf.reshape((p * blk[0],) + blk[1:])


def locality_all_to_all(x: jax.Array, outer: Axes, local: Axes = (), *,
                        tiled: bool = False,
                        assume_varying: bool = False) -> jax.Array:
    """Two-tier personalized exchange over ``outer + local`` (region-major).

    ``x``'s leading dim is split into p equal blocks; block j goes to rank j
    and the output's block i came from rank i — ``lax.all_to_all`` with
    ``split_axis=concat_axis=0, tiled=True`` semantics.  Composed of the
    split halves so the eager and overlapped paths cannot drift.
    """
    return locality_all_to_all_finish(locality_all_to_all_start(
        x, outer, local, tiled=tiled, assume_varying=assume_varying))


def all_to_all(x: jax.Array, outer: Axes, local: Axes = (), *,
               algorithm: str = "locality", tiled: bool = False,
               assume_varying: bool = False) -> jax.Array:
    """All-to-all dispatcher: 'locality' (two-tier, minimized inter-pod
    phase), 'xla' (lax.all_to_all — direct pairwise under the analyzer's
    pricing), or 'auto' (tuning policy)."""
    if algorithm == "auto":
        algorithm = _resolve_auto("all_to_all", x, _tup(outer), _tup(local))
    if algorithm == "locality":
        return locality_all_to_all(x, outer, local, tiled=tiled,
                                   assume_varying=assume_varying)
    if algorithm == "xla":
        axes = _tup(outer) + _tup(local)
        if not assume_varying:
            x = _varying(x, axes)
        if _size(axes) == 1:
            return x
        return lax.all_to_all(x, axes, split_axis=0, concat_axis=0,
                              tiled=True)
    raise ValueError(f"unknown all_to_all algorithm {algorithm!r}; "
                     f"known: {ALL_TO_ALL_ALGORITHMS + ('auto',)}")


def all_to_all_start(x: jax.Array, outer: Axes, local: Axes = (), *,
                     algorithm: str = "locality", tiled: bool = False,
                     assume_varying: bool = False) -> PendingCollective:
    """Issue an all-to-all; complete with :func:`all_to_all_finish`.  For
    'locality' the DCN rounds genuinely complete in start; 'xla' has no
    local tail, so the split is a program-order hook."""
    if algorithm == "auto":
        algorithm = _resolve_auto("all_to_all", x, _tup(outer), _tup(local))
    if algorithm == "locality":
        return locality_all_to_all_start(x, outer, local, tiled=tiled,
                                         assume_varying=assume_varying)
    full = all_to_all(x, outer, local, algorithm=algorithm, tiled=tiled,
                      assume_varying=assume_varying)
    return PendingCollective((full,), _SplitMeta("all_to_all", "done"))


def all_to_all_finish(pending: PendingCollective) -> jax.Array:
    """Complete an :func:`all_to_all_start`; bit-identical to eager."""
    assert pending.meta.op == "all_to_all", pending.meta
    return locality_all_to_all_finish(pending)


# =============================================================================
# Reductions
# =============================================================================
def reduce_scatter(y: jax.Array, outer: Axes, local: Axes = (), *,
                   algorithm: str = "locality_bruck") -> jax.Array:
    """Sum-reduce-scatter: linear transpose of the chosen allgather.

    ``y`` has leading dim divisible by p; rank i ends with the i-th tile of
    the sum over ranks. The transposed schedule communicates exactly the same
    edges as the forward allgather, reversed — so the locality structure (and
    the non-local message/byte counts of paper Eq. 4) carry over.
    """
    outer, local = _tup(outer), _tup(local)
    p = _size(outer + local)
    assert y.shape[0] % p == 0, f"leading dim {y.shape[0]} not divisible by {p}"
    x_shape = (y.shape[0] // p,) + y.shape[1:]
    y = _varying(y, outer + local)

    def ag(x):
        return allgather(x, outer, local, algorithm=algorithm, tiled=True)

    # vjp at a *device-varying* zero primal: an unvarying primal would make
    # the vma-aware transpose psum the cotangent (replicated-input semantics).
    primal = jnp.zeros(x_shape, y.dtype) + y.reshape(-1)[0] * 0
    _, vjp = jax.vjp(ag, primal)
    (out,) = vjp(y)
    return out


# Generic reduction-op hook: every hand-rolled reduction below is written
# against a binary combiner, so allreduce is not sum-only (the serve decode
# cache-combine needs a max phase for its running softmax maximum).
REDUCE_BINOPS = {"sum": jnp.add, "max": jnp.maximum, "min": jnp.minimum}
_XLA_REDUCERS = {"sum": lax.psum, "max": lax.pmax, "min": lax.pmin}


def _binop(op):
    if op not in REDUCE_BINOPS:
        raise ValueError(f"unknown reduction op {op!r}; "
                         f"known: {sorted(REDUCE_BINOPS)}")
    return REDUCE_BINOPS[op]


def _rhd_reduce_scatter(x: jax.Array, axes: tuple[str, ...],
                        op: str = "sum") -> jax.Array:
    """Recursive-halving reduce-scatter over ``axes`` (XOR partners).

    Leading dim must be divisible by p. Rank i ends with tile i of the
    reduction. log2(p) rounds; round k exchanges 1/2^{k+1} of the buffer.
    """
    combine = _binop(op)
    p = _size(axes)
    idx = lax.axis_index(axes)
    assert x.shape[0] % p == 0
    assert p & (p - 1) == 0, "recursive halving needs power-of-two size"
    buf = x
    d = p // 2
    while d >= 1:
        pairs = [(s, s ^ d) for s in range(p)]
        half = buf.shape[0] // 2
        bit = (idx & d) != 0
        # keep the half matching our bit (MSB-first -> final tile index = idx)
        send_start = jnp.where(bit, 0, half)
        keep_start = jnp.where(bit, half, 0)
        starts = lambda s: (s,) + (0,) * (buf.ndim - 1)
        send = lax.dynamic_slice(buf, starts(send_start), (half,) + buf.shape[1:])
        keep = lax.dynamic_slice(buf, starts(keep_start), (half,) + buf.shape[1:])
        recv = lax.ppermute(send, axes, pairs)
        buf = combine(keep, recv)
        d //= 2
    return buf


def _rd_allreduce(x: jax.Array, axes: tuple[str, ...],
                  op: str = "sum") -> jax.Array:
    """Recursive-doubling allreduce over ``axes`` — ANY axis size.

    Powers of two run the classic log2(p) XOR-partner full-buffer exchange
    (latency-optimal). Other sizes take the standard fold/unfold adaptation
    (Rabenseifner; the allreduce generalization of the padded-Bruck /
    allgatherv machinery in Jocksch et al.): the p - m surplus ranks
    (m = largest power of two <= p) first fold their value into a core
    partner, the power-of-two core runs recursive doubling, and one unfold
    round sends the result back — log2(m) + 2 full-buffer messages, still
    logarithmic. ppermute delivers zeros to ranks outside a round's pair
    set, so every fold/core combine is masked to the ranks that really
    received (an unmasked ``max`` with an implicit zero would corrupt
    negative operands).
    """
    combine = _binop(op)
    p = _size(axes)
    if p == 1:
        return x
    buf = x
    m = 1 << (p.bit_length() - 1)      # largest power of two <= p
    surplus = p - m
    idx = lax.axis_index(axes) if surplus else None
    if surplus:
        pairs = [(s, s - m) for s in range(m, p)]
        recv = lax.ppermute(buf, axes, pairs)
        buf = jnp.where(idx < surplus, combine(buf, recv), buf)
    d = 1
    while d < m:
        pairs = [(s, s ^ d) for s in range(m)]
        recv = lax.ppermute(buf, axes, pairs)
        nxt = combine(buf, recv)
        buf = nxt if not surplus else jnp.where(idx < m, nxt, buf)
        d *= 2
    if surplus:
        pairs = [(s, s + m) for s in range(surplus)]
        recv = lax.ppermute(buf, axes, pairs)
        buf = jnp.where(idx >= m, recv, buf)
    return buf




def _locality_allreduce(x: jax.Array, outer: Axes, local: Axes, *,
                       outer_algorithm: str = "rhd",
                       op: str = "sum") -> jax.Array:
    """Locality-aware allreduce (paper's structure applied to reductions).

    local reduce-scatter → per-lane allreduce across regions → local
    allgather (Bruck). Non-local traffic per rank: 2·ceil(log2 r) messages
    of b/p_ℓ bytes ("rhd"), or ~log2(r) messages ("rd", latency-optimal),
    or XLA's choice ("psum", explicit opt-in only) — vs ~2·b bytes for a
    flat ring allreduce.

    Every structure runs on ARBITRARY region counts (no silent psum
    fallback): "rhd" on a non-power r swaps the recursive-halving
    reduce-scatter for the Bruck-transpose reduce-scatter
    (:func:`reduce_scatter` with ``algorithm="bruck"`` — the allgatherv
    adaptation's reversed schedule, same ceil(log2 r) rounds and partial
    payloads), and "rd" uses the fold/unfold generalization of
    :func:`_rd_allreduce` (log2(m) + 2 rounds).

    ``op`` selects the reduction ("sum"/"max"/"min"). Non-sum reductions
    skip the scatter structure (there is no pmax_scatter, and their use
    case — running softmax maxima — is latency-bound): local
    recursive-doubling then per-lane outer recursive-doubling, any axis
    size via the same fold/unfold rounds.

    Works on arbitrary-shaped ``x`` (flattens + pads internally).
    """
    outer, local = _tup(outer), _tup(local)
    r, pl = _size(outer), _size(local)
    x = _varying(x, outer + local)
    if op != "sum":
        _binop(op)                           # validate
        with jax.named_scope(f"loc_allreduce_{op}_r{r}_pl{pl}"):
            if pl > 1:
                x = _rd_allreduce(x, local, op=op)
            if r > 1:
                x = _rd_allreduce(x, outer, op=op)
        return x
    shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % pl
    if pad:
        flat = jnp.pad(flat, (0, pad))

    with jax.named_scope(f"loc_allreduce_r{r}_pl{pl}"):
        if pl > 1:
            part = lax.psum_scatter(flat, local, scatter_dimension=0, tiled=True)
        else:
            part = flat
        if r > 1:
            if outer_algorithm == "rhd":
                npart = part.shape[0]
                pad2 = (-npart) % r
                if pad2:
                    part = jnp.pad(part, (0, pad2))
                if r & (r - 1):
                    # non-power region count: the Bruck-TRANSPOSE RS (the
                    # allgatherv adaptation's reversed schedule — same
                    # ceil(log2 r) rounds and partial payloads as the
                    # forward gather)
                    rs = reduce_scatter(part, outer, algorithm="bruck")
                else:
                    rs = _rhd_reduce_scatter(part, outer)
                part = _bruck_allgather(rs, outer, tiled=True)
                if pad2:
                    part = part[:npart]
            elif outer_algorithm == "rd":
                part = _rd_allreduce(part, outer)
            elif outer_algorithm == "psum":
                part = lax.psum(part, outer)
            else:
                raise ValueError(f"unknown outer_algorithm {outer_algorithm!r}")
        if pl > 1:
            full = _bruck_allgather(part, local, tiled=True)
        else:
            full = part
    if pad:
        full = full[:n]
    return full.reshape(shape)


def allreduce(x: jax.Array, outer: Axes, local: Axes = (), *,
              algorithm: str = "locality", outer_algorithm: str = "rhd",
              op: str = "sum") -> jax.Array:
    """Allreduce dispatcher: 'locality' (paper-structured), 'xla' (lax.psum /
    pmax / pmin per ``op``), or 'auto' (tuning policy picks between the two)."""
    outer, local = _tup(outer), _tup(local)
    _binop(op)                               # validate early
    if algorithm == "auto":
        algorithm = _resolve_auto("allreduce", x, outer, local)
    if algorithm == "xla" or (not local) or _size(local) == 1:
        return _XLA_REDUCERS[op](x, outer + local)
    if algorithm == "locality":
        return _locality_allreduce(x, outer, local,
                                  outer_algorithm=outer_algorithm, op=op)
    raise ValueError(f"unknown allreduce algorithm {algorithm!r}")


def allreduce_start(x: jax.Array, outer: Axes, local: Axes = (), *,
                    algorithm: str = "locality", outer_algorithm: str = "rhd",
                    op: str = "sum") -> PendingCollective:
    """Issue an allreduce; complete it with :func:`allreduce_finish`.

    Reduction rounds form one dependency chain (each combines the previous
    round's result), so there is no local tail to defer: start runs the
    whole reduction. The split is a *program-order* hook — call start as
    soon as the operand exists and finish at the consumer, and every op
    between the two is independent compute XLA can overlap the wire with.
    """
    red = allreduce(x, outer, local, algorithm=algorithm,
                    outer_algorithm=outer_algorithm, op=op)
    return PendingCollective((red,), _SplitMeta("allreduce", "done"))


def allreduce_finish(pending: PendingCollective) -> jax.Array:
    assert pending.meta.op == "allreduce", pending.meta
    return pending.arrays[0]


# =============================================================================
# Logsumexp combine — the serve decode cache-combine (§Perf, serve/engine.py)
# =============================================================================
def logsumexp_combine(o: jax.Array, m: jax.Array, l: jax.Array,
                               outer: Axes, local: Axes = (), *,
                               algorithm: str = "locality",
                               outer_algorithm: str = "rhd"
                               ) -> tuple[jax.Array, jax.Array]:
    """Numerically-safe combine of flash-style partial softmax stats.

    Each rank holds, for its slice of the attention (reduction) axis:
      o: (..., D)  unnormalized accumulator  Σ_j exp(s_j − m)·v_j
      m: (...)     running maximum of its local scores
      l: (...)     Σ_j exp(s_j − m)

    Three steps over the ``(outer, local)`` axes:
      1. max-allreduce of ``m`` → global maximum M (latency-bound:
         recursive doubling per locality level, payload is bytes/(D+1));
      2. device-local rescale of o and l by exp(m − M) — a rank whose slice
         is fully masked carries m = −big and contributes exp(−big) ≈ 0;
      3. ONE packed sum-allreduce of [o, l] (paper-structured RS→AG for
         "locality", psum for "xla") instead of two separate collectives.

    Returns (o_total, l_total) in fp32; the caller normalizes o/l.

    Composed of the split halves below, so the eager path and the
    overlapped serve path (max-allreduce issued right after the scores,
    finished after the o/l accumulation) cannot drift.
    """
    with jax.named_scope("logsumexp_combine"):
        pending = logsumexp_combine_start(m, outer, local,
                                                   algorithm=algorithm)
        return logsumexp_combine_finish(
            o, l, pending, algorithm=algorithm,
            outer_algorithm=outer_algorithm)


def logsumexp_combine_start(m: jax.Array, outer: Axes,
                                     local: Axes = (), *,
                                     algorithm: str = "locality"
                                     ) -> PendingCollective:
    """Phase 1 of the decode cache-combine: max-allreduce of the running
    maxima. Depends ONLY on ``m`` — issue it the moment the masked scores
    exist, before the (heavy) exp/accumulate that produces o and l, and the
    latency-bound max phase rides behind that compute."""
    outer, local = _tup(outer), _tup(local)
    m = m.astype(jnp.float32)
    with jax.named_scope("logsumexp_combine_start"):
        M = allreduce(m, outer, local, algorithm=algorithm,
                      outer_algorithm="rd", op="max")
    return PendingCollective((m, M), _SplitMeta("logsumexp", "max_done",
                                                outer, local))


def logsumexp_combine_finish(o: jax.Array, l: jax.Array,
                                      pending: PendingCollective, *,
                                      algorithm: str = "locality",
                                      outer_algorithm: str = "rhd"
                                      ) -> tuple[jax.Array, jax.Array]:
    """Phases 2+3: rescale by exp(m − M), one packed [o, l] sum-allreduce."""
    assert pending.meta.op == "logsumexp", pending.meta
    m, M = pending.arrays
    outer, local = pending.meta.outer, pending.meta.local
    with jax.named_scope("logsumexp_combine_finish"):
        scale = jnp.exp(m - M)
        o32 = o.astype(jnp.float32) * scale[..., None]
        l32 = l.astype(jnp.float32) * scale
        payload = jnp.concatenate([o32.reshape(-1), l32.reshape(-1)])
        tot = allreduce(payload, outer, local, algorithm=algorithm,
                        outer_algorithm=outer_algorithm, op="sum")
    n_o = o32.size
    return tot[:n_o].reshape(o32.shape), tot[n_o:].reshape(l32.shape)


# =============================================================================
# Unified collective surface (DESIGN.md §12) — ONE entry point, one vocabulary
# =============================================================================
#: Canonical collective kinds. "combine" is the decode logsumexp cache-combine
#: (tuning cell name: "logsumexp_combine" — accepted as a kind alias).
KINDS = ("allgather", "allreduce", "reduce_scatter", "all_to_all",
         "cache_migrate", "combine")

#: THE algorithm vocabulary, per kind.  These exact strings are what the
#: tuning cache keys (tuning/cache.make_key), the policy crossover tables,
#: and the comm-ledger labels (telemetry: "train/moe_dispatch:locality") use
#: — one enum, no per-subsystem drift.  "auto" resolves through
#: repro.tuning.policy at trace time.
ALGORITHMS_BY_KIND = {
    "allgather": ("bruck", "ring", "hierarchical", "multilane",
                  "locality_bruck", "xla", "auto"),
    "allreduce": ("locality", "xla", "auto"),
    "reduce_scatter": ("bruck", "ring", "hierarchical", "multilane",
                       "locality_bruck", "xla"),
    "all_to_all": ("locality", "xla", "auto"),
    "cache_migrate": ("locality_bruck", "multilane", "xla", "auto"),
    "combine": ("locality", "xla", "auto"),
}

#: Per-kind default when ``algorithm`` is omitted — the locality schedule
#: everywhere one exists, matching each family function's own default.
DEFAULT_ALGORITHM = {
    "allgather": "locality_bruck", "allreduce": "locality",
    "reduce_scatter": "locality_bruck", "all_to_all": "locality",
    "cache_migrate": "auto", "combine": "locality",
}

_KIND_ALIASES = {"logsumexp_combine": "combine"}


def _norm_kind(kind: str) -> str:
    kind = _KIND_ALIASES.get(kind, kind)
    if kind not in KINDS:
        raise ValueError(f"unknown collective kind {kind!r}; known: {KINDS}")
    return kind


def collective(kind: str, *operands: jax.Array, outer: Axes,
               local: Axes = (), algorithm: str | None = None,
               start: bool = False, **kwargs):
    """The single collective entry point (thin dispatch, zero new math).

    ``collective(kind, x, outer=..., local=..., algorithm=...)`` runs the
    named family eagerly; ``start=True`` returns a :class:`PendingCollective`
    to complete with :func:`finish`.  Operands per kind: one array for
    allgather / allreduce / reduce_scatter / all_to_all / cache_migrate;
    ``(o, m, l)`` for the eager "combine" and just ``(m,)`` for its start
    half (o and l are supplied to :func:`finish`).  Remaining ``kwargs``
    (``tiled``, ``op``, ``outer_algorithm``, ``assume_varying``) pass
    through to the family function.
    """
    kind = _norm_kind(kind)
    if algorithm is None:
        algorithm = DEFAULT_ALGORITHM[kind]
    if algorithm not in ALGORITHMS_BY_KIND[kind]:
        raise ValueError(
            f"unknown algorithm {algorithm!r} for kind {kind!r}; known: "
            f"{ALGORITHMS_BY_KIND[kind]}")
    if kind == "combine":
        if start:
            (m,) = operands
            return logsumexp_combine_start(m, outer, local,
                                           algorithm=algorithm, **kwargs)
        o, m, l = operands
        return logsumexp_combine(o, m, l, outer, local, algorithm=algorithm,
                                 **kwargs)
    (x,) = operands
    if kind == "reduce_scatter":
        if start:
            raise NotImplementedError(
                "reduce_scatter has no start/finish split (its rounds form "
                "one dependency chain ending at the caller)")
        return reduce_scatter(x, outer, local, algorithm=algorithm, **kwargs)
    eager, starter = {
        "allgather": (allgather, allgather_start),
        "allreduce": (allreduce, allreduce_start),
        "all_to_all": (all_to_all, all_to_all_start),
        "cache_migrate": (cache_migrate, None),
    }[kind]
    if start:
        if starter is None:
            raise NotImplementedError(f"{kind} has no start/finish split")
        return starter(x, outer, local, algorithm=algorithm, **kwargs)
    return eager(x, outer, local, algorithm=algorithm, **kwargs)


def finish(pending: PendingCollective, *operands: jax.Array, **kwargs):
    """Complete any ``collective(..., start=True)``; dispatches on the
    pending op.  The "combine" kind takes its deferred ``(o, l)`` operands
    here; every other kind takes none."""
    op = pending.meta.op
    if op == "logsumexp":
        o, l = operands
        return logsumexp_combine_finish(o, l, pending, **kwargs)
    assert not operands, (op, len(operands))
    return {"allgather": allgather_finish, "allreduce": allreduce_finish,
            "all_to_all": all_to_all_finish}[op](pending, **kwargs)


@dataclasses.dataclass(frozen=True)
class Collective:
    """A configured collective: kind + algorithm + axes bound once, applied
    many times — ``Collective("allgather", outer=("pod",), local=("data",))``
    then ``c(x)`` / ``c.start(x)`` + ``c.finish(pending)``.  Pure sugar over
    :func:`collective`; exists so call sites carry ONE object instead of
    re-threading (kind, algorithm, outer, local) through every layer."""

    kind: str
    outer: tuple[str, ...] = ()
    local: tuple[str, ...] = ()
    algorithm: str | None = None

    def __post_init__(self):
        object.__setattr__(self, "outer", _tup(self.outer))
        object.__setattr__(self, "local", _tup(self.local))
        _norm_kind(self.kind)

    def __call__(self, *operands, **kwargs):
        return collective(self.kind, *operands, outer=self.outer,
                          local=self.local, algorithm=self.algorithm,
                          **kwargs)

    def start(self, *operands, **kwargs) -> PendingCollective:
        return self(*operands, start=True, **kwargs)

    @staticmethod
    def finish(pending: PendingCollective, *operands, **kwargs):
        return finish(pending, *operands, **kwargs)


# =============================================================================
# Deprecated aliases (DESIGN.md §12 deprecation policy)
# =============================================================================
# The algorithm-specific entry points predate the unified surface; they warn
# ONCE per process and forward unchanged.  Removal one release out.  The
# family functions (allgather/allreduce/reduce_scatter/all_to_all/
# cache_migrate/logsumexp_combine, their _start/_finish halves, and
# collective()/Collective/finish) are the supported API.
_WARNED: set[str] = set()


def _deprecated(name: str, replacement: str, fn):
    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        if name not in _WARNED:
            _WARNED.add(name)
            warnings.warn(
                f"repro.core.collectives.{name} is deprecated; use "
                f"{replacement} (removal one release out, see DESIGN.md §12)",
                DeprecationWarning, stacklevel=2)
        return fn(*args, **kwargs)
    wrapper.__name__ = name
    wrapper.__qualname__ = name
    return wrapper


bruck_allgather = _deprecated(
    "bruck_allgather", 'collective("allgather", ..., algorithm="bruck")',
    _bruck_allgather)
ring_allgather = _deprecated(
    "ring_allgather", 'collective("allgather", ..., algorithm="ring")',
    _ring_allgather)
hierarchical_allgather = _deprecated(
    "hierarchical_allgather",
    'collective("allgather", ..., algorithm="hierarchical")',
    _hierarchical_allgather)
multilane_allgather = _deprecated(
    "multilane_allgather",
    'collective("allgather", ..., algorithm="multilane")',
    _multilane_allgather)
locality_bruck_allgather = _deprecated(
    "locality_bruck_allgather",
    'collective("allgather", ..., algorithm="locality_bruck")',
    _locality_bruck_allgather)
locality_bruck_allgather_start = _deprecated(
    "locality_bruck_allgather_start",
    'collective("allgather", ..., algorithm="locality_bruck", start=True)',
    _locality_bruck_allgather_start)
locality_bruck_allgather_finish = _deprecated(
    "locality_bruck_allgather_finish", "finish(pending)",
    _locality_bruck_allgather_finish)
locality_allreduce = _deprecated(
    "locality_allreduce", 'collective("allreduce", ..., '
    'algorithm="locality")', _locality_allreduce)
locality_logsumexp_combine = _deprecated(
    "locality_logsumexp_combine", 'collective("combine", o, m, l, ...)',
    logsumexp_combine)
locality_logsumexp_combine_start = _deprecated(
    "locality_logsumexp_combine_start",
    'collective("combine", m, ..., start=True)', logsumexp_combine_start)
locality_logsumexp_combine_finish = _deprecated(
    "locality_logsumexp_combine_finish", "finish(pending, o, l)",
    logsumexp_combine_finish)
