"""Postal cost models — paper §2 Eq. 1 and §3/§4 Eqs. 2-4.

Two uses:
  1. Reproduce the paper's modeled figures (Figs. 7-8) with the Lassen CPU
     parameter sets (eager/rendezvous split at 8192 bytes, following [6]).
  2. Project the same trade-off onto the TPU v5e target (ICI = local,
     DCN = non-local) to drive ``core/autotune.py``.

All times in seconds, sizes in bytes.
"""
from __future__ import annotations

import dataclasses
import math

from .topology import RegionMap, ceil_log, rd_rounds


@dataclasses.dataclass(frozen=True)
class LinkParams:
    """One α/β parameter pair (postal model for a single message class)."""

    alpha: float          # per-message latency [s]
    beta: float           # per-byte transport cost [s/B]

    def msg_cost(self, nbytes: float) -> float:
        return self.alpha + self.beta * nbytes


@dataclasses.dataclass(frozen=True)
class ProtocolParams:
    """Eager/rendezvous split (paper §4: >= 8192 bytes uses rendezvous)."""

    eager: LinkParams
    rendezvous: LinkParams
    eager_limit: int = 8192

    def msg_cost(self, nbytes: float) -> float:
        p = self.rendezvous if nbytes >= self.eager_limit else self.eager
        return p.msg_cost(nbytes)


@dataclasses.dataclass(frozen=True)
class MachineParams:
    """Local + non-local message classes for one machine (paper Eq. 2).

    The two tiers ARE the split ICI/DCN postal parameters: on the TPU sets
    ``local`` holds (α_ℓ, β_ℓ) for intra-pod ICI and ``nonlocal_`` holds
    (α, β) for the inter-pod DCN. The rendezvous-regime accessors below
    expose them as plain floats — the (α_local, α_nonlocal, β_local,
    β_nonlocal) quadruple ``locality_bruck_phase_split`` and
    ``overlap_model`` price two-tier ('pod','data') schedules with.
    """

    name: str
    local: ProtocolParams       # α_ℓ, β_ℓ  (ICI)
    nonlocal_: ProtocolParams   # α, β      (DCN)

    @property
    def alpha_local(self) -> float:
        return self.local.rendezvous.alpha

    @property
    def beta_local(self) -> float:
        return self.local.rendezvous.beta

    @property
    def alpha_nonlocal(self) -> float:
        return self.nonlocal_.rendezvous.alpha

    @property
    def beta_nonlocal(self) -> float:
        return self.nonlocal_.rendezvous.beta

    def cost(self, *, n_local: int, s_local: float, n_nonlocal: int,
             s_nonlocal: float) -> float:
        """Eq. 2 with per-class mean message size (n messages, s total bytes)."""
        t = 0.0
        if n_local:
            t += n_local * self.local.msg_cost(s_local / n_local)
        if n_nonlocal:
            t += n_nonlocal * self.nonlocal_.msg_cost(s_nonlocal / n_nonlocal)
        return t


def _p(alpha_us: float, bw_gbs: float) -> LinkParams:
    return LinkParams(alpha=alpha_us * 1e-6, beta=1.0 / (bw_gbs * 1e9))


def two_tier_machine(name: str, *, alpha_local_us: float, bw_local_gbs: float,
                     alpha_nonlocal_us: float, bw_nonlocal_gbs: float
                     ) -> MachineParams:
    """MachineParams from a bare (α_local, β_local, α_nonlocal, β_nonlocal)
    quadruple — no eager/rendezvous split (accelerator interconnects have no
    MPI protocol switch). The constructor operators use to fit measured
    ICI/DCN ping-pong numbers into the postal layer."""
    loc = _p(alpha_local_us, bw_local_gbs)
    nl = _p(alpha_nonlocal_us, bw_nonlocal_gbs)
    return MachineParams(
        name=name,
        local=ProtocolParams(eager=loc, rendezvous=loc),
        nonlocal_=ProtocolParams(eager=nl, rendezvous=nl),
    )


# ---------------------------------------------------------------------------
# Parameter sets.
#
# LASSEN values approximate the intra-socket / inter-node CPU ping-pong fits
# of Bienz et al. 2021 [6] (paper Fig. 3): sub-µs eager latency through cache
# within a socket vs multi-µs injection over EDR InfiniBand.
# QUARTZ (Intel Xeon E5, Omni-Path) treats the node as the region.
# TPU_V5E maps local→ICI (intra-pod) and non-local→DCN (inter-pod); α from
# typical collective-permute launch overheads, β from 50 GB/s/link ICI and
# ~25 GB/s effective per-chip DCN share.
# ---------------------------------------------------------------------------
LASSEN = MachineParams(
    name="lassen",
    local=ProtocolParams(eager=_p(0.45, 20.0), rendezvous=_p(1.3, 38.0)),
    nonlocal_=ProtocolParams(eager=_p(1.8, 5.0), rendezvous=_p(5.2, 11.5)),
)

QUARTZ = MachineParams(
    name="quartz",
    local=ProtocolParams(eager=_p(0.6, 10.0), rendezvous=_p(1.6, 16.0)),
    nonlocal_=ProtocolParams(eager=_p(1.5, 4.0), rendezvous=_p(4.1, 10.0)),
)

TPU_V5E = two_tier_machine("tpu_v5e", alpha_local_us=1.0, bw_local_gbs=50.0,
                           alpha_nonlocal_us=10.0, bw_nonlocal_gbs=25.0)

# Cross-REGION multi-pod target (the 2×16×16 mesh of launch/mesh.py with
# pods in different buildings/regions): same ICI tier, but the DCN tier
# pays WAN-class launch latency and a thinner effective per-chip share.
# This is the parameter set benchmarks/multipod.py prices the two-tier
# train gather and serve combine under.
TPU_MULTIPOD = two_tier_machine("tpu_multipod",
                                alpha_local_us=1.0, bw_local_gbs=50.0,
                                alpha_nonlocal_us=80.0, bw_nonlocal_gbs=6.0)

MACHINES = {m.name: m for m in (LASSEN, QUARTZ, TPU_V5E, TPU_MULTIPOD)}


# ---------------------------------------------------------------------------
# Closed forms — paper Eqs. 3 and 4.
# ---------------------------------------------------------------------------
def bruck_model(p: int, block_bytes: float, m: MachineParams) -> float:
    """Eq. 3: T = log2(p)·α + (b-1)·β  (all traffic non-local, worst rank)."""
    n = ceil_log(2, p)
    b = block_bytes * p
    s = b - block_bytes / max(p, 1)  # (p-1)/p · b == "b - 1 value" in the paper
    if n == 0:
        return 0.0
    return m.cost(n_local=0, s_local=0.0, n_nonlocal=n, s_nonlocal=s)


def locality_bruck_model(p: int, p_local: int, block_bytes: float,
                         m: MachineParams) -> float:
    """Eq. 4: T = log_{p_ℓ}(r)·α + (b/p_ℓ)·β + (log_{p_ℓ}(r)+1)·α_ℓ·log2(p_ℓ)
                 + (b-1)·β_ℓ.

    The paper's Eq. 4 counts one α_ℓ per *local allgather phase*; each local
    phase is itself a Bruck over p_ℓ ranks, i.e. log2(p_ℓ) messages. We keep
    the per-message accounting (matching the measured implementation); with
    log2(p_ℓ) = 1 both reduce to the paper's form.
    """
    region = RegionMap(p=p, p_local=p_local)
    r = region.n_regions

    # Simulate the (group, active) round sequence exactly — for r a power of
    # p_ℓ this reduces to the paper's closed form (non-local bytes ≈ b/p_ℓ,
    # local bytes = b − 1). For other region counts the allgatherv
    # adaptation applies: the worst rank (lane 1) sends min(group, r−group)
    # chunks per round — the wrapped final round carries only the partial
    # payload its peer is missing, not the entire buffer.
    n_nl = 0
    s_nl = 0.0
    s_l = block_bytes * (p_local - 1)            # initial local allgather
    n_l = ceil_log(2, p_local)
    group = 1
    while group < r:
        n_groups = -(-r // group)
        active = min(p_local, n_groups)
        n_nl += 1
        s_nl += block_bytes * min(group, r - group) * p_local
        # redistribution: (active-1) new chunks of group·p_ℓ blocks each
        # (partial units are zero-padded back to group chunks — DESIGN.md §7)
        s_l += block_bytes * (active - 1) * group * p_local
        n_l += ceil_log(2, p_local)
        group = min(group * active, r)

    return m.cost(n_local=n_l, s_local=s_l, n_nonlocal=n_nl, s_nonlocal=s_nl)


def hierarchical_model(p: int, p_local: int, block_bytes: float,
                       m: MachineParams) -> float:
    """Master-per-region gather → Bruck among masters → broadcast [Träff'06]."""
    region = RegionMap(p=p, p_local=p_local)
    r = region.n_regions
    b = block_bytes * p
    lg_l = ceil_log(2, p_local)
    lg_r = ceil_log(2, r)
    # Master rank dominates: it does the non-local Bruck over region blocks.
    s_nl = block_bytes * p_local * max(r - 1, 0)
    # Master also receives the gather and sends the bcast (full buffer).
    s_l = block_bytes * p_local + b * lg_l  # gather in + bcast out (binomial)
    return m.cost(n_local=2 * lg_l, s_local=s_l, n_nonlocal=lg_r, s_nonlocal=s_nl)


def multilane_model(p: int, p_local: int, block_bytes: float,
                    m: MachineParams) -> float:
    """One lane per local rank [Träff & Hunold'20]: lane Bruck then local AG."""
    region = RegionMap(p=p, p_local=p_local)
    r = region.n_regions
    lg_r = ceil_log(2, r)
    lg_l = ceil_log(2, p_local)
    s_nl = block_bytes * max(r - 1, 0)            # each lane moves its own block
    s_l = block_bytes * r * max(p_local - 1, 0)   # local combine of all lanes
    return m.cost(n_local=lg_l, s_local=s_l, n_nonlocal=lg_r, s_nonlocal=s_nl)


def ring_model(p: int, block_bytes: float, m: MachineParams,
               p_local: int | None = None) -> float:
    """Ring: p-1 neighbor messages; with regions, only the region-boundary
    crossings are non-local (p_ℓ-1 of every p_ℓ steps stay local)."""
    if p <= 1:
        return 0.0
    if p_local:
        region = RegionMap(p=p, p_local=p_local)
        n_nl = region.n_regions if region.n_regions > 1 else 0
        n_l = (p - 1) - n_nl
    else:
        n_nl, n_l = p - 1, 0
    return m.cost(n_local=n_l, s_local=block_bytes * n_l,
                  n_nonlocal=n_nl, s_nonlocal=block_bytes * n_nl)


def max_allreduce_model(p: int, p_local: int, nbytes: float, m: MachineParams,
                        *, structure: str = "locality") -> float:
    """Recursive-doubling max-allreduce (the first phase of the serve decode
    logsumexp combine — no scatter structure exists for non-sum ops).

    structure="locality": rd_rounds(p_ℓ) local rounds then rd_rounds(r)
    non-local rounds, each moving the full (tiny) buffer — matches
    ``collectives.locality_allreduce(op="max")`` including the fold/unfold
    rounds a non-power tier size adds (log2(m) + 2 instead of log2(n)).
    structure="flat": log2(p) rounds over the flat rank; partners at
    distance ≥ p_ℓ cross the region boundary, so only the first
    log2(p_ℓ) rounds stay local.
    """
    region = RegionMap(p=p, p_local=p_local)
    r = region.n_regions
    if p <= 1:
        return 0.0
    if structure == "locality":
        n_l, n_nl = rd_rounds(p_local), rd_rounds(r)
    elif structure == "flat":
        n = ceil_log(2, p)
        n_l = min(ceil_log(2, p_local), n)
        n_nl = n - n_l
    else:
        raise ValueError(f"unknown structure {structure!r}")
    return m.cost(n_local=n_l, s_local=nbytes * n_l,
                  n_nonlocal=n_nl, s_nonlocal=nbytes * n_nl)


# ---------------------------------------------------------------------------
# Overlap terms — the double-buffered prefetch pipeline (DESIGN.md §5).
# ---------------------------------------------------------------------------
#: TPU v5e bf16 peak (per chip) — the default compute-rate for pricing the
#: overlap window; mirrors hlo_analysis.PEAK_FLOPS_BF16 (kept literal here so
#: the postal layer stays import-free of the HLO layer).
PEAK_FLOPS_DEFAULT = 197e12


def locality_bruck_phase_split(p: int, p_local: int, block_bytes: float,
                               m: MachineParams) -> tuple[float, float, float]:
    """Algorithm 2's cost split along the ``allgather_start/finish`` seam.

    Returns ``(t_start_local, t_nonlocal, t_finish_local)``:

    * ``t_start_local``  — local traffic that must run before the last
      non-local round (initial local allgather + intermediate
      redistributions); lives in ``start``;
    * ``t_nonlocal``     — every non-local (DCN) round; lives in ``start``;
    * ``t_finish_local`` — the final local redistribution, deferred to
      ``finish`` at the consumer.

    The three phases are priced separately (per-phase mean message sizes),
    which *refines* Eq. 4's aggregate-mean accounting: their sum is the
    phase-resolved eager cost the overlap model composes from.
    """
    region = RegionMap(p=p, p_local=p_local)
    r, pl = region.n_regions, p_local
    if p <= 1:
        return 0.0, 0.0, 0.0
    if pl <= 1:
        return 0.0, bruck_model(p, block_bytes, m), 0.0

    b = block_bytes
    n_sl, s_sl = ceil_log(2, pl), b * (pl - 1)        # initial local AG
    n_nl = 0
    s_nl = 0.0
    n_fl = s_fl = 0.0
    group = 1
    while group < r:
        n_groups = -(-r // group)
        active = min(pl, n_groups)
        n_nl += 1
        # allgatherv adaptation: the worst lane sends min(group, r−group)
        # chunks (partial on the wrapped final round of non-power counts)
        s_nl += b * min(group, r - group) * pl
        redist_n = ceil_log(2, pl)
        redist_s = b * (active - 1) * group * pl
        if group * active >= r:            # last round: redistribute in finish
            n_fl, s_fl = redist_n, redist_s
        else:
            n_sl += redist_n
            s_sl += redist_s
        group = min(group * active, r)

    t_sl = m.cost(n_local=n_sl, s_local=s_sl, n_nonlocal=0, s_nonlocal=0.0)
    t_nl = m.cost(n_local=0, s_local=0.0, n_nonlocal=n_nl, s_nonlocal=s_nl)
    t_fl = m.cost(n_local=int(n_fl), s_local=s_fl, n_nonlocal=0,
                  s_nonlocal=0.0)
    return t_sl, t_nl, t_fl


@dataclasses.dataclass(frozen=True)
class OverlapCost:
    """Per-layer gather cost under the eager vs prefetched schedule.

    ``t_compute`` is the layer's matmul time — the window the double-buffered
    pipeline slides the ``start`` chain (local prologue + non-local rounds)
    into. The ``finish`` tail always stays exposed at the consumer.
    """

    t_start_local: float
    t_nonlocal: float
    t_finish_local: float
    t_compute: float

    @property
    def exposed_eager(self) -> float:
        """All communication serialized in front of the compute."""
        return self.t_start_local + self.t_nonlocal + self.t_finish_local

    @property
    def exposed_prefetch(self) -> float:
        """start chain hidden behind the previous layer's compute."""
        chain = self.t_start_local + self.t_nonlocal
        return self.t_finish_local + max(0.0, chain - self.t_compute)

    @property
    def exposed_nonlocal_eager(self) -> float:
        return self.t_nonlocal

    @property
    def exposed_nonlocal_prefetch(self) -> float:
        """The chain overlaps compute front-to-back; the non-local rounds sit
        at its tail, so they are the last to become exposed."""
        exposed_chain = max(0.0, self.t_start_local + self.t_nonlocal
                            - self.t_compute)
        return min(self.t_nonlocal, exposed_chain)

    @property
    def hidden(self) -> float:
        return self.exposed_eager - self.exposed_prefetch

    def step_time(self, prefetch: bool) -> float:
        return self.t_compute + (self.exposed_prefetch if prefetch
                                 else self.exposed_eager)


def overlap_model(p: int, p_local: int, block_bytes: float, flops: float,
                  m: MachineParams, *,
                  peak_flops: float = PEAK_FLOPS_DEFAULT) -> OverlapCost:
    """Price one layer's param gather against its compute window.

    ``block_bytes`` is the per-rank shard of the layer's parameters (what
    each rank contributes to the gather); ``flops`` the layer's per-device
    matmul work. This is the (topology, bytes, flops) overlap term the
    tuning policy learns crossovers over.
    """
    t_sl, t_nl, t_fl = locality_bruck_phase_split(p, p_local, block_bytes, m)
    return OverlapCost(t_start_local=t_sl, t_nonlocal=t_nl,
                       t_finish_local=t_fl,
                       t_compute=flops / max(peak_flops, 1.0))


MODELS = {
    "bruck": lambda p, pl, bb, m: bruck_model(p, bb, m),
    "ring": lambda p, pl, bb, m: ring_model(p, bb, m, pl),
    "hierarchical": hierarchical_model,
    "multilane": multilane_model,
    "locality_bruck": locality_bruck_model,
}


def cache_migrate_model(algorithm: str, p: int, p_local: int,
                        block_bytes: float,
                        m: MachineParams | str) -> float:
    """Closed-form price of a KV-slab migration (collectives.cache_migrate).

    Migration is a replication of a sequence-sharded slab over the full
    (outer, local) mesh, so each eligible algorithm prices as its allgather
    closed form — but at slab-sized blocks, where α and β trade off
    differently than for activation payloads (hence its own tuning cell):
    the locality schedule minimizes DCN *messages*, multilane minimizes
    per-rank DCN *bytes*, and GSPMD's flat all-gather ring-decomposes into
    a boundary crossing per region.
    """
    if isinstance(m, str):
        m = MACHINES[m]
    if algorithm == "locality_bruck":
        return locality_bruck_model(p, p_local, block_bytes, m)
    if algorithm == "multilane":
        return multilane_model(p, p_local, block_bytes, m)
    if algorithm == "xla":
        return ring_model(p, block_bytes, m, p_local)
    raise ValueError(f"unknown cache_migrate algorithm {algorithm!r}")


def xla_all_to_all_model(p: int, p_local: int, block_bytes: float,
                         m: MachineParams) -> float:
    """Flat pairwise all-to-all (the XLA baseline): every rank sends one
    ``block_bytes`` message straight to each peer — ``p_ℓ-1`` local,
    ``p - p_ℓ`` crossing the region boundary. ``block_bytes`` is one
    (source, destination)-pair payload, i.e. b/p of the per-rank buffer."""
    if p <= 1:
        return 0.0
    n_nl = p - p_local
    n_l = p_local - 1
    return m.cost(n_local=n_l, s_local=n_l * block_bytes,
                  n_nonlocal=n_nl, s_nonlocal=n_nl * block_bytes)


def locality_all_to_all_model(p: int, p_local: int, block_bytes: float,
                              m: MachineParams) -> float:
    """Two-tier all-to-all (collectives.locality_all_to_all): pod offsets
    o ∈ [1, q) are lane-assigned round-robin, so lane λ ships
    ``n_off(λ) = ceil((q-1-λ)/p_ℓ)`` aggregated p_ℓ²-block DCN messages —
    q-1 per region total vs p_ℓ²·(q-1) pairwise — bracketed by the local
    collect and delivery exchanges. Same unpadded per-rank accounting as
    the ``schedules.locality_all_to_all`` oracle (Eq. 2 over the worst
    rank), so this closed form and ``schedule_cost(mode="postal")`` agree
    exactly. ``block_bytes`` is one (source, destination)-pair payload."""
    region = RegionMap(p=p, p_local=p_local)
    q, pl = region.n_regions, p_local
    if p <= 1:
        return 0.0
    nrounds = -(-(q - 1) // pl) if q > 1 else 0
    n_off = [sum(1 for t in range(nrounds) if t * pl + lam + 1 <= q - 1)
             for lam in range(pl)]
    b = block_bytes
    worst = 0.0
    for lam in range(pl):
        # collect: one message per peer lane that owns any offset
        n_l = sum(1 for o in range(pl) if o != lam and n_off[o] > 0)
        s_l = ((q - 1) - n_off[lam]) * pl * b
        # delivery: own-region block + received slab columns to every lane
        n_l += pl - 1
        s_l += (pl - 1) * (1 + n_off[lam] * pl) * b
        cost = m.cost(n_local=n_l, s_local=s_l, n_nonlocal=n_off[lam],
                      s_nonlocal=n_off[lam] * pl * pl * b)
        worst = max(worst, cost)
    return worst


def all_to_all_model(algorithm: str, p: int, p_local: int, block_bytes: float,
                     m: MachineParams | str) -> float:
    """Closed-form price of a personalized exchange (collectives.all_to_all)
    under the canonical algorithm vocabulary. ``block_bytes`` is one
    (source, destination)-pair payload — the b/p unit the all-to-all
    schedules count blocks in."""
    if isinstance(m, str):
        m = MACHINES[m]
    if algorithm == "locality":
        return locality_all_to_all_model(p, p_local, block_bytes, m)
    if algorithm == "xla":
        return xla_all_to_all_model(p, p_local, block_bytes, m)
    raise ValueError(f"unknown all_to_all algorithm {algorithm!r}")


def checkpoint_replication_model(q: int, shard_bytes: float,
                                 m: MachineParams | str, *,
                                 rf: int = 2) -> float:
    """Price of placing ``rf - 1`` inter-pod replicas of each rank's
    checkpoint shard (checkpoint layout v2, DESIGN.md §10).

    Replica exchange is the degenerate outer phase of the locality-Bruck
    schedule: every rank sends its shard to the lane-aligned rank of pod
    ``(p + k) mod q`` for k = 1..rf-1 — (rf-1) non-local messages of
    ``shard_bytes`` each, zero local traffic (the shard already lives on
    the sender). The same Eq.-2 postal terms as the gather's outer rounds,
    so replication and the training collectives are priced in one currency.
    """
    if isinstance(m, str):
        m = MACHINES[m]
    rf = min(rf, max(q, 1))
    if q <= 1 or rf <= 1:
        return 0.0
    n = rf - 1
    return m.cost(n_local=0, s_local=0.0, n_nonlocal=n,
                  s_nonlocal=n * shard_bytes)


def choose_replication(q: int, shard_bytes: float, m: MachineParams | str, *,
                       budget_s: float | None = None) -> int:
    """Replication factor for checkpoint v2: 2 (one inter-pod replica —
    any single lost pod is recoverable from its neighbour) whenever the
    topology has pods to replicate across and the modeled exchange fits
    ``budget_s``; 1 otherwise. The budget defaults to unconstrained: a
    checkpoint's replica exchange overlaps the async writer, so only an
    explicit operator budget (e.g. a preemption grace window) trims it."""
    if q <= 1:
        return 1
    if budget_s is not None and checkpoint_replication_model(
            q, shard_bytes, m, rf=2) > budget_s:
        return 1
    return 2


def schedule_cost(schedule, m: MachineParams, block_bytes: float,
                  region: RegionMap | None = None, *,
                  mode: str = "round") -> float:
    """Evaluate a generated ``Schedule`` under machine ``m``.

    mode="postal": paper Eq. 2 on the worst single rank's aggregate counts.
    mode="round":  synchronous rounds; each round costs the max over ranks of
                   its per-rank send cost (closer to measured behaviour).
    """
    if mode == "postal":
        best = 0.0
        for (n_l, s_l, n_nl, s_nl) in schedule.per_rank_stats(region).values():
            t = m.cost(n_local=n_l, s_local=s_l * block_bytes,
                       n_nonlocal=n_nl, s_nonlocal=s_nl * block_bytes)
            best = max(best, t)
        return best

    reg = region or schedule.region
    total = 0.0
    for rnd in schedule.rounds:
        worst = 0.0
        per_rank: dict[int, float] = {}
        for s in rnd.sends:
            local = reg.is_local(s.src, s.dst) if reg else False
            proto = m.local if local else m.nonlocal_
            per_rank[s.src] = per_rank.get(s.src, 0.0) + proto.msg_cost(
                len(s.blocks) * block_bytes)
        if per_rank:
            worst = max(per_rank.values())
        total += worst
    return total
