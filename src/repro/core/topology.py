"""Topology / region abstractions for locality-aware collectives.

A *region* (paper §2.1) is a set of ranks within which communication is cheap
(intra-node / intra-socket on MPI clusters; intra-pod ICI on multi-pod TPU).
Ranks are numbered region-major: global rank = region * p_local + local_rank,
matching row-major enumeration of a ("pod", ...) JAX mesh axis tuple.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class RegionMap:
    """Maps flat ranks <-> (region, local_rank) for a two-level hierarchy."""

    p: int          # total ranks
    p_local: int    # ranks per region

    def __post_init__(self):
        if self.p % self.p_local != 0:
            raise ValueError(f"p={self.p} not divisible by p_local={self.p_local}")

    @property
    def n_regions(self) -> int:
        return self.p // self.p_local

    def region_of(self, rank: int) -> int:
        return rank // self.p_local

    def local_rank_of(self, rank: int) -> int:
        return rank % self.p_local

    def rank_of(self, region: int, local_rank: int) -> int:
        return (region % self.n_regions) * self.p_local + (local_rank % self.p_local)

    def is_local(self, src: int, dst: int) -> bool:
        return self.region_of(src) == self.region_of(dst)


def ceil_log(base: int, x: int) -> int:
    """ceil(log_base(x)) computed exactly with integers."""
    if x <= 1:
        return 0
    steps, cover = 0, 1
    while cover < x:
        cover *= base
        steps += 1
    return steps


def rd_rounds(n: int) -> int:
    """Message rounds of the non-power-capable recursive-doubling allreduce
    (``collectives._rd_allreduce``): log2(n) for powers of two, otherwise
    log2(m) + 2 for the fold/unfold adaptation (m = largest power of two
    below n: one fold round, the power-of-two core, one unfold round)."""
    if n <= 1:
        return 0
    lg = ceil_log(2, n)
    return lg if n & (n - 1) == 0 else (lg - 1) + 2


def is_power_of(base: int, x: int) -> bool:
    if x < 1:
        return False
    while x % base == 0:
        x //= base
    return x == 1


def mesh_region_map(mesh, outer_axes: tuple[str, ...], local_axes: tuple[str, ...]) -> RegionMap:
    """RegionMap for a shard_map over ``outer_axes + local_axes`` of ``mesh``.

    jax enumerates a tuple of axis names row-major (first axis slowest), so the
    flat rank over (outer, local) is outer_idx * local_size + local_idx —
    exactly the region-major numbering RegionMap assumes.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    p_outer = math.prod(sizes[a] for a in outer_axes) if outer_axes else 1
    p_local = math.prod(sizes[a] for a in local_axes)
    return RegionMap(p=p_outer * p_local, p_local=p_local)


def device_pod_map(mesh, pod_axes: tuple[str, ...]) -> dict[int, int]:
    """device.id -> pod index, for classifying HLO collective-permute edges.

    ``pod_axes`` are the mesh axes whose product enumerates pods (usually
    ("pod",)). Devices within one pod share ICI; edges between pods are DCN.
    """
    axis_names = list(mesh.axis_names)
    dev_array = np.asarray(mesh.devices)
    pod_dims = [axis_names.index(a) for a in pod_axes]
    out: dict[int, int] = {}
    for idx in np.ndindex(*dev_array.shape):
        pod = 0
        for d in pod_dims:
            pod = pod * dev_array.shape[d] + idx[d]
        out[dev_array[idx].id] = pod
    return out
