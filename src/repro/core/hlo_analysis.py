"""Compiled-HLO analysis: collective inventory + locality classification.

The dry-run's "profile" (no real hardware): parse ``compiled.as_text()``,
find every collective op, sum its operand bytes, and for collective-permute
classify each source→target edge as local (intra-pod ICI) or non-local
(inter-pod DCN) using the device→pod map. This is how we *measure* the
paper's claim on the compiled artifact: the locality-aware schedules must
show fewer non-local edges/bytes than the baselines.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s+((?:\([^=]*?\))|(?:\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_PAIRS_RE = re.compile(r"source_target_pairs=\{([\d,{} ]*)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_: dict
    permute_edges_local: int = 0
    permute_edges_nonlocal: int = 0
    permute_bytes_local: int = 0
    permute_bytes_nonlocal: int = 0

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_.values())

    def summary(self) -> str:
        lines = [f"  {k:20s} n={self.counts[k]:4d} bytes={self.bytes_[k]:,}"
                 for k in sorted(self.counts)]
        lines.append(f"  permute edges local/nonlocal: "
                     f"{self.permute_edges_local}/{self.permute_edges_nonlocal}"
                     f"  bytes {self.permute_bytes_local:,}/"
                     f"{self.permute_bytes_nonlocal:,}")
        return "\n".join(lines)


def collective_stats(hlo_text: str, device_pod: dict[int, int] | None = None
                     ) -> CollectiveStats:
    """Scan HLO for collectives. ``device_pod`` maps device id -> pod index
    for classifying collective-permute edges (None: skip classification).

    Bytes are the per-participant output shape of each op — the amount one
    device sends/receives (async ops counted once via their -start form).
    """
    counts: dict = defaultdict(int)
    nbytes: dict = defaultdict(int)
    st = CollectiveStats(counts=counts, bytes_=nbytes)
    for op, type_str, line in _collective_lines(hlo_text):
        b = _shape_bytes(type_str)
        counts[op] += 1
        nbytes[op] += b
        if op == "collective-permute" and device_pod is not None:
            pm = _PAIRS_RE.search(line)
            if pm:
                pairs = re.findall(r"\{(\d+),(\d+)\}", pm.group(0))
                n_local = n_nonlocal = 0
                for s, t in pairs:
                    if device_pod.get(int(s)) == device_pod.get(int(t)):
                        n_local += 1
                    else:
                        n_nonlocal += 1
                st.permute_edges_local += n_local
                st.permute_edges_nonlocal += n_nonlocal
                # per-edge payload = the op's per-participant bytes
                st.permute_bytes_local += b * (n_local > 0)
                st.permute_bytes_nonlocal += b * (n_nonlocal > 0)
    return st


_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_COMPUTATION_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->")
_ROOT_OP_RE = re.compile(r"ROOT\s+%?[\w.\-]+\s*=\s*\S+\s+([a-z\-]+)\(")


def _collective_lines(hlo_text: str):
    """Yield (op, type_str, line) for every collective op line, counting
    async start/done pairs once (the shared scan behind the helpers below)."""
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _OP_RE.search(line)
        if m:
            yield m.group(2), m.group(1), line


def op_payloads(hlo_text: str, op: str) -> list[int]:
    """Per-op payload bytes for every occurrence of one collective ``op``
    (e.g. "all-reduce") in the HLO. Used to assert the *absence* of a
    collective over a given payload — the locality decode path must show no
    all-reduce of the full attention-stat payload (its combine compiles to
    collective-permutes / reduce-scatters instead)."""
    if op not in COLLECTIVES:
        raise ValueError(f"unknown collective op {op!r}")
    return [_shape_bytes(t) for o, t, _ in _collective_lines(hlo_text)
            if o == op]


def _combiner_roots(hlo_text: str) -> dict[str, str]:
    """Computation name -> its ROOT operation (e.g. "maximum", "add")."""
    roots: dict[str, str] = {}
    current = None
    for line in hlo_text.splitlines():
        s = line.strip()
        m = _COMPUTATION_RE.match(s)
        if m and "=" not in s.split("(", 1)[0]:
            current = m.group(1)
            continue
        r = _ROOT_OP_RE.search(s)
        if r and current is not None:
            roots.setdefault(current, r.group(1))
    return roots


def allreduce_combiners(hlo_text: str) -> list[str]:
    """The ROOT operation of every all-reduce's ``to_apply`` computation
    ("add", "maximum", ...). GSPMD's implicit combine of a softmax over a
    sharded axis necessarily includes a MAX-combiner all-reduce (the
    running maximum) — its absence, together with the explicit
    permute/reduce-scatter schedule, is the compiled-artifact proof that
    the locality decode path executes the combine manually. The combiner
    computation's NAME is not trustworthy (GSPMD emits "region_N.clone"
    etc.), so the computation body is resolved to its root op; add-combiner
    all-reduces also arise from harmless sharded-matmul partial sums, so
    combiner kind, not payload size, is the discriminator."""
    roots = _combiner_roots(hlo_text)
    out = []
    for op, _, line in _collective_lines(hlo_text):
        if op != "all-reduce":
            continue
        t = _TO_APPLY_RE.search(line)
        name = t.group(1) if t else ""
        out.append(roots.get(name, name))
    return out


# ---------------------------------------------------------------------------
# roofline terms (TPU v5e constants per the assignment)
# ---------------------------------------------------------------------------
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # B/s per chip
ICI_BW = 50e9                     # B/s per link


@dataclasses.dataclass
class Roofline:
    """Roofline terms from the dry-run's compiled artifact.

    All inputs are PER-DEVICE quantities: XLA's ``cost_analysis`` runs on
    the partitioned module (verified: flops halve when chips double), and
    the collective scan sums per-participant op shapes. One caveat of the
    CPU backend: while-loop (lax.scan) bodies are costed ONCE, not × trip
    count, so HLO flops/bytes undercount layer-scanned models. The compute
    term is therefore floored by the analytic MODEL_FLOPS (6·N·D train,
    2·N_active·D inference) — exact for matmul-dominated steps; the HLO
    value is kept for the useful-fraction diagnostic.
    """

    flops: float                  # per-device HLO flops
    hbm_bytes: float              # per-device bytes accessed
    collective_bytes: float       # per-device collective bytes (HLO scan)
    n_chips: int
    model_flops: float = 0.0      # 6·N·D (useful work, GLOBAL)

    @property
    def model_flops_per_chip(self) -> float:
        return self.model_flops / self.n_chips

    @property
    def compute_s(self) -> float:
        return max(self.flops, self.model_flops_per_chip) / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / compiled flops (≤1; catches remat/redundancy waste
        where the scan-undercount doesn't mask it)."""
        denom = max(self.flops, self.model_flops_per_chip)
        return self.model_flops_per_chip / denom if denom else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful-compute time / modeled step time (the score)."""
        t_useful = self.model_flops_per_chip / PEAK_FLOPS_BF16
        t_bound = max(self.compute_s, self.memory_s, self.collective_s)
        return t_useful / t_bound if t_bound else 0.0

    def row(self) -> dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "useful_fraction": self.useful_fraction,
            "roofline_fraction": self.roofline_fraction,
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
        }
