"""Compiled-HLO analysis: collective inventory + locality classification.

The dry-run's "profile" (no real hardware): parse ``compiled.as_text()``,
find every collective op, sum its operand bytes, and classify its traffic
as local (intra-pod ICI) or non-local (inter-pod DCN) using the device→pod
map. This is how we *measure* the paper's claim on the compiled artifact:
the locality-aware schedules must show fewer non-local edges/bytes than the
baselines.

Two classification tiers:

* **collective-permute** — EXACT: every ``source_target_pairs`` edge is one
  message of the op's per-participant payload; an edge whose endpoints sit
  in different pods is a DCN message.
* **group collectives** (all-gather / all-reduce / reduce-scatter /
  all-to-all) — XLA does not expose their internal schedule in the HLO
  text, so a replica group that spans pods is priced under the standard
  ring decomposition (the bandwidth-optimal schedule XLA itself defaults
  to): (n-1) rounds of b/n-byte neighbour messages per direction — one
  pass for all-gather / reduce-scatter, two for all-reduce — with each
  rank-order-adjacent (cyclic) pair in different pods counting as a DCN
  link; all-to-all is direct pairwise exchange of b/n per ordered pair.
  This matches ``tuning.measure.simulate_allreduce("xla")``'s accounting,
  so the HLO ground truth and the policy's model price the flat baseline
  identically. Both explicit ``{{0,1},{2,3}}`` and iota
  ``[2,4]<=[8]T(1,0)`` replica-group encodings are parsed.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

import numpy as np

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
# A result type is either a scalar/array type token or a parenthesised
# tuple. Tuple types may carry `/*index=N*/` element comments (CPU-backend
# tuple-shaped all-to-all), so the tuple branch matches on balanced parens,
# not on "no '=' inside".
_OP_RE = re.compile(
    r"=\s+((?:\([^()]*\))|(?:\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_PAIRS_RE = re.compile(r"source_target_pairs=\{([\d,{} ]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{([\d,{} ]*)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[([\d,]+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_: dict
    # collective-permute: exact per-edge accounting (one message per
    # source→target pair, payload = the op's per-participant bytes)
    permute_edges_local: int = 0
    permute_edges_nonlocal: int = 0
    permute_bytes_local: int = 0
    permute_bytes_nonlocal: int = 0
    # group collectives (all-gather/all-reduce/reduce-scatter/all-to-all):
    # ring-decomposition accounting over each replica group (module
    # docstring) — messages and bytes crossing the pod boundary
    group_msgs_local: int = 0
    group_msgs_nonlocal: int = 0
    group_bytes_local: float = 0.0
    group_bytes_nonlocal: float = 0.0

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_.values())

    @property
    def nonlocal_msgs(self) -> float:
        """Total DCN-crossing messages: exact permute edges + the ring-
        modeled messages of every pod-crossing group collective."""
        return self.permute_edges_nonlocal + self.group_msgs_nonlocal

    @property
    def nonlocal_bytes(self) -> float:
        """Total DCN-crossing bytes (same two tiers as nonlocal_msgs)."""
        return self.permute_bytes_nonlocal + self.group_bytes_nonlocal

    def summary(self) -> str:
        lines = [f"  {k:20s} n={self.counts[k]:4d} bytes={self.bytes_[k]:,}"
                 for k in sorted(self.counts)]
        lines.append(f"  permute edges local/nonlocal: "
                     f"{self.permute_edges_local}/{self.permute_edges_nonlocal}"
                     f"  bytes {self.permute_bytes_local:,}/"
                     f"{self.permute_bytes_nonlocal:,}")
        lines.append(f"  group msgs local/nonlocal: "
                     f"{self.group_msgs_local}/{self.group_msgs_nonlocal}"
                     f"  bytes {self.group_bytes_local:,.0f}/"
                     f"{self.group_bytes_nonlocal:,.0f}")
        return "\n".join(lines)


def _replica_groups(line: str, device_pod: dict[int, int]
                    ) -> list[list[int]] | None:
    """Parse an op line's replica groups (explicit braces or iota form).

    Returns None when the line carries no replica_groups attribute; an
    empty/``{}`` attribute means "one group of every device". Explicit
    brace groups may be UNEVEN (different sizes per group — what GSPMD
    emits when a non-power pod count shards a dim its size doesn't divide
    evenly); each group is classified with its own length. An iota list
    whose dims cover only a prefix of the device grid (prod(dims) <
    prod(bounds): a subgroup collective on a subset of the mesh) takes the
    prefix of the transposed enumeration instead of failing the reshape.
    """
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        dims = [int(x) for x in m.group(1).split(",")]
        bounds = [int(x) for x in m.group(2).split(",")]
        perm = ([int(x) for x in m.group(3).split(",")]
                if m.group(3) else list(range(len(bounds))))
        flat = np.arange(math.prod(bounds)).reshape(bounds)
        flat = flat.transpose(perm).reshape(-1)
        return flat[: math.prod(dims)].reshape(dims).tolist()
    m = _GROUPS_RE.search(line)
    if m is None:
        return None
    groups = [[int(x) for x in grp.replace(" ", "").split(",") if x]
              for grp in re.findall(r"\{([\d, ]*)\}", m.group(0))]
    groups = [g for g in groups if g]
    return groups if groups else [sorted(device_pod)]


#: ring passes per group collective: one (reduce-scatter OR allgather ring)
#: vs two chained for all-reduce (RS then AG)
_RING_PASSES = {"all-gather": 1, "reduce-scatter": 1, "all-reduce": 2}


def _classify_group_op(op: str, b: int, line: str,
                       device_pod: dict[int, int], st: CollectiveStats
                       ) -> None:
    """Ring-decomposition DCN accounting for one group-collective op line
    (module docstring): per cyclic rank-order link, AG/RS move (n-1)
    shard-sized messages, all-reduce 2(n-1); all-to-all exchanges b/n per
    ordered pair directly."""
    groups = _replica_groups(line, device_pod)
    if groups is None:
        return
    for g in groups:
        n = len(g)
        if n <= 1:
            continue
        if op == "all-to-all":
            per = b / n
            for s in g:
                for t in g:
                    if s == t:
                        continue
                    if device_pod.get(s) == device_pod.get(t):
                        st.group_msgs_local += 1
                        st.group_bytes_local += per
                    else:
                        st.group_msgs_nonlocal += 1
                        st.group_bytes_nonlocal += per
            continue
        # op bytes are per-participant: the full buffer for all-gather /
        # all-reduce (shard = b/n moves per ring step), the already-
        # scattered shard for reduce-scatter (shard = b)
        shard = b if op == "reduce-scatter" else b / n
        msgs = _RING_PASSES[op] * (n - 1)
        for i in range(n):
            s, t = g[i], g[(i + 1) % n]
            if device_pod.get(s) == device_pod.get(t):
                st.group_msgs_local += msgs
                st.group_bytes_local += msgs * shard
            else:
                st.group_msgs_nonlocal += msgs
                st.group_bytes_nonlocal += msgs * shard


def collective_stats(hlo_text: str, device_pod: dict[int, int] | None = None
                     ) -> CollectiveStats:
    """Scan HLO for collectives. ``device_pod`` maps device id -> pod index
    for classifying collective traffic (None: skip classification).

    Bytes are the per-participant output shape of each op — the amount one
    device sends/receives (async ops counted once via their -start form).
    With a ``device_pod`` map, collective-permute edges are classified
    EXACTLY (one message of the op payload per source→target pair) and
    group collectives under the ring decomposition — see the module
    docstring and ``nonlocal_msgs``/``nonlocal_bytes``.
    """
    counts: dict = defaultdict(int)
    nbytes: dict = defaultdict(int)
    st = CollectiveStats(counts=counts, bytes_=nbytes)
    for op, type_str, line in _collective_lines(hlo_text):
        b = _shape_bytes(type_str)
        counts[op] += 1
        nbytes[op] += b
        if device_pod is None:
            continue
        if op == "collective-permute":
            pm = _PAIRS_RE.search(line)
            if pm:
                pairs = re.findall(r"\{(\d+),(\d+)\}", pm.group(0))
                for s, t in pairs:
                    if device_pod.get(int(s)) == device_pod.get(int(t)):
                        st.permute_edges_local += 1
                        st.permute_bytes_local += b
                    else:
                        st.permute_edges_nonlocal += 1
                        st.permute_bytes_nonlocal += b
        else:
            _classify_group_op(op, b, line, device_pod, st)
    return st


_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_COMPUTATION_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->")
_ROOT_OP_RE = re.compile(r"ROOT\s+%?[\w.\-]+\s*=\s*\S+\s+([a-z\-]+)\(")


def _collective_lines(hlo_text: str):
    """Yield (op, type_str, line) for every collective op line, counting
    async start/done pairs once (the shared scan behind the helpers below)."""
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _OP_RE.search(line)
        if m:
            yield m.group(2), m.group(1), line


def op_payloads(hlo_text: str, op: str) -> list[int]:
    """Per-op payload bytes for every occurrence of one collective ``op``
    (e.g. "all-reduce") in the HLO. Used to assert the *absence* of a
    collective over a given payload — the locality decode path must show no
    all-reduce of the full attention-stat payload (its combine compiles to
    collective-permutes / reduce-scatters instead)."""
    if op not in COLLECTIVES:
        raise ValueError(f"unknown collective op {op!r}")
    return [_shape_bytes(t) for o, t, _ in _collective_lines(hlo_text)
            if o == op]


def _combiner_roots(hlo_text: str) -> dict[str, str]:
    """Computation name -> its ROOT operation (e.g. "maximum", "add")."""
    roots: dict[str, str] = {}
    current = None
    for line in hlo_text.splitlines():
        s = line.strip()
        m = _COMPUTATION_RE.match(s)
        if m and "=" not in s.split("(", 1)[0]:
            current = m.group(1)
            continue
        r = _ROOT_OP_RE.search(s)
        if r and current is not None:
            roots.setdefault(current, r.group(1))
    return roots


def allreduce_combiners(hlo_text: str) -> list[str]:
    """The ROOT operation of every all-reduce's ``to_apply`` computation
    ("add", "maximum", ...). GSPMD's implicit combine of a softmax over a
    sharded axis necessarily includes a MAX-combiner all-reduce (the
    running maximum) — its absence, together with the explicit
    permute/reduce-scatter schedule, is the compiled-artifact proof that
    the locality decode path executes the combine manually. The combiner
    computation's NAME is not trustworthy (GSPMD emits "region_N.clone"
    etc.), so the computation body is resolved to its root op; add-combiner
    all-reduces also arise from harmless sharded-matmul partial sums, so
    combiner kind, not payload size, is the discriminator."""
    roots = _combiner_roots(hlo_text)
    out = []
    for op, _, line in _collective_lines(hlo_text):
        if op != "all-reduce":
            continue
        t = _TO_APPLY_RE.search(line)
        name = t.group(1) if t else ""
        out.append(roots.get(name, name))
    return out


# ---------------------------------------------------------------------------
# roofline terms (TPU v5e constants per the assignment)
# ---------------------------------------------------------------------------
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # B/s per chip
ICI_BW = 50e9                     # B/s per link


@dataclasses.dataclass
class Roofline:
    """Roofline terms from the dry-run's compiled artifact.

    All inputs are PER-DEVICE quantities: XLA's ``cost_analysis`` runs on
    the partitioned module (verified: flops halve when chips double), and
    the collective scan sums per-participant op shapes. One caveat of the
    CPU backend: while-loop (lax.scan) bodies are costed ONCE, not × trip
    count, so HLO flops/bytes undercount layer-scanned models. The compute
    term is therefore floored by the analytic MODEL_FLOPS (6·N·D train,
    2·N_active·D inference) — exact for matmul-dominated steps; the HLO
    value is kept for the useful-fraction diagnostic.
    """

    flops: float                  # per-device HLO flops
    hbm_bytes: float              # per-device bytes accessed
    collective_bytes: float       # per-device collective bytes (HLO scan)
    n_chips: int
    model_flops: float = 0.0      # 6·N·D (useful work, GLOBAL)

    @property
    def model_flops_per_chip(self) -> float:
        return self.model_flops / self.n_chips

    @property
    def compute_s(self) -> float:
        return max(self.flops, self.model_flops_per_chip) / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / compiled flops (≤1; catches remat/redundancy waste
        where the scan-undercount doesn't mask it)."""
        denom = max(self.flops, self.model_flops_per_chip)
        return self.model_flops_per_chip / denom if denom else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful-compute time / modeled step time (the score)."""
        t_useful = self.model_flops_per_chip / PEAK_FLOPS_BF16
        t_bound = max(self.compute_s, self.memory_s, self.collective_s)
        return t_useful / t_bound if t_bound else 0.0

    def row(self) -> dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "useful_fraction": self.useful_fraction,
            "roofline_fraction": self.roofline_fraction,
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
        }
