"""Allgather schedule generators — the paper's algorithms in pure python.

Each generator *executes* its algorithm over an abstract network and returns
the complete schedule (every point-to-point send of every round) plus the
final buffer contents of every rank. These serve three roles:

  1. Correctness oracle for the JAX/shard_map implementations
     (``core/collectives.py``) — same math, independent code.
  2. Input to the postal cost model (``core/cost_model.py``) — the paper's
     Eq. 2 evaluated on *actual* per-rank message/byte counts.
  3. Reproduction of the paper's §4 closed forms (tests assert them).

Algorithms:
  * ``bruck``            — Algorithm 1 (standard Bruck) [Bruck et al. '97]
  * ``ring``             — ring allgather [Chan et al. '07]
  * ``hierarchical``     — master-per-region gather/allgather/bcast [Träff '06]
  * ``multilane``        — one lane per local rank [Träff & Hunold '20]
  * ``locality_bruck``   — Algorithm 2, THE paper's contribution

A "block" is one rank's initial contribution (m/p values). Buffers are lists
of *origin rank ids* in canonical receive order; byte counts are in block
units (multiply by block_bytes for real sizes).
"""
from __future__ import annotations

import dataclasses

from .topology import RegionMap, ceil_log


@dataclasses.dataclass(frozen=True)
class Send:
    src: int
    dst: int
    blocks: tuple[int, ...]   # origin ids moved by this message


@dataclasses.dataclass(frozen=True)
class Round:
    sends: tuple[Send, ...]
    phase: str                # human-readable phase tag


@dataclasses.dataclass
class Schedule:
    p: int
    rounds: list[Round]
    buffers: list[list[int]]  # final buffer (origin ids, canonical order) per rank
    algorithm: str
    region: RegionMap | None = None

    # ---- derived stats (paper §4 terms) ------------------------------------
    def per_rank_stats(self, region: RegionMap | None = None):
        """Returns dict rank -> (n_local, s_local, n_nonlocal, s_nonlocal).

        n = message count, s = blocks sent, split by locality. With no region
        map everything is counted non-local (flat network, paper Eq. 1).
        """
        region = region or self.region
        stats = {r: [0, 0, 0, 0] for r in range(self.p)}
        for rnd in self.rounds:
            for s in rnd.sends:
                local = region.is_local(s.src, s.dst) if region else False
                k = 0 if local else 2
                stats[s.src][k] += 1
                stats[s.src][k + 1] += len(s.blocks)
        return {r: tuple(v) for r, v in stats.items()}

    def max_nonlocal_msgs(self, region: RegionMap | None = None) -> int:
        return max(v[2] for v in self.per_rank_stats(region).values())

    def max_nonlocal_blocks(self, region: RegionMap | None = None) -> int:
        return max(v[3] for v in self.per_rank_stats(region).values())

    def n_rounds(self) -> int:
        return len(self.rounds)

    def validate(self) -> None:
        """Every rank must end with every block exactly once, canonical order."""
        want = list(range(self.p))
        for r, buf in enumerate(self.buffers):
            if sorted(set(buf)) != want:
                missing = set(want) - set(buf)
                raise AssertionError(
                    f"{self.algorithm}: rank {r} buffer incomplete, missing {sorted(missing)[:8]}")
            if buf != want:
                raise AssertionError(
                    f"{self.algorithm}: rank {r} buffer not canonical: {buf[:8]}...")


def _exchange(bufs: list[list[int]], sends: list[Send]) -> None:
    """Apply one round of sends simultaneously (MPI_Isend/Irecv semantics)."""
    incoming: dict[int, list[int]] = {}
    for s in sends:
        incoming.setdefault(s.dst, []).extend(s.blocks)
    for dst, blocks in incoming.items():
        seen = set(bufs[dst])
        bufs[dst].extend(b for b in blocks if b not in seen)


# =============================================================================
# Algorithm 1 — standard Bruck allgather
# =============================================================================
def bruck(p: int, region: RegionMap | None = None) -> Schedule:
    bufs = [[r] for r in range(p)]
    rounds: list[Round] = []
    d = 1
    step = 0
    while d < p:
        cnt = min(d, p - d)
        sends = tuple(
            Send(src=r, dst=(r - d) % p, blocks=tuple(bufs[r][:cnt])) for r in range(p))
        _exchange(bufs, list(sends))
        rounds.append(Round(sends=sends, phase=f"bruck-step{step}"))
        d *= 2
        step += 1
    # final rotation: bruck leaves rank r with [r, r+1, ..., r+p-1] (mod p)
    bufs = [sorted(buf) for buf in bufs]
    return Schedule(p=p, rounds=rounds, buffers=bufs, algorithm="bruck", region=region)


# =============================================================================
# Ring allgather
# =============================================================================
def ring(p: int, region: RegionMap | None = None) -> Schedule:
    bufs = [[r] for r in range(p)]
    last = list(range(p))  # most recently received block per rank
    rounds: list[Round] = []
    for step in range(p - 1):
        sends = tuple(Send(src=r, dst=(r - 1) % p, blocks=(last[r],)) for r in range(p))
        new_last = [last[(r + 1) % p] for r in range(p)]
        _exchange(bufs, list(sends))
        last = new_last
        rounds.append(Round(sends=sends, phase=f"ring-step{step}"))
    bufs = [sorted(buf) for buf in bufs]
    return Schedule(p=p, rounds=rounds, buffers=bufs, algorithm="ring", region=region)


# =============================================================================
# Hierarchical allgather [Träff '06]: gather -> master allgather -> broadcast
# =============================================================================
def hierarchical(p: int, p_local: int) -> Schedule:
    region = RegionMap(p=p, p_local=p_local)
    pl, r = p_local, region.n_regions
    bufs = [[rank] for rank in range(p)]
    rounds: list[Round] = []

    # Phase 1: binomial-tree gather to master (local rank 0) in each region.
    d = 1
    while d < pl:
        sends = []
        for rank in range(p):
            l = region.local_rank_of(rank)
            if l % (2 * d) == d:
                sends.append(Send(src=rank, dst=rank - d, blocks=tuple(bufs[rank])))
        _exchange(bufs, sends)
        rounds.append(Round(sends=tuple(sends), phase=f"hier-gather-d{d}"))
        d *= 2

    # Phase 2: Bruck allgather among masters only.
    d = 1
    step = 0
    while d < r:
        cnt = min(d, r - d)
        sends = []
        for R in range(r):
            src = region.rank_of(R, 0)
            dst = region.rank_of((R - d) % r, 0)
            # master sends its first cnt *region-blocks* (cnt * pl origin blocks)
            sends.append(Send(src=src, dst=dst, blocks=tuple(bufs[src][: cnt * pl])))
        _exchange(bufs, sends)
        rounds.append(Round(sends=tuple(sends), phase=f"hier-bruck-step{step}"))
        d *= 2
        step += 1

    # Phase 3: binomial broadcast from master within each region.
    d = 1
    while d < pl:
        sends = []
        for rank in range(p):
            l = region.local_rank_of(rank)
            if l < d and l + d < pl:
                sends.append(Send(src=rank, dst=rank + d, blocks=tuple(bufs[rank])))
        _exchange(bufs, sends)
        rounds.append(Round(sends=tuple(sends), phase=f"hier-bcast-d{d}"))
        d *= 2

    bufs = [sorted(buf) for buf in bufs]
    return Schedule(p=p, rounds=rounds, buffers=bufs, algorithm="hierarchical", region=region)


# =============================================================================
# Multi-lane allgather [Träff & Hunold '20]
# =============================================================================
def multilane(p: int, p_local: int) -> Schedule:
    region = RegionMap(p=p, p_local=p_local)
    pl, r = p_local, region.n_regions
    bufs = [[rank] for rank in range(p)]
    rounds: list[Round] = []

    # Phase 1: per-lane Bruck over regions (all lanes concurrently; each lane
    # carries only its own block -> non-local bytes reduced by p_local).
    d = 1
    step = 0
    while d < r:
        cnt = min(d, r - d)
        sends = []
        for rank in range(p):
            R, l = region.region_of(rank), region.local_rank_of(rank)
            dst = region.rank_of((R - d) % r, l)
            sends.append(Send(src=rank, dst=dst, blocks=tuple(bufs[rank][:cnt])))
        _exchange(bufs, sends)
        rounds.append(Round(sends=tuple(sends), phase=f"lane-bruck-step{step}"))
        d *= 2
        step += 1

    # Phase 2: local Bruck allgather combining the lanes.
    d = 1
    step = 0
    while d < pl:
        cnt = min(d, pl - d)
        sends = []
        for rank in range(p):
            R, l = region.region_of(rank), region.local_rank_of(rank)
            dst = region.rank_of(R, (l - d) % pl)
            sends.append(Send(src=rank, dst=dst, blocks=tuple(bufs[rank][: cnt * r])))
        _exchange(bufs, sends)
        rounds.append(Round(sends=tuple(sends), phase=f"lane-local-step{step}"))
        d *= 2
        step += 1

    bufs = [sorted(buf) for buf in bufs]
    return Schedule(p=p, rounds=rounds, buffers=bufs, algorithm="multilane", region=region)


# =============================================================================
# Algorithm 2 — locality-aware Bruck allgather (the paper's contribution)
# =============================================================================
def _local_unit_bruck(bufs, region: RegionMap, units: dict[int, tuple[int, ...]],
                      phase: str, rounds: list[Round], contributors: int) -> None:
    """Local allgather of per-rank *units* within each region, in place.

    Faithful to Alg. 2's local step: each contributing rank (local id < g)
    contributes one unit — its newly received chunk (rank 0 re-contributes its
    current group chunk, the paper's "contribute the original data for
    simplicity"). A Bruck allgather runs among the g contributors on whole
    units; a binomial broadcast then fills the idle ranks (the paper's
    MPI_Allgatherv case for non-power region counts).
    """
    pl = region.p_local
    g = contributors
    # Bruck over units among contributors.
    unit_bufs = {rank: [units[rank]] for rank in units}
    d = 1
    while d < g:
        cnt = min(d, g - d)
        sends = []
        moved: list[tuple[int, list[tuple[int, ...]]]] = []
        for rank in range(region.p):
            R, l = region.region_of(rank), region.local_rank_of(rank)
            if l >= g:
                continue
            dst = region.rank_of(R, (l - d) % g)
            payload = unit_bufs[rank][:cnt]
            sends.append(Send(src=rank, dst=dst,
                              blocks=tuple(b for u in payload for b in u)))
            moved.append((dst, payload))
        for dst, payload in moved:
            unit_bufs[dst].extend(payload)
        _exchange(bufs, sends)
        rounds.append(Round(sends=tuple(sends), phase=f"{phase}-bruck-d{d}"))
        d *= 2
    # Binomial broadcast of the gathered result to idle ranks (g < pl only).
    have = g
    while have < pl:
        sends = []
        for rank in range(region.p):
            R, l = region.region_of(rank), region.local_rank_of(rank)
            if l < have and l + have < pl:
                blocks = tuple(b for u in unit_bufs[region.rank_of(R, l % g)] for b in u)
                sends.append(Send(src=rank, dst=region.rank_of(R, l + have), blocks=blocks))
        _exchange(bufs, sends)
        rounds.append(Round(sends=tuple(sends), phase=f"{phase}-bcast-{have}"))
        have *= 2


def locality_bruck(p: int, p_local: int) -> Schedule:
    """Paper Algorithm 2, generalized to any region count (allgatherv form).

    Round i (regions covered so far: ``group``): local rank ℓ exchanges its
    buffer with the region ℓ·group away (global distance ℓ·group·p_ℓ,
    matching Alg. 2's dist = id_ℓ · p_ℓ^{i+1} when r is a power of p_ℓ).
    Local rank 0 is idle non-locally (paper §3). A local allgather then
    redistributes the received group buffers inside each region.

    Allgatherv adaptation: lane ℓ sends only the ``min(group, r - ℓ·group)``
    region chunks its peer is actually missing — on the wrapped final round
    of a non-power region count this is a PARTIAL payload (the paper's
    MPI_Allgatherv case), so non-local blocks stay below the full-buffer
    exchange for every region count, not just powers of p_ℓ. Matches the
    executable ``core/collectives.locality_bruck_allgather``.
    """
    region = RegionMap(p=p, p_local=p_local)
    pl, r = p_local, region.n_regions
    if pl == 1:
        # single-rank regions: no lanes to spread over — degenerate to the
        # standard Bruck (matches collectives.locality_bruck_allgather)
        sched = bruck(p, region)
        return dataclasses.replace(sched, algorithm="locality_bruck")
    bufs = [[rank] for rank in range(p)]
    rounds: list[Round] = []

    # Step 0: local Bruck allgather of initial values (Alg. 2 line 1).
    init_units = {rank: (rank,) for rank in range(p)}
    _local_unit_bruck(bufs, region, init_units, "loc-init", rounds, contributors=pl)

    group = 1           # regions whose data each rank currently holds
    i = 0
    while group < r:
        n_groups = -(-r // group)                  # ceil: groups still distinct
        active = min(pl, n_groups)                 # offsets 0..active-1 exist
        # Non-local exchange: one message per rank with local id 1..active-1.
        # Lane ℓ holds chunks [R, R+group) and its peer (region R - ℓ·group)
        # is missing only the first min(group, r - ℓ·group) of them.
        sends = []
        received: dict[int, tuple[int, ...]] = {}
        for rank in range(p):
            R, l = region.region_of(rank), region.local_rank_of(rank)
            if l == 0 or l >= active:
                continue  # idle (paper: first process per region idle)
            need = min(group, r - l * group)
            dst = region.rank_of((R - l * group) % r, l)
            blocks = tuple(region.rank_of(R + j, lr)
                           for j in range(need) for lr in range(pl))
            assert set(blocks) <= set(bufs[rank]), (rank, i, need)
            sends.append(Send(src=rank, dst=dst, blocks=blocks))
            received[dst] = blocks
        _exchange(bufs, sends)
        rounds.append(Round(sends=tuple(sends), phase=f"loc-nonlocal-step{i}"))
        # Local redistribution: contributors' units are the chunks just
        # received (local rank 0 re-contributes its own group chunk).
        units = {}
        for rank in range(p):
            l = region.local_rank_of(rank)
            if l == 0:
                units[rank] = tuple(bufs[rank])
            elif l < active:
                units[rank] = received[rank]
        _local_unit_bruck(bufs, region, units, f"loc-redist{i}", rounds,
                          contributors=active)
        group = min(group * active, r)
        i += 1

    bufs = [sorted(buf) for buf in bufs]
    return Schedule(p=p, rounds=rounds, buffers=bufs, algorithm="locality_bruck",
                    region=region)


ALGORITHMS = {
    "bruck": lambda p, pl=None: bruck(p, RegionMap(p, pl) if pl else None),
    "ring": lambda p, pl=None: ring(p, RegionMap(p, pl) if pl else None),
    "hierarchical": lambda p, pl: hierarchical(p, pl),
    "multilane": lambda p, pl: multilane(p, pl),
    "locality_bruck": lambda p, pl: locality_bruck(p, pl),
}


# =============================================================================
# All-to-all oracles — personalized exchange (the MoE dispatch collective)
# =============================================================================
# A block here is a (source, destination) pair, encoded src·p + dst; every
# rank starts owning the p blocks {r·p + d} and must end holding the p blocks
# {s·p + r}. ``Schedule.buffers`` lists the blocks each rank RECEIVED (own
# block r·p+r included); ``validate_all_to_all`` replaces the allgather
# ``Schedule.validate``. ``per_rank_stats`` works unchanged, so the postal
# model prices these schedules through the same ``cost_model.schedule_cost``.


def a2a_block(src: int, dst: int, p: int) -> int:
    return src * p + dst


def validate_all_to_all(sched: Schedule) -> None:
    """Every rank must end with exactly the p blocks addressed to it."""
    p = sched.p
    for r, buf in enumerate(sched.buffers):
        want = [a2a_block(s, r, p) for s in range(p)]
        if sorted(set(buf)) != want:
            missing = set(want) - set(buf)
            raise AssertionError(
                f"{sched.algorithm}: rank {r} missing blocks for sources "
                f"{sorted(b // p for b in missing)[:8]}")


def _a2a_deliver(delivered: list[set], sends: list[Send], p: int) -> None:
    """Credit every block that just reached its destination rank."""
    for s in sends:
        for b in s.blocks:
            if b % p == s.dst:
                delivered[s.dst].add(b)


def xla_all_to_all(p: int, p_local: int | None = None) -> Schedule:
    """Flat direct pairwise exchange — the XLA baseline the analyzer prices:
    p-1 rotation rounds, each rank shipping one block straight to its
    destination (b/p bytes per ordered pair)."""
    region = RegionMap(p, p_local) if p_local else None
    delivered = [{a2a_block(r, r, p)} for r in range(p)]
    rounds: list[Round] = []
    for k in range(1, p):
        sends = [Send(src=r, dst=(r + k) % p,
                      blocks=(a2a_block(r, (r + k) % p, p),))
                 for r in range(p)]
        _a2a_deliver(delivered, sends, p)
        rounds.append(Round(sends=tuple(sends), phase=f"a2a-pairwise-k{k}"))
    return Schedule(p=p, rounds=rounds, buffers=[sorted(d) for d in delivered],
                    algorithm="xla", region=region)


def locality_all_to_all(p: int, p_local: int) -> Schedule:
    """Two-tier all-to-all (collectives.locality_all_to_all's oracle).

    Offsets o ∈ [1, q) are lane-assigned round-robin (offset o → lane
    (o-1) mod p_ℓ, round (o-1) div p_ℓ — Algorithm 2's modular lane
    geometry, partial last round for non-power q). Three phases:
    intra-region collect (each lane accumulates the whole region's blocks
    for its pods), one aggregated p_ℓ²-block inter-region message per
    active lane per round — q-1 DCN messages per region total vs
    p_ℓ²·(q-1) for the flat exchange — then intra-region delivery.
    Local sends are counted unpadded (the executable ships zero-padded
    uniform slabs on the partial round; DCN counts are exact either way).
    """
    region = RegionMap(p=p, p_local=p_local)
    pl, q = p_local, region.n_regions
    delivered = [{a2a_block(r, r, p)} for r in range(p)]
    rounds: list[Round] = []
    nrounds = -(-(q - 1) // pl) if q > 1 else 0

    def lane_offsets(lam: int) -> list[int]:
        return [t * pl + lam + 1 for t in range(nrounds)
                if t * pl + lam + 1 <= q - 1]

    # Phase 1: local collect — rank (R, m) hands lane (m+k)%pl the blocks
    # destined to that lane's assigned pods.
    for k in range(1, pl):
        sends = []
        for R in range(q):
            for m in range(pl):
                lam = (m + k) % pl
                src = region.rank_of(R, m)
                blocks = tuple(
                    a2a_block(src, region.rank_of((R + o) % q, dl), p)
                    for o in lane_offsets(lam) for dl in range(pl))
                if blocks:
                    sends.append(Send(src=src, dst=region.rank_of(R, lam),
                                      blocks=blocks))
        if sends:
            _a2a_deliver(delivered, sends, p)
            rounds.append(Round(sends=tuple(sends), phase=f"a2a-collect-k{k}"))

    # Phase 2: aggregated inter-region rounds (the minimized DCN phase).
    for t in range(nrounds):
        active = min(pl, (q - 1) - t * pl)
        sends = []
        for lam in range(active):
            o = t * pl + lam + 1
            for R in range(q):
                src = region.rank_of(R, lam)
                dst = region.rank_of((R + o) % q, lam)
                blocks = tuple(
                    a2a_block(region.rank_of(R, sm),
                              region.rank_of((R + o) % q, dl), p)
                    for sm in range(pl) for dl in range(pl))
                sends.append(Send(src=src, dst=dst, blocks=blocks))
        _a2a_deliver(delivered, sends, p)
        rounds.append(Round(sends=tuple(sends), phase=f"a2a-nonlocal-t{t}"))

    # Phase 3: local delivery of the received slab columns + own-region blocks.
    for k in range(1, pl):
        sends = []
        for R in range(q):
            for m in range(pl):
                dst_lane = (m + k) % pl
                src = region.rank_of(R, m)
                dst = region.rank_of(R, dst_lane)
                blocks = [a2a_block(src, dst, p)]       # own-region block
                for o in lane_offsets(m):
                    Rs = (R - o) % q
                    blocks.extend(a2a_block(region.rank_of(Rs, sm), dst, p)
                                  for sm in range(pl))
                sends.append(Send(src=src, dst=dst, blocks=tuple(blocks)))
        _a2a_deliver(delivered, sends, p)
        rounds.append(Round(sends=tuple(sends), phase=f"a2a-deliver-k{k}"))
    return Schedule(p=p, rounds=rounds, buffers=[sorted(d) for d in delivered],
                    algorithm="locality", region=region)


#: All-to-all schedule generators, keyed by the canonical algorithm strings
#: (collectives.ALL_TO_ALL_ALGORITHMS).
ALL_TO_ALL_SCHEDULES = {
    "locality": locality_all_to_all,
    "xla": lambda p, pl: xla_all_to_all(p, pl),
}
