"""AdamW with global-norm clipping, functional (optax-style but self-built).

Optimizer state is a pytree congruent with params, so whatever sharding the
params carry (TP over 'model', FSDP over 'data') automatically extends to
mu/nu — ZeRO-style optimizer-state sharding falls out of the param specs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    mu: Any
    nu: Any
    step: jax.Array

    @staticmethod
    def create(params) -> "TrainState":
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return TrainState(params=params, mu=zeros,
                          nu=jax.tree.map(jnp.copy, zeros),
                          step=jnp.zeros((), jnp.int32))


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def apply(self, state: TrainState, grads) -> tuple[TrainState, dict]:
        step = state.step + 1
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-12)) \
            if self.clip_norm else jnp.asarray(1.0)
        lr = self._lr(step)
        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, mu, nu):
            g = g.astype(jnp.float32) * scale
            mu = b1 * mu + (1 - b1) * g
            nu = b2 * nu + (1 - b2) * g * g
            mhat = mu / c1
            vhat = nu / c2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay and p.ndim >= 2:   # decay matrices only
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

        out = jax.tree.map(upd, state.params, grads, state.mu, state.nu)
        params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        new_state = TrainState(params=params, mu=mu, nu=nu, step=step)
        return new_state, {"grad_norm": gnorm, "lr": lr}
