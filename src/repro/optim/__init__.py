from .adamw import AdamW, TrainState, global_norm
from .schedules import constant, cosine_warmup
