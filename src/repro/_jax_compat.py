"""Backfill newer public JAX APIs onto older installs.

The codebase is written against the current JAX API surface
(``jax.shard_map``, ``jax.set_mesh``, ``jax.typeof`` + varying-manual-axes
tracking, ``lax.pcast``, ``lax.axis_size``). Some deployment containers pin an
older jax (0.4.x) where these live elsewhere or do not exist; this module
installs semantically equivalent fallbacks at ``import repro`` time so the
same source runs on both. Every patch is guarded by ``hasattr`` — on a
current JAX this module is a no-op.

Fallback semantics on old JAX:

* ``jax.shard_map(f, mesh=..., in_specs=..., out_specs=..., axis_names=S,
  check_vma=...)`` maps onto ``jax.experimental.shard_map.shard_map`` with
  ``auto = mesh.axis_names - S`` and ``check_rep=False`` (0.4.x replication
  checking predates vma tracking and rejects some valid ppermute patterns).
* ``jax.set_mesh(mesh)`` enters the mesh's context manager and keeps it
  active for the life of the process (old JAX has no global mesh setter,
  only the ``with mesh:`` ambient context).
* ``jax.typeof(x).vma`` returns a universal axis set, so callers that
  normalize varying-ness (``a not in jax.typeof(x).vma``) see every axis as
  already varying and skip the ``lax.pcast`` — correct because old
  shard_map with ``check_rep=False`` performs no replication tracking.
* ``lax.axis_size(name)`` falls back to the ``lax.psum(1, name)`` idiom,
  which constant-folds to a Python int at trace time.
"""
from __future__ import annotations

import types


class _UniversalAxisSet(frozenset):
    """A frozenset that claims to contain every element (vma stand-in)."""

    def __contains__(self, item) -> bool:  # noqa: D105
        return True


_ACTIVE_MESH_CTX: list = []

# True when this install predates native jax.shard_map (and with it the vma
# tracking that makes partial-auto + in-body sharding constraints work). On
# these versions XLA's SPMD partitioner RET_CHECKs on any sharding
# annotation inside a partially-manual computation (spmd_partitioner.cc
# "Incompatible manual sharding"), so activation-constraint hooks must be
# disabled inside manual-DP shard_map bodies (see train/sharding.py).
LEGACY_PARTIAL_AUTO = False


def scan_compat(f, init, xs, length=None):
    """``lax.scan`` that degrades to a Python unroll on legacy JAX.

    Old XLA crashes (``Check failed: sharding.IsManualSubgroup()``) when a
    while-loop variable carries an auto-axis sharding inside a partially
    manual shard_map body — which is exactly what a scan over
    model-sharded stacked layer params is. The unroll trades compile time
    for correctness; on current JAX this is ``lax.scan`` verbatim.
    """
    import jax
    import jax.numpy as jnp

    if not LEGACY_PARTIAL_AUTO:
        return jax.lax.scan(f, init, xs, length=length)
    if length is None:
        length = jax.tree_util.tree_leaves(xs)[0].shape[0]
    carry, ys = init, []
    for i in range(length):
        xi = jax.tree_util.tree_map(lambda t: t[i], xs)
        carry, y = f(carry, xi)
        ys.append(y)
    stacked = jax.tree_util.tree_map(lambda *e: jnp.stack(e), *ys)
    return carry, stacked


def install() -> None:
    global LEGACY_PARTIAL_AUTO
    import jax
    from jax import lax

    if not hasattr(jax, "shard_map"):
        LEGACY_PARTIAL_AUTO = True
        # Newer JAX defaults to the partitionable threefry, making random
        # values independent of the output sharding. Old JAX defaults to
        # False, where the same PRNGKey yields DIFFERENT params under
        # different out_shardings (e.g. FSDP vs replicated init) — align
        # with the new default.
        try:
            jax.config.update("jax_threefry_partitionable", True)
        except AttributeError:
            pass
        from jax.experimental.shard_map import shard_map as _shard_map

        def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                      check_vma=True, **_kw):
            auto = frozenset()
            if axis_names is not None:
                auto = frozenset(mesh.axis_names) - frozenset(axis_names)
            return _shard_map(f, mesh, in_specs, out_specs,
                              check_rep=False, auto=auto)

        jax.shard_map = shard_map

    if not hasattr(jax, "set_mesh"):
        def set_mesh(mesh):
            while _ACTIVE_MESH_CTX:
                _ACTIVE_MESH_CTX.pop().__exit__(None, None, None)
            if mesh is not None:
                mesh.__enter__()
                _ACTIVE_MESH_CTX.append(mesh)
            return mesh

        jax.set_mesh = set_mesh

    if not hasattr(jax, "typeof"):
        _all_axes = _UniversalAxisSet()

        def typeof(x):
            shape = getattr(x, "shape", ())
            dtype = getattr(x, "dtype", None)
            return types.SimpleNamespace(shape=shape, dtype=dtype,
                                         vma=_all_axes)

        jax.typeof = typeof

    if not hasattr(lax, "axis_size"):
        def axis_size(name):
            return lax.psum(1, name)

        lax.axis_size = axis_size

    try:
        jax.ShapeDtypeStruct((1,), "float32", vma=frozenset())
    except TypeError:
        _SDS = jax.ShapeDtypeStruct

        class ShapeDtypeStruct(_SDS):
            """Accepts (and drops) the newer ``vma`` kwarg on old JAX."""

            def __init__(self, shape, dtype, *args, vma=None, **kwargs):
                super().__init__(shape, dtype, *args, **kwargs)

        ShapeDtypeStruct.__name__ = "ShapeDtypeStruct"
        jax.ShapeDtypeStruct = ShapeDtypeStruct

    if not hasattr(lax, "pcast"):
        # vma tracking does not exist on old JAX: casting to "varying" is an
        # identity (nothing tracks the annotation), which matches the
        # check_rep=False shard_map fallback above.
        def pcast(x, axes, to=None):
            return x

        lax.pcast = pcast
