"""Train-step factory: loss, backward, gradient sync, optimizer — one jit.

Two gradient-sync paths (selected by ``grad_sync``):

* ``"xla"``      — pure GSPMD: batch sharded over DP axes, params replicated
                   (or FSDP-sharded) — XLA inserts its own all-reduce /
                   reduce-scatter. The stock baseline.
* ``"locality"`` / ``"locality_rd"`` / ``"flat_psum"`` — paper mode: the
  forward/backward runs inside a ``shard_map`` that is *manual* over the DP
  axes (``pod`` crossing the expensive boundary, ``data`` local) and *auto*
  over ``model`` (GSPMD still handles TP). Per-DP-shard gradients are then
  synchronized with the locality-aware collectives of ``core/collectives.py``
  — the paper's algorithm is the literal gradient-sync path, and its
  schedule is visible in the compiled HLO as collective-permutes.

Distributed-optimization extras: gradient bucketing (fuse small leaves into
~bucket_mb collectives) and optional bf16 compression of the DP sync.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import collectives as C
from repro.models import encdec, transformer
from repro.optim import AdamW, TrainState
from .sharding import (DP_AXES, batch_spec, block_slice_dims, dp_axes,
                       fsdp_param_axes, fsdp_param_dims, gather_outer_local,
                       make_shard_fn, moe_ep_mask, normalize_axes,
                       param_specs)


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def xent_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Cross-entropy that stays sharded over the vocab dim.

    take_along_axis on a 'model'-sharded vocab would make GSPMD replicate
    the logits (an all-gather of the largest tensor in the step — measured
    ~2.5 GiB/device on the 151k-vocab cells). A sharded iota==label mask
    keeps every op elementwise over the sharded dim; only (B, S) partials
    cross shards.
    """
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    if os.environ.get("REPRO_XENT_GATHER"):      # §Perf A/B baseline path
        ll = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    else:
        vocab_pos = jax.lax.broadcasted_iota(jnp.int32, lg.shape, lg.ndim - 1)
        mask = vocab_pos == labels[..., None]
        ll = jnp.sum(jnp.where(mask, lg, 0.0), axis=-1)
    return jnp.mean(lse - ll)


def make_loss_fn(cfg, *, remat: bool = True):
    model = encdec if cfg.family == "audio" else transformer

    def loss_fn(params, batch, shard, prefetch=None, moe_dispatch=None):
        kw: dict[str, Any] = {}
        if cfg.family == "audio":
            kw["frames"] = batch["frames"]
        if cfg.family == "vlm" and "img_embeds" in batch:
            kw["img_embeds"] = batch["img_embeds"]
        if prefetch is not None:
            kw["prefetch"] = prefetch
        if moe_dispatch is not None:
            kw["moe_dispatch"] = moe_dispatch
        logits, aux, _ = model.forward(params, cfg, batch["tokens"],
                                       mode="train", shard=shard, remat=remat,
                                       **kw)
        loss = xent_loss(logits, batch["labels"])
        total = loss + aux["moe_aux"]
        return total, {"loss": loss, "moe_aux": aux["moe_aux"]}

    return loss_fn


# ---------------------------------------------------------------------------
# double-buffered FSDP param prefetch (the train half of DESIGN.md §5)
# ---------------------------------------------------------------------------

class BlockPrefetch:
    """Per-layer ZeRO-3 gather hook for the scanned transformer pipeline.

    ``start`` issues the allgather of ONE super-block slice's shards over
    its DP axes (split halves of core/collectives — the wire rounds,
    including every non-local DCN round of a ('pod','data')-sharded leaf,
    complete in start); ``finish`` completes the local ICI tail at the
    consumer. The PendingCollective rides the scan carry with the two-tier
    (outer, local) layout in its meta, so the double buffer hides exactly
    the DCN rounds. The model scan calls start for layer i + depth before
    layer i's compute; autodiff transposes each start/finish pair into the
    matching reduce-scatter, placed with the same lookahead in the
    backward.

    Bitwise-identical to the eager ``_gather`` path: same cast, same
    moveaxis, same locality-Bruck schedule per leaf (on a single region it
    degenerates to the local Bruck with a deferred reorder).
    """

    def __init__(self, slice_dims, slice_axes, dtype, depth: int):
        self.dims = slice_dims        # fsdp dim per slice leaf (-1 = repl.)
        self.axes = slice_axes        # comma-joined DP axes per leaf ("")
        self.dtype = dtype
        self.depth = depth

    def _cast(self, leaf):
        return leaf.astype(self.dtype) if leaf.dtype == jnp.float32 else leaf

    def start(self, slice_shards):
        def go(leaf, k, ax):
            if k < 0:
                return self._cast(leaf)
            x = jnp.moveaxis(self._cast(leaf), k, 0)
            outer, local = gather_outer_local(ax)
            return C.allgather_start(x, outer, local,
                                     algorithm="locality_bruck", tiled=True,
                                     assume_varying=True)
        with jax.named_scope("repro:prefetch_start"):
            return jax.tree.map(go, slice_shards, self.dims, self.axes)

    def finish(self, pending):
        def done(p, k):
            if k < 0:
                return p
            return jnp.moveaxis(C.allgather_finish(p), 0, k)
        with jax.named_scope("repro:prefetch_finish"):
            return jax.tree.map(done, pending, self.dims,
                                is_leaf=lambda v: isinstance(v, C.PendingCollective))


# ---------------------------------------------------------------------------
# gradient bucketing for the DP sync
# ---------------------------------------------------------------------------

def bucketed_sync(grads, sync_flat: Callable[[jax.Array], jax.Array],
                  bucket_mb: float = 64.0, compress: bool = False):
    """Flatten grads into ≤bucket_mb fp32 buckets, sync each, unflatten.

    Fuses the many small-leaf collectives (norm scales, biases) into a few
    large ones — the standard DDP bucketing trick, which also puts the
    collectives squarely in the paper's bandwidth regime.
    """
    leaves, treedef = jax.tree.flatten(grads)
    sizes = [int(np.prod(l.shape)) for l in leaves]
    limit = int(bucket_mb * 1024 * 1024 / 4)
    buckets: list[list[int]] = [[]]
    acc = 0
    for i, s in enumerate(sizes):
        if acc + s > limit and buckets[-1]:
            buckets.append([])
            acc = 0
        buckets[-1].append(i)
        acc += s
    out: list[jax.Array | None] = [None] * len(leaves)
    for idxs in buckets:
        flat = jnp.concatenate(
            [leaves[i].astype(jnp.float32).reshape(-1) for i in idxs])
        if compress:
            flat = flat.astype(jnp.bfloat16)
        flat = sync_flat(flat)
        flat = flat.astype(jnp.float32)
        off = 0
        for i in idxs:
            out[i] = flat[off:off + sizes[i]].reshape(leaves[i].shape)
            off += sizes[i]
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# step factory
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StepArtifacts:
    step_fn: Callable                 # jitted (state, batch) -> (state, metrics)
    state_shardings: Any
    batch_shardings: Any
    abstract_state: Any               # ShapeDtypeStruct pytree
    pspecs: Any
    grad_sync: str = ""               # resolved mode (never "auto")
    grad_algorithm: str = ""          # collective algorithm behind it
    grad_sync_source: str = ""        # "table" | "model" | "explicit"
    prefetch_depth: int = 0           # resolved FSDP gather lookahead (0=eager)
    prefetch_source: str = ""         # "table"|"model"|"dispatch"|"explicit"|"n/a"
    fsdp_axes: tuple = ()             # resolved FSDP sharding domain
    moe_dispatch: str = "none"        # resolved EP algorithm ("none" = off)
    moe_transport: str = ""           # "tokens" | "slots" when dispatch is on
    moe_dispatch_source: str = ""     # "table" | "model" | "explicit" | "n/a"
    events: tuple = ()                # TelemetryEvents raised while building


def abstract_batch(cfg, shape) -> dict:
    """shape: a ShapeSpec/name (uses cfg.input_specs) or an explicit dict of
    ShapeDtypeStructs (smoke tests / custom drivers)."""
    if isinstance(shape, dict):
        return dict(shape)
    return dict(cfg.input_specs(shape))


def custom_batch_specs(cfg, global_batch: int, seq_len: int) -> dict:
    """Token/label specs for an arbitrary (B, S) — examples and tests."""
    sd = jax.ShapeDtypeStruct
    out = {"tokens": sd((global_batch, seq_len), jnp.int32),
           "labels": sd((global_batch, seq_len), jnp.int32)}
    if cfg.family == "audio":
        out["frames"] = sd((global_batch, cfg.enc_seq, cfg.d_model), cfg.dtype)
    if cfg.family == "vlm":
        out["img_embeds"] = sd((global_batch, cfg.n_img_tokens, cfg.d_model),
                               cfg.dtype)
    return out


def make_train_step(cfg, mesh, *, optimizer: AdamW | None = None,
                    grad_sync: str = "xla", fsdp: bool = False,
                    fsdp_axes: str | tuple[str, ...] = "auto",
                    seq_shard: bool = False, remat: bool = True,
                    bucket_mb: float = 64.0, compress: bool = False,
                    donate: bool = True, shape="train_4k",
                    grad_accum: int = 1,
                    prefetch_depth: int | str = 0,
                    moe_dispatch: str = "none") -> StepArtifacts:
    """grad_accum > 1 splits the per-device batch into microbatches inside a
    lax.scan: activation residency drops ~grad_accum×, the DP sync still
    happens once per step on the accumulated grads (the paper's collective
    amortizes over the whole global batch).

    grad_sync="auto" resolves the algorithm from the postal model
    (core/autotune.py) using the model's gradient size and the mesh
    topology — the paper's Eq. 2-4 promoted into a runtime policy.

    fsdp_axes: the DP axes FSDP shards params over — "auto" spans every DP
    axis on the mesh (('pod','data') on multi-pod: the ZeRO-3 gather runs
    the locality-aware Bruck with outer=('pod',), local=('data',) and its
    transpose reduce-scatters the grads over the SAME two-tier schedule,
    so only the ceil(log_{p_ℓ}(r)) non-local rounds cross the DCN — for
    ANY pod count r, power of two or not: non-power counts take
    Algorithm 2's allgatherv adaptation with partial final-round payloads
    and the grad sync's outer tier runs the Bruck-transpose
    reduce-scatter instead of silently degrading to psum, DESIGN.md §7);
    ("data",) forces the legacy intra-pod layout (pods replicate params
    and the grad sync adds a pod allreduce per bucket).

    prefetch_depth: lookahead of the double-buffered FSDP gather pipeline
    (DESIGN.md §5): 0 = eager (whole stacked gather in front of the
    forward), d >= 1 = layer i + d's gather issued before layer i's
    compute inside the scan. "auto" asks the tuning policy's overlap term
    (per-layer gather bytes × layer flops on this topology), guarded by
    the measured per-dispatch overhead of the live backend — a host-CPU
    harness with no real wire resolves to 0. Applies to paper-mode FSDP
    on the transformer family; degrades to eager where the in-scan gather
    cannot run (legacy partial-auto split, encdec).

    moe_dispatch: locality expert parallelism (DESIGN.md §12) — "none" keeps
    replicated experts; "locality" / "xla" shard routed-expert weights over
    the full DP composite and route token slots through
    ``core/collectives.all_to_all`` with that algorithm; "auto" resolves via
    the tuning policy's all_to_all cell. Engages only in paper mode
    (grad_sync != "xla") on transformer-family MoE configs whose expert and
    batch counts divide the DP size; otherwise records "n/a" and keeps the
    replicated path."""
    optimizer = optimizer or AdamW()
    model = encdec if cfg.family == "audio" else transformer
    loss_fn = make_loss_fn(cfg, remat=remat)

    build_events: list = []

    def _warn(msg: str, **attrs) -> None:
        # degradations must be LOUD: a structured event on the artifact
        # (Trainer surfaces it) plus a stdlib warning for direct callers
        import warnings
        from repro.telemetry import TelemetryEvent
        build_events.append(TelemetryEvent(msg, kind="warning", attrs=attrs))
        warnings.warn(msg, stacklevel=3)

    grad_algorithm = grad_sync
    grad_sync_source = "explicit"
    if grad_sync == "auto":
        # resolve through the tuning policy with the model's gradient size
        # and the mesh topology: measured crossover table when persisted,
        # postal-model prior otherwise (paper Eqs. 2-4 as a runtime policy).
        from repro.tuning.policy import default_policy
        import numpy as _np
        a_p = jax.eval_shape(lambda k: model.init_params(k, cfg),
                             jax.random.PRNGKey(0))
        grad_bytes = sum(int(_np.prod(l.shape)) for l in jax.tree.leaves(a_p)) * 2
        names = list(mesh.axis_names)
        p_l = (mesh.devices.shape[names.index("data")]
               if "data" in names else 1)
        r = (mesh.devices.shape[names.index("pod")] if "pod" in names else 1)
        # allreduce convention: nbytes is the FULL reduced vector (the
        # executors send nbytes/p per message themselves)
        sel = default_policy().select("allreduce", r * p_l, p_l, grad_bytes)
        grad_algorithm, grad_sync_source = sel.algorithm, sel.source
        grad_sync = "locality" if sel.algorithm == "locality" else "flat_psum"

    # --- abstract state + shardings ------------------------------------------
    a_params = jax.eval_shape(
        lambda k: model.init_params(k, cfg), jax.random.PRNGKey(0))

    dp = dp_axes(mesh)
    outer = ("pod",) if "pod" in mesh.axis_names else ()
    local = tuple(a for a in dp if a != "pod")

    b_abstract = abstract_batch(cfg, shape)
    b_specs = {k: P(dp, *([None] * (len(v.shape) - 1)))
               for k, v in b_abstract.items()}
    batch_sh = {k: NamedSharding(mesh, s) for k, s in b_specs.items()}

    dp_size = 1
    for ax in dp:
        dp_size *= mesh.devices.shape[list(mesh.axis_names).index(ax)]

    # --- locality expert-parallel dispatch resolution (DESIGN.md §12) -------
    from repro import _jax_compat
    from repro.models.moe import MoeDispatch
    _names = list(mesh.axis_names)
    n_pods = mesh.devices.shape[_names.index("pod")] if "pod" in _names else 1
    moe_algorithm = moe_dispatch
    moe_transport = ""
    moe_dispatch_source = "explicit"
    ep_ok = (moe_dispatch != "none" and grad_sync != "xla"
             and cfg.family != "audio" and getattr(cfg, "n_experts", 0) > 0
             and dp_size > 1 and cfg.n_experts % dp_size == 0
             and int(b_abstract["tokens"].shape[0]) % dp_size == 0
             and not (_jax_compat.LEGACY_PARTIAL_AUTO
                      and set(mesh.axis_names) - set(dp)))
    if not ep_ok:
        moe_algorithm = "none"
        moe_dispatch_source = "n/a"
    elif moe_dispatch == "auto":
        # price the slot-table exchange (the larger of the two transports)
        # through the tuning policy's all_to_all cell
        from repro.models.moe import capacity as _moe_capacity
        from repro.tuning.policy import default_policy as _dpol
        S = int(b_abstract["tokens"].shape[1])
        slot_bytes = ((int(b_abstract["tokens"].shape[0]) // dp_size)
                      * cfg.n_experts * _moe_capacity(cfg, S)
                      * cfg.d_model * jnp.dtype(cfg.dtype).itemsize)
        sel = _dpol().select("all_to_all", dp_size, dp_size // n_pods,
                             slot_bytes)
        moe_algorithm, moe_dispatch_source = sel.algorithm, sel.source
    ep_on = moe_algorithm != "none"
    moe_hook = None
    if ep_on:
        # tokens transport wins bytes when one pod-aggregated copy of the
        # token block undercuts K·cf slot copies (strict for qwen2's
        # K·cf = 5 at q in {2,3}); algorithm="xla" stays on slots — it IS
        # the flat baseline the multipod gate compares against.
        kcf = cfg.top_k * cfg.capacity_factor
        span = n_pods if n_pods > 1 else dp_size
        moe_transport = ("tokens"
                        if moe_algorithm == "locality" and span < kcf
                        else "slots")
        moe_hook = MoeDispatch(outer=outer, local=local,
                               algorithm=moe_algorithm,
                               transport=moe_transport, p=dp_size)

    pspecs = param_specs(a_params, mesh, fsdp=fsdp, fsdp_axes=fsdp_axes,
                         moe_ep=ep_on)
    ep_tree = (moe_ep_mask(a_params) if ep_on
               else jax.tree.map(lambda _: False, a_params))
    resolved_fsdp_axes = (() if not fsdp else
                          dp_axes(mesh) if fsdp_axes == "auto" else
                          tuple(a for a in normalize_axes(fsdp_axes)
                                if a in mesh.axis_names))
    a_state = jax.eval_shape(TrainState.create, a_params)
    state_specs = TrainState(params=pspecs, mu=pspecs, nu=pspecs, step=P())
    state_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), state_specs)

    # --- prefetch pipeline resolution (paper-mode FSDP, transformer only) ---
    names = list(mesh.axis_names)
    d_size = mesh.devices.shape[names.index("data")] if "data" in names else 1
    can_prefetch = (fsdp and grad_sync != "xla" and cfg.family != "audio"
                    and d_size > 1 and "blocks" in a_params)
    prefetch_source = "explicit"
    if prefetch_depth == "auto":
        prefetch_source = "n/a"
        resolved_depth = 0
        if can_prefetch:
            # per-layer overlap term: per-rank gather bytes of one scanned
            # super-block slice vs that slice's forward matmul window. The
            # gather span is per-leaf: ('pod','data')-sharded leaves split
            # over the full DP size, data-only leaves over the pod-local
            # slice — and the topology handed to the policy is the widest
            # span so the DCN rounds are priced when any leaf crosses pods.
            blk_dims = fsdp_param_dims(pspecs)["blocks"]
            blk_axes = fsdp_param_axes(pspecs)["blocks"]
            blk_leaves = jax.tree.leaves(a_params["blocks"])
            dim_leaves = jax.tree.leaves(blk_dims)
            axes_leaves = jax.tree.leaves(blk_axes)
            itemsize = jnp.dtype(cfg.dtype).itemsize
            slice_elems = sum(int(np.prod(l.shape[1:])) for l in blk_leaves)
            gather_bytes = sum(
                int(np.prod(l.shape[1:])) * itemsize
                / (dp_size if "pod" in a else d_size)
                for l, k, a in zip(blk_leaves, dim_leaves, axes_leaves)
                if k >= 0)
            crosses_pods = any("pod" in a for k, a in
                               zip(dim_leaves, axes_leaves) if k >= 0)
            p_gather = dp_size if crosses_pods else d_size
            tokens_per_dev = int(np.prod(b_abstract["tokens"].shape)) \
                // max(dp_size, 1)
            layer_flops = 2.0 * slice_elems * tokens_per_dev
            from repro.tuning.measure import dispatch_overhead_s
            from repro.tuning.policy import default_policy
            sel = default_policy().select_overlap(
                p_gather, d_size, gather_bytes, layer_flops,
                dispatch_overhead_s=dispatch_overhead_s())
            resolved_depth = (C.PREFETCH_DEPTH_DEFAULT
                              if sel.algorithm == "prefetch" else 0)
            prefetch_source = sel.source
    else:
        resolved_depth = int(prefetch_depth)
        if resolved_depth and not can_prefetch:
            # nothing to pipeline on this config — encdec has no scanned
            # transformer blocks, non-FSDP/flat-sync no in-scan gather
            _warn(f"prefetch_depth={resolved_depth} requested but this "
                  f"config cannot pipeline the FSDP gather (family="
                  f"{cfg.family}, fsdp={fsdp}, grad_sync={grad_sync}): "
                  f"degrading to eager",
                  requested_depth=resolved_depth, family=cfg.family,
                  fsdp=fsdp, grad_sync=grad_sync)
            resolved_depth = 0
            prefetch_source = "n/a"

    # --- microbatch accumulation helper -------------------------------------
    def _accumulated(one_fn, batch):
        """Run one_fn over grad_accum microbatches via lax.scan, summing the
        ((loss, metrics), grads) pytree; caller divides by grad_accum."""
        if grad_accum <= 1:
            return one_fn(batch)
        mbs = jax.tree.map(
            lambda t: t.reshape((grad_accum, t.shape[0] // grad_accum)
                                + t.shape[1:]), batch)
        first = jax.tree.map(lambda t: t[0], mbs)
        out_sh = jax.eval_shape(one_fn, first)
        init = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), out_sh)

        def sbody(acc, mb):
            return jax.tree.map(lambda a, b: a + b, acc, one_fn(mb)), None

        from repro._jax_compat import scan_compat
        acc, _ = scan_compat(sbody, init, mbs)
        return jax.tree.map(lambda t: t / grad_accum, acc)

    # --- gradient computation ---------------------------------------------
    if grad_sync == "xla":
        def grads_of(params, batch):
            shard = make_shard_fn(mesh, seq_shard=seq_shard)

            def one(mb):
                with jax.named_scope("repro:compute"):
                    return jax.value_and_grad(loss_fn, has_aux=True)(
                        params, mb, shard)

            (_, metrics), grads = _accumulated(one, batch)
            return grads, metrics
    else:
        alg = {"locality": ("locality", "rhd"),
               "locality_rd": ("locality", "rd"),
               "flat_psum": ("xla", "rhd")}[grad_sync]

        # fsdp dim + DP axes per leaf (-1/"" = replicated). In paper mode
        # the DP axes are *manual*: ZeRO-3-style shards enter the
        # shard_map, are gathered with the (locality-aware) Bruck
        # allgather before use, and autodiff transposes the gather into
        # the matching reduce-scatter of the gradients — paper Algorithm 2
        # as the literal FSDP communication path. A ('pod','data')-sharded
        # leaf runs the two-tier schedule with outer=('pod',): its
        # non-local rounds are the ONLY DCN traffic of that leaf's whole
        # gather+sync cycle (the transpose reduce-scatters over both tiers
        # at once, no separate pod allreduce). Leaves sharded over 'data'
        # alone keep the per-shard pod allreduce (1/p_ℓ of the bytes).
        fsdp_dims = fsdp_param_dims(pspecs)
        fsdp_axs = fsdp_param_axes(pspecs)
        # EP expert leaves stay sharded through the forward (the dispatch
        # all-to-all IS their exchange); gather-skip them here and let the
        # return-leg transpose deliver their complete grads to the owner.
        gdims = jax.tree.map(lambda k, e: -1 if e else k, fsdp_dims, ep_tree)
        param_in_specs = jax.tree.map(
            lambda sp, k: P(*[(sp[i] if i == k else None)
                              for i in range(len(sp))]),
            pspecs, fsdp_dims, is_leaf=lambda x: isinstance(x, P))

        def _gather(shard_leaf, k, ax=""):
            if k < 0:
                return shard_leaf.astype(cfg.dtype) \
                    if shard_leaf.dtype == jnp.float32 else shard_leaf
            with jax.named_scope("repro:fsdp_gather"):
                x = shard_leaf.astype(cfg.dtype)   # gather the bf16 copy
                x = jnp.moveaxis(x, k, 0)
                g_outer, g_local = gather_outer_local(ax)
                if g_outer:
                    full = C.allgather(x, g_outer, g_local,
                                       algorithm="locality_bruck", tiled=True,
                                       assume_varying=True)
                else:
                    full = C.allgather(x, (), g_local or ("data",),
                                       algorithm="bruck", tiled=True,
                                       assume_varying=True)
                return jnp.moveaxis(full, 0, k)

        def sync_pod(t):
            if not outer:
                return t / dp_size
            with jax.named_scope("repro:grad_sync"):
                return C.allreduce(t, (), outer, algorithm="locality",
                                   outer_algorithm=alg[1]) / dp_size

        def sync_full(t):
            with jax.named_scope("repro:grad_sync"):
                return C.allreduce(t, outer, local, algorithm=alg[0],
                                   outer_algorithm=alg[1]) / dp_size

        # the double-buffered pipeline hook: block shards stay sharded into
        # the forward, gathered per scanned layer with depth-ahead issue
        hook = None
        if resolved_depth > 0 and can_prefetch:
            hook = BlockPrefetch(block_slice_dims(gdims["blocks"]),
                                 fsdp_axs["blocks"], cfg.dtype,
                                 resolved_depth)

        def body(params, batch):
            shard = make_shard_fn(mesh, manual_dp=True, seq_shard=seq_shard)

            def one(mb):
                def sharded_loss(shards):
                    if hook is not None:
                        rest = {k: v for k, v in shards.items()
                                if k != "blocks"}
                        rdims = {k: v for k, v in gdims.items()
                                 if k != "blocks"}
                        raxes = {k: v for k, v in fsdp_axs.items()
                                 if k != "blocks"}
                        full = jax.tree.map(_gather, rest, rdims, raxes)
                        full["blocks"] = shards["blocks"]
                        with jax.named_scope("repro:compute"):
                            return loss_fn(full, mb, shard, prefetch=hook,
                                           moe_dispatch=moe_hook)
                    full = jax.tree.map(_gather, shards, gdims, fsdp_axs)
                    with jax.named_scope("repro:compute"):
                        return loss_fn(full, mb, shard,
                                       moe_dispatch=moe_hook)
                return jax.value_and_grad(sharded_loss, has_aux=True)(params)

            # microbatches accumulate per-device; the (locality-aware) DP
            # sync below runs ONCE on the accumulated grads.
            (_, metrics), grads = _accumulated(one, batch)

            # sync, by per-leaf FSDP geometry:
            #   ('pod','data')-sharded: the gather transpose already
            #     reduce-scattered over BOTH tiers — scale to the mean,
            #     zero extra collectives;
            #   EP expert shards: the return-leg all-to-all transpose
            #     already summed every rank's cotangent at the owner —
            #     scale only, same bucket;
            #   'data'-sharded: reduce-scattered intra-pod — finish with
            #     the pod allreduce;
            #   replicated: full locality allreduce over (pod, data).
            leaves, treedef = jax.tree.flatten(grads)
            dims = jax.tree.leaves(fsdp_dims)
            axs = jax.tree.leaves(fsdp_axs)
            eps = jax.tree.leaves(ep_tree)
            idx_done = [i for i, (k, a, e) in enumerate(zip(dims, axs, eps))
                        if e or (k >= 0 and "pod" in a)]
            idx_rs = [i for i, (k, a, e) in enumerate(zip(dims, axs, eps))
                      if not e and k >= 0 and "pod" not in a]
            idx_full = [i for i, (k, e) in enumerate(zip(dims, eps))
                        if not e and k < 0]

            for i in idx_done:
                leaves[i] = leaves[i] / dp_size
            if idx_rs and fsdp:
                sub = bucketed_sync([leaves[i] for i in idx_rs], sync_pod,
                                    bucket_mb=bucket_mb, compress=compress)
                for j, i in enumerate(idx_rs):
                    leaves[i] = sub[j]
            if idx_full:
                sub = bucketed_sync([leaves[i] for i in idx_full], sync_full,
                                    bucket_mb=bucket_mb, compress=compress)
                for j, i in enumerate(idx_full):
                    leaves[i] = sub[j]
            grads = jax.tree.unflatten(treedef, leaves)
            metrics = jax.tree.map(
                lambda t: jax.lax.psum(t, dp) / dp_size, metrics)
            return grads, metrics

        from repro import _jax_compat
        non_dp = set(mesh.axis_names) - set(dp)
        if _jax_compat.LEGACY_PARTIAL_AUTO and non_dp:
            # Legacy XLA cannot partition manual-axis collectives
            # (ppermute/axis_index/psum) inside a *partially* manual
            # computation — it RET_CHECKs on the manual-subgroup shardings.
            # Split paper mode into two regions: fwd/bwd in the partial-auto
            # shard_map (no collectives; per-shard grads leave stacked on a
            # fresh leading dp axis), then the locality-aware sync in a
            # FULLY manual shard_map over every mesh axis, where the
            # ppermute schedules partition fine. One extra device-local
            # reshape per leaf; identical numerics and collective schedule.
            # FSDP degrades to ZeRO-1 semantics here: the in-body Bruck
            # param gather is also a manual-axis collective, so GSPMD
            # gathers at the jit boundary instead (in_specs P() below) and
            # the step's final with_sharding_constraint re-scatters. The
            # prefetch pipeline needs the in-body gather, so it degrades
            # with it (reflected in StepArtifacts.prefetch_depth = 0).
            if resolved_depth:
                _warn(f"prefetch_depth={resolved_depth} requested but the "
                      f"legacy partial-auto split cannot run the in-scan "
                      f"gather: degrading to eager",
                      requested_depth=resolved_depth, legacy=True)
            resolved_depth, prefetch_source = 0, "n/a"
            nogather_dims = jax.tree.map(lambda _: -1, fsdp_dims)

            def _strip_dp(sp: P) -> P:
                # drop every DP axis ('data' AND 'pod' of the composite
                # FSDP entries) — the sync shard_map re-stacks the grads on
                # a fresh leading dp axis, so an inner pod/data entry would
                # name a manual axis twice.
                ent = []
                for s in sp:
                    names = (s,) if isinstance(s, str) else tuple(s or ())
                    names = tuple(n for n in names if n not in dp)
                    ent.append(names[0] if len(names) == 1
                               else (names or None))
                return P(*ent)

            sync_pspecs = jax.tree.map(_strip_dp, pspecs,
                                       is_leaf=lambda x: isinstance(x, P))

            def compute_body(params, batch):
                shard = make_shard_fn(mesh, manual_dp=True, seq_shard=seq_shard)

                def one(mb):
                    def sharded_loss(shards):
                        full = jax.tree.map(_gather, shards, nogather_dims)
                        return loss_fn(full, mb, shard)
                    return jax.value_and_grad(sharded_loss, has_aux=True)(params)

                (_, metrics), grads = _accumulated(one, batch)
                stack = lambda t: t[None]
                return jax.tree.map(stack, grads), jax.tree.map(stack, metrics)

            def sync_body(grads, metrics):
                grads = jax.tree.map(lambda t: t[0], grads)
                leaves, treedef = jax.tree.flatten(grads)
                leaves = bucketed_sync(leaves, sync_full,
                                       bucket_mb=bucket_mb, compress=compress)
                grads = jax.tree.unflatten(treedef, leaves)
                metrics = jax.tree.map(
                    lambda t: jax.lax.psum(t[0], dp) / dp_size, metrics)
                return grads, metrics

            compute = jax.shard_map(
                compute_body, mesh=mesh,
                in_specs=(P(), {k: b_specs[k] for k in b_abstract}),
                out_specs=(P(dp), P(dp)),
                axis_names=set(dp), check_vma=False)
            sync_in = jax.tree.map(lambda sp: P(dp, *tuple(sp)), sync_pspecs,
                                   is_leaf=lambda x: isinstance(x, P))
            sync = jax.shard_map(
                sync_body, mesh=mesh, in_specs=(sync_in, P(dp)),
                out_specs=(sync_pspecs, P()), check_vma=False)

            def grads_of(params, batch):
                return sync(*compute(params, batch))
        else:
            in_specs = (param_in_specs if (fsdp or ep_on) else P(),
                        {k: b_specs[k] for k in b_abstract})
            out_specs = ((param_in_specs if (fsdp or ep_on) else P()), P())
            grads_of = jax.shard_map(
                body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                axis_names=set(dp), check_vma=False)

    # --- the full step -------------------------------------------------------
    def step(state: TrainState, batch):
        grads, metrics = grads_of(state.params, batch)
        grads = jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s),
            grads, pspecs)
        with jax.named_scope("repro:optimizer"):
            new_state, opt_metrics = optimizer.apply(state, grads)
        return new_state, {**metrics, **opt_metrics}

    jit_kw: dict[str, Any] = dict(
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, None),
    )
    if donate:
        jit_kw["donate_argnums"] = (0,)
    step_fn = jax.jit(step, **jit_kw)
    return StepArtifacts(step_fn=step_fn, state_shardings=state_sh,
                         batch_shardings=batch_sh, abstract_state=a_state,
                         pspecs=pspecs, grad_sync=grad_sync,
                         grad_algorithm=grad_algorithm,
                         grad_sync_source=grad_sync_source,
                         prefetch_depth=resolved_depth,
                         prefetch_source=prefetch_source,
                         fsdp_axes=resolved_fsdp_axes,
                         moe_dispatch=moe_algorithm,
                         moe_transport=moe_transport,
                         moe_dispatch_source=moe_dispatch_source,
                         events=tuple(build_events))


def init_state(cfg, mesh, artifacts: StepArtifacts, seed: int = 0) -> TrainState:
    model = encdec if cfg.family == "audio" else transformer
    init = jax.jit(lambda k: TrainState.create(model.init_params(k, cfg)),
                   out_shardings=artifacts.state_shardings)
    return init(jax.random.PRNGKey(seed))
