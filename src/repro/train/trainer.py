"""Trainer: the fault-tolerant training loop.

Wires together data pipeline, jitted train step, async checkpointing,
straggler monitoring and (simulated) failure recovery:

* every ``ckpt_every`` steps the full TrainState is checkpointed
  asynchronously (atomic commit — see checkpoint/store.py);
* a ``SimulatedFault`` (stand-in for a lost chip/host) triggers recovery:
  restore the newest complete checkpoint and continue — optionally onto a
  *different* mesh (elastic restart; the data pipeline is stateless so the
  batch stream resumes exactly at the restored step);
* step wall-times feed the StepMonitor; straggler events are recorded in
  ``trainer.events`` (a real deployment would export them to the fleet
  controller).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLM
from repro.optim import AdamW
from repro.runtime import FaultInjector, SimulatedFault, StepMonitor
from .step import StepArtifacts, custom_batch_specs, init_state, make_train_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    seq_len: int = 128
    global_batch: int = 8
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 25
    keep_last: int = 3
    log_every: int = 10
    grad_sync: str = "locality"
    fsdp: bool = False
    seq_shard: bool = False
    prefetch_depth: int | str = 0     # FSDP gather lookahead (DESIGN.md §5)
    lr: float = 3e-4
    seed: int = 0
    straggler_k: float = 3.0


class Trainer:
    def __init__(self, model_cfg, mesh, tcfg: TrainerConfig,
                 *, data: SyntheticLM | None = None,
                 fault_injector: FaultInjector | None = None,
                 log: Callable[[str], None] = print):
        self.model_cfg = model_cfg
        self.mesh = mesh
        self.tcfg = tcfg
        self.data = data or SyntheticLM(
            vocab_size=model_cfg.vocab_size, seq_len=tcfg.seq_len,
            global_batch=tcfg.global_batch, seed=tcfg.seed)
        self.faults = fault_injector or FaultInjector()
        self.monitor = StepMonitor(k=tcfg.straggler_k)
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep_last=tcfg.keep_last)
        self.events: list[str] = []
        self.log = log
        self.metrics_history: list[dict] = []
        self._build(mesh)
        self._init_or_restore()

    # ------------------------------------------------------------------
    def _build(self, mesh) -> None:
        self.mesh = mesh
        t = self.tcfg
        self.artifacts = make_train_step(
            self.model_cfg, mesh,
            optimizer=AdamW(lr=t.lr),
            grad_sync=t.grad_sync, fsdp=t.fsdp, seq_shard=t.seq_shard,
            prefetch_depth=t.prefetch_depth,
            shape=custom_batch_specs(self.model_cfg, t.global_batch, t.seq_len))
        if t.grad_sync == "auto":
            self.log(f"[trainer] grad_sync=auto -> "
                     f"{self.artifacts.grad_sync} "
                     f"({self.artifacts.grad_algorithm}, "
                     f"{self.artifacts.grad_sync_source})")
        if t.prefetch_depth == "auto":
            self.log(f"[trainer] prefetch_depth=auto -> "
                     f"{self.artifacts.prefetch_depth} "
                     f"({self.artifacts.prefetch_source})")

    def _init_or_restore(self) -> None:
        restored = self.ckpt.restore(self.artifacts.abstract_state,
                                     shardings=self.artifacts.state_shardings)
        if restored is not None:
            ckpt_step, self.state = restored
            self.step = ckpt_step
            self.events.append(f"restored checkpoint at step {ckpt_step}")
            self.log(f"[trainer] restored step {ckpt_step}")
        else:
            self.state = init_state(self.model_cfg, self.mesh, self.artifacts,
                                    seed=self.tcfg.seed)
            self.step = 0

    def _device_batch(self, batch: dict[str, np.ndarray]):
        out = {}
        for k, v in batch.items():
            sh = self.artifacts.batch_shardings.get(k)
            out[k] = jax.device_put(v, sh)
        return out

    def _augment(self, batch: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
        """Attach stub modality inputs (frames / patch embeddings)."""
        cfg = self.model_cfg
        B = self.tcfg.global_batch
        rng = np.random.Generator(np.random.Philox(key=self.step))
        if cfg.family == "audio":
            batch["frames"] = rng.standard_normal(
                (B, cfg.enc_seq, cfg.d_model), dtype=np.float32).astype("bfloat16")
        if cfg.family == "vlm":
            batch["img_embeds"] = rng.standard_normal(
                (B, cfg.n_img_tokens, cfg.d_model), dtype=np.float32
            ).astype("bfloat16")
        return batch

    # ------------------------------------------------------------------
    def recover(self, mesh=None) -> None:
        """Failure path: rebuild (possibly on a smaller mesh) and restore."""
        self.ckpt.wait()
        self._build(mesh or self.mesh)
        self._init_or_restore()

    def run(self) -> dict[str, Any]:
        t = self.tcfg
        while self.step < t.steps:
            try:
                batch = self._augment(self.data.batch(self.step))
                t0 = time.perf_counter()
                self.state, metrics = self.artifacts.step_fn(
                    self.state, self._device_batch(batch))
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                self.faults.check(self.step)
            except SimulatedFault as e:
                self.events.append(str(e))
                self.log(f"[trainer] {e} -> recovering")
                self.recover()
                continue
            self.events.extend(self.monitor.record(
                dt, algorithm=self.artifacts.grad_algorithm))
            self.step += 1
            m = {k: float(v) for k, v in metrics.items()}
            m["step"], m["dt"] = self.step, dt
            m["grad_algorithm"] = self.artifacts.grad_algorithm
            self.metrics_history.append(m)
            if self.step % t.log_every == 0 or self.step == t.steps:
                self.log(f"[trainer] step {self.step:5d} "
                         f"loss {m['loss']:.4f} gnorm {m['grad_norm']:.3f} "
                         f"({dt*1e3:.0f} ms)")
            if self.step % t.ckpt_every == 0 or self.step == t.steps:
                self.ckpt.save(self.step, self.state)
        self.ckpt.wait()
        return {"final_loss": self.metrics_history[-1]["loss"],
                "steps": self.step, "events": list(self.events)}
