"""Trainer: the fault-tolerant training loop.

Wires together data pipeline, jitted train step, async checkpointing,
straggler monitoring and (simulated) failure recovery:

* every ``ckpt_every`` steps the full TrainState is checkpointed
  asynchronously (atomic commit — see checkpoint/store.py);
* a ``SimulatedFault`` (stand-in for a lost chip/host) triggers recovery:
  restore the newest complete checkpoint and continue — optionally onto a
  *different* mesh (elastic restart; the data pipeline is stateless so the
  batch stream resumes exactly at the restored step);
* step wall-times feed the StepMonitor; events are structured
  ``TelemetryEvent``s in ``trainer.events`` (str subclasses — the legacy
  substring consumers keep working) and are logged the moment they occur,
  never gated behind ``log_every``;
* telemetry (DESIGN.md §8): every phase runs under a tracer span
  (``train/step``, ``train/data``, ``train/compile``, checkpoint spans from
  the store), step metrics publish into the metrics registry, and — when
  ``comm_telemetry`` is on — the step is AOT-compiled so its HLO can be
  scanned once by ``collective_stats``: the resulting ``CommReport``
  (expected inter-pod bytes/msgs per invocation) is stamped into the
  registry under ``"train/step:<mode>"`` and accounted per executed step,
  making
  predicted-vs-actual comm reconciliation exact by construction and any
  unstamped/stale step path a visible mismatch.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLM
from repro.optim import AdamW
from repro.runtime import (FaultInjector, PreemptionSignal, SimulatedFault,
                           StepMonitor)
from repro import telemetry
from repro.telemetry import TelemetryEvent
from .step import StepArtifacts, custom_batch_specs, init_state, make_train_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    seq_len: int = 128
    global_batch: int = 8
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 25
    keep_last: int = 3
    log_every: int = 10
    grad_sync: str = "locality"
    fsdp: bool = False
    seq_shard: bool = False
    prefetch_depth: int | str = 0     # FSDP gather lookahead (DESIGN.md §5)
    moe_dispatch: str = "none"        # locality expert parallelism (§12)
    lr: float = 3e-4
    seed: int = 0
    straggler_k: float = 3.0
    # AOT-compile the step and stamp its CommReport (HLO comm ground truth)
    # into the metrics registry; falls back to the plain jitted step (with a
    # "warning" event) if the AOT path is unavailable on this backend.
    comm_telemetry: bool = True


class Trainer:
    def __init__(self, model_cfg, mesh, tcfg: TrainerConfig,
                 *, data: SyntheticLM | None = None,
                 fault_injector: FaultInjector | None = None,
                 preemption: PreemptionSignal | None = None,
                 log: Callable[[str], None] = print,
                 tracer: telemetry.Tracer | None = None,
                 registry: telemetry.MetricsRegistry | None = None,
                 step_hook: Callable[["Trainer"], None] | None = None):
        self.model_cfg = model_cfg
        self.mesh = mesh
        self.tcfg = tcfg
        self.data = data or SyntheticLM(
            vocab_size=model_cfg.vocab_size, seq_len=tcfg.seq_len,
            global_batch=tcfg.global_batch, seed=tcfg.seed)
        self.faults = fault_injector or FaultInjector()
        self.preemption = preemption
        self.status = "initialized"
        self._ckpt_failures_seen = 0
        self.monitor = StepMonitor(k=tcfg.straggler_k)
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep_last=tcfg.keep_last)
        self.events: list[TelemetryEvent] = []
        self.log = log
        # called at the top of every loop iteration (before the preemption
        # poll) with the trainer itself: the fleet controller's tick — it
        # may flip ``self.preemption`` to request a graceful resize drain
        self.step_hook = step_hook
        self.tracer = tracer or telemetry.get_tracer()
        self.registry = registry or telemetry.get_registry()
        self.metrics_history: list[dict] = []
        self.comm_report: telemetry.CommReport | None = None
        self._build(mesh)
        self._init_or_restore()

    # ------------------------------------------------------------------
    def _event(self, message: str, *, kind: str = "info",
               attrs: dict | None = None, log: bool = True) -> TelemetryEvent:
        """Append one structured event; surface it through ``log``
        immediately (events must never be lost to ``log_every`` skipping a
        step's output)."""
        ev = TelemetryEvent(message, kind=kind, step=getattr(self, "step",
                                                             None),
                            attrs=attrs)
        self.events.append(ev)
        if log:
            self.log(f"[trainer] {ev}")
        return ev

    def _abstract_batch(self) -> dict:
        t = self.tcfg
        return custom_batch_specs(self.model_cfg, t.global_batch, t.seq_len)

    def _build(self, mesh) -> None:
        self.mesh = mesh
        t = self.tcfg
        with self.tracer.span("train/build", mesh=str(mesh.devices.shape)):
            self.artifacts = make_train_step(
                self.model_cfg, mesh,
                optimizer=AdamW(lr=t.lr),
                grad_sync=t.grad_sync, fsdp=t.fsdp, seq_shard=t.seq_shard,
                prefetch_depth=t.prefetch_depth,
                moe_dispatch=t.moe_dispatch,
                shape=self._abstract_batch())
        # degradation warnings raised while building (e.g. a requested
        # prefetch pipeline the config cannot run) surface immediately
        for ev in self.artifacts.events:
            self.events.append(ev)
            self.log(f"[trainer] {ev}")
        # the EWMA describes the topology the old step function ran on —
        # carrying it across an elastic rebuild falsely flags the first
        # steps on a slower mesh (see StepMonitor.reset)
        self.monitor.reset()
        if t.grad_sync == "auto":
            self.log(f"[trainer] grad_sync=auto -> "
                     f"{self.artifacts.grad_sync} "
                     f"({self.artifacts.grad_algorithm}, "
                     f"{self.artifacts.grad_sync_source})")
        if t.prefetch_depth == "auto":
            self.log(f"[trainer] prefetch_depth=auto -> "
                     f"{self.artifacts.prefetch_depth} "
                     f"({self.artifacts.prefetch_source})")
        if t.moe_dispatch != "none":
            self.log(f"[trainer] moe_dispatch={t.moe_dispatch} -> "
                     f"{self.artifacts.moe_dispatch} "
                     f"({self.artifacts.moe_transport or '-'}, "
                     f"{self.artifacts.moe_dispatch_source})")
        self._stamp_comm(t)
        self._stamp_moe_comm(t)

    def _stamp_comm(self, t: TrainerConfig) -> None:
        """AOT-compile the step ONCE ahead of time: the compiled executable
        both serves the train loop (no second jit compile on first step) and
        yields the HLO text the CommReport is derived from. Compile time
        lands in the registry as a tracked gauge."""
        self.comm_report = None
        self._step_callable = self.artifacts.step_fn
        # label qualified by the RESOLVED sync mode so A/B trainers in one
        # process (locality vs xla) keep separate reconciliation ledgers
        self.comm_label = f"train/step:{self.artifacts.grad_sync}"
        if not t.comm_telemetry:
            return
        try:
            with self.tracer.span("train/compile"):
                t0 = time.perf_counter()
                lowered = self.artifacts.step_fn.lower(
                    self.artifacts.abstract_state, self._abstract_batch())
                compiled = lowered.compile()
                compile_s = time.perf_counter() - t0
            hlo = compiled.as_text()
            report = telemetry.comm_report(hlo, self.mesh,
                                           label=self.comm_label)
            self._step_callable = compiled
            self.comm_report = report
            self.registry.gauge("train/compile_time_s").set(compile_s)
            self.registry.attach_comm_report(self.comm_label, report)
            self._event(
                f"comm report: {report.nonlocal_bytes:.0f} inter-pod B / "
                f"{report.nonlocal_msgs:.0f} msgs, {report.dp_bytes:.0f} "
                f"DP-crossing B per step "
                f"(locality_schedule={report.has_locality_schedule})",
                kind="comm", attrs=report.asdict(), log=False)
        except Exception as e:            # pragma: no cover - backend quirks
            self._event(f"comm telemetry unavailable: "
                        f"{type(e).__name__}: {e}", kind="warning")

    def _stamp_moe_comm(self, t: TrainerConfig) -> None:
        """Per-layer attribution ledger for the locality MoE dispatch: lower
        ONE representative dispatch round-trip (collect -> expert shard ->
        return) on the step's abstract shapes, stamp its CommReport under
        ``train/moe_dispatch:<alg>`` and account ``n_moe_layers``
        invocations per executed step — reconcile() stays exact by
        construction while attributing the dispatch's share of the step's
        inter-pod traffic to the MoE exchange specifically."""
        self.moe_comm_label = None
        self._moe_layers = 0
        art = self.artifacts
        if not t.comm_telemetry or art.moe_dispatch == "none":
            return
        try:
            import jax.numpy as jnp
            from jax.sharding import PartitionSpec as P
            from repro.models import moe as moe_mod
            from repro.models.moe import MoeDispatch
            from repro.train.sharding import dp_axes

            cfg = self.model_cfg
            mesh = self.mesh
            dp = dp_axes(mesh)
            outer = ("pod",) if "pod" in mesh.axis_names else ()
            local = tuple(a for a in dp if a != "pod")
            names = list(mesh.axis_names)
            p = 1
            for ax in dp:
                p *= np.asarray(mesh.devices).shape[names.index(ax)]
            hook = MoeDispatch(outer=outer, local=local,
                               algorithm=art.moe_dispatch,
                               transport=art.moe_transport, p=p)
            E, d = cfg.n_experts, cfg.d_model
            dff = cfg.d_expert or cfg.d_ff
            S = t.seq_len
            C_cap = moe_mod.capacity(cfg, S)
            dt = jnp.dtype(cfg.dtype)
            pdt = jnp.dtype(cfg.param_dtype)

            def body(params, x_pad, tok_idx):
                return moe_mod._ep_apply(params, x_pad, tok_idx, cfg, hook,
                                         C_cap)

            fn = jax.shard_map(
                body, mesh=mesh,
                in_specs=({k: P(dp, None, None)
                           for k in ("gate", "up", "down")},
                          P(dp, None, None), P(dp, None)),
                out_specs=P(dp, None, None),
                axis_names=set(dp), check_vma=False)
            B = t.global_batch
            a_params = {
                "gate": jax.ShapeDtypeStruct((E, d, dff), pdt),
                "up": jax.ShapeDtypeStruct((E, d, dff), pdt),
                "down": jax.ShapeDtypeStruct((E, dff, d), pdt),
            }
            a_x = jax.ShapeDtypeStruct((B, S + 1, d), dt)
            a_idx = jax.ShapeDtypeStruct((B, E * C_cap), jnp.int32)
            hlo = jax.jit(fn).lower(a_params, a_x, a_idx).compile().as_text()
            label = f"train/moe_dispatch:{art.moe_dispatch}"
            report = telemetry.comm_report(hlo, mesh, label=label)
            self.registry.attach_comm_report(label, report)
            self.moe_comm_label = label
            self._moe_layers = sum(1 for s in cfg.layer_plan()
                                   if s.mlp == "moe")
            self._event(
                f"moe dispatch comm ({art.moe_dispatch}/"
                f"{art.moe_transport}): {report.nonlocal_bytes:.0f} "
                f"inter-pod B / {report.nonlocal_msgs:.0f} msgs per layer "
                f"x {self._moe_layers} layers/step",
                kind="comm", attrs=report.asdict(), log=False)
        except Exception as e:            # pragma: no cover - backend quirks
            self._event(f"moe dispatch telemetry unavailable: "
                        f"{type(e).__name__}: {e}", kind="warning")

    def _init_or_restore(self, step: int | None = None) -> None:
        with self.tracer.span("train/restore"):
            if step is None:
                restored = self.ckpt.restore(
                    self.artifacts.abstract_state,
                    shardings=self.artifacts.state_shardings)
            else:
                from repro.checkpoint import restore_checkpoint
                restored = restore_checkpoint(
                    self.tcfg.ckpt_dir, self.artifacts.abstract_state,
                    step=step, shardings=self.artifacts.state_shardings)
        if restored is not None:
            ckpt_step, self.state = restored
            self.step = ckpt_step
            self._event(f"restored checkpoint at step {ckpt_step}",
                        kind="restore", attrs={"ckpt_step": ckpt_step},
                        log=False)
            self.log(f"[trainer] restored step {ckpt_step}")
        else:
            self.state = init_state(self.model_cfg, self.mesh, self.artifacts,
                                    seed=self.tcfg.seed)
            self.step = 0

    def _device_batch(self, batch: dict[str, np.ndarray]):
        out = {}
        for k, v in batch.items():
            sh = self.artifacts.batch_shardings.get(k)
            out[k] = jax.device_put(v, sh)
        return out

    def _augment(self, batch: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
        """Attach stub modality inputs (frames / patch embeddings)."""
        cfg = self.model_cfg
        B = self.tcfg.global_batch
        rng = np.random.Generator(np.random.Philox(key=self.step))
        if cfg.family == "audio":
            batch["frames"] = rng.standard_normal(
                (B, cfg.enc_seq, cfg.d_model), dtype=np.float32).astype("bfloat16")
        if cfg.family == "vlm":
            batch["img_embeds"] = rng.standard_normal(
                (B, cfg.n_img_tokens, cfg.d_model), dtype=np.float32
            ).astype("bfloat16")
        return batch

    # ------------------------------------------------------------------
    def recover(self, mesh=None) -> None:
        """Failure path: rebuild (possibly on a smaller mesh) and restore."""
        self.ckpt.wait()
        self._build(mesh or self.mesh)
        self._init_or_restore()

    def fit(self, *, resume: str | int = "auto") -> dict[str, Any]:
        """Elastic entry point: train to ``tcfg.steps``, resuming per
        ``resume`` — ``"auto"`` continues from the committed LATEST step
        (the constructor already restored it; this is the restart-loop
        default), ``"none"`` reinitializes from scratch, an int restores
        that exact step (rollback)."""
        if resume == "none":
            if self.step:
                self._event("resume=none: reinitializing from scratch",
                            kind="restore")
            self.state = init_state(self.model_cfg, self.mesh,
                                    self.artifacts, seed=self.tcfg.seed)
            self.step = 0
        elif isinstance(resume, int) and not isinstance(resume, bool):
            self._init_or_restore(step=resume)
            if self.step != resume:
                from repro.checkpoint import CheckpointError
                raise CheckpointError(f"no checkpoint at step {resume} "
                                      f"under {self.tcfg.ckpt_dir}")
        elif resume != "auto":
            raise ValueError(f"resume must be 'auto', 'none' or an int, "
                             f"got {resume!r}")
        return self.run()

    def _check_ckpt_health(self) -> None:
        """Surface writer failures as events the moment they happen — the
        old manager deferred them into the next save()/wait() call."""
        h = self.ckpt.health
        if h.failures > self._ckpt_failures_seen:
            self._ckpt_failures_seen = h.failures
            self._event(f"checkpoint writer unhealthy ({h.state}): "
                        f"{h.last_error}", kind="warning",
                        attrs={"state": h.state, "failures": h.failures,
                               "retries": h.retries})

    def _preempt(self) -> bool:
        if self.preemption is None or not self.preemption.should_stop(
                self.step):
            return False
        # final blocking save + clean drain: restart resumes exactly here
        self.ckpt.save(self.step, self.state, blocking=True)
        self.registry.count("train/preemptions")
        self._event(f"preempted: drained after blocking save at step "
                    f"{self.step}", kind="preemption",
                    attrs={"step": self.step})
        self.status = "preempted"
        return True

    def run(self) -> dict[str, Any]:
        t = self.tcfg
        reg = self.registry
        self.status = "running"
        while self.step < t.steps:
            if self.step_hook is not None:
                self.step_hook(self)
            if self._preempt():
                break
            try:
                with self.tracer.span("train/step", step=self.step):
                    with self.tracer.span("train/data"):
                        batch = self._augment(self.data.batch(self.step))
                        device_batch = self._device_batch(batch)
                    t0 = time.perf_counter()
                    with self.tracer.span("train/step_fn"):
                        self.state, metrics = self._step_callable(
                            self.state, device_batch)
                        jax.block_until_ready(metrics["loss"])
                    # injected straggler: the sleep lands INSIDE the timed
                    # region, scaled past k×ewma so the monitor must flag it
                    slept = self.faults.delay(
                        self.step, floor_s=2 * t.straggler_k *
                        self.monitor.ewma)
                    dt = time.perf_counter() - t0
                self.faults.check(self.step)
            except SimulatedFault as e:
                self._event(str(e), kind="fault", log=False)
                reg.count("train/faults")
                self.log(f"[trainer] {e} -> recovering")
                self.recover()
                continue
            if slept:
                self._event(f"injected straggler: slept {slept:.3f}s",
                            kind="fault", attrs={"slept": slept}, log=False)
            for ev in self.monitor.record(
                    dt, algorithm=self.artifacts.grad_algorithm):
                # surfaced immediately — a straggler between log_every
                # boundaries used to vanish into the event list silently
                self.events.append(ev)
                self.log(f"[trainer] {ev}")
                if ev.kind == "straggler":
                    reg.count("train/stragglers")
            self.step += 1
            reg.count("train/steps")
            reg.observe("train/step_time_s", dt)
            reg.gauge("train/tokens_per_s").set(
                t.global_batch * t.seq_len / dt if dt else 0.0)
            if self.comm_report is not None:
                reg.record_comm(self.comm_label)
            if self.moe_comm_label is not None and self._moe_layers:
                reg.record_comm(self.moe_comm_label, self._moe_layers)
            m = {k: float(v) for k, v in metrics.items()}
            m["step"], m["dt"] = self.step, dt
            m["grad_algorithm"] = self.artifacts.grad_algorithm
            self.metrics_history.append(m)
            reg.gauge("train/loss").set(m["loss"])
            if self.step % t.log_every == 0 or self.step == t.steps:
                self.log(f"[trainer] step {self.step:5d} "
                         f"loss {m['loss']:.4f} gnorm {m['grad_norm']:.3f} "
                         f"({dt*1e3:.0f} ms)")
            if self.step % t.ckpt_every == 0 or self.step == t.steps:
                self.ckpt.save(self.step, self.state)
            self._check_ckpt_health()
        self.ckpt.wait()
        if self.status != "preempted":
            self.status = "complete"
        return {"final_loss": (self.metrics_history[-1]["loss"]
                               if self.metrics_history else None),
                "steps": self.step, "status": self.status,
                "events": list(self.events)}
