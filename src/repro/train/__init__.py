from .sharding import make_shard_fn, param_specs, batch_spec
from .step import make_train_step
from .trainer import Trainer, TrainerConfig
