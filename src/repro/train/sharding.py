"""Sharding rules: parameter PartitionSpecs + activation constraint hooks.

Parameters are sharded 2-D (Megatron-style TP over ``model`` + optional
FSDP/ZeRO over the DP axes); a dim is sharded only if divisible by the axis
size (otherwise GSPMD padding would silently waste memory — we prefer
explicit replication and record it). On a multi-pod mesh the FSDP dim
shards over the COMPOSITE ``('pod', 'data')`` axes (pod-major, matching the
region-major rank of ``core/topology.RegionMap``) whenever the dim is
divisible by the full DP size — so the ZeRO-3 gather genuinely crosses the
DCN boundary and the locality-aware Bruck schedule has non-local rounds to
optimize. This holds for ANY pod count q (3, 5, 6 — Algorithm 2's
allgatherv adaptation, DESIGN.md §7): the divisibility test is against
q·p_data, so when q ∤ dim (but p_data | dim) the leaf falls back to
intra-pod 'data' sharding (pods hold replicas, the grad sync adds a pod
allreduce) — per-leaf geometry, never an all-or-nothing layout switch.
Activation hooks are the ``shard`` callbacks threaded through the model
zoo; in paper-mode (inside the ``shard_map`` over DP axes) the DP axes are
manual and must be dropped from every constraint —
``make_shard_fn(..., manual_dp=True)`` does exactly that.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

DP_AXES = ("pod", "data")       # batch axes (outer = pod boundary)
MODEL_AXIS = "model"


def dp_axes(mesh) -> tuple[str, ...]:
    """The DP axes actually present on this mesh ('pod' only if multi-pod)."""
    return tuple(a for a in DP_AXES if a in mesh.axis_names)


def normalize_axes(axes: str | tuple[str, ...]) -> tuple[str, ...]:
    """A bare axis-name string means ONE axis, not its characters —
    ``"data"`` → ``("data",)`` (iterating the raw string would silently
    match no axis and disable the feature it configures)."""
    return (axes,) if isinstance(axes, str) else tuple(axes)


def _axsize(mesh, name) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.devices.shape[list(mesh.axis_names).index(name)]


def _div(dim: int, n: int) -> bool:
    return n > 1 and dim % n == 0


def moe_expert_leaf(path: tuple[str, ...], shape: tuple[int, ...]) -> bool:
    """True for routed-expert weight leaves — the (E, d_in, d_out) stacks
    locality expert parallelism shards over the DP axes (shared-expert and
    dense-MLP projections are 2-D and never match)."""
    return path[-1] in ("gate", "up", "down") and len(shape) == 3 \
        and "shared" not in path


def _leaf_spec(path: tuple[str, ...], shape: tuple[int, ...], mesh,
               fs_axes: tuple[str, ...],
               ep_axes: tuple[str, ...] = ()) -> P:
    """Heuristic spec from the leaf's key name; leading stacked dims are
    handled by the caller. ``fs_axes`` are the DP axes the FSDP dim may
    shard over (empty = no FSDP); ``ep_axes`` the DP axes routed-expert
    E dims shard over (empty = replicated/TP experts)."""
    name = path[-1]
    m = _axsize(mesh, MODEL_AXIS)
    d = _axsize(mesh, "data")
    full = math.prod(_axsize(mesh, a) for a in fs_axes) if fs_axes else 1
    ep = math.prod(_axsize(mesh, a) for a in ep_axes) if ep_axes else 1

    def fdim(dim):
        # FSDP: prefer the full composite ('pod','data') span; dims only
        # divisible by the 'data' size shard intra-pod (pods replicate).
        if not fs_axes:
            return None
        if len(fs_axes) > 1 and _div(dim, full):
            return tuple(fs_axes)
        return "data" if ("data" in fs_axes and _div(dim, d)) else None

    def mdim(dim):
        return MODEL_AXIS if _div(dim, m) else None

    if len(shape) == 0:
        return P()
    if name in ("scale", "bias", "A_log", "D", "dt_bias", "conv_b"):
        return P(*([None] * len(shape)))
    if name == "router":                       # (d, E) small, replicated
        return P(None, None)
    if name in ("embed", "head"):
        v_dim, d_dim = (0, 1) if name == "embed" else (1, 0)
        spec = [None, None]
        spec[v_dim] = mdim(shape[v_dim])
        spec[d_dim] = fdim(shape[d_dim])
        return P(*spec)
    if name in ("wq", "wk", "wv", "in_proj"):  # col-parallel: (d, out)
        return P(fdim(shape[0]), mdim(shape[1]))
    if name in ("wo", "out_proj"):             # row-parallel: (in, d)
        return P(mdim(shape[0]), fdim(shape[1]))
    if name in ("gate", "up"):
        if len(shape) == 3:                    # MoE experts (E, d, f): EP
            if moe_expert_leaf(path, shape) and _div(shape[0], ep):
                return P(tuple(ep_axes), None, None)
            return P(mdim(shape[0]), fdim(shape[1]), None)
        return P(fdim(shape[0]), mdim(shape[1]))
    if name == "down":
        if len(shape) == 3:                    # (E, f, d)
            if moe_expert_leaf(path, shape) and _div(shape[0], ep):
                return P(tuple(ep_axes), None, None)
            return P(mdim(shape[0]), None, fdim(shape[2]))
        return P(mdim(shape[0]), fdim(shape[1]))
    if name == "conv_w":                       # (W, Ch) depthwise
        return P(None, mdim(shape[1]))
    # fallback: shard the largest divisible dim over model
    best = max(range(len(shape)), key=lambda i: shape[i])
    spec = [None] * len(shape)
    if _div(shape[best], m):
        spec[best] = MODEL_AXIS
    return P(*spec)


def param_specs(abstract_params, mesh, *, fsdp: bool = False,
                fsdp_axes: str | tuple[str, ...] = "auto",
                moe_ep: bool = False):
    """PartitionSpec pytree for a params tree (use jax.eval_shape output).

    fsdp_axes: DP axes the FSDP dim shards over — "auto" uses every DP axis
    on the mesh (('pod','data') on multi-pod, the locality-aware layout);
    pass ("data",) to force the legacy intra-pod layout (pods replicate
    params; benchmarks use this as the flat baseline).

    moe_ep: shard routed-expert weight E dims over the full DP composite
    (the locality-dispatch layout — each rank owns E/p experts and tokens
    travel, DESIGN.md §12). Only leaves whose E is divisible by the DP size
    take the EP spec; others keep the TP/FSDP layout.
    """
    if not fsdp:
        fs_axes: tuple[str, ...] = ()
    elif fsdp_axes == "auto":
        fs_axes = dp_axes(mesh)
    else:
        fs_axes = tuple(a for a in normalize_axes(fsdp_axes)
                        if a in mesh.axis_names)
    ep_axes = dp_axes(mesh) if moe_ep else ()

    def visit(path, leaf):
        keys = tuple(getattr(p, "key", getattr(p, "idx", None)) for p in path)
        keys = tuple(str(k) for k in keys)
        # stacked scan segments: ("blocks", "slotj", ...) carry a leading
        # reps dim; encdec stacks under enc_layers/dec_layers.
        stacked = any(k in ("blocks",) or k.endswith("_layers") for k in keys)
        spec = _leaf_spec(keys, leaf.shape[1:] if stacked else leaf.shape,
                          mesh, fs_axes, ep_axes)
        return P(None, *spec) if stacked else spec

    return jax.tree_util.tree_map_with_path(visit, abstract_params)


def moe_ep_mask(abstract_params):
    """Per-leaf bool pytree: True for routed-expert weight leaves (the
    leaves ``param_specs(..., moe_ep=True)`` shards over DP and the paper
    mode must NOT gather — their grads arrive complete at the owner)."""
    def visit(path, leaf):
        keys = tuple(str(getattr(p, "key", getattr(p, "idx", None)))
                     for p in path)
        stacked = any(k in ("blocks",) or k.endswith("_layers") for k in keys)
        shape = leaf.shape[1:] if stacked else leaf.shape
        return moe_expert_leaf(keys, shape)
    return jax.tree_util.tree_map_with_path(visit, abstract_params)


def batch_spec() -> dict:
    return {"tokens": P(DP_AXES, None), "labels": P(DP_AXES, None)}


# ---------------------------------------------------------------------------
# FSDP gather geometry (shared by the eager gather and the prefetch pipeline)
# ---------------------------------------------------------------------------
def fsdp_dim(spec: P) -> int:
    """Index of the DP-sharded dim of a leaf spec (-1 = replicated) —
    the dim the ZeRO-3 gather (and its reduce-scatter transpose) runs over.
    Matches both the intra-pod ('data') and the composite ('pod','data')
    layouts (every FSDP entry contains 'data')."""
    for i, s in enumerate(spec):
        names = (s,) if isinstance(s, str) else tuple(s or ())
        if "data" in names:
            return i
    return -1


def fsdp_leaf_axes(spec: P) -> str:
    """Comma-joined DP axes of the leaf's FSDP dim, outer-major
    ("pod,data" / "data" / "" = replicated). A flat string — not a tuple —
    so a whole-tree ``jax.tree.map`` keeps one leaf per parameter."""
    k = fsdp_dim(spec)
    if k < 0:
        return ""
    s = spec[k]
    names = (s,) if isinstance(s, str) else tuple(s or ())
    return ",".join(a for a in DP_AXES if a in names)


def fsdp_param_dims(pspecs):
    """Per-leaf fsdp dim for a whole param-spec pytree."""
    return jax.tree.map(fsdp_dim, pspecs, is_leaf=lambda x: isinstance(x, P))


def fsdp_param_axes(pspecs):
    """Per-leaf comma-joined FSDP axes ("" = replicated) for a spec pytree."""
    return jax.tree.map(fsdp_leaf_axes, pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def gather_outer_local(axes: str) -> tuple[tuple[str, ...], tuple[str, ...]]:
    """(outer, local) split of a comma-joined FSDP axes string: 'pod' is the
    non-local (DCN) tier, everything else stays local (ICI) — the split the
    locality-aware Bruck gather and its reduce-scatter transpose run over."""
    names = tuple(a for a in axes.split(",") if a)
    return (tuple(a for a in names if a == "pod"),
            tuple(a for a in names if a != "pod"))


def block_slice_dims(block_dims):
    """Shift stacked-block fsdp dims to ONE scan slice's coordinates.

    ``param_specs`` prefixes stacked leaves with P(None, ...) for the reps
    dim, so a leaf sharded on dim k of the slice reports k+1 on the stack;
    inside the scan the slice has no leading dim and the gather runs on
    k — this undoes the offset (replicated leaves stay -1).
    """
    return jax.tree.map(lambda k: k - 1 if k >= 1 else -1, block_dims)


# ---------------------------------------------------------------------------
# activation constraint hooks
# ---------------------------------------------------------------------------
_ACT_RULES: dict[str, tuple] = {
    # kind -> dims description; DP marks the batch dim, M the model-sharded dim
    "act":        ("dp", None, None),            # (B, S, d)
    "act_heads":  ("dp", None, "model", None),   # (B, S, H, D)
    "act_ff":     ("dp", None, "model"),         # (B, S, F)
    "moe_act":    ("dp", "model", None, None),   # (B, E, C, d)
    "logits":     ("dp", None, "model"),         # (B, S, V)
}


def make_shard_fn(mesh=None, *, manual_dp: bool = False, seq_shard: bool = False,
                  enable: bool = True):
    """Returns shard(x, kind) applying with_sharding_constraint per rules.

    manual_dp: inside a shard_map manual over DP — drop DP axes (only auto
    'model' axis constraints are legal there).
    seq_shard: sequence-parallel residuals — shard the seq dim of (B,S,d)
    activations over 'model' between blocks (perf knob).
    """
    from repro import _jax_compat
    if manual_dp and _jax_compat.LEGACY_PARTIAL_AUTO:
        # Legacy JAX: any wsc inside a partially-manual shard_map body trips
        # the old SPMD partitioner ("Incompatible manual sharding"). Dropping
        # the hints is safe — XLA replicates the auto ('model') axis within
        # the manual region instead of tiling it.
        enable = False
    if not enable:
        return lambda x, kind: x
    dp = dp_axes(mesh) if mesh is not None else DP_AXES
    m = _axsize(mesh, MODEL_AXIS) if mesh is not None else 1
    dp_size = (math.prod(_axsize(mesh, a) for a in dp)
               if mesh is not None else 1)

    def on_model(dim: int) -> bool:
        return m > 1 and dim % m == 0

    def shard(x, kind):
        rule = _ACT_RULES.get(kind)
        if rule is None or x.ndim != len(rule):
            return x
        spec = []
        for i, r in enumerate(rule):
            if r == "dp":
                # constrain only divisible dims — hinting a batch-1 decode
                # activation onto 8 DP devices makes GSPMD shard the
                # upstream projection matmuls over idle ranks and pay a
                # (pod,data) partial-sum all-reduce to re-replicate at the
                # manual-region boundary (pure noise traffic)
                on_dp = (dp and not manual_dp and mesh is not None
                         and x.shape[i] % max(dp_size, 1) == 0)
                if mesh is None:
                    on_dp = not manual_dp and bool(dp)
                spec.append(dp if on_dp else None)
            elif r == "model":
                spec.append(MODEL_AXIS if on_model(x.shape[i]) else None)
            else:
                spec.append(None)
        if seq_shard and kind == "act":
            spec[1] = MODEL_AXIS if on_model(x.shape[1]) else None
        if all(s is None for s in spec):
            return x
        return jax.lax.with_sharding_constraint(x, P(*spec))

    return shard
