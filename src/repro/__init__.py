"""repro — locality-aware collectives, training and serving stack.

Importing the package installs JAX version-compat fallbacks (see
``repro._jax_compat``) so modules written against the current JAX API run
unchanged on older pinned installs.
"""
from . import _jax_compat

_jax_compat.install()
