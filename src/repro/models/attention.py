"""Grouped-query attention with full / sliding-window / chunked-local variants.

Design notes
------------
* Long sequences never materialize an S×S score tensor: the query axis is
  processed in static chunks (python loop → static HLO slices), and each
  query chunk attends only to the *statically known* valid KV range:
    - causal full:   kv[0 : (i+1)·c]
    - sliding window kv[(i+1)·c - c - w : (i+1)·c]
    - chunked local  kv[floor(i·c / chunk)·chunk : (i+1)·c]
  This keeps compiled FLOPs at the exact triangular count (no masked waste),
  which matters because cost_analysis() of the dry-run is our roofline input.
* Decode attends a single query against the KV cache with position masks.
* GQA: query heads are grouped over KV heads; softmax in fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import apply_rope, dense_init, rmsnorm, softcap

NEG_INF = -2.0 ** 30  # large-negative for bf16-safe masking (cast later)


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def attn_init(rng, cfg) -> dict:
    D = cfg.head_dim_
    ks = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * D, cfg.param_dtype),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * D, cfg.param_dtype),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * D, cfg.param_dtype),
        "wo": dense_init(ks[3], cfg.n_heads * D, cfg.d_model, cfg.param_dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.zeros((D,), cfg.param_dtype)}
        p["k_norm"] = {"scale": jnp.zeros((D,), cfg.param_dtype)}
    return p


def attn_param_count(cfg) -> int:
    D = cfg.head_dim_
    n = 2 * cfg.d_model * cfg.n_heads * D + 2 * cfg.d_model * cfg.n_kv_heads * D
    if cfg.qk_norm:
        n += 2 * D
    return n


# ---------------------------------------------------------------------------
# core scores for one query chunk against a KV slice
# ---------------------------------------------------------------------------

def _chunk_attend(q, k, v, q_pos, k_pos, *, causal, window, chunk, cap):
    """q: (B,Cq,H,D) k/v: (B,L,KV,D); positions are (Cq,)/(L,) int arrays.

    Returns (B,Cq,H,D). Masks: causal (q>=k), window (q-k < w), chunked-local
    (same chunk). fp32 softmax.
    """
    B, Cq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Cq, KV, G, D)
    s = jnp.einsum("bikgd,bjkd->bkgij", qg, k).astype(jnp.float32)
    s = s * (D ** -0.5)
    if cap:
        s = cap * jnp.tanh(s / cap)
    mask = jnp.ones((Cq, k.shape[1]), bool)
    dq = q_pos[:, None]
    dk = k_pos[None, :]
    if causal:
        mask &= dq >= dk
    if window:
        mask &= (dq - dk) < window
    if chunk:
        mask &= (dq // chunk) == (dk // chunk)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgij,bjkd->bikgd", p.astype(v.dtype), v)
    return o.reshape(B, Cq, H, D)


def multihead_attention(q, k, v, *, causal=True, window=0, chunk=0, cap=0.0,
                        q_chunk=512, q_offset=0):
    """Full-sequence attention, q-chunked with static valid-KV slices.

    q: (B,S,H,D), k/v: (B,T,KV,D). ``q_offset`` is the absolute position of
    q[0] relative to k[0] (0 for self-attention over the same sequence).
    """
    B, S, H, D = q.shape
    T = k.shape[1]
    c = min(q_chunk, S)
    if S % c != 0:
        c = S  # fall back to a single chunk for odd lengths (smoke tests)
    outs = []
    for i in range(S // c):
        q_i = q[:, i * c:(i + 1) * c]
        q_pos = q_offset + i * c + jnp.arange(c)
        hi = min(T, q_offset + (i + 1) * c) if causal else T
        lo = 0
        if window:
            lo = max(0, q_offset + i * c - (window - 1))
        elif chunk:
            lo = ((q_offset + i * c) // chunk) * chunk
        # align to nice boundaries for static-shape reuse
        k_i = k[:, lo:hi]
        v_i = v[:, lo:hi]
        k_pos = lo + jnp.arange(hi - lo)
        outs.append(_chunk_attend(q_i, k_i, v_i, q_pos, k_pos, causal=causal,
                                  window=window, chunk=chunk, cap=cap))
    return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]


def _mask_bcast(mask):
    """Broadcast a slot mask over (B,KV,G,Lloc) scores.

    Lockstep decode carries a scalar ``pos`` and an (Lloc,) mask; continuous
    batching carries a per-row (B,) ``pos`` and a (B,Lloc) mask.
    """
    return mask[None, None, None] if mask.ndim == 1 else mask[:, None, None, :]


def decode_stats_scores(q, k_cache, pos, *, slot_offset=0, total_len=None,
                        window=0, chunk=0, cap=0.0, ring=False):
    """The cheap prefix of one-token decode attention over a cache slice:
    masked fp32 scores.

    q (B,1,H,D) vs k (B,Lloc,KV,D) holding global slots
    [slot_offset, slot_offset + Lloc) of a ``total_len``-slot cache.
    Returns ``(s, mask)`` with s (B,KV,G,Lloc) already NEG_INF-masked and
    mask (Lloc,) boolean — (B,Lloc) when ``pos`` is a per-row (B,) vector.
    Split out so the serve engine can issue the
    max-allreduce of the running maxima right here — everything after
    (exp / sum / the P·V matmul, :func:`decode_stats_accumulate` or the
    Pallas kernel in ``kernels/decode_stats``) is independent compute the
    collective hides behind (DESIGN.md §5).
    """
    B, _, H, D = q.shape
    L_loc = k_cache.shape[1]
    L_tot = total_len or L_loc
    KV = k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, D)
    s = jnp.einsum("bkgd,bjkd->bkgj", qg, k_cache).astype(jnp.float32)
    s = s * (D ** -0.5)
    if cap:
        s = cap * jnp.tanh(s / cap)
    p_ = pos[:, None] if jnp.ndim(pos) == 1 else pos  # (B,1) rows broadcast
    j = slot_offset + jnp.arange(L_loc)
    t_j = (p_ - ((p_ - j) % L_tot)) if ring else j    # token held by slot j
    mask = t_j >= 0 if ring else (j <= p_)
    if window:
        mask &= (p_ - t_j) < window
    if chunk:
        mask &= (t_j // chunk) == (p_ // chunk)
    s = jnp.where(_mask_bcast(mask), s, NEG_INF)
    return s, mask


def decode_stats_accumulate(s, mask, m, v_cache):
    """The heavy suffix: exp(s − m), row sums, and the P·V contraction.

    s/mask from :func:`decode_stats_scores`, m (B,KV,G) the slice's running
    max. Returns fp32 ``(o, l)`` reshaped to (B,1,H,D) / (B,1,H).
    """
    B, KV, G, _ = s.shape
    H = KV * G
    D = v_cache.shape[-1]
    p = jnp.exp(s - m[..., None])
    p = jnp.where(_mask_bcast(mask), p, 0.0)          # m=NEG_INF ⇒ exp(0)=1
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgj,bjkd->bkgd", p.astype(v_cache.dtype),
                   v_cache).astype(jnp.float32)
    return o.reshape(B, 1, H, D), l.reshape(B, 1, H)


def decode_partial_stats(q, k_cache, v_cache, pos, *, slot_offset=0,
                         total_len=None, window=0, chunk=0, cap=0.0,
                         ring=False):
    """Flash-style partial stats of one-token decode attention over a cache
    *slice*: q (B,1,H,D) vs k/v (B,Lloc,KV,D) holding global slots
    [slot_offset, slot_offset + Lloc) of a ``total_len``-slot cache.

    Returns fp32 ``(o, m, l)`` with o (B,1,H,D) the UNNORMALIZED accumulator
    Σ_j exp(s_j − m)·v_j, m (B,1,H) the running max over this slice, and
    l (B,1,H) = Σ_j exp(s_j − m). A fully-masked slice yields (0, NEG_INF, 0)
    — the combine's global rescale exp(m − M) zeroes its contribution. This
    is the per-shard body the serve engine wraps in ``shard_map`` for the
    sequence-parallel locality cache-combine; the single-device decode path
    below finalizes the same stats, so the two paths cannot drift.

    Composed of :func:`decode_stats_scores` + :func:`decode_stats_accumulate`
    — the exact op sequence the engine's overlapped region traces, so the
    split path is bitwise-identical to this one.
    """
    B, _, H, _ = q.shape
    s, mask = decode_stats_scores(q, k_cache, pos, slot_offset=slot_offset,
                                  total_len=total_len, window=window,
                                  chunk=chunk, cap=cap, ring=ring)
    m = jnp.max(s, axis=-1)                           # (B,KV,G)
    o, l = decode_stats_accumulate(s, mask, m, v_cache)
    return o, m.reshape(B, 1, H), l


def decode_attention(q, k_cache, v_cache, pos, *, window=0, chunk=0, cap=0.0,
                     ring=False):
    """One-token decode: q (B,1,H,D) vs cache (B,L,KV,D); ``pos`` = absolute
    index of the query token (its own KV already written).

    ring=True: the cache is a ring buffer of L slots (L = window or chunk
    size), slot j holding token t_j = pos − ((pos − j) mod L). Windowed and
    chunked-local layers never need more history than that — a long_500k
    windowed cache shrinks from 524288 to 4096 slots (§Perf iteration 7).
    """
    o, _, l = decode_partial_stats(q, k_cache, v_cache, pos, window=window,
                                   chunk=chunk, cap=cap, ring=ring)
    # slot ``pos`` is always attendable, so l > 0 on the full cache
    return (o / l[..., None]).astype(v_cache.dtype)


# ---------------------------------------------------------------------------
# public layer apply
# ---------------------------------------------------------------------------

def attention(params, x, cfg, spec, *, positions=None, cache=None,
              cross_kv=None, causal=True, shard=None, decode_combine=None):
    """Self- (or cross-) attention layer.

    Modes:
      cache None, cross_kv None : full-sequence self-attention; returns
                                  (out, (k, v)) so prefill can build a cache.
      cache (k,v,pos)           : single-token decode; returns (out, new_cache).
      cross_kv (k,v)            : cross-attention (whisper decoder); no mask.

    decode_combine: optional serve-layer hook replacing the decode cache
    write + attention with a distributed implementation (the locality-aware
    sequence-parallel combine). Called as
    ``decode_combine(q, k_new, v_new, k_cache, v_cache, pos, meta)`` with
    meta = {window, chunk, cap, ring}; returns ``(o, k_cache', v_cache')``
    or None to fall back to the plain (GSPMD) path for this layer.
    """
    shard = shard or (lambda t, _k: t)
    dt = cfg.dtype
    B, S, _ = x.shape
    D = cfg.head_dim_
    H, KV = cfg.n_heads, cfg.n_kv_heads
    window = cfg.window if spec.attn == "window" else 0
    chunk = cfg.chunk if spec.attn == "chunked" else 0

    q = (x @ params["wq"].astype(dt)).reshape(B, S, H, D)
    q = shard(q, "act_heads")
    if cross_kv is None:
        k = (x @ params["wk"].astype(dt)).reshape(B, S, KV, D)
        v = (x @ params["wv"].astype(dt)).reshape(B, S, KV, D)
    else:
        k, v = cross_kv

    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        if cross_kv is None:
            k = rmsnorm(params["k_norm"], k, cfg.norm_eps)

    if positions is None:
        positions = jnp.arange(S)[None]
    if spec.rope and cross_kv is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    if cache is not None:
        # decode: write this token's KV (ring slot pos % L for ring caches)
        # then attend to the cache.
        k_cache, v_cache, pos = cache["k"], cache["v"], cache["pos"]
        L_c = k_cache.shape[1]
        ring = bool(cache.get("ring", False))
        res = None
        if decode_combine is not None:
            res = decode_combine(q, k, v, k_cache, v_cache, pos,
                                 {"window": window, "chunk": chunk,
                                  "cap": cfg.attn_softcap, "ring": ring})
        if res is None:
            slot = pos % L_c if ring else pos
            if jnp.ndim(pos) == 1:
                # continuous batching: per-row write positions (B,)
                row_dus = jax.vmap(
                    lambda c, u, s: jax.lax.dynamic_update_slice(
                        c, u, (s, 0, 0)))
                k_cache = row_dus(k_cache, k.astype(k_cache.dtype), slot)
                v_cache = row_dus(v_cache, v.astype(v_cache.dtype), slot)
            else:
                k_cache = jax.lax.dynamic_update_slice(
                    k_cache, k.astype(k_cache.dtype), (0, slot, 0, 0))
                v_cache = jax.lax.dynamic_update_slice(
                    v_cache, v.astype(v_cache.dtype), (0, slot, 0, 0))
            o = decode_attention(q, k_cache, v_cache, pos, window=window,
                                 chunk=chunk, cap=cfg.attn_softcap, ring=ring)
        else:
            o, k_cache, v_cache = res
        new_cache = {"k": k_cache, "v": v_cache, "pos": pos}
        out = o.reshape(B, S, H * D) @ params["wo"].astype(dt)
        return out, new_cache

    if cross_kv is not None:
        o = multihead_attention(q, k, v, causal=False, cap=cfg.attn_softcap)
        out = o.reshape(B, S, H * D) @ params["wo"].astype(dt)
        return out, None

    o = multihead_attention(q, k, v, causal=causal, window=window, chunk=chunk,
                            cap=cfg.attn_softcap)
    o = shard(o, "act_heads")
    out = o.reshape(B, S, H * D) @ params["wo"].astype(dt)
    return out, (k, v)
