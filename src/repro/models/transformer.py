"""Decoder-only stack builder: dense / MoE / SSM / hybrid / VLM backbones.

The layer plan (configs.base.ModelConfig.layer_plan) is compiled into a
*periodic super-block scan*: the smallest repeating pattern of layers (e.g.
gemma2's [window, full], llama4's [chunked ×3, global-NoPE], zamba2's
[mamba ×6, shared-attn]) becomes one ``lax.scan`` body with per-slot stacked
parameters; any non-periodic remainder is applied unrolled. This bounds HLO
size at 512 devices while supporting weight sharing (zamba2's shared
attention block closes over a single parameter set inside the scan body).

Modes: 'train' (full-seq logits), 'prefill' (build KV/SSM caches, last-token
logits), 'decode' (one token against caches).
"""
from __future__ import annotations

import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

from .attention import attention, attn_init, attn_param_count
from .layers import (embed_init, mlp_apply, mlp_init, mlp_param_count,
                     norm_apply, norm_init, softcap)
from .moe import moe_apply, moe_init, moe_param_count
from .ssm import mamba_apply, mamba_cache_specs, mamba_init, mamba_param_count

Shard = Callable[[jax.Array, str], jax.Array]
_noop: Shard = lambda t, _k: t


# ---------------------------------------------------------------------------
# layer plan → (period, reps, remainder)
# ---------------------------------------------------------------------------

def find_period(plan) -> tuple[int, int, int]:
    keys = [s.key() for s in plan]
    n = len(keys)
    for pi in range(1, n + 1):
        reps = n // pi
        if reps < 1:
            break
        if all(keys[i] == keys[i % pi] for i in range(reps * pi)):
            return pi, reps, n - reps * pi
    return n, 1, 0


# ---------------------------------------------------------------------------
# per-layer init / apply
# ---------------------------------------------------------------------------

def _layer_init(rng, cfg, spec) -> dict:
    d = cfg.d_model
    if spec.mixer == "mamba2":
        k1, k2 = jax.random.split(rng)
        return {"ln": norm_init(cfg, d), "mamba": mamba_init(k2, cfg)}
    if spec.mixer == "shared_attn":
        return {}                      # params live once at the top level
    ks = jax.random.split(rng, 2)
    p = {"ln1": norm_init(cfg, d), "attn": attn_init(ks[0], cfg),
         "ln2": norm_init(cfg, d)}
    if spec.mlp == "moe":
        p["moe"] = moe_init(ks[1], cfg)
    elif spec.mlp == "dense":
        p["mlp"] = mlp_init(ks[1], d, cfg.d_ff, cfg.mlp_act, cfg.param_dtype)
    if cfg.sandwich_norm:
        p["post_ln1"] = norm_init(cfg, d)
        p["post_ln2"] = norm_init(cfg, d)
    return p


def ring_cache_len(cfg, spec) -> int | None:
    """Ring-buffer cache size for windowed/chunked-local attention layers —
    they never attend past the last window/chunk tokens, so the decode cache
    is a W-slot ring instead of the full context (§Perf iteration 7)."""
    if spec.mixer not in ("attn", "shared_attn"):
        return None
    if spec.attn == "window" and cfg.window:
        return cfg.window
    if spec.attn == "chunked" and cfg.chunk:
        return cfg.chunk
    return None


def _shared_attn_init(rng, cfg) -> dict:
    """zamba2's weight-shared attention+MLP block."""
    ks = jax.random.split(rng, 2)
    return {"ln1": norm_init(cfg, cfg.d_model), "attn": attn_init(ks[0], cfg),
            "ln2": norm_init(cfg, cfg.d_model),
            "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_act,
                            cfg.param_dtype)}


def _apply_layer(lp, x, cfg, spec, *, positions, cache, build_cache,
                 cache_len, pos, shard: Shard, decode_combine=None,
                 moe_dispatch=None):
    """Returns (x, aux, new_cache)."""
    aux = jnp.zeros((), jnp.float32)
    if spec.mixer == "mamba2":
        h = norm_apply(cfg, lp["ln"], x)
        if cache is not None:
            y, nc = mamba_apply(lp["mamba"], h, cfg, cache=cache, shard=shard)
        elif build_cache:
            y, nc = mamba_apply(lp["mamba"], h, cfg, cache={}, shard=shard)
        else:
            y, nc = mamba_apply(lp["mamba"], h, cfg, shard=shard)
        return shard(x + y, "act"), aux, nc

    ring_len = ring_cache_len(cfg, spec)
    h = norm_apply(cfg, lp["ln1"], x)
    if cache is not None:
        attn_cache = {"k": cache["k"], "v": cache["v"], "pos": pos,
                      "ring": ring_len is not None}
        a, nc_full = attention(lp["attn"], h, cfg, spec, positions=positions,
                               cache=attn_cache, shard=shard,
                               decode_combine=decode_combine)
        nc = {"k": nc_full["k"], "v": nc_full["v"]}
    else:
        a, kv = attention(lp["attn"], h, cfg, spec, positions=positions,
                          shard=shard)
        nc = None
        if build_cache:
            k, v = kv
            B, S = k.shape[0], k.shape[1]
            L = cache_len or S
            if ring_len is not None:
                L = min(L, ring_len)
            if S <= L:
                pad = [(0, 0), (0, L - S), (0, 0), (0, 0)]
                nc = {"k": jnp.pad(k.astype(cfg.dtype), pad),
                      "v": jnp.pad(v.astype(cfg.dtype), pad)}
            else:
                # ring: keep the last L keys, token t at slot t % L
                sh = (S - L) % L
                nc = {"k": jnp.roll(k[:, S - L:].astype(cfg.dtype), sh, axis=1),
                      "v": jnp.roll(v[:, S - L:].astype(cfg.dtype), sh, axis=1)}
    if cfg.sandwich_norm:
        a = norm_apply(cfg, lp["post_ln1"], a)
    x = shard(x + a, "act")

    h = norm_apply(cfg, lp["ln2"], x)
    if spec.mlp == "moe":
        m, aux = moe_apply(lp["moe"], h, cfg, shard=shard,
                           dispatch=moe_dispatch)
    elif spec.mlp == "dense":
        m = mlp_apply(lp["mlp"], h, cfg.mlp_act)
    else:
        m = jnp.zeros_like(h)
    if cfg.sandwich_norm:
        m = norm_apply(cfg, lp["post_ln2"], m)
    return shard(x + m, "act"), aux, nc


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def init_params(rng, cfg) -> dict:
    plan = cfg.layer_plan()
    pi, reps, rem = find_period(plan)
    ks = jax.random.split(rng, 4 + pi)
    params: dict[str, Any] = {
        "embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model,
                            cfg.param_dtype),
        "final_norm": norm_init(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["head"] = embed_init(ks[1], cfg.padded_vocab, cfg.d_model,
                                    cfg.param_dtype).T
    if any(s.mixer == "shared_attn" for s in plan):
        params["shared_attn"] = _shared_attn_init(ks[2], cfg)

    scan_params = {}
    for j in range(pi):
        spec = plan[j]
        keys = jax.random.split(jax.random.fold_in(ks[3], j), reps)
        scan_params[f"slot{j}"] = jax.vmap(
            lambda k, s=spec: _layer_init(k, cfg, s))(keys)
    params["blocks"] = scan_params
    params["rest"] = [
        _layer_init(jax.random.fold_in(ks[3], 1000 + i), cfg, plan[reps * pi + i])
        for i in range(rem)]
    return params


def forward(params, cfg, tokens, *, img_embeds=None, mode="train", cache=None,
            cache_len=0, shard: Shard | None = None, remat=True,
            decode_combine=None, prefetch=None, moe_dispatch=None):
    """Returns (logits, aux, new_cache).

    train:   logits (B,S,Vpad); new_cache None.
    prefill: logits (B,1,Vpad) for the last position; new_cache filled, with
             cache["pos"] = S (next write position).
    decode:  tokens (B,1); cache required; logits (B,1,Vpad).
    decode_combine: serve-layer hook for the decode cache write + attention
             over a sequence-sharded cache (see models/attention.attention).
    moe_dispatch: expert-parallel dispatch hook (models/moe.MoeDispatch) —
             train-mode paper path where MoE expert weights arrive as E/p
             per-rank shards and slot routing runs over the manual DP axes.
    prefetch: train-layer hook for the double-buffered FSDP pipeline
             (DESIGN.md §5). When set (train mode only), ``params["blocks"]``
             holds per-device SHARDS and the scan becomes a pipelined
             double buffer: ``prefetch.start`` issues the gather for layer
             i + depth BEFORE layer i's compute, ``prefetch.finish``
             completes it at the consumer. The hook carries ``.depth``
             (lookahead slots); every other param subtree arrives gathered
             as usual.
    """
    shard = shard or _noop
    plan = cfg.layer_plan()
    pi, reps, rem = find_period(plan)
    block_specs = plan[:pi]
    dt = cfg.dtype
    B, S = tokens.shape
    decode = cache is not None
    build_cache = (mode == "prefill")

    x = params["embed"].astype(dt)[tokens]
    if cfg.scale_embed:
        x = x * math.sqrt(cfg.d_model)
    if img_embeds is not None:
        n_img = img_embeds.shape[1]
        x = jnp.concatenate([img_embeds.astype(dt), x[:, n_img:]], axis=1)
    x = shard(x, "act")

    if decode:
        pos = cache["pos"]
        # scalar pos = lockstep batch; (B,) pos = continuous batching with
        # per-row positions ((B,) does not broadcast to (B,1) — reshape)
        positions = (pos[:, None] if jnp.ndim(pos) == 1
                     else jnp.broadcast_to(pos, (B, 1)))
    else:
        pos = None
        positions = jnp.arange(S)[None]

    aux_total = jnp.zeros((), jnp.float32)

    def body(x_carry, xs):
        lp_all, cache_all = xs
        aux_acc = jnp.zeros((), jnp.float32)
        ncs = {}
        for j, spec in enumerate(block_specs):
            lp = (params["shared_attn"] if spec.mixer == "shared_attn"
                  else lp_all[f"slot{j}"])
            c = cache_all[f"slot{j}"] if cache_all is not None else None
            x_carry, aux, nc = _apply_layer(
                lp, x_carry, cfg, spec, positions=positions, cache=c,
                build_cache=build_cache, cache_len=cache_len, pos=pos,
                shard=shard, decode_combine=decode_combine,
                moe_dispatch=moe_dispatch if mode == "train" else None)
            aux_acc += aux
            ncs[f"slot{j}"] = nc
        return x_carry, (aux_acc, ncs)

    if prefetch is not None and mode != "train":
        # the step.py contract puts per-device SHARDS in params["blocks"]
        # whenever the hook is set — falling through to the eager scan
        # would consume shard-shaped leaves as full weights
        raise NotImplementedError(
            "prefetch pipeline is train-mode only (see DESIGN.md §5)")
    body_fn = jax.checkpoint(body) if (remat and mode == "train") else body
    scan_cache = cache["blocks"] if decode else None
    from repro._jax_compat import scan_compat
    if prefetch is not None:
        # Double-buffered pipeline: the scan carries a FIFO of `depth`
        # in-flight gathers. Each iteration issues the gather for layer
        # i + depth FIRST (data-independent of this layer's output — the
        # scheduler can put its rounds on the wire under the matmuls),
        # then completes layer i's pending gather and computes. The last
        # `depth` layers drain the FIFO unrolled. Gathers stay OUTSIDE the
        # remat boundary so the backward transposes them into their
        # reduce-scatters exactly once (no re-gather on recompute).
        def apply_block(x_carry, lp_all):
            aux_acc = jnp.zeros((), jnp.float32)
            for j, spec in enumerate(block_specs):
                lp = (params["shared_attn"] if spec.mixer == "shared_attn"
                      else lp_all[f"slot{j}"])
                x_carry, aux, _ = _apply_layer(
                    lp, x_carry, cfg, spec, positions=positions, cache=None,
                    build_cache=False, cache_len=cache_len, pos=pos,
                    shard=shard, decode_combine=None,
                    moe_dispatch=moe_dispatch)
                aux_acc += aux
            return x_carry, aux_acc

        block_fn = jax.checkpoint(apply_block) if remat else apply_block
        blocks = params["blocks"]
        take = lambda i: jax.tree.map(lambda t: t[i], blocks)
        depth = max(1, int(getattr(prefetch, "depth", 1)))
        scan_ncs = None
        if reps <= depth:
            # lookahead covers the whole stack: issue everything up front
            pendings = [prefetch.start(take(i)) for i in range(reps)]
            for i in range(reps):
                x, aux = block_fn(x, prefetch.finish(pendings[i]))
                aux_total += aux
        else:
            fifo = tuple(prefetch.start(take(i)) for i in range(depth))
            xs_ahead = jax.tree.map(lambda t: t[depth:], blocks)

            def pf_body(carry, lp_ahead):
                x_c, pend = carry
                nxt = prefetch.start(lp_ahead)          # layer i + depth
                x_c, aux = block_fn(x_c, prefetch.finish(pend[0]))
                return (x_c, pend[1:] + (nxt,)), aux

            (x, fifo), aux_s = scan_compat(pf_body, (x, fifo), xs_ahead,
                                           length=reps - depth)
            aux_total += jnp.sum(aux_s)
            for i in range(depth):                      # drain the FIFO
                x, aux = block_fn(x, prefetch.finish(fifo[i]))
                aux_total += aux
    else:
        x, (aux_s, scan_ncs) = scan_compat(
            body_fn, x, (params["blocks"], scan_cache), length=reps)
        aux_total += jnp.sum(aux_s)

    rest_ncs = []
    for i in range(rem):
        spec = plan[reps * pi + i]
        c = cache["rest"][i] if decode else None
        lp = (params["shared_attn"] if spec.mixer == "shared_attn"
              else params["rest"][i])
        x, aux, nc = _apply_layer(
            lp, x, cfg, spec, positions=positions, cache=c,
            build_cache=build_cache, cache_len=cache_len, pos=pos, shard=shard,
            decode_combine=decode_combine,
            moe_dispatch=moe_dispatch if mode == "train" else None)
        aux_total += aux
        rest_ncs.append(nc)

    x = norm_apply(cfg, params["final_norm"], x)
    if mode == "prefill":
        x = x[:, -1:]
    head = (params["embed"].T if cfg.tie_embeddings else params["head"])
    logits = x @ head.astype(dt)
    logits = shard(logits, "logits")
    if cfg.final_softcap:
        logits = softcap(logits, cfg.final_softcap)

    new_cache = None
    if build_cache:
        new_cache = {"blocks": scan_ncs, "rest": rest_ncs,
                     "pos": jnp.asarray(S, jnp.int32)}
    elif decode:
        new_cache = {"blocks": scan_ncs, "rest": rest_ncs,
                     "pos": cache["pos"] + 1}
    return logits, {"moe_aux": aux_total}, new_cache


# ---------------------------------------------------------------------------
# caches for decode dry-run (ShapeDtypeStructs, no allocation)
# ---------------------------------------------------------------------------

def cache_specs(cfg, batch: int, cache_len: int, *,
                vector_pos: bool = False) -> dict:
    plan = cfg.layer_plan()
    pi, reps, rem = find_period(plan)
    D = cfg.head_dim_

    def slot_spec(spec, stacked: bool):
        lead = (reps,) if stacked else ()
        if spec.mixer == "mamba2":
            base = mamba_cache_specs(cfg, batch)
            return {k: jax.ShapeDtypeStruct(lead + v.shape, v.dtype)
                    for k, v in base.items()}
        L = cache_len
        rl = ring_cache_len(cfg, spec)
        if rl is not None:
            L = min(L, rl)
        shp = lead + (batch, L, cfg.n_kv_heads, D)
        return {"k": jax.ShapeDtypeStruct(shp, cfg.dtype),
                "v": jax.ShapeDtypeStruct(shp, cfg.dtype)}

    return {
        "blocks": {f"slot{j}": slot_spec(plan[j], True) for j in range(pi)},
        "rest": [slot_spec(plan[reps * pi + i], False) for i in range(rem)],
        "pos": jax.ShapeDtypeStruct((batch,) if vector_pos else (),
                                    jnp.int32),
    }


# ---------------------------------------------------------------------------
# exact parameter counts (roofline MODEL_FLOPS input)
# ---------------------------------------------------------------------------

def param_count(cfg, active_only: bool = False) -> int:
    plan = cfg.layer_plan()
    d = cfg.d_model
    norm_n = 2 * d if cfg.norm_type == "ln" else d
    total = cfg.padded_vocab * d          # embed (tied head reuses it)
    if not cfg.tie_embeddings:
        total += cfg.padded_vocab * d
    total += norm_n                        # final norm
    shared_counted = False
    for spec in plan:
        if spec.mixer == "mamba2":
            total += norm_n + mamba_param_count(cfg)
            continue
        if spec.mixer == "shared_attn":
            if shared_counted:
                continue
            shared_counted = True
            total += 2 * norm_n + attn_param_count(cfg) + mlp_param_count(
                d, cfg.d_ff, cfg.mlp_act)
            continue
        total += 2 * norm_n + attn_param_count(cfg)
        if cfg.sandwich_norm:
            total += 2 * norm_n
        if spec.mlp == "moe":
            total += moe_param_count(cfg, active_only=active_only)
        elif spec.mlp == "dense":
            total += mlp_param_count(d, cfg.d_ff, cfg.mlp_act)
    return int(total)
