"""Whisper-style encoder-decoder backbone.

The audio conv frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings (B, enc_seq, d_model) — the two
conv1d+GELU layers of real Whisper are out of scope. Sinusoidal absolute
positions are used on both sides (real Whisper: sinusoidal encoder, learned
decoder — recorded in DESIGN.md; sinusoidal generalizes to the assigned
32k decode shapes that exceed Whisper's native 448-token table).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .attention import attention, attn_init, attn_param_count
from .layers import (embed_init, mlp_apply, mlp_init, mlp_param_count,
                     norm_apply, norm_init)
from repro.configs.base import LayerSpec

_noop = lambda t, _k: t


def _sinusoid(positions, d):
    """positions: (...,) -> (..., d) transformer sinusoidal embedding."""
    half = d // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


_SELF = LayerSpec(mixer="attn", attn="full", mlp="dense", rope=False)


def _enc_layer_init(rng, cfg):
    ks = jax.random.split(rng, 2)
    return {"ln1": norm_init(cfg, cfg.d_model), "attn": attn_init(ks[0], cfg),
            "ln2": norm_init(cfg, cfg.d_model),
            "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_act,
                            cfg.param_dtype)}


def _dec_layer_init(rng, cfg):
    ks = jax.random.split(rng, 3)
    return {"ln1": norm_init(cfg, cfg.d_model), "self_attn": attn_init(ks[0], cfg),
            "lnx": norm_init(cfg, cfg.d_model), "cross_attn": attn_init(ks[1], cfg),
            "ln2": norm_init(cfg, cfg.d_model),
            "mlp": mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp_act,
                            cfg.param_dtype)}


def init_params(rng, cfg) -> dict:
    ks = jax.random.split(rng, 4)
    enc_keys = jax.random.split(ks[0], cfg.n_enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "embed": embed_init(ks[2], cfg.padded_vocab, cfg.d_model, cfg.param_dtype),
        "enc_layers": jax.vmap(lambda k: _enc_layer_init(k, cfg))(enc_keys),
        "enc_norm": norm_init(cfg, cfg.d_model),
        "dec_layers": jax.vmap(lambda k: _dec_layer_init(k, cfg))(dec_keys),
        "final_norm": norm_init(cfg, cfg.d_model),
    }


def encode(params, cfg, frames, *, shard=None):
    """frames: (B, T, d) stub embeddings -> (B, T, d) encoder output."""
    shard = shard or _noop
    dt = cfg.dtype
    T = frames.shape[1]
    x = frames.astype(dt) + _sinusoid(jnp.arange(T), cfg.d_model).astype(dt)
    x = shard(x, "act")

    def body(x_c, lp):
        h = norm_apply(cfg, lp["ln1"], x_c)
        a, _ = attention(lp["attn"], h, cfg, _SELF, causal=False, shard=shard)
        x_c = x_c + a
        h = norm_apply(cfg, lp["ln2"], x_c)
        x_c = shard(x_c + mlp_apply(lp["mlp"], h, cfg.mlp_act), "act")
        return x_c, None

    from repro._jax_compat import scan_compat
    x, _ = scan_compat(body, x, params["enc_layers"])
    return norm_apply(cfg, params["enc_norm"], x)


def _cross_kv(lp, cfg, enc_out):
    dt = cfg.dtype
    B, T, _ = enc_out.shape
    D = cfg.head_dim_
    k = (enc_out @ lp["cross_attn"]["wk"].astype(dt)).reshape(B, T, cfg.n_kv_heads, D)
    v = (enc_out @ lp["cross_attn"]["wv"].astype(dt)).reshape(B, T, cfg.n_kv_heads, D)
    return k, v


def forward(params, cfg, tokens, *, frames=None, mode="train", cache=None,
            cache_len=0, shard=None, remat=True, decode_combine=None,
            prefetch=None):
    """Returns (logits, aux, new_cache). See transformer.forward for modes.

    decode-mode cache: {"self": stacked {k,v}, "cross": stacked (k,v),
                        "pos": int32} — cross K/V computed once at prefill.
    decode_combine applies to the decoder *self*-attention caches only; the
    cross-attention K/V are read-only prefill products and stay on the
    GSPMD path.
    prefetch: the double-buffered FSDP pipeline hook is a decoder-only-stack
    feature; the encoder-decoder path keeps eager gathers (train/step.py
    never enables it for the audio family) and rejects a hook loudly rather
    than consuming sharded params as if they were gathered.
    """
    if prefetch is not None:
        raise NotImplementedError(
            "prefetch pipeline is transformer-only (see DESIGN.md §5)")
    shard = shard or _noop
    dt = cfg.dtype
    B, S = tokens.shape
    decode = cache is not None
    build = (mode == "prefill")

    if decode:
        pos = cache["pos"]
        positions = jnp.broadcast_to(pos, (B, 1))
        enc_out = None
        cross_stack = cache["cross"]
    else:
        pos = None
        positions = jnp.arange(S)[None]
        enc_out = encode(params, cfg, frames, shard=shard)
        cross_stack = None

    x = params["embed"].astype(dt)[tokens]
    if cfg.scale_embed:
        x = x * math.sqrt(cfg.d_model)
    x = x + _sinusoid(positions, cfg.d_model).astype(dt)
    x = shard(x, "act")

    def body(x_c, xs):
        lp, c, cross = xs
        h = norm_apply(cfg, lp["ln1"], x_c)
        if decode:
            self_cache = {"k": c["k"], "v": c["v"], "pos": pos}
            a, nc_full = attention(lp["self_attn"], h, cfg, _SELF,
                                   positions=positions, cache=self_cache,
                                   shard=shard,
                                   decode_combine=decode_combine)
            nc = {"k": nc_full["k"], "v": nc_full["v"]}
            ck, cv = cross
        else:
            a, kv = attention(lp["self_attn"], h, cfg, _SELF,
                              positions=positions, shard=shard)
            nc = None
            if build:
                k, v = kv
                L = cache_len or S
                padw = [(0, 0), (0, L - S), (0, 0), (0, 0)]
                nc = {"k": jnp.pad(k.astype(dt), padw),
                      "v": jnp.pad(v.astype(dt), padw)}
            ck, cv = _cross_kv(lp, cfg, enc_out)
        x_c = x_c + a
        h = norm_apply(cfg, lp["lnx"], x_c)
        ca, _ = attention(lp["cross_attn"], h, cfg, _SELF, cross_kv=(ck, cv),
                          shard=shard)
        x_c = x_c + ca
        h = norm_apply(cfg, lp["ln2"], x_c)
        x_c = shard(x_c + mlp_apply(lp["mlp"], h, cfg.mlp_act), "act")
        new_cross = (ck, cv) if build else None
        return x_c, (nc, new_cross)

    body_fn = jax.checkpoint(body) if (remat and mode == "train") else body
    self_stack = cache["self"] if decode else None
    from repro._jax_compat import scan_compat
    x, (self_ncs, cross_ncs) = scan_compat(
        body_fn, x, (params["dec_layers"], self_stack, cross_stack),
        length=cfg.n_layers)

    x = norm_apply(cfg, params["final_norm"], x)
    if mode == "prefill":
        x = x[:, -1:]
    logits = x @ params["embed"].T.astype(dt)
    logits = shard(logits, "logits")

    new_cache = None
    if build:
        new_cache = {"self": self_ncs, "cross": cross_ncs,
                     "pos": jnp.asarray(S, jnp.int32)}
    elif decode:
        new_cache = {"self": self_ncs, "cross": cache["cross"],
                     "pos": cache["pos"] + 1}
    return logits, {"moe_aux": jnp.zeros((), jnp.float32)}, new_cache


def cache_specs(cfg, batch: int, cache_len: int) -> dict:
    D = cfg.head_dim_
    L = cfg.n_layers
    kv = (L, batch, cache_len, cfg.n_kv_heads, D)
    ckv = (L, batch, cfg.enc_seq, cfg.n_kv_heads, D)
    sd = jax.ShapeDtypeStruct
    return {
        "self": {"k": sd(kv, cfg.dtype), "v": sd(kv, cfg.dtype)},
        "cross": (sd(ckv, cfg.dtype), sd(ckv, cfg.dtype)),
        "pos": sd((), jnp.int32),
    }


def param_count(cfg, active_only: bool = False) -> int:
    d = cfg.d_model
    norm_n = 2 * d if cfg.norm_type == "ln" else d
    enc = cfg.n_enc_layers * (2 * norm_n + attn_param_count(cfg) +
                              mlp_param_count(d, cfg.d_ff, cfg.mlp_act))
    dec = cfg.n_layers * (3 * norm_n + 2 * attn_param_count(cfg) +
                          mlp_param_count(d, cfg.d_ff, cfg.mlp_act))
    # embed + encoder + decoder + enc_norm + final_norm
    return int(cfg.padded_vocab * d + enc + dec + 2 * norm_n)
