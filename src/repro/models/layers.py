"""Shared layer primitives: norms, MLPs, embeddings, rotary embeddings.

All layers are functional: ``*_init(rng, ...) -> params`` and a pure apply.
Params are plain dicts; compute happens in ``cfg.dtype`` (bf16), params are
stored in ``cfg.param_dtype`` (fp32) and cast at use.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(rng, d_in: int, d_out: int, dtype=jnp.float32) -> jax.Array:
    scale = 1.0 / jnp.sqrt(d_in)
    return (jax.random.normal(rng, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(rng, vocab: int, d: int, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(rng, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.zeros((d,), dtype)}          # (1 + scale) convention


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)


def layernorm_init(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.zeros((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    y = y * (1.0 + params["scale"].astype(jnp.float32)) + params["bias"].astype(jnp.float32)
    return y.astype(dt)


def norm_init(cfg, d: int) -> dict:
    return (layernorm_init(d, cfg.param_dtype) if cfg.norm_type == "ln"
            else rmsnorm_init(d, cfg.param_dtype))


def norm_apply(cfg, params: dict, x: jax.Array) -> jax.Array:
    return (layernorm(params, x, cfg.norm_eps) if cfg.norm_type == "ln"
            else rmsnorm(params, x, cfg.norm_eps))


# ---------------------------------------------------------------------------
# gated MLP (SwiGLU / GeGLU) and plain MLP (whisper)
# ---------------------------------------------------------------------------

def mlp_init(rng, d_model: int, d_ff: int, act: str, dtype=jnp.float32) -> dict:
    ks = jax.random.split(rng, 3)
    if act == "gelu_mlp":                              # plain 2-matrix MLP
        return {"up": dense_init(ks[0], d_model, d_ff, dtype),
                "down": dense_init(ks[1], d_ff, d_model, dtype)}
    return {"gate": dense_init(ks[0], d_model, d_ff, dtype),
            "up": dense_init(ks[1], d_model, d_ff, dtype),
            "down": dense_init(ks[2], d_ff, d_model, dtype)}


def mlp_apply(params: dict, x: jax.Array, act: str) -> jax.Array:
    dt = x.dtype
    if act == "gelu_mlp":
        h = jax.nn.gelu(x @ params["up"].astype(dt))
        return h @ params["down"].astype(dt)
    g = x @ params["gate"].astype(dt)
    u = x @ params["up"].astype(dt)
    g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
    return (g * u) @ params["down"].astype(dt)


def mlp_param_count(d_model: int, d_ff: int, act: str) -> int:
    return 2 * d_model * d_ff if act == "gelu_mlp" else 3 * d_model * d_ff


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    freqs = rope_freqs(x.shape[-1], theta)             # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]                   # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)
