"""Mamba2 (SSD — state-space duality) mixer: chunked train/prefill scan and
O(1)-state decode. [Dao & Gu 2024, arXiv:2405.21060]

Recurrence (per head h, state size N, head dim P):
    h_t = exp(dt_t·A) · h_{t-1} + dt_t · B_t ⊗ x_t        h ∈ R^{N×P}
    y_t = C_t · h_t + D · x_t
Chunked SSD: within chunks of Q tokens the quadratic (dual) form is used;
across chunks a sequential scan carries the state. The Pallas kernel in
``kernels/ssd`` implements the same tiling for TPU VMEM; this file is the
pure-jnp reference used by the model and as the kernel oracle.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import dense_init, rmsnorm


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    P = cfg.ssm_headdim
    H = d_inner // P
    N = cfg.ssm_state
    G = cfg.ssm_ngroups
    return d_inner, H, P, N, G


def mamba_init(rng, cfg) -> dict:
    d_inner, H, P, N, G = _dims(cfg)
    W = cfg.ssm_conv
    conv_ch = d_inner + 2 * G * N
    d_in_proj = 2 * d_inner + 2 * G * N + H
    ks = jax.random.split(rng, 6)
    dt = jnp.exp(jax.random.uniform(ks[2], (H,), jnp.float32) *
                 (math.log(0.1) - math.log(0.001)) + math.log(0.001))
    return {
        "in_proj": dense_init(ks[0], cfg.d_model, d_in_proj, cfg.param_dtype),
        "conv_w": (jax.random.normal(ks[1], (W, conv_ch), jnp.float32) /
                   math.sqrt(W)).astype(cfg.param_dtype),
        "conv_b": jnp.zeros((conv_ch,), cfg.param_dtype),
        "dt_bias": (dt + jnp.log(-jnp.expm1(-dt))).astype(cfg.param_dtype),
        "A_log": jnp.log(1.0 + jax.random.uniform(ks[3], (H,)) * 15.0
                         ).astype(cfg.param_dtype),
        "D": jnp.ones((H,), cfg.param_dtype),
        "norm": {"scale": jnp.zeros((d_inner,), cfg.param_dtype)},
        "out_proj": dense_init(ks[4], d_inner, cfg.d_model, cfg.param_dtype),
    }


def mamba_param_count(cfg) -> int:
    d_inner, H, P, N, G = _dims(cfg)
    conv_ch = d_inner + 2 * G * N
    d_in_proj = 2 * d_inner + 2 * G * N + H
    return (cfg.d_model * d_in_proj + cfg.ssm_conv * conv_ch + conv_ch +
            3 * H + d_inner + d_inner * cfg.d_model)


# ---------------------------------------------------------------------------
# chunked SSD core
# ---------------------------------------------------------------------------

def ssd_chunked(x, dt, A, B, C, Q: int, h0=None, *, precise: bool = False):
    """x:(Bt,S,H,P) dt:(Bt,S,H) A:(H,) B,C:(Bt,S,G,N). Returns (y, h_final).

    Mixed precision (§Perf): the *scalar path* — softplus'd dt, the cumsum
    of log-decays and their exponentials, shapes ≤ (Bt,S,H) or (H,Q,Q) —
    stays fp32 (exponential stability); every (…,P)/(…,N)-scale tensor and
    both dual-form matmuls run in bf16 with fp32 accumulation. This halves
    the HBM traffic of the jnp lowering that the dry-run measures — the
    Pallas SSD kernel fuses the same math into VMEM tiles on real TPUs.
    The sequential part is a lax.scan over S/Q chunks.
    """
    Bt, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    Hg = H // G
    nc = S // Q
    assert nc * Q == S, f"seq {S} not divisible by chunk {Q}"
    f32 = jnp.float32
    bf16 = f32 if precise else jnp.bfloat16
    xc = x.reshape(Bt, nc, Q, H, P).astype(bf16)
    dtc = dt.reshape(Bt, nc, Q, H).astype(f32)
    Bc = B.reshape(Bt, nc, Q, G, N).astype(bf16)
    Cc = C.reshape(Bt, nc, Q, G, N).astype(bf16)

    da = dtc * A.astype(f32)                         # (Bt,nc,Q,H), negative
    cum = jnp.cumsum(da, axis=2)                     # within-chunk cumulative
    seg_end = cum[:, :, -1]                          # (Bt,nc,H) full-chunk decay

    def to_heads(t):
        """(Bt,nc,Q,G,N) -> (Bt,nc,Q,H,N) by repeating each group Hg times."""
        if G == 1:
            return jnp.broadcast_to(t, (Bt, nc, Q, H, N))
        return jnp.repeat(t, Hg, axis=3)

    # --- intra-chunk (dual quadratic form) --------------------------------
    # bf16-out einsums: TPU MXU accumulates bf16 dots in fp32 internally, so
    # this is the native semantic; crucially it keeps the *cotangents* bf16
    # too — a preferred_element_type=f32 here poisons the entire backward
    # chain (conv, split, in_proj grads) into fp32 (§Perf iteration 5).
    CB = jnp.einsum("bcigν,bcjgν->bcgij", Cc, Bc)    # (Bt,nc,G,Q,Q)
    CBh = (jnp.broadcast_to(CB, (Bt, nc, H, Q, Q)) if G == 1
           else jnp.repeat(CB, Hg, axis=2))
    cum_h = cum.transpose(0, 1, 3, 2)                # (Bt,nc,H,Q)
    decay = jnp.exp(cum_h[..., :, None] - cum_h[..., None, :])
    decay = jnp.where(jnp.tril(jnp.ones((Q, Q), bool)), decay, 0.0)
    dtx = dtc.astype(bf16)[..., None] * xc           # (Bt,nc,Q,H,P)
    L = CBh * decay.astype(bf16)
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", L, dtx)

    # --- chunk states -------------------------------------------------------
    dec_to_end = jnp.exp(seg_end[:, :, None] - cum)  # (Bt,nc,Q,H)
    Bh = to_heads(Bc)
    S_c = jnp.einsum("bcjh,bcjhν,bcjhp->bchνp",
                     (dec_to_end * dtc).astype(bf16), Bh, xc)

    # --- inter-chunk recurrence (fp32 carry: exact state) --------------------
    if h0 is None:
        h0 = jnp.zeros((Bt, H, N, P), f32)

    def step(h, inp):
        dec, s = inp                                  # dec (Bt,H), s (Bt,H,N,P)
        h_out = h                                     # state BEFORE this chunk
        h = jnp.exp(dec)[..., None, None] * h + s.astype(f32)
        return h, h_out

    from repro._jax_compat import scan_compat
    h_fin, h_prev = scan_compat(
        step, h0, (seg_end.transpose(1, 0, 2), S_c.transpose(1, 0, 2, 3, 4)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)          # (Bt,nc,H,N,P)

    Ch = to_heads(Cc)
    y_inter = jnp.einsum("bcihν,bchνp->bcihp",
                         (jnp.exp(cum).astype(bf16))[..., None] * Ch,
                         h_prev.astype(bf16))

    y = (y_intra.astype(f32) + y_inter.astype(f32)).reshape(Bt, S, H, P)
    return y, h_fin


# ---------------------------------------------------------------------------
# layer apply
# ---------------------------------------------------------------------------

def _causal_conv(u, w, b):
    """u: (B,S,Ch), depthwise causal conv width W.

    (§Perf iteration 4 tried W shifted multiply-adds instead — REFUTED:
    the pads/FMAs materialize ~2.75× the tensor traffic of the single
    grouped-conv op; reverted.)
    """
    W = w.shape[0]
    Ch = u.shape[-1]
    out = jax.lax.conv_general_dilated(
        u, w[:, None, :], window_strides=(1,), padding=[(W - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"), feature_group_count=Ch)
    return out + b


def _split_proj(cfg, proj):
    d_inner, H, P, N, G = _dims(cfg)
    z, xBC, dt = jnp.split(proj, [d_inner, 2 * d_inner + 2 * G * N], axis=-1)
    return z, xBC, dt


def mamba_apply(params, x_in, cfg, *, cache=None, shard=None):
    """Mamba2 mixer. Train/prefill: full sequence (returns final state for
    prefill cache). Decode: cache = {"conv": (B,W-1,Ch), "h": (B,H,N,P)}."""
    shard = shard or (lambda t, _k: t)
    d_inner, H, P, N, G = _dims(cfg)
    W = cfg.ssm_conv
    dt_ = x_in.dtype
    Bt, S, _ = x_in.shape

    proj = x_in @ params["in_proj"].astype(dt_)
    z, xBC_raw, dt_raw = _split_proj(cfg, proj)

    if cache is not None and S == 1:
        xBC = xBC_raw
        conv_cache = cache["conv"]
        window = jnp.concatenate([conv_cache, xBC.astype(conv_cache.dtype)], 1)
        w = params["conv_w"].astype(jnp.float32)
        u = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), w)
        xBC_c = jax.nn.silu(u + params["conv_b"].astype(jnp.float32))[:, None]
        new_conv = window[:, 1:]
        x, Bs, Cs = jnp.split(
            xBC_c, [d_inner, d_inner + G * N], axis=-1)
        x = x.reshape(Bt, H, P)
        Bs = Bs.reshape(Bt, G, N)
        Cs = Cs.reshape(Bt, G, N)
        dtv = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) +
                              params["dt_bias"].astype(jnp.float32))  # (B,H)
        A = -jnp.exp(params["A_log"].astype(jnp.float32))
        h = cache["h"]
        Hg = H // G
        Bh = jnp.repeat(Bs, Hg, axis=1)[:, :H]
        Ch = jnp.repeat(Cs, Hg, axis=1)[:, :H]
        h = (jnp.exp(dtv * A)[..., None, None] * h +
             jnp.einsum("bh,bhν,bhp->bhνp", dtv, Bh, x.astype(jnp.float32)))
        y = jnp.einsum("bhν,bhνp->bhp", Ch, h)
        y = y + params["D"].astype(jnp.float32)[None, :, None] * x.astype(jnp.float32)
        y = y.reshape(Bt, 1, d_inner).astype(dt_)
        y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
        out = y @ params["out_proj"].astype(dt_)
        return out, {"conv": new_conv, "h": h}

    xBC = _causal_conv(xBC_raw.astype(dt_), params["conv_w"].astype(dt_),
                       params["conv_b"].astype(dt_))
    xBC = jax.nn.silu(xBC)
    x, Bs, Cs = jnp.split(xBC, [d_inner, d_inner + G * N], axis=-1)
    x = x.reshape(Bt, S, H, P)
    x = shard(x, "act_heads")
    Bs = Bs.reshape(Bt, S, G, N)
    Cs = Cs.reshape(Bt, S, G, N)
    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                          params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    Q = min(cfg.ssm_chunk, S)
    if S % Q:
        Q = S
    y, h_fin = ssd_chunked(x, dtv, A, Bs, Cs, Q)
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * x.astype(jnp.float32)
    y = y.reshape(Bt, S, d_inner).astype(dt_)
    y = shard(y, "act_ff")
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ params["out_proj"].astype(dt_)

    if cache is not None:  # prefill: conv window = last W-1 raw inputs
        pad = jnp.zeros((Bt, max(0, W - 1 - S), xBC_raw.shape[-1]), cfg.dtype)
        tail = xBC_raw[:, max(0, S - (W - 1)):].astype(cfg.dtype)
        new_cache = {"conv": jnp.concatenate([pad, tail], 1), "h": h_fin}
        return out, new_cache
    return out, None


def mamba_cache_specs(cfg, batch: int):
    d_inner, H, P, N, G = _dims(cfg)
    conv_ch = d_inner + 2 * G * N
    return {
        "conv": jax.ShapeDtypeStruct((batch, cfg.ssm_conv - 1, conv_ch), cfg.dtype),
        "h": jax.ShapeDtypeStruct((batch, H, N, P), jnp.float32),
    }
