"""Mixture-of-Experts MLP with capacity-bounded sort-based dispatch.

Dispatch never materializes a (B,S,E,C) one-hot: per batch row, the S·K
(token, expert) assignments are sorted by expert id, ranked within their
expert, and converted into a static (E, C) gather/scatter index table.
Dropped tokens (rank ≥ capacity) fall through via the residual connection.

Sharding: expert-parallelism shards the leading E dim of expert weights and
of the dispatched (B, E, C, d) activations over the ``model`` mesh axis (the
``shard`` hooks 'experts' / 'moe_act'). Router compute is replicated.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init

AUX_LOSS_W = 0.01


def moe_init(rng, cfg) -> dict:
    E = cfg.n_experts
    dff = cfg.d_expert or cfg.d_ff
    ks = jax.random.split(rng, 5)
    vinit = jax.vmap(lambda k, di=cfg.d_model, do=dff: dense_init(k, di, do))
    vinit_dn = jax.vmap(lambda k, di=dff, do=cfg.d_model: dense_init(k, di, do))
    p = {
        "router": dense_init(ks[0], cfg.d_model, E),
        "gate": vinit(jax.random.split(ks[1], E)).astype(cfg.param_dtype),
        "up": vinit(jax.random.split(ks[2], E)).astype(cfg.param_dtype),
        "down": vinit_dn(jax.random.split(ks[3], E)).astype(cfg.param_dtype),
    }
    if cfg.n_shared_experts:
        dsh = cfg.d_shared_expert or cfg.n_shared_experts * dff
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "gate": dense_init(kk[0], cfg.d_model, dsh, cfg.param_dtype),
            "up": dense_init(kk[1], cfg.d_model, dsh, cfg.param_dtype),
            "down": dense_init(kk[2], dsh, cfg.d_model, cfg.param_dtype),
        }
    return p


def moe_param_count(cfg, active_only: bool = False) -> int:
    E = cfg.top_k if active_only else cfg.n_experts
    dff = cfg.d_expert or cfg.d_ff
    n = cfg.d_model * cfg.n_experts            # router (always full)
    n += E * 3 * cfg.d_model * dff
    if cfg.n_shared_experts:
        dsh = cfg.d_shared_expert or cfg.n_shared_experts * dff
        n += 3 * cfg.d_model * dsh
    return n


def capacity(cfg, S: int) -> int:
    c = int(S * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(c, cfg.top_k)


def _dispatch_tables(idx, gates, E: int, S: int, K: int, C: int):
    """Build (E·C) gather/scatter tables for one batch row.

    idx:   (S, K) expert id per assignment
    gates: (S, K) combine weight per assignment
    Returns tok_idx (E·C,) int32 in [0, S] (S = sentinel), weight (E·C,).
    """
    flat_e = idx.reshape(-1)                        # (S*K,)
    flat_tok = jnp.repeat(jnp.arange(S, dtype=jnp.int32), K)
    flat_w = gates.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)        # expert-major order
    e_sorted = flat_e[order]
    tok_sorted = flat_tok[order]
    w_sorted = flat_w[order]
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.cumsum(counts) - counts            # exclusive prefix
    rank = jnp.arange(S * K) - starts[e_sorted]     # position within expert
    keep = rank < C
    # dropped assignments scatter to an out-of-range slot (mode="drop")
    slot = jnp.where(keep, e_sorted * C + jnp.clip(rank, 0, C - 1), E * C)
    tok_idx = jnp.full((E * C,), S, jnp.int32).at[slot].set(
        tok_sorted, mode="drop")
    weight = jnp.zeros((E * C,), flat_w.dtype).at[slot].set(
        w_sorted, mode="drop")
    return tok_idx, weight


def moe_apply(params: dict, x: jax.Array, cfg, *, shard=None):
    """x: (B, S, d). Returns (out, aux_loss)."""
    shard = shard or (lambda t, _k: t)
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = capacity(cfg, S)
    dt = x.dtype

    logits = (x @ params["router"].astype(dt)).astype(jnp.float32)  # (B,S,E)
    if getattr(cfg, "router_act", "softmax") == "sigmoid":
        probs = jax.nn.sigmoid(logits)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, K)                            # (B,S,K)
    if cfg.router_norm_topk and K > 1:
        gates = gates / jnp.sum(gates, -1, keepdims=True)

    # auxiliary load-balance loss (Switch-style): E * <f_e> . <p_e>
    me = jnp.mean(jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    pe = jnp.mean(jax.nn.softmax(logits, -1), axis=(0, 1))
    aux = AUX_LOSS_W * E * jnp.sum(me * pe)

    tok_idx, weight = jax.vmap(
        lambda i, g: _dispatch_tables(i, g, E, S, K, C))(idx, gates)  # (B,E*C)

    x_pad = jnp.concatenate([x, jnp.zeros((B, 1, d), dt)], axis=1)   # sentinel
    disp = jnp.take_along_axis(x_pad, tok_idx[..., None], axis=1)    # (B,E*C,d)
    disp = disp.reshape(B, E, C, d)
    disp = shard(disp, "moe_act")

    g = jnp.einsum("becd,edf->becf", disp, params["gate"].astype(dt))
    u = jnp.einsum("becd,edf->becf", disp, params["up"].astype(dt))
    h = jax.nn.silu(g) * u
    y = jnp.einsum("becf,efd->becd", h, params["down"].astype(dt))
    y = shard(y, "moe_act")
    y = (y.reshape(B, E * C, d) * weight[..., None].astype(dt))

    out = jnp.zeros((B, S + 1, d), dt).at[
        jnp.arange(B)[:, None], tok_idx].add(y, mode="drop")[:, :S]
    out = shard(out, "act")

    if cfg.n_shared_experts:
        sh = params["shared"]
        gg = jax.nn.silu(x @ sh["gate"].astype(dt)) * (x @ sh["up"].astype(dt))
        out = out + gg @ sh["down"].astype(dt)
    return out, aux
