"""Mixture-of-Experts MLP with capacity-bounded sort-based dispatch.

Dispatch never materializes a (B,S,E,C) one-hot: per batch row, the S·K
(token, expert) assignments are sorted by expert id, ranked within their
expert, and converted into a static (E, C) gather/scatter index table.
Dropped tokens (rank ≥ capacity) fall through via the residual connection.

Sharding: two expert-parallel layouts.

* GSPMD (default): the leading E dim of expert weights and of the dispatched
  (B, E, C, d) activations shards over the ``model`` mesh axis (the ``shard``
  hooks 'experts' / 'moe_act'). Router compute is replicated.
* Locality dispatch (paper mode, DESIGN.md §12): inside the manual-DP
  shard_map the E dim shards over the composite ('pod','data') DP axes — each
  rank owns E/p experts and token slots travel through
  ``core/collectives.all_to_all`` (a :class:`MoeDispatch` hook threaded from
  ``train/step.py``). Two transports: "slots" ships the dispatched
  (B, E, C, d) slot table both ways; "tokens" allgathers each rank's token
  block ONCE (the locality-Bruck schedule ships one aggregated copy per
  destination pod), routes only the small int32 index tables through the
  all-to-all, and gathers at the owner — strictly fewer inter-pod bytes than
  the flat exchange whenever top_k · capacity_factor exceeds the pod count.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .layers import dense_init

AUX_LOSS_W = 0.01


@dataclasses.dataclass(frozen=True)
class MoeDispatch:
    """Expert-parallel dispatch hook (train/step.py → moe_apply).

    When set, the expert weights arriving at ``moe_apply`` are per-rank
    shards of E // p experts and the exchange runs over the manual
    ``outer + local`` mesh axes with ``core/collectives.all_to_all``.
    ``algorithm`` is resolved (never "auto") so the transport choice and the
    comm-ledger label are static.
    """

    outer: tuple          # ('pod',) on multi-pod meshes, () otherwise
    local: tuple          # intra-pod DP axes, e.g. ('data',)
    algorithm: str        # "locality" | "xla"
    transport: str        # "tokens" | "slots"
    p: int                # total DP ranks = expert-parallel degree


def moe_init(rng, cfg) -> dict:
    E = cfg.n_experts
    dff = cfg.d_expert or cfg.d_ff
    ks = jax.random.split(rng, 5)
    vinit = jax.vmap(lambda k, di=cfg.d_model, do=dff: dense_init(k, di, do))
    vinit_dn = jax.vmap(lambda k, di=dff, do=cfg.d_model: dense_init(k, di, do))
    p = {
        "router": dense_init(ks[0], cfg.d_model, E),
        "gate": vinit(jax.random.split(ks[1], E)).astype(cfg.param_dtype),
        "up": vinit(jax.random.split(ks[2], E)).astype(cfg.param_dtype),
        "down": vinit_dn(jax.random.split(ks[3], E)).astype(cfg.param_dtype),
    }
    if cfg.n_shared_experts:
        dsh = cfg.d_shared_expert or cfg.n_shared_experts * dff
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "gate": dense_init(kk[0], cfg.d_model, dsh, cfg.param_dtype),
            "up": dense_init(kk[1], cfg.d_model, dsh, cfg.param_dtype),
            "down": dense_init(kk[2], dsh, cfg.d_model, cfg.param_dtype),
        }
    return p


def moe_param_count(cfg, active_only: bool = False) -> int:
    E = cfg.top_k if active_only else cfg.n_experts
    dff = cfg.d_expert or cfg.d_ff
    n = cfg.d_model * cfg.n_experts            # router (always full)
    n += E * 3 * cfg.d_model * dff
    if cfg.n_shared_experts:
        dsh = cfg.d_shared_expert or cfg.n_shared_experts * dff
        n += 3 * cfg.d_model * dsh
    return n


def capacity(cfg, S: int) -> int:
    c = int(S * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(c, cfg.top_k)


def _dispatch_tables(idx, gates, E: int, S: int, K: int, C: int):
    """Build (E·C) gather/scatter tables for one batch row.

    idx:   (S, K) expert id per assignment
    gates: (S, K) combine weight per assignment
    Returns tok_idx (E·C,) int32 in [0, S] (S = sentinel), weight (E·C,).
    """
    flat_e = idx.reshape(-1)                        # (S*K,)
    flat_tok = jnp.repeat(jnp.arange(S, dtype=jnp.int32), K)
    flat_w = gates.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)        # expert-major order
    e_sorted = flat_e[order]
    tok_sorted = flat_tok[order]
    w_sorted = flat_w[order]
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.cumsum(counts) - counts            # exclusive prefix
    rank = jnp.arange(S * K) - starts[e_sorted]     # position within expert
    keep = rank < C
    # dropped assignments scatter to an out-of-range slot (mode="drop")
    slot = jnp.where(keep, e_sorted * C + jnp.clip(rank, 0, C - 1), E * C)
    tok_idx = jnp.full((E * C,), S, jnp.int32).at[slot].set(
        tok_sorted, mode="drop")
    weight = jnp.zeros((E * C,), flat_w.dtype).at[slot].set(
        w_sorted, mode="drop")
    return tok_idx, weight


def _expert_mlp(params: dict, h_in: jax.Array, dt) -> jax.Array:
    """The per-expert SwiGLU on dispatched slots: (B, E, C, d) -> same."""
    g = jnp.einsum("becd,edf->becf", h_in, params["gate"].astype(dt))
    u = jnp.einsum("becd,edf->becf", h_in, params["up"].astype(dt))
    h = jax.nn.silu(g) * u
    return jnp.einsum("becf,efd->becd", h, params["down"].astype(dt))


def _ep_apply(params: dict, x_pad: jax.Array, tok_idx: jax.Array, cfg,
              dispatch: MoeDispatch, C_cap: int) -> jax.Array:
    """Expert-parallel slot compute: route token slots to the rank owning
    their expert, apply the shard's experts, route results home.

    Runs inside the manual-DP shard_map; ``params`` hold (E/p, d, f) shards.
    Both transports deliver bitwise-identical slot values to the owner (pure
    permutations / exact-copy gathers), so the forward output and the router
    gradients are bitwise-equal across transports AND across algorithms.
    Returns (B, E·C, d) pre-combine slot outputs in global expert-major
    order (the layout the caller's ``weight`` table indexes).
    """
    from repro.core import collectives as C

    Bl, S1, d = x_pad.shape
    E = cfg.n_experts
    p, alg = dispatch.p, dispatch.algorithm
    Ep = E // p
    o, l = dispatch.outer, dispatch.local
    dt = x_pad.dtype

    if dispatch.transport == "tokens":
        # Ship each rank's (sentinel-padded) token block ONCE — on the
        # locality-Bruck schedule a pod's aggregate crosses the DCN one time
        # per destination pod — and move only the int32 slot tables through
        # the all-to-all; the owner gathers its slots from the full copy.
        with jax.named_scope(f"moe_dispatch_{alg}_tokens"):
            ag = "locality_bruck" if (alg == "locality" and o) else "bruck"
            if alg == "xla":
                ag = "xla"
            xg = C.allgather(x_pad.reshape(Bl * S1, d), o, l,
                             algorithm=ag, tiled=True)
            xg = xg.reshape(p, Bl, S1, d)
            ii = jnp.moveaxis(tok_idx.reshape(Bl, p, Ep * C_cap), 1, 0)
            ri = C.all_to_all(ii.reshape(p * Bl, Ep * C_cap), o, l,
                              algorithm=alg)
            ri = ri.reshape(p, Bl, Ep * C_cap)
            h_in = jnp.take_along_axis(xg, ri[..., None], axis=2)
            h_in = h_in.reshape(p * Bl, Ep, C_cap, d)
    else:
        # Slot-table transport: dispatch at home, ship the (E/p)·C slot
        # slabs to their owners. alg="xla" is the flat GSPMD-equivalent
        # exchange the multipod gate baselines against.
        with jax.named_scope(f"moe_dispatch_{alg}_slots"):
            disp = jnp.take_along_axis(x_pad, tok_idx[..., None], axis=1)
            dd = jnp.moveaxis(disp.reshape(Bl, p, Ep * C_cap, d), 1, 0)
            h_in = C.all_to_all(dd.reshape(p * Bl, Ep * C_cap, d), o, l,
                                algorithm=alg)
            h_in = h_in.reshape(p * Bl, Ep, C_cap, d)

    y = _expert_mlp(params, h_in, dt)                   # (p·Bl, Ep, C, d)

    with jax.named_scope(f"moe_return_{alg}"):
        back = C.all_to_all(y.reshape(p * Bl, Ep * C_cap, d), o, l,
                            algorithm=alg)
    yb = back.reshape(p, Bl, Ep, C_cap, d)
    return jnp.moveaxis(yb, 0, 1).reshape(Bl, E * C_cap, d)


def moe_apply(params: dict, x: jax.Array, cfg, *, shard=None,
              dispatch: MoeDispatch | None = None):
    """x: (B, S, d). Returns (out, aux_loss).

    dispatch: expert-parallel hook (paper mode) — expert weights are per-rank
    E/p shards and slot routing runs over the manual DP axes; None keeps the
    replicated-expert GSPMD path.
    """
    shard = shard or (lambda t, _k: t)
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = capacity(cfg, S)
    dt = x.dtype

    logits = (x @ params["router"].astype(dt)).astype(jnp.float32)  # (B,S,E)
    if getattr(cfg, "router_act", "softmax") == "sigmoid":
        probs = jax.nn.sigmoid(logits)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, K)                            # (B,S,K)
    if cfg.router_norm_topk and K > 1:
        gates = gates / jnp.sum(gates, -1, keepdims=True)

    # auxiliary load-balance loss (Switch-style): E * <f_e> . <p_e>
    me = jnp.mean(jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    pe = jnp.mean(jax.nn.softmax(logits, -1), axis=(0, 1))
    aux = AUX_LOSS_W * E * jnp.sum(me * pe)

    tok_idx, weight = jax.vmap(
        lambda i, g: _dispatch_tables(i, g, E, S, K, C))(idx, gates)  # (B,E*C)

    x_pad = jnp.concatenate([x, jnp.zeros((B, 1, d), dt)], axis=1)   # sentinel
    if dispatch is not None:
        y = _ep_apply(params, x_pad, tok_idx, cfg, dispatch, C)
    else:
        disp = jnp.take_along_axis(x_pad, tok_idx[..., None], axis=1)  # (B,E*C,d)
        disp = disp.reshape(B, E, C, d)
        disp = shard(disp, "moe_act")
        y = _expert_mlp(params, disp, dt)
        y = shard(y, "moe_act")
        y = y.reshape(B, E * C, d)
    y = y * weight[..., None].astype(dt)

    out = jnp.zeros((B, S + 1, d), dt).at[
        jnp.arange(B)[:, None], tok_idx].add(y, mode="drop")[:, :S]
    out = shard(out, "act")

    if cfg.n_shared_experts:
        sh = params["shared"]
        gg = jax.nn.silu(x @ sh["gate"].astype(dt)) * (x @ sh["up"].astype(dt))
        out = out + gg @ sh["down"].astype(dt)
    return out, aux
