"""Production mesh builders.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (pods, 16, 16) chips, axes ("pod", "data", "model") — the
"pod" axis crosses the DCN boundary (the paper's non-local region boundary);
"data"/"model" stay on ICI. ``pods`` defaults to 2 and need NOT be a power
of two: the locality collectives run Algorithm 2's allgatherv adaptation on
any region count (DESIGN.md §7), so 3-, 5- and 6-pod fleets are first-class
mesh shapes.

Functions, not module-level constants: importing this module never touches
jax device state (jax fixes the device count at first backend init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, pods: int = 2):
    shape = (pods, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dp_mesh(pods: int, data: int):
    """A pure-DP ('pod','data') mesh (no model axis).

    This is the fully-manual-capable multi-pod shape: with no auto axis
    the paper-mode shard_map is manual over EVERY mesh axis, so the
    in-body locality collectives (ZeRO-3 gather, prefetch pipeline, grad
    reduce-scatter) partition even on the legacy 0.4.x SPMD partitioner —
    the mesh benchmarks/multipod.py proves the train-FSDP byte reduction
    on. A single pod degenerates to the ('data',) mesh.
    """
    if pods > 1:
        return jax.make_mesh((pods, data), ("pod", "data"))
    return jax.make_mesh((data,), ("data",))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return jax.make_mesh(shape, axes)
