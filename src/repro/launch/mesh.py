"""Production mesh builders.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis crosses the DCN boundary (the paper's non-local region boundary);
"data"/"model" stay on ICI.

Functions, not module-level constants: importing this module never touches
jax device state (jax fixes the device count at first backend init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return jax.make_mesh(shape, axes)
