"""Training launcher (CPU-runnable): smoke-scale configs on a host mesh.

``python -m repro.launch.train --arch llama3.2-3b --steps 100 --devices 8``

Runs the REDUCED config of the chosen architecture (the full configs are
exercised via the dry-run; this driver demonstrates the end-to-end loop:
data → sharded step → locality-aware grad sync → checkpoints → recovery).
"""
import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--mesh", default=None, help="e.g. 2x2x2 (pod,data,model)")
    ap.add_argument("--grad-sync", default="locality")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject simulated failures at these steps")
    args = ap.parse_args()

    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.devices}")
    import jax
    from repro import configs
    from repro.runtime import FaultInjector
    from repro.train import Trainer, TrainerConfig

    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("pod", "data", "model")[-len(shape):]
    else:
        shape = (2, args.devices // 4, 2) if args.devices >= 8 else (args.devices, 1)
        axes = ("pod", "data", "model")[:len(shape)]
    mesh = jax.make_mesh(shape, axes)
    jax.set_mesh(mesh)

    cfg = configs.get_smoke(args.arch)
    tcfg = TrainerConfig(
        steps=args.steps, seq_len=args.seq_len, global_batch=args.global_batch,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        grad_sync=args.grad_sync, lr=args.lr)
    trainer = Trainer(cfg, mesh, tcfg,
                      fault_injector=FaultInjector(tuple(args.fail_at)))
    out = trainer.run()
    print(f"[train] done: {out}")


if __name__ == "__main__":
    main()
