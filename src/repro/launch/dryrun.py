import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces, WITHOUT allocating any real arrays
(ShapeDtypeStruct stand-ins only):

  * proof the distribution config is coherent: ``.lower().compile()`` must
    succeed on the 16×16 single-pod mesh and the 2×16×16 multi-pod mesh;
  * ``compiled.memory_analysis()``  — proves the cell fits HBM;
  * ``compiled.cost_analysis()``    — HLO FLOPs / bytes for §Roofline;
  * an HLO collective scan (core/hlo_analysis.py) — collective bytes and
    the local/non-local split of every collective-permute edge.

Results land in ``results/dryrun/<arch>__<shape>__<mesh>[__tag].json``;
existing files are skipped (idempotent, resumable).

Usage::

    python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh single          # 40 cells
    python -m repro.launch.dryrun --all --mesh multi           # 40 cells
"""
import argparse
import json
import time
import traceback

import jax
import numpy as np

from repro import configs
from repro.core.hlo_analysis import Roofline, collective_stats
from repro.core.topology import device_pod_map
from repro.launch.mesh import make_production_mesh
from repro.models import encdec, transformer
from repro.serve import ServeSpec
from repro.serve.engine import cache_shardings, cache_specs, make_serve_fns
from repro.train.sharding import dp_axes, param_specs
from repro.train.step import make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def model_flops(cfg, shape) -> float:
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch          # decode: 1 tok/row


def lower_cell(cfg, shape, mesh, *, grad_sync="locality", fsdp=True,
               seq_shard=False, remat=True, moe_dispatch="auto"):
    """Returns the jax ``Lowered`` for one cell (plus the step artifacts
    for train, so the caller can record the resolved MoE dispatch)."""
    if shape.kind == "train":
        # "auto" lets make_train_step resolve expert-parallel dispatch per
        # cell: the tuning policy picks the algorithm where the config is
        # eligible (MoE arch, E and B divisible by the DP span), and the
        # cell degrades to "none" everywhere else
        art = make_train_step(cfg, mesh, grad_sync=grad_sync, fsdp=fsdp,
                              seq_shard=seq_shard, remat=remat,
                              shape=shape, moe_dispatch=moe_dispatch)
        return art.step_fn.lower(art.abstract_state,
                                 dict(cfg.input_specs(shape))), art
    if shape.kind == "prefill":
        art = make_serve_fns(cfg, mesh, ServeSpec(batch=shape.global_batch,
                                                  cache_len=shape.seq_len))
        return art.prefill_fn.lower(art.abstract_params,
                                    dict(cfg.input_specs(shape))), art
    # decode: cache of seq_len context + one-token step
    art = make_serve_fns(cfg, mesh, ServeSpec(batch=shape.global_batch,
                                              cache_len=shape.seq_len))
    c_specs = cache_specs(cfg, shape.global_batch, shape.seq_len)
    tok = jax.ShapeDtypeStruct((shape.global_batch, 1), np.int32)
    return art.decode_fn.lower(art.abstract_params, c_specs, tok), art


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             grad_sync="locality", fsdp=True, seq_shard=False, remat=True,
             moe_dispatch="auto", tag="", out_dir=RESULTS_DIR,
             force=False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch}__{shape_name}__{mesh_kind}{('__' + tag) if tag else ''}.json"
    path = os.path.join(out_dir, fname)
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    cfg = configs.get(arch)
    shape = configs.SHAPES_BY_NAME[shape_name]
    if shape_name == "long_500k" and not cfg.runs_long_500k:
        res = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
               "status": "skipped", "reason": "full-attention arch"}
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        return res

    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    n_chips = mesh.devices.size
    t0 = time.time()
    res = {"arch": arch, "shape": shape_name, "mesh": mesh_kind, "tag": tag,
           "grad_sync": grad_sync, "fsdp": fsdp, "seq_shard": seq_shard,
           "n_chips": n_chips}
    try:
        with jax.set_mesh(mesh):
            lowered, art = lower_cell(cfg, shape, mesh, grad_sync=grad_sync,
                                      fsdp=fsdp, seq_shard=seq_shard,
                                      remat=remat, moe_dispatch=moe_dispatch)
            if shape.kind == "train":
                res["moe_dispatch"] = art.moe_dispatch
                res["moe_transport"] = art.moe_transport
            compiled = lowered.compile()
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            # legacy jax (0.4.x) returns a one-element list of dicts here
            if isinstance(cost, (list, tuple)):
                cost = cost[0] if cost else {}
            hlo = compiled.as_text()
        pod_map = device_pod_map(mesh, ("pod",)) if multi else None
        stats = collective_stats(hlo, pod_map)
        from repro.telemetry import comm_report
        rep = comm_report(hlo, mesh,
                          label=f"{arch}/{shape_name}/{mesh_kind}")
        res["comm"] = rep.asdict()
        res["locality_schedule"] = rep.has_locality_schedule
        if (multi and shape.kind == "train" and grad_sync == "locality"
                and not rep.has_locality_schedule):
            # the paper's schedule lowers to pod-crossing collective
            # permutes; a locality-configured train cell compiling to HLO
            # with NONE has silently regressed to flat XLA collectives
            raise AssertionError(
                "locality regression: grad_sync='locality' on a multi-pod "
                "mesh compiled to zero pod-crossing collective-permute "
                "edges (flat XLA collectives took over)")
        mf = model_flops(cfg, shape)
        roof = Roofline(flops=float(cost.get("flops", 0.0)),
                        hbm_bytes=float(cost.get("bytes accessed", 0.0)),
                        collective_bytes=float(stats.total_bytes),
                        n_chips=n_chips, model_flops=mf)
        res.update({
            "status": "ok",
            "compile_s": round(time.time() - t0, 1),
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            },
            "cost": {k: float(v) for k, v in cost.items()
                     if isinstance(v, (int, float))},
            "collectives": {
                "counts": dict(stats.counts),
                "bytes": dict(stats.bytes_),
                "permute_edges_local": stats.permute_edges_local,
                "permute_edges_nonlocal": stats.permute_edges_nonlocal,
                "permute_bytes_nonlocal": stats.permute_bytes_nonlocal,
                "group_msgs_nonlocal": stats.group_msgs_nonlocal,
                "group_bytes_nonlocal": stats.group_bytes_nonlocal,
                # the DCN ground truth (permute edges exact + ring-modeled
                # group collectives) benchmarks/multipod.py gates on
                "nonlocal_msgs": stats.nonlocal_msgs,
                "nonlocal_bytes": stats.nonlocal_bytes,
            },
            "model_flops": mf,
            "roofline": roof.row(),
        })
    except Exception as e:  # record the failure — these are bugs to fix
        res.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                    "trace": traceback.format_exc()[-2000:],
                    "compile_s": round(time.time() - t0, 1)})
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--grad-sync", default="locality")
    ap.add_argument("--moe-dispatch", default="auto",
                    choices=["none", "locality", "xla", "auto"])
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    cells = []
    archs = configs.ARCHS if (args.all or not args.arch) else [args.arch]
    for arch in archs:
        cfg = configs.get(arch)
        shapes = ([args.shape] if args.shape
                  else [s.name for s in cfg.shapes()])
        for s in shapes:
            cells.append((arch, s))

    for arch, s in cells:
        r = run_cell(arch, s, args.mesh, grad_sync=args.grad_sync,
                     fsdp=not args.no_fsdp, seq_shard=args.seq_shard,
                     remat=not args.no_remat, moe_dispatch=args.moe_dispatch,
                     tag=args.tag, out_dir=args.out, force=args.force)
        if r["status"] == "ok":
            roof = r["roofline"]
            print(f"[dryrun] {arch:24s} {s:12s} {args.mesh:6s} OK "
                  f"compile={r['compile_s']:.0f}s "
                  f"dom={roof['dominant']:10s} "
                  f"roofline={roof['roofline_fraction']:.3f} "
                  f"peak={_gb(r['memory']['peak_bytes'])}")
        elif r["status"] == "skipped":
            print(f"[dryrun] {arch:24s} {s:12s} {args.mesh:6s} SKIP "
                  f"({r['reason']})")
        else:
            print(f"[dryrun] {arch:24s} {s:12s} {args.mesh:6s} ERROR "
                  f"{r['error'][:120]}")


def _gb(b):
    return f"{b / 2**30:.2f}GiB" if b else "n/a"


if __name__ == "__main__":
    main()
