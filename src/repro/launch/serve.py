"""Serving launcher (CPU-runnable): batched greedy decoding on a host mesh.

``python -m repro.launch.serve --arch mamba2-780m --batch 8 --max-new 16``
"""
import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.devices}")
    import time

    import jax
    import numpy as np
    from repro import configs
    from repro.models import encdec, transformer
    from repro.serve import Engine, Request, ServeSpec

    mesh = jax.make_mesh((2, args.devices // 4, 2) if args.devices >= 8
                         else (args.devices, 1),
                         ("pod", "data", "model")[:3 if args.devices >= 8 else 2])
    jax.set_mesh(mesh)

    cfg = configs.get_smoke(args.arch)
    mod = encdec if cfg.family == "audio" else transformer
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    if cfg.family == "audio":
        raise SystemExit("use examples/serve_batched.py for the enc-dec path")
    # round the cache up to page granularity (page_len must divide cache_len)
    need = args.prompt_len + args.max_new
    spec = ServeSpec(batch=args.batch, cache_len=-(-need // 16) * 16)
    eng = Engine(cfg, mesh, params, spec)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len), dtype=np.int32)
    t0 = time.perf_counter()
    for i in range(args.batch):
        eng.submit(Request(tokens=prompts[i], max_new=args.max_new))
    results = eng.drain()
    dt = time.perf_counter() - t0
    sample = results[0].tokens[:12]
    print(f"[serve] drained {len(results)} requests "
          f"({args.batch * args.max_new} tokens) in {dt:.2f}s "
          f"({args.batch * args.max_new / dt:.1f} tok/s); sample: {sample}")


if __name__ == "__main__":
    main()
