"""Model/arch configuration schema shared by the model zoo and the launcher.

A ``ModelConfig`` fully determines an architecture: the layer plan (which
mixer — attention variant or Mamba2 — plus which MLP — dense or MoE — at
every depth), all dimensions, and the modality frontend stub. ``shapes()``
yields the assigned input-shape set; ``input_specs()`` builds the
ShapeDtypeStruct stand-ins used by the multi-pod dry-run (never allocates).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Per-layer plan entries
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer of the stack: a (mixer, mlp) pair.

    mixer: 'attn' | 'mamba2' | 'shared_attn' (zamba2 weight-reuse block)
    attn:  'full' | 'window' | 'chunked' | 'none' (bidirectional for encoders
           is selected by the model kind, not per-layer)
    mlp:   'dense' | 'moe' | 'none'
    rope:  rotary applied to this layer's attention (False => NoPE)
    """

    mixer: str = "attn"
    attn: str = "full"
    mlp: str = "dense"
    rope: bool = True

    def key(self) -> tuple:
        return (self.mixer, self.attn, self.mlp, self.rope)


# ---------------------------------------------------------------------------
# Shapes assigned to every LM-family architecture
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = (
    ShapeSpec("train_4k", 4_096, 256, "train"),
    ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    ShapeSpec("decode_32k", 32_768, 128, "decode"),
    ShapeSpec("long_500k", 524_288, 1, "decode"),
)
SHAPES_BY_NAME = {s.name: s for s in SHAPES}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 => d_model // n_heads

    # --- attention options ---------------------------------------------------
    rope_theta: float = 10_000.0
    window: int = 0                 # sliding-window size (0 = unused)
    chunk: int = 0                  # chunked-local attention size (llama4 iRoPE)
    attn_pattern: tuple[str, ...] = ("full",)   # per-layer cycle
    nope_every: int = 0             # every k-th layer: global attention, no RoPE
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    qk_norm: bool = False
    sandwich_norm: bool = False     # gemma2 post-norms
    mlp_act: str = "silu"           # silu (SwiGLU) | gelu (GeGLU) | gelu_mlp
    scale_embed: bool = False       # gemma2/whisper: x *= sqrt(d_model)
    norm_type: str = "rms"          # rms | ln (whisper)

    # --- MoE ------------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0               # expert hidden dim (0 => d_ff)
    n_shared_experts: int = 0
    d_shared_expert: int = 0        # hidden dim of the always-on shared FFN
    moe_every: int = 1              # MoE MLP at layers where i % moe_every == 0
    router_norm_topk: bool = True   # normalize top-k weights to sum to 1
    router_act: str = "softmax"     # softmax | sigmoid (llama4)
    capacity_factor: float = 1.25

    # --- SSM (Mamba2) ----------------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # --- hybrid (zamba2): shared attention block every k mamba layers ----------
    shared_attn_every: int = 0

    # --- enc-dec (whisper) ------------------------------------------------------
    n_enc_layers: int = 0
    enc_seq: int = 0                # stub frontend sequence length (frames)

    # --- vlm -------------------------------------------------------------------
    n_img_tokens: int = 0           # stub patch-embedding prefix length

    # --- embedding / misc --------------------------------------------------------
    tie_embeddings: bool = True
    vocab_pad_multiple: int = 256
    norm_eps: float = 1e-5
    dtype: Any = jnp.bfloat16       # activation/compute dtype
    param_dtype: Any = jnp.float32

    # ------------------------------------------------------------------ helpers
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab_size // m) * m

    @property
    def sub_quadratic(self) -> bool:
        """True if decode state is o(seq): SSM/hybrid/windowed/chunked-local.

        Archs with ANY full-attention layer (incl. gemma2's alternating global
        layers and llama4's NoPE global layers) hold a full-length KV cache on
        those layers, but remain sub-quadratic in *compute* per token; the
        long_500k applicability rule tracks attention-free/windowed archs plus
        chunked/hybrid designs (see DESIGN.md §Arch-applicability).
        """
        if self.family in ("ssm", "hybrid"):
            return True
        plan = self.layer_plan()
        return all(s.attn in ("window", "chunked", "none") or s.mixer != "attn"
                   for s in plan)

    @property
    def runs_long_500k(self) -> bool:
        # per assignment: run for SSM/hybrid/linear-attn (+ windowed/chunked
        # which are O(1)-state per token); skip pure full-attention archs.
        if self.family in ("ssm", "hybrid"):
            return True
        plan = self.layer_plan()
        n_full = sum(1 for s in plan if s.mixer == "attn" and s.attn == "full")
        return n_full == 0 or (self.chunk > 0)  # llama4: 3/4 chunked

    def shapes(self) -> tuple[ShapeSpec, ...]:
        out = []
        for s in SHAPES:
            if s.name == "long_500k" and not self.runs_long_500k:
                continue
            out.append(s)
        return tuple(out)

    # ------------------------------------------------------------------ plan
    def layer_plan(self) -> tuple[LayerSpec, ...]:
        """The (mixer, mlp) pair at every depth, derived from the family."""
        plan: list[LayerSpec] = []
        if self.family == "ssm":
            return tuple(LayerSpec(mixer="mamba2", attn="none", mlp="none")
                         for _ in range(self.n_layers))
        if self.family == "hybrid":
            # zamba2: mamba2 trunk; a weight-shared attention block is applied
            # after every `shared_attn_every` mamba layers.
            for i in range(self.n_layers):
                plan.append(LayerSpec(mixer="mamba2", attn="none", mlp="none"))
                if self.shared_attn_every and (i + 1) % self.shared_attn_every == 0:
                    plan.append(LayerSpec(mixer="shared_attn", attn="full",
                                          mlp="dense"))
            return tuple(plan)
        for i in range(self.n_layers):
            if self.nope_every and (i + 1) % self.nope_every == 0:
                attn, rope = "full", False          # llama4 global-NoPE layer
            else:
                attn = self.attn_pattern[i % len(self.attn_pattern)]
                rope = True
            mlp = "moe" if (self.n_experts and i % self.moe_every == 0) else "dense"
            plan.append(LayerSpec(mixer="attn", attn=attn, mlp=mlp, rope=rope))
        return tuple(plan)

    # ------------------------------------------------------------------ inputs
    def input_specs(self, shape: ShapeSpec | str) -> dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for every model input of ``shape``.

        train:   tokens/labels (B, S) — next-token targets.
        prefill: tokens (B, S) — returns logits for the last position + cache.
        decode:  tokens (B, 1) + the KV/SSM cache for a context of S tokens
                 (cache specs come from ``serve.cache_specs``; this returns the
                 token-side inputs only — the launcher composes the two).
        Modality stubs: whisper adds precomputed frame embeddings; internvl2
        adds patch embeddings that occupy the first ``n_img_tokens`` positions.
        """
        if isinstance(shape, str):
            shape = SHAPES_BY_NAME[shape]
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        sd = jax.ShapeDtypeStruct
        out: dict[str, jax.ShapeDtypeStruct] = {}
        if shape.kind == "train":
            out["tokens"] = sd((B, S), i32)
            out["labels"] = sd((B, S), i32)
        elif shape.kind == "prefill":
            out["tokens"] = sd((B, S), i32)
        else:  # decode
            out["tokens"] = sd((B, 1), i32)
        if self.family == "audio":
            out["frames"] = sd((B, self.enc_seq, self.d_model), self.dtype)
        if self.family == "vlm" and shape.kind != "decode":
            out["img_embeds"] = sd((B, self.n_img_tokens, self.d_model), self.dtype)
        return out

    # ------------------------------------------------------------------ sizes
    def param_count(self) -> int:
        """Exact parameter count of the built model (embedding included once
        if tied). Used for MODEL_FLOPS = 6·N·D roofline accounting."""
        from repro.models import transformer, encdec  # local import, no cycle
        if self.family == "audio":
            return encdec.param_count(self)
        return transformer.param_count(self)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top_k experts only)."""
        from repro.models import transformer, encdec
        if self.family == "audio":
            return encdec.param_count(self)
        return transformer.param_count(self, active_only=True)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family variant for CPU smoke tests."""
    base = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2),
        d_ff=256,
        vocab_size=512,
        head_dim=32,
        window=min(cfg.window, 64) if cfg.window else 0,
        chunk=min(cfg.chunk, 64) if cfg.chunk else 0,
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        d_expert=64 if cfg.n_experts else 0,
        n_shared_experts=min(cfg.n_shared_experts, 2),
        d_shared_expert=64 if cfg.n_shared_experts else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_headdim=16 if cfg.ssm_state else 64,
        ssm_chunk=32,
        shared_attn_every=2 if cfg.shared_attn_every else 0,
        n_enc_layers=min(cfg.n_enc_layers, 2),
        enc_seq=32 if cfg.enc_seq else 0,
        n_img_tokens=8 if cfg.n_img_tokens else 0,
        vocab_pad_multiple=64,
        name=cfg.name + "-smoke",
    )
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
