"""Architecture registry: ``get(name)`` / ``get_smoke(name)`` / ``ARCHS``."""
from __future__ import annotations

import importlib

from .base import ModelConfig, ShapeSpec, SHAPES, SHAPES_BY_NAME, reduced

ARCHS = (
    "zamba2-1.2b",
    "qwen2-moe-a2.7b",
    "llama4-scout-17b-a16e",
    "h2o-danube-3-4b",
    "gemma2-9b",
    "llama3.2-3b",
    "yi-6b",
    "mamba2-780m",
    "whisper-tiny",
    "internvl2-26b",
)

_MODULES = {name: name.replace("-", "_").replace(".", "_") for name in ARCHS}


def get(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {list(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def get_smoke(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return getattr(mod, "SMOKE", None) or reduced(mod.CONFIG)
