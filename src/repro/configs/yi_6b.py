"""yi-6b [dense] — llama-arch GQA, kv=4.

32L, d_model=4096, 32H (kv=4), d_ff=11008, vocab=64000, rope 5M.
[arXiv:2403.04652; hf]. long_500k skipped (full attention).
"""
from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="yi-6b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    head_dim=128,
    rope_theta=5000000.0,
    tie_embeddings=False,
)

SMOKE = reduced(CONFIG)
