"""whisper-tiny [audio] — encoder-decoder; conv frontend STUB.

4 enc + 4 dec layers, d_model=384, 6H (kv=6), d_ff=1536, vocab=51865.
``input_specs()`` supplies precomputed frame embeddings (B, 1500, 384).
[arXiv:2212.04356]. LayerNorm + plain GELU MLP; sinusoidal positions both
sides (DESIGN.md). long_500k skipped (full attention).
"""
from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,
    n_enc_layers=4,
    enc_seq=1500,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    head_dim=64,
    norm_type="ln",
    mlp_act="gelu_mlp",
    scale_embed=False,
    tie_embeddings=True,
)

SMOKE = reduced(CONFIG)
