"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed experts, top-4.

24L, d_model=2048, 16H (kv=16), expert d_ff=1408, vocab=151936.
[hf:Qwen/Qwen1.5-MoE-A2.7B]. The 60 routed experts are padded to 64 for
EP=16 divisibility (4 never-routed experts; capacity unaffected —
DESIGN.md §Arch-applicability).
"""
from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    head_dim=128,
    n_experts=64,                 # 60 routed + 4 padding experts
    top_k=4,
    d_expert=1408,
    n_shared_experts=4,
    d_shared_expert=5632,         # 4 × 1408 always-on shared FFN
    router_norm_topk=True,
    tie_embeddings=False,
)

SMOKE = reduced(CONFIG)
