"""mamba2-780m [ssm] — attention-free SSD (state-space duality).

48L, d_model=1536, ssm_state=128, headdim=64 (→ 48 SSD heads at expand=2),
vocab=50280. [arXiv:2405.21060]. O(1) decode state → long_500k runs.
The paper's allgather applies only at the communication layer (no attention
to shard) — DESIGN.md §Arch-applicability.
"""
from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=1,            # attention-free; SSD heads derive from ssm dims
    n_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_ngroups=1,
    ssm_chunk=256,
    tie_embeddings=True,
)

SMOKE = reduced(CONFIG)
