"""internvl2-26b [vlm] — InternLM2-20B backbone; InternViT frontend STUB.

48L, d_model=6144, 48H (kv=8), d_ff=16384, vocab=92553.
``input_specs()`` supplies precomputed patch embeddings (B, 256, d_model)
that occupy the first 256 backbone positions. [arXiv:2404.16821; hf].
long_500k skipped (full attention).
"""
from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    head_dim=128,
    rope_theta=1000000.0,
    n_img_tokens=256,
    tie_embeddings=False,
)

SMOKE = reduced(CONFIG)
