"""llama3.2-3b [dense] — small llama3; pure full attention.

28L, d_model=3072, 24H (kv=8), d_ff=8192, vocab=128256, rope 500k.
[hf:meta-llama/Llama-3.2-3B]. long_500k skipped (full attention).
"""
from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="llama3.2-3b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    head_dim=128,
    rope_theta=500000.0,
    tie_embeddings=True,
)

SMOKE = reduced(CONFIG)
