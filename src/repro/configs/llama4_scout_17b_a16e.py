"""llama4-scout-17b-a16e [moe] — 16 experts top-1 + shared expert, iRoPE.

48L, d_model=5120, 40H (kv=8), expert d_ff=8192, vocab=202048.
iRoPE: chunked-local attention (8192) on 3 of every 4 layers; every 4th
layer is global attention with NO rope (NoPE). Sigmoid router, top-1.
Early-fusion vision is stubbed (text-only LM shapes; DESIGN.md).
[hf:meta-llama/Llama-4-Scout-17B-16E]
"""
from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    head_dim=128,
    rope_theta=500000.0,
    chunk=8192,
    attn_pattern=("chunked",),
    nope_every=4,
    n_experts=16,
    top_k=1,
    d_expert=8192,
    n_shared_experts=1,
    d_shared_expert=8192,
    router_act="sigmoid",
    router_norm_topk=False,
    qk_norm=True,
    tie_embeddings=False,
)

SMOKE = reduced(CONFIG)
