"""gemma2-9b [dense] — alternating local(4096)/global attention, softcaps.

42L, d_model=3584, 16H (kv=8), d_ff=14336, vocab=256000, head_dim=256,
GeGLU, sandwich norms, attn softcap 50, final softcap 30, scaled embeds.
[arXiv:2408.00118; hf]. Global layers are full attention → long_500k
skipped (DESIGN.md §Arch-applicability).
"""
from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=256000,
    head_dim=256,
    window=4096,
    attn_pattern=("window", "full"),
    attn_softcap=50.0,
    final_softcap=30.0,
    sandwich_norm=True,
    mlp_act="gelu",
    scale_embed=True,
    tie_embeddings=True,
)

SMOKE = reduced(CONFIG)
