"""zamba2-1.2b [hybrid] — Mamba2 trunk + one weight-shared attention block.

38 Mamba2 layers, d_model=2048, shared attn block (32H, kv=32, d_ff=8192)
applied every 6 Mamba2 layers, vocab 32000, ssm_state=64.
[arXiv:2411.15242; hf]. Zamba2's per-application LoRA deltas on the shared
block are simplified to pure weight reuse (DESIGN.md §Arch-applicability).
"""
from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    head_dim=64,
    ssm_state=64,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_chunk=128,   # §Perf: halves the (H,Q,Q) dual-form score footprint

    shared_attn_every=6,
    mlp_act="gelu",
)

SMOKE = reduced(CONFIG)
