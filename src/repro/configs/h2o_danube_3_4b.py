"""h2o-danube-3-4b [dense] — llama+mistral mix with sliding-window attention.

24L, d_model=3840, 32H (kv=8), d_ff=10240, vocab=32000, window=4096.
[arXiv:2401.16818]. All layers windowed → O(window) decode state →
long_500k runs.
"""
from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    head_dim=120,
    window=4096,
    attn_pattern=("window",),
    rope_theta=10000.0,
    tie_embeddings=False,
)

SMOKE = reduced(CONFIG)
