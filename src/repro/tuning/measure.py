"""Micro-benchmark harness for collective algorithms.

Two executors behind one ``measure()`` entry point:

* **real** — wall-clock timing of the actual shard_map/ppermute collective
  on the live mesh: jit, warmup, ``block_until_ready``, median of k. This is
  the number that matters on TPU/GPU fleets.
* **simulated** — deterministic stand-in for containers with one CPU device
  (CI, laptops): the *schedule generators* of ``core/schedules.py`` execute
  the algorithm over an abstract network and each synchronous round is
  priced with ``core/cost_model.schedule_cost(mode="round")`` under a named
  machine parameter set. "Measured" is therefore the per-round simulation on
  real schedules while "modeled" stays the paper's closed forms (Eqs. 3-4)
  — the two disagree exactly where Fig. 9 shows the closed forms mispredict
  (final-round over-count, non-power region counts), so the policy layer has
  a genuine crossover signal to learn even on CPU.

The machine fingerprint keys cache entries so a table measured on one
platform is never consulted on another.
"""
from __future__ import annotations

import dataclasses
import statistics
import time

from repro.core import cost_model, schedules
from repro.core.topology import RegionMap, ceil_log

ALLGATHER_ALGORITHMS = tuple(schedules.ALGORITHMS)   # the five paper algs
ALLREDUCE_ALGORITHMS = ("locality", "xla")
LOGSUMEXP_ALGORITHMS = ("locality", "xla")
OVERLAP_ALGORITHMS = ("eager", "prefetch")
MIGRATE_ALGORITHMS = ("locality_bruck", "multilane", "xla")
ALL_TO_ALL_ALGORITHMS = ("locality", "xla")   # == collectives.ALL_TO_ALL_ALGORITHMS

# Serving head dims are 64-128; the running-max phase of the logsumexp
# combine moves payload/(D+1) bytes. Priced at D=64 (the conservative end:
# the largest relative max-phase cost).
LOGSUMEXP_HEAD_DIM = 64

# The overlap term is a function of (topology, bytes, FLOPs) but the table
# schema is 2-D (topology × byte bucket), so arithmetic intensity
# (flops-per-gathered-byte) is folded into the collective NAME at octave
# resolution: "overlap:i<k>" covers intensities in (2^{k-1}, 2^k]. For an
# FSDP transformer layer the intensity is ≈ tokens-per-device-per-step
# (flops ≈ 2·params·tokens, bytes ≈ 2·params), so the sweep default spans
# small-batch (2^7) to large-batch (2^13) regimes.
OVERLAP_INTENSITY_OCTAVES = (7, 10, 13)


def overlap_collective(flops_per_byte: float) -> str:
    """Collective name keying the overlap term's intensity octave."""
    import math
    k = max(0, math.ceil(math.log2(max(flops_per_byte, 1.0))))
    return f"overlap:i{k}"


def overlap_intensity(collective: str) -> float:
    """Representative flops-per-byte of an "overlap:i<k>" collective name."""
    return float(2 ** int(collective.split(":i", 1)[1]))


@dataclasses.dataclass(frozen=True)
class Fingerprint:
    """Identity of the machine a measurement is valid for."""

    platform: str          # jax backend: cpu / tpu / gpu
    device_kind: str
    n_devices: int
    simulated_machine: str = ""   # set when the simulated executor was used

    def key(self) -> str:
        kind = self.device_kind.replace(" ", "_").replace("|", "_")
        base = f"{self.platform}:{kind}:{self.n_devices}"
        return f"sim:{self.simulated_machine}" if self.simulated_machine else base

    @classmethod
    def detect(cls, simulated_machine: str = "") -> "Fingerprint":
        import jax
        devs = jax.devices()
        return cls(platform=jax.default_backend(),
                   device_kind=devs[0].device_kind,
                   n_devices=len(devs),
                   simulated_machine=simulated_machine)


# ---------------------------------------------------------------------------
# simulated executor
# ---------------------------------------------------------------------------
def simulate_allgather(algorithm: str, p: int, p_local: int,
                       nbytes_per_rank: float,
                       machine: cost_model.MachineParams | str) -> float:
    """Round-synchronous schedule simulation (seconds, deterministic)."""
    if isinstance(machine, str):
        machine = cost_model.MACHINES[machine]
    if p <= 1:
        return 0.0
    sched = schedules.ALGORITHMS[algorithm](p, p_local)
    region = RegionMap(p=p, p_local=p_local)
    return cost_model.schedule_cost(sched, machine, nbytes_per_rank,
                                    region=region, mode="round")


def simulate_allreduce(algorithm: str, p: int, p_local: int,
                       nbytes: float,
                       machine: cost_model.MachineParams | str) -> float:
    """Deterministic model of the two allreduce structures we can emit.

    "xla": flat ring reduce-scatter + ring allgather — 2(p-1) neighbor
    messages of nbytes/p, of which 2·r cross a region boundary.
    "locality": core/collectives.allreduce(algorithm="locality") — local ring RS, per
    lane across regions a recursive-halving RS + Bruck AG (power-of-two
    region counts) or the Bruck-transpose RS + Bruck AG of the allgatherv
    adaptation (any other count) — both 2·ceil(log2 r) non-local messages
    moving 2·(r-1)/r of the per-lane shard, so one formula prices both.
    """
    if isinstance(machine, str):
        machine = cost_model.MACHINES[machine]
    if p <= 1:
        return 0.0
    region = RegionMap(p=p, p_local=p_local)
    r, pl = region.n_regions, p_local
    if algorithm == "xla":
        n = 2 * (p - 1)
        per = nbytes / p
        n_nl = 2 * r if r > 1 else 0
        n_l = n - n_nl
        return machine.cost(n_local=n_l, s_local=per * n_l,
                            n_nonlocal=n_nl, s_nonlocal=per * n_nl)
    if algorithm == "locality":
        t = 0.0
        if pl > 1:   # local ring reduce-scatter
            t += machine.cost(n_local=pl - 1,
                              s_local=nbytes * (pl - 1) / pl,
                              n_nonlocal=0, s_nonlocal=0.0)
        shard = nbytes / pl
        if r > 1:    # recursive-halving RS + Bruck AG over regions, per lane
            lg = ceil_log(2, r)
            t += machine.cost(n_local=0, s_local=0.0, n_nonlocal=2 * lg,
                              s_nonlocal=2.0 * shard * (r - 1) / r)
        if pl > 1:   # local Bruck allgather of the reduced shards
            t += machine.cost(n_local=ceil_log(2, pl),
                              s_local=nbytes * (pl - 1) / pl,
                              n_nonlocal=0, s_nonlocal=0.0)
        return t
    raise ValueError(f"unknown allreduce algorithm {algorithm!r}")


def simulate_logsumexp_combine(algorithm: str, p: int, p_local: int,
                               nbytes: float,
                               machine: cost_model.MachineParams | str,
                               head_dim: int = LOGSUMEXP_HEAD_DIM) -> float:
    """Two-phase decode cache-combine: max-allreduce of the running maxima
    (payload nbytes/(head_dim+1)) then the packed o+l sum-allreduce
    (payload nbytes). "xla" prices GSPMD's implicit combine (flat recursive
    doubling for the max, flat ring for the sum); "locality" the explicit
    ``collectives.logsumexp_combine`` structure. The two-phase
    accounting replaces the single-sum-allreduce pricing the serve layer
    used before it could execute the combine.
    """
    if isinstance(machine, str):
        machine = cost_model.MACHINES[machine]
    if p <= 1:
        return 0.0
    max_bytes = nbytes / (head_dim + 1)
    if algorithm == "xla":
        return (cost_model.max_allreduce_model(p, p_local, max_bytes, machine,
                                               structure="flat")
                + simulate_allreduce("xla", p, p_local, nbytes, machine))
    if algorithm == "locality":
        return (cost_model.max_allreduce_model(p, p_local, max_bytes, machine,
                                               structure="locality")
                + simulate_allreduce("locality", p, p_local, nbytes, machine))
    raise ValueError(f"unknown logsumexp_combine algorithm {algorithm!r}")


def simulate_cache_migrate(algorithm: str, p: int, p_local: int,
                           nbytes: float,
                           machine: cost_model.MachineParams | str) -> float:
    """KV-slab migration (``core/collectives.cache_migrate``): replicate a
    sequence-sharded cache slab over the full mesh so the destination insert
    can mask it into the owning batch row.

    Executes the same schedule generators as the activation allgather, but
    keyed as its own tuning cell: slab payloads (a whole request's KV) sit
    orders of magnitude above decode activations, so the α-dominated
    locality schedule and the β-dominated multilane schedule cross over in
    a different byte regime. "xla" prices GSPMD's flat all-gather at its
    ring decomposition (every hop a potential boundary crossing).
    """
    if algorithm not in MIGRATE_ALGORITHMS:
        raise ValueError(f"unknown cache_migrate algorithm {algorithm!r}")
    sched_alg = "ring" if algorithm == "xla" else algorithm
    return simulate_allgather(sched_alg, p, p_local, nbytes, machine)


def simulate_all_to_all(algorithm: str, p: int, p_local: int,
                        nbytes: float,
                        machine: cost_model.MachineParams | str) -> float:
    """Personalized exchange (``core/collectives.all_to_all`` — the MoE
    dispatch transport). ``nbytes`` is the per-rank buffer; the schedules
    count blocks in (source, destination)-pair units of ``nbytes / p``.
    Round-synchronous pricing over the ``ALL_TO_ALL_SCHEDULES`` oracles:
    "locality" is the two-tier exchange (q-1 aggregated DCN messages per
    region), "xla" the flat p-1-round pairwise rotation GSPMD emits.
    """
    if isinstance(machine, str):
        machine = cost_model.MACHINES[machine]
    if algorithm not in ALL_TO_ALL_ALGORITHMS:
        raise ValueError(f"unknown all_to_all algorithm {algorithm!r}")
    if p <= 1:
        return 0.0
    sched = schedules.ALL_TO_ALL_SCHEDULES[algorithm](p, p_local)
    region = RegionMap(p=p, p_local=p_local)
    return cost_model.schedule_cost(sched, machine, nbytes / p,
                                    region=region, mode="round")


def simulate_overlap(algorithm: str, p: int, p_local: int, nbytes: float,
                     machine: cost_model.MachineParams | str, *,
                     flops: float | None = None,
                     flops_per_byte: float | None = None) -> float:
    """Per-layer step-time under the eager vs prefetched gather schedule.

    ``nbytes`` is the per-rank shard of one layer's parameters. The compute
    window is ``flops`` (exact, when the caller knows the layer) or
    ``flops_per_byte · nbytes`` (the octave representative the sweep grids
    over). Deterministic — there is no wall-clock overlap executor; real
    overlap is measured end-to-end by ``benchmarks/overlap.py``.
    """
    if isinstance(machine, str):
        machine = cost_model.MACHINES[machine]
    if algorithm not in OVERLAP_ALGORITHMS:
        raise ValueError(f"unknown overlap algorithm {algorithm!r}")
    if flops is None:
        flops = (flops_per_byte or 1.0) * nbytes
    oc = cost_model.overlap_model(p, p_local, nbytes, flops, machine)
    return oc.step_time(prefetch=(algorithm == "prefetch"))


def simulate(collective: str, algorithm: str, p: int, p_local: int,
             nbytes: float, machine: cost_model.MachineParams | str) -> float:
    if collective == "allgather":
        return simulate_allgather(algorithm, p, p_local, nbytes, machine)
    if collective == "allreduce":
        return simulate_allreduce(algorithm, p, p_local, nbytes, machine)
    if collective == "logsumexp_combine":
        return simulate_logsumexp_combine(algorithm, p, p_local, nbytes,
                                          machine)
    if collective == "cache_migrate":
        return simulate_cache_migrate(algorithm, p, p_local, nbytes, machine)
    if collective == "all_to_all":
        return simulate_all_to_all(algorithm, p, p_local, nbytes, machine)
    if collective.startswith("overlap:i"):
        return simulate_overlap(algorithm, p, p_local, nbytes, machine,
                                flops_per_byte=overlap_intensity(collective))
    raise ValueError(f"unknown collective {collective!r}")


# ---------------------------------------------------------------------------
# measured dispatch overhead (the overlap policy's reality check)
# ---------------------------------------------------------------------------
_DISPATCH_OVERHEAD: float | None = None


def dispatch_overhead_s(*, iters: int = 20, refresh: bool = False) -> float:
    """Measured (not modeled) per-dispatch overhead of the live backend.

    Times a cached trivial jitted computation end to end (dispatch + sync)
    and returns the median — the floor cost every extra issued collective /
    unrolled pipeline stage pays on this host. ``Policy.select_overlap``
    compares this MEASURED quantity against the MODELED hidden
    communication of the prefetch schedule: on a host-CPU harness there is
    no real wire, the modeled hidden time is fiction, and the dispatch
    overhead is what the double-buffered pipeline actually adds per layer
    (the BENCH_overlap wall-clock regression: prefetched slower than eager
    on CPU). Cached per process.
    """
    global _DISPATCH_OVERHEAD
    if _DISPATCH_OVERHEAD is not None and not refresh:
        return _DISPATCH_OVERHEAD
    import jax
    import jax.numpy as jnp
    f = jax.jit(lambda x: x + 1.0)
    x = jnp.zeros((8,), jnp.float32)
    jax.block_until_ready(f(x))                 # compile outside the timing
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(f(x))
        samples.append(time.perf_counter() - t0)
    _DISPATCH_OVERHEAD = statistics.median(samples)
    return _DISPATCH_OVERHEAD


# ---------------------------------------------------------------------------
# real executor
# ---------------------------------------------------------------------------
def _measure_real(collective: str, algorithm: str, p: int, p_local: int,
                  nbytes: float, dtype: str, *, iters: int, warmup: int) -> float:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.core import collectives as C

    devs = jax.devices()
    if len(devs) < p:
        raise RuntimeError(f"need {p} devices, have {len(devs)}")
    r = p // p_local
    mesh_devs = np.asarray(devs[:p]).reshape(r, p_local)
    mesh = jax.sharding.Mesh(mesh_devs, ("outer", "local"))
    itemsize = jnp.dtype(dtype).itemsize
    n_elems = max(1, int(nbytes) // itemsize)
    x = jnp.zeros((p * n_elems,), dtype=dtype)

    if collective == "allgather":
        def body(s):
            return C.allgather(s, "outer", "local", algorithm=algorithm,
                               tiled=True)
    elif collective == "cache_migrate":
        def body(s):
            return C.cache_migrate(s, "outer", "local", algorithm=algorithm,
                                   tiled=True)
    elif collective == "allreduce":
        def body(s):
            return C.allreduce(s, "outer", "local", algorithm=algorithm)
    elif collective == "all_to_all":
        # per-rank buffer must split p ways: round the element count up
        n_elems = -(-n_elems // p) * p
        x = jnp.zeros((p * n_elems,), dtype=dtype)

        def body(s):
            return C.all_to_all(s, "outer", "local", algorithm=algorithm)
    elif collective == "logsumexp_combine":
        # payload layout mirrors the decode stats: (n, D) o-accumulator +
        # (n,) running max + (n,) sumexp, n rows per rank
        D = LOGSUMEXP_HEAD_DIM
        n_rows = max(1, int(nbytes) // ((D + 1) * itemsize))
        x = (jnp.zeros((p * n_rows, D), dtype), jnp.zeros((p * n_rows,), dtype),
             jnp.ones((p * n_rows,), dtype))

        def body(o, m, l):
            ot, lt = C.logsumexp_combine(
                o, m, l, "outer", "local", algorithm=algorithm)
            return ot, lt
    else:
        raise ValueError(f"unknown collective {collective!r}")

    if collective == "logsumexp_combine":
        spec = P(("outer", "local"))
        f = jax.jit(jax.shard_map(body, mesh=mesh,
                                  in_specs=(spec, spec, spec),
                                  out_specs=(spec, spec), check_vma=False))
        args = x
    else:
        f = jax.jit(jax.shard_map(body, mesh=mesh,
                                  in_specs=P(("outer", "local")),
                                  out_specs=P(("outer", "local"))))
        args = (x,)

    def run():
        out = f(*args)
        jax.block_until_ready(out)

    for _ in range(warmup):
        run()
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        run()
        samples.append(time.perf_counter() - t0)
    return statistics.median(samples)


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------
def measure(collective: str, algorithm: str, p: int, p_local: int,
            nbytes: float, dtype: str = "float32", *, mode: str = "auto",
            machine: str = "lassen", iters: int = 5, warmup: int = 2) -> float:
    """Median time (seconds) for one collective configuration.

    mode: "real" (wall clock on the live mesh), "simulated" (deterministic
    schedule pricing under ``machine``), or "auto" — real on accelerator
    backends with enough devices, simulated otherwise (the CPU fallback
    that makes sweeps runnable in single-device containers).

    The overlap term ("overlap:i<k>") is always simulated: its "real"
    number needs a fused compute+gather pipeline, which is exactly what
    ``benchmarks/overlap.py`` measures end-to-end.
    """
    if collective.startswith("overlap:"):
        mode = "simulated"
    if mode == "auto":
        import jax
        real = jax.default_backend() != "cpu" and len(jax.devices()) >= p
        mode = "real" if real else "simulated"
    if mode == "simulated":
        return simulate(collective, algorithm, p, p_local, nbytes, machine)
    if mode == "real":
        return _measure_real(collective, algorithm, p, p_local, nbytes, dtype,
                             iters=iters, warmup=warmup)
    raise ValueError(f"unknown mode {mode!r}")
