"""Selection policy: measured crossover tables backed by the postal model.

The policy answers one question — *which algorithm for this collective at
this size on this topology* — from two sources:

1. a :class:`~repro.tuning.cache.TuningCache` of measured (or simulated)
   per-bucket costs, compiled into a byte-bucketed **crossover table** with
   hysteresis: walking buckets in ascending byte order, the incumbent
   algorithm is kept unless a challenger beats it by more than
   ``hysteresis`` (default 10%) *in that bucket*. This suppresses flapping
   between near-tied algorithms across adjacent buckets (measured costs are
   noisy exactly near crossover points, NCCL's tuner does the same);
2. the paper's postal model (``core/autotune.model_costs`` for allgather,
   ``measure.simulate_allreduce`` for allreduce) when no table entry covers
   the request — so ``algorithm="auto"`` always resolves, table or not.

The process-default policy is discovered lazily from ``REPRO_TUNING_TABLE``
or ``./results/tuning_table.json`` (what ``benchmarks/run.py tune`` writes).
"""
from __future__ import annotations

import dataclasses
import os
import threading

from .cache import SchemaVersionError, TuningCache, bucket_bytes
from .measure import (ALL_TO_ALL_ALGORITHMS, ALLREDUCE_ALGORITHMS,
                      LOGSUMEXP_ALGORITHMS, MIGRATE_ALGORITHMS,
                      OVERLAP_ALGORITHMS, Fingerprint, overlap_collective,
                      overlap_intensity, simulate_all_to_all,
                      simulate_allreduce, simulate_cache_migrate,
                      simulate_logsumexp_combine, simulate_overlap)

DEFAULT_TABLE_ENV = "REPRO_TUNING_TABLE"
DEFAULT_TABLE_PATH = os.path.join("results", "tuning_table.json")


@dataclasses.dataclass(frozen=True)
class Selection:
    algorithm: str
    source: str                 # "table" | "model"
    cost: float | None = None   # seconds under the deciding source, if known


class Policy:
    def __init__(self, cache: TuningCache | None = None, *,
                 fingerprint: str | None = None, machine: str = "tpu_v5e",
                 hysteresis: float = 0.10):
        self.cache = cache
        self._fingerprint = fingerprint
        self.machine = machine
        self.hysteresis = hysteresis
        self._crossover_memo: dict[tuple, list[tuple[int, str, float]]] = {}

    @property
    def fingerprint(self) -> str:
        # lazy: detection touches jax.devices() (backend init) and is only
        # needed once a table lookup actually happens
        if self._fingerprint is None:
            self._fingerprint = Fingerprint.detect().key()
        return self._fingerprint

    # ------------------------------------------------------------------
    def crossover_table(self, collective: str, p: int, p_local: int,
                        dtype: str) -> list[tuple[int, str, float]]:
        """[(bucket_bytes, algorithm, cost_s)] ascending, hysteresis applied.

        The returned algorithm for bucket b applies to all sizes in
        (prev_bucket, b]; the last entry extends to infinity.
        """
        key = (collective, p, p_local, dtype)
        memo = self._crossover_memo.get(key)
        if memo is not None:
            return memo
        table: list[tuple[int, str, float]] = []
        if self.cache is not None:
            incumbent: str | None = None
            for e in self.cache.group(self.fingerprint, p, p_local,
                                      collective, dtype):
                best = e.best
                if incumbent is not None and incumbent in e.costs:
                    # keep the incumbent unless the challenger clearly wins
                    if e.costs[best] >= (1.0 - self.hysteresis) * e.costs[incumbent]:
                        best = incumbent
                incumbent = best
                table.append((e.bucket, best, e.costs[best]))
        self._crossover_memo[key] = table
        return table

    # ------------------------------------------------------------------
    @staticmethod
    def _table_lookup(table, nbytes: float) -> Selection:
        """Bucket walk shared by every table-backed selection. Beyond the
        largest measured bucket the bandwidth regime is flat in algorithm
        order, so the last entry extends to infinity."""
        b = bucket_bytes(nbytes)
        for bucket, algorithm, cost in table:
            if b <= bucket:
                return Selection(algorithm, "table", cost)
        _, algorithm, cost = table[-1]
        return Selection(algorithm, "table", cost)

    def select(self, collective: str, p: int, p_local: int, nbytes: float,
               dtype: str = "float32") -> Selection:
        if p <= 1:
            return Selection("bruck" if collective == "allgather" else "xla",
                             "model", 0.0)
        table = self.crossover_table(collective, p, p_local, dtype)
        if table:
            return self._table_lookup(table, nbytes)
        return self._model_fallback(collective, p, p_local, nbytes)

    def _model_fallback(self, collective: str, p: int, p_local: int,
                        nbytes: float) -> Selection:
        if collective == "allgather":
            from repro.core.autotune import model_costs
            if p_local <= 1 or p <= p_local:
                return Selection("bruck", "model")
            costs = model_costs(p, p_local, nbytes, self.machine)
            best = min(costs, key=costs.get)
            return Selection(best, "model", costs[best])
        if collective == "allreduce":
            costs = {a: simulate_allreduce(a, p, p_local, nbytes, self.machine)
                     for a in ALLREDUCE_ALGORITHMS}
            if p_local <= 1 or p <= p_local:
                return Selection("xla", "model", costs["xla"])
            best = min(costs, key=costs.get)
            return Selection(best, "model", costs[best])
        if collective == "logsumexp_combine":
            # the serve decode cache-combine: two-phase max+sum pricing.
            # Unlike plain allreduce, a single-region topology does NOT
            # default to "xla" — the explicit RS→AG sum structure can beat
            # the flat ring even inside one region, and the manual decode
            # path only engages when the policy (or an override) says so.
            costs = {a: simulate_logsumexp_combine(a, p, p_local, nbytes,
                                                   self.machine)
                     for a in LOGSUMEXP_ALGORITHMS}
            best = min(costs, key=costs.get)
            return Selection(best, "model", costs[best])
        if collective == "cache_migrate":
            # KV-slab migration: single-region topologies take GSPMD's flat
            # gather (nothing crosses a boundary); multi-region the three
            # eligible schedules are priced on the slab's byte regime.
            costs = {a: simulate_cache_migrate(a, p, p_local, nbytes,
                                               self.machine)
                     for a in MIGRATE_ALGORITHMS}
            if p_local <= 1 or p <= p_local:
                return Selection("xla", "model", costs["xla"])
            best = min(costs, key=costs.get)
            return Selection(best, "model", costs[best])
        if collective == "all_to_all":
            # MoE dispatch transport: degenerate topologies (one region, or
            # one rank per region with nothing to aggregate over) take
            # GSPMD's flat pairwise exchange; otherwise price the two-tier
            # schedule against it.
            costs = {a: simulate_all_to_all(a, p, p_local, nbytes,
                                            self.machine)
                     for a in ALL_TO_ALL_ALGORITHMS}
            if p_local <= 1 or p <= p_local:
                return Selection("xla", "model", costs["xla"])
            best = min(costs, key=costs.get)
            return Selection(best, "model", costs[best])
        if collective.startswith("overlap:i"):
            fpb = overlap_intensity(collective)
            costs = {a: simulate_overlap(a, p, p_local, nbytes, self.machine,
                                         flops_per_byte=fpb)
                     for a in OVERLAP_ALGORITHMS}
            best = min(costs, key=costs.get)
            return Selection(best, "model", costs[best])
        raise ValueError(f"unknown collective {collective!r}")

    # ------------------------------------------------------------------
    def select_overlap(self, p: int, p_local: int, nbytes: float,
                       flops: float, dtype: str = "float32", *,
                       dispatch_overhead_s: float | None = None) -> Selection:
        """Eager vs prefetched gather schedule for one layer.

        The (topology, bytes, flops) domain maps onto the 2-D table by
        folding arithmetic intensity into the collective name
        ("overlap:i<k>", octave resolution). With a table entry the
        crossover machinery (buckets + hysteresis) decides; otherwise the
        model fallback prices the layer with its *exact* flops.

        dispatch_overhead_s: the MEASURED per-dispatch overhead of the live
        backend (``measure.dispatch_overhead_s()``). Overlap cells are only
        ever simulated (there is no wall-clock overlap executor), so both
        the table and the model can promise hidden communication that a
        host-CPU harness — where there is no real wire to hide — can never
        deliver while still paying the pipeline's extra dispatches. When
        the measured overhead meets or exceeds the MODELED hidden time per
        layer, the selection falls back to eager (source "dispatch"): the
        fix for the BENCH_overlap prefetched-slower-than-eager regression.
        """
        if p <= 1:
            return Selection("eager", "model", 0.0)
        coll = overlap_collective(flops / max(nbytes, 1.0))
        table = self.crossover_table(coll, p, p_local, dtype)
        if table:
            sel = self._table_lookup(table, nbytes)
        else:
            costs = {a: simulate_overlap(a, p, p_local, nbytes, self.machine,
                                         flops=flops)
                     for a in OVERLAP_ALGORITHMS}
            best = min(costs, key=costs.get)
            sel = Selection(best, "model", costs[best])
        if sel.algorithm == "prefetch" and dispatch_overhead_s:
            hidden = (simulate_overlap("eager", p, p_local, nbytes,
                                       self.machine, flops=flops)
                      - simulate_overlap("prefetch", p, p_local, nbytes,
                                         self.machine, flops=flops))
            if dispatch_overhead_s >= hidden:
                return Selection("eager", "dispatch", sel.cost)
        return sel

    # ------------------------------------------------------------------
    def stale_buckets(self, max_age: int) -> list[str]:
        """Table keys whose measurement lags the newest sweep by >= max_age
        generations (empty without a cache). Operators feed this to
        ``benchmarks/run.py tune --stale-after N`` to re-measure exactly the
        aged cells."""
        if self.cache is None:
            return []
        return self.cache.stale_keys(max_age)


# ---------------------------------------------------------------------------
# process-default policy
# ---------------------------------------------------------------------------
_default_lock = threading.Lock()
_default_policy: Policy | None = None
_default_loaded = False


def _discover_table_path() -> str | None:
    env = os.environ.get(DEFAULT_TABLE_ENV)
    if env:
        return env if os.path.exists(env) else None
    return DEFAULT_TABLE_PATH if os.path.exists(DEFAULT_TABLE_PATH) else None


def default_policy() -> Policy:
    """The lazily-discovered process policy (always returns one).

    With a persisted table (``$REPRO_TUNING_TABLE`` or
    ``results/tuning_table.json``) selections come from measured crossovers;
    otherwise from the postal-model prior. A table written by the simulated
    executor fingerprints as ``sim:<machine>`` and is honoured on any host
    (it is a deterministic function of the machine parameters, not of the
    hardware it was computed on).
    """
    global _default_policy, _default_loaded
    with _default_lock:
        if not _default_loaded:
            cache = None
            fingerprint = None
            path = _discover_table_path()
            if path:
                try:
                    cache = TuningCache.load(path)
                except (SchemaVersionError, OSError, ValueError,
                        TypeError, KeyError):
                    # unreadable/corrupt/foreign table: "auto" must still
                    # resolve — fall back to the model prior
                    cache = None
                if cache is not None and len(cache):
                    # honour a simulated-sweep table regardless of host:
                    # if the live fingerprint has no entries, adopt the
                    # (lexicographically first) sim fingerprint present
                    fps = {k.split("|", 1)[0] for k in cache.entries}
                    live = Fingerprint.detect().key()
                    if live not in fps:
                        sims = sorted(f for f in fps if f.startswith("sim:"))
                        if sims:
                            fingerprint = sims[0]
            _default_policy = Policy(cache, fingerprint=fingerprint)
            _default_loaded = True
        return _default_policy


def set_default_policy(policy: Policy | None) -> None:
    """Inject (tests) or reset (None -> rediscover on next use)."""
    global _default_policy, _default_loaded
    with _default_lock:
        _default_policy = policy
        _default_loaded = policy is not None


def resolve(collective: str, p: int, p_local: int, nbytes: float,
            dtype: str = "float32") -> str:
    """Convenience: algorithm name for ``algorithm="auto"`` call sites."""
    return default_policy().select(collective, p, p_local, nbytes, dtype).algorithm
