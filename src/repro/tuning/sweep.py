"""Offline sweep driver: measure every (collective, algorithm, size) cell,
persist the tuning table, and emit a Fig. 9-style measured-vs-modeled report.

``python -m repro.tuning.sweep --p 16 --p-local 4`` (or the ``tune``
subcommand of ``benchmarks/run.py``) produces:

* ``results/tuning_table.json``  — the versioned TuningCache the policy
  layer consults for ``algorithm="auto"`` (see policy.py discovery rules);
* ``BENCH_tuning.json``          — per-cell measured + modeled costs, the
  winner under each, and the crossover tables with hysteresis applied —
  the data behind the paper's Fig. 9 comparison, plus an agreement summary
  (fraction of cells where model and measurement pick the same winner).
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Any, Sequence

from repro.core import autotune
from .cache import Entry, TuningCache, bucket_bytes
from .measure import (ALLGATHER_ALGORITHMS, ALLREDUCE_ALGORITHMS,
                      LOGSUMEXP_ALGORITHMS, Fingerprint, measure,
                      simulate_allreduce, simulate_logsumexp_combine)
from .policy import Policy

DEFAULT_SIZES = tuple(2 ** k for k in range(6, 23, 2))   # 64 B .. 4 MiB
DEFAULT_COLLECTIVES = ("allgather", "allreduce", "logsumexp_combine")
SMOKE_SIZES = (256, 4096, 65536)         # CI pre-merge: 3 octaves, 1 iter

_ALGORITHMS = {"allgather": ALLGATHER_ALGORITHMS,
               "allreduce": ALLREDUCE_ALGORITHMS,
               "logsumexp_combine": LOGSUMEXP_ALGORITHMS}


def run_sweep(p: int = 16, p_local: int = 4, *,
              sizes: Sequence[int] = DEFAULT_SIZES,
              collectives: Sequence[str] = DEFAULT_COLLECTIVES,
              dtype: str = "float32", mode: str = "auto",
              machine: str = "lassen", hysteresis: float = 0.10,
              iters: int = 5, warmup: int = 2) -> tuple[TuningCache, dict]:
    """Measure the grid, returning (cache, report_dict)."""
    import jax

    simulated = mode == "simulated" or (
        mode == "auto" and (jax.default_backend() == "cpu"
                            or len(jax.devices()) < p))
    fp = Fingerprint.detect(simulated_machine=machine if simulated else "")
    eff_mode = "simulated" if simulated else "real"

    cache = TuningCache()
    cells: list[dict[str, Any]] = []
    for collective in collectives:
        algorithms = _ALGORITHMS[collective]
        for nbytes in sizes:
            costs = {}
            for alg in algorithms:
                costs[alg] = measure(collective, alg, p, p_local, nbytes,
                                     dtype, mode=eff_mode, machine=machine,
                                     iters=iters, warmup=warmup)
            entry = Entry(collective=collective, p=p, p_local=p_local,
                          dtype=dtype, bucket=bucket_bytes(nbytes),
                          costs=costs, source=eff_mode)
            cache.put(fp.key(), entry)

            # the paper's closed-form prediction for the same cell. For
            # allreduce in simulated mode "measured" IS the model (there is
            # no schedule generator for the reduce structures), so the cell
            # is flagged and excluded from the agreement statistic below.
            if collective == "allgather":
                modeled = autotune.model_costs(p, p_local, nbytes, machine)
                self_cmp = False
            elif collective == "allreduce":
                modeled = {a: simulate_allreduce(a, p, p_local, nbytes, machine)
                           for a in ALLREDUCE_ALGORITHMS}
                self_cmp = eff_mode == "simulated"
            else:                       # logsumexp_combine
                modeled = {a: simulate_logsumexp_combine(a, p, p_local,
                                                         nbytes, machine)
                           for a in LOGSUMEXP_ALGORITHMS}
                self_cmp = eff_mode == "simulated"
            cells.append({
                "collective": collective, "p": p, "p_local": p_local,
                "dtype": dtype, "nbytes": nbytes,
                "measured_s": costs, "modeled_s": modeled,
                "measured_winner": min(costs, key=costs.get),
                "modeled_winner": min(modeled, key=modeled.get),
                "self_comparison": self_cmp,
            })

    policy = Policy(cache, fingerprint=fp.key(), machine=machine,
                    hysteresis=hysteresis)
    crossovers = {
        c: [{"bucket_bytes": b, "algorithm": a, "cost_s": t}
            for b, a, t in policy.crossover_table(c, p, p_local, dtype)]
        for c in collectives
    }
    agree = [c["measured_winner"] == c["modeled_winner"] for c in cells
             if not c["self_comparison"]]
    report = {
        "fingerprint": fp.key(),
        "mode": eff_mode,
        "machine_model": machine,
        "topology": {"p": p, "p_local": p_local, "n_regions": p // p_local},
        "hysteresis": hysteresis,
        "cells": cells,
        "crossover_tables": crossovers,
        "winner_agreement": {
            "matched": sum(agree), "total": len(agree),
            "fraction": (sum(agree) / len(agree)) if agree else None,
        },
    }
    return cache, report


def write_outputs(cache: TuningCache, report: dict, *,
                  table_path: str, report_path: str) -> None:
    """Persist, merging into an existing table (so an operator can sweep one
    topology at a time — entries are keyed by topology, new keys win)."""
    if os.path.exists(table_path):
        try:
            merged = TuningCache.load(table_path)
        except (OSError, ValueError, TypeError, KeyError):
            merged = TuningCache()          # unreadable/corrupt: start over
        # SchemaVersionError propagates: never clobber a table written by a
        # newer schema (cache.py's refuse-to-guess invariant)
        merged.entries.update(cache.entries)
        cache = merged
    cache.save(table_path)
    d = os.path.dirname(os.path.abspath(report_path))
    os.makedirs(d, exist_ok=True)
    with open(report_path, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)


def main(argv: Sequence[str] | None = None) -> tuple[TuningCache, dict]:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--p", type=int, default=16, help="total ranks")
    ap.add_argument("--p-local", type=int, default=4, help="ranks per region")
    ap.add_argument("--sizes", default=None,
                    help="comma-separated bytes-per-rank list")
    ap.add_argument("--collectives", default=",".join(DEFAULT_COLLECTIVES))
    ap.add_argument("--smoke", action="store_true",
                    help="CI pre-merge mode: 3 byte octaves, single "
                         "iteration, no warmup, and (unless --mode is "
                         "given) the deterministic simulated executor — "
                         "a single unwarmed wall-clock sample would be "
                         "compile-dominated and must never be persisted "
                         "as a real-hardware crossover")
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--mode", default="auto",
                    choices=["auto", "real", "simulated"])
    ap.add_argument("--machine", default="lassen",
                    help="cost-model parameter set for the simulated executor")
    ap.add_argument("--hysteresis", type=float, default=0.10)
    ap.add_argument("--table", default=os.path.join("results",
                                                    "tuning_table.json"))
    ap.add_argument("--report", default="BENCH_tuning.json")
    args = ap.parse_args(argv)

    sizes = (tuple(int(s) for s in args.sizes.split(","))
             if args.sizes else (SMOKE_SIZES if args.smoke else DEFAULT_SIZES))
    mode = args.mode
    if args.smoke:
        if mode == "real":
            ap.error("--smoke cannot use --mode real: a single unwarmed "
                     "sample is compile-dominated and would be persisted "
                     "as a measured crossover")
        mode = "simulated"
    cache, report = run_sweep(
        args.p, args.p_local, sizes=sizes,
        collectives=tuple(args.collectives.split(",")), dtype=args.dtype,
        mode=mode, machine=args.machine, hysteresis=args.hysteresis,
        iters=1 if args.smoke else 5, warmup=0 if args.smoke else 2)
    write_outputs(cache, report, table_path=args.table,
                  report_path=args.report)
    agg = report["winner_agreement"]
    print(f"tuning table: {args.table} ({len(cache)} entries, "
          f"fingerprint {report['fingerprint']})")
    print(f"report:       {args.report} "
          f"(model/measurement winner agreement {agg['matched']}/{agg['total']})")
    return cache, report


if __name__ == "__main__":
    main()
