"""Offline sweep driver: measure every (collective, algorithm, size) cell,
persist the tuning table, and emit a Fig. 9-style measured-vs-modeled report.

``python -m repro.tuning.sweep --p 16 --p-local 4`` (or the ``tune``
subcommand of ``benchmarks/run.py``) produces:

* ``results/tuning_table.json``  — the versioned TuningCache the policy
  layer consults for ``algorithm="auto"`` (see policy.py discovery rules);
* ``BENCH_tuning.json``          — per-cell measured + modeled costs, the
  winner under each, and the crossover tables with hysteresis applied —
  the data behind the paper's Fig. 9 comparison, plus an agreement summary
  (fraction of cells where model and measurement pick the same winner).
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Any, Sequence

from repro.core import autotune
from .cache import Entry, TuningCache, bucket_bytes, make_key
from .measure import (ALL_TO_ALL_ALGORITHMS, ALLGATHER_ALGORITHMS,
                      ALLREDUCE_ALGORITHMS, LOGSUMEXP_ALGORITHMS,
                      MIGRATE_ALGORITHMS, OVERLAP_ALGORITHMS,
                      OVERLAP_INTENSITY_OCTAVES, Fingerprint, measure,
                      overlap_intensity, simulate_allreduce,
                      simulate_logsumexp_combine, simulate_overlap)
from .policy import Policy

DEFAULT_SIZES = tuple(2 ** k for k in range(6, 23, 2))   # 64 B .. 4 MiB
DEFAULT_COLLECTIVES = ("allgather", "allreduce", "logsumexp_combine",
                       "cache_migrate", "all_to_all", "overlap")
SMOKE_SIZES = (256, 4096, 65536)         # CI pre-merge: 3 octaves, 1 iter


def _algorithms_for(collective: str):
    if collective.startswith("overlap"):
        return OVERLAP_ALGORITHMS
    return {"allgather": ALLGATHER_ALGORITHMS,
            "allreduce": ALLREDUCE_ALGORITHMS,
            "logsumexp_combine": LOGSUMEXP_ALGORITHMS,
            "cache_migrate": MIGRATE_ALGORITHMS,
            "all_to_all": ALL_TO_ALL_ALGORITHMS}[collective]


def _expand_collectives(collectives: Sequence[str]) -> list[str]:
    """"overlap" fans out into its intensity-octave cells (overlap:i<k>)."""
    out: list[str] = []
    for c in collectives:
        if c == "overlap":
            out.extend(f"overlap:i{k}" for k in OVERLAP_INTENSITY_OCTAVES)
        else:
            out.append(c)
    return out


def run_sweep(p: int = 16, p_local: int = 4, *,
              sizes: Sequence[int] = DEFAULT_SIZES,
              collectives: Sequence[str] = DEFAULT_COLLECTIVES,
              dtype: str = "float32", mode: str = "auto",
              machine: str = "lassen", hysteresis: float = 0.10,
              iters: int = 5, warmup: int = 2,
              existing: TuningCache | None = None,
              stale_after: int | None = None) -> tuple[TuningCache, dict]:
    """Measure the grid, returning (cache, report_dict).

    New entries are stamped with generation ``existing.max_generation() + 1``
    (1 on a fresh table). With ``stale_after=N`` and an ``existing`` table,
    cells whose current entry is younger than N generations are SKIPPED —
    the merge in :func:`write_outputs` keeps their old measurement — so a
    periodic re-measure sweep touches only aged buckets.
    """
    import jax

    simulated = mode == "simulated" or (
        mode == "auto" and (jax.default_backend() == "cpu"
                            or len(jax.devices()) < p))
    fp = Fingerprint.detect(simulated_machine=machine if simulated else "")
    eff_mode = "simulated" if simulated else "real"
    generation = (existing.max_generation() if existing is not None else 0) + 1

    cache = TuningCache()
    cells: list[dict[str, Any]] = []
    skipped = 0
    for collective in _expand_collectives(collectives):
        algorithms = _algorithms_for(collective)
        for nbytes in sizes:
            if stale_after is not None and existing is not None:
                prev = existing.entries.get(make_key(
                    fp.key(), p, p_local, collective, dtype,
                    bucket_bytes(nbytes)))
                if prev is not None and \
                        generation - 1 - prev.generation < stale_after:
                    skipped += 1          # fresh enough: keep the old cell
                    continue
            # overlap cells have no wall-clock executor (measure() forces
            # them simulated) — label the persisted source accordingly even
            # on accelerator sweeps where every other cell is real
            cell_mode = ("simulated" if collective.startswith("overlap:")
                         else eff_mode)
            costs = {}
            for alg in algorithms:
                costs[alg] = measure(collective, alg, p, p_local, nbytes,
                                     dtype, mode=cell_mode, machine=machine,
                                     iters=iters, warmup=warmup)
            entry = Entry(collective=collective, p=p, p_local=p_local,
                          dtype=dtype, bucket=bucket_bytes(nbytes),
                          costs=costs, source=cell_mode,
                          generation=generation)
            cache.put(fp.key(), entry)

            # the paper's closed-form prediction for the same cell. For
            # allreduce in simulated mode "measured" IS the model (there is
            # no schedule generator for the reduce structures), so the cell
            # is flagged and excluded from the agreement statistic below.
            if collective == "allgather":
                modeled = autotune.model_costs(p, p_local, nbytes, machine)
                self_cmp = False
            elif collective == "allreduce":
                modeled = {a: simulate_allreduce(a, p, p_local, nbytes, machine)
                           for a in ALLREDUCE_ALGORITHMS}
                self_cmp = eff_mode == "simulated"
            elif collective == "cache_migrate":
                # closed forms vs the round-simulated schedules: a genuine
                # comparison even on CPU, like the allgather cells
                from repro.core.cost_model import cache_migrate_model
                modeled = {a: cache_migrate_model(a, p, p_local, nbytes,
                                                  machine)
                           for a in MIGRATE_ALGORITHMS}
                self_cmp = False
            elif collective == "all_to_all":
                # closed forms (worst-rank postal) vs the round-simulated
                # oracle schedules — a genuine comparison even on CPU
                from repro.core.cost_model import all_to_all_model
                modeled = {a: all_to_all_model(a, p, p_local, nbytes / p,
                                               machine)
                           for a in ALL_TO_ALL_ALGORITHMS}
                self_cmp = False
            elif collective.startswith("overlap:i"):
                fpb = overlap_intensity(collective)
                modeled = {a: simulate_overlap(a, p, p_local, nbytes, machine,
                                               flops_per_byte=fpb)
                           for a in OVERLAP_ALGORITHMS}
                self_cmp = True         # the overlap executor IS the model
            else:                       # logsumexp_combine
                modeled = {a: simulate_logsumexp_combine(a, p, p_local,
                                                         nbytes, machine)
                           for a in LOGSUMEXP_ALGORITHMS}
                self_cmp = eff_mode == "simulated"
            cells.append({
                "collective": collective, "p": p, "p_local": p_local,
                "dtype": dtype, "nbytes": nbytes,
                "measured_s": costs, "modeled_s": modeled,
                "measured_winner": min(costs, key=costs.get),
                "modeled_winner": min(modeled, key=modeled.get),
                "self_comparison": self_cmp,
            })

    policy = Policy(cache, fingerprint=fp.key(), machine=machine,
                    hysteresis=hysteresis)
    crossovers = {
        c: [{"bucket_bytes": b, "algorithm": a, "cost_s": t}
            for b, a, t in policy.crossover_table(c, p, p_local, dtype)]
        for c in _expand_collectives(collectives)
    }
    agree = [c["measured_winner"] == c["modeled_winner"] for c in cells
             if not c["self_comparison"]]
    from .measure import dispatch_overhead_s
    report = {
        "fingerprint": fp.key(),
        "mode": eff_mode,
        "machine_model": machine,
        # the live backend's measured per-dispatch cost — the floor the
        # overlap policy's dispatch guard compares modeled hidden comm to
        "dispatch_overhead_s": dispatch_overhead_s(),
        "topology": {"p": p, "p_local": p_local, "n_regions": p // p_local},
        "hysteresis": hysteresis,
        "generation": generation,
        "stale_skipped": skipped,
        "cells": cells,
        "crossover_tables": crossovers,
        "winner_agreement": {
            "matched": sum(agree), "total": len(agree),
            "fraction": (sum(agree) / len(agree)) if agree else None,
        },
    }
    return cache, report


def write_outputs(cache: TuningCache, report: dict, *,
                  table_path: str, report_path: str,
                  existing: TuningCache | None = None) -> None:
    """Persist, merging into an existing table (so an operator can sweep one
    topology at a time — entries are keyed by topology, new keys win).
    ``existing`` reuses an already-loaded merge base (main() loads it for
    the staleness pass) instead of re-parsing the file."""
    import jax
    # same shape as benchmarks.common.bench_metadata — the CI trend job only
    # compares BENCH files whose meta matches (like with like)
    report.setdefault("meta", {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "device_kind": jax.devices()[0].device_kind,
    })
    if existing is None and os.path.exists(table_path):
        try:
            existing = TuningCache.load(table_path)
        except (OSError, ValueError, TypeError, KeyError):
            existing = None                 # unreadable/corrupt: start over
        # SchemaVersionError propagates: never clobber a table written by a
        # newer schema (cache.py's refuse-to-guess invariant)
    if existing is not None:
        merged = TuningCache(dict(existing.entries))
        merged.entries.update(cache.entries)
        cache = merged
    cache.save(table_path)
    d = os.path.dirname(os.path.abspath(report_path))
    os.makedirs(d, exist_ok=True)
    with open(report_path, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)


def main(argv: Sequence[str] | None = None) -> tuple[TuningCache, dict]:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--p", type=int, default=16, help="total ranks")
    ap.add_argument("--p-local", type=int, default=4, help="ranks per region")
    ap.add_argument("--sizes", default=None,
                    help="comma-separated bytes-per-rank list")
    ap.add_argument("--collectives", default=",".join(DEFAULT_COLLECTIVES))
    ap.add_argument("--smoke", action="store_true",
                    help="CI pre-merge mode: 3 byte octaves, single "
                         "iteration, no warmup, and (unless --mode is "
                         "given) the deterministic simulated executor — "
                         "a single unwarmed wall-clock sample would be "
                         "compile-dominated and must never be persisted "
                         "as a real-hardware crossover")
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--mode", default="auto",
                    choices=["auto", "real", "simulated"])
    ap.add_argument("--machine", default="lassen",
                    help="cost-model parameter set for the simulated executor")
    ap.add_argument("--hysteresis", type=float, default=0.10)
    ap.add_argument("--stale-after", type=int, default=None, metavar="N",
                    help="re-measure only buckets whose entry is >= N sweep "
                         "generations old (plus missing cells); fresh cells "
                         "keep their existing measurement")
    ap.add_argument("--table", default=os.path.join("results",
                                                    "tuning_table.json"))
    ap.add_argument("--report", default="BENCH_tuning.json")
    args = ap.parse_args(argv)

    sizes = (tuple(int(s) for s in args.sizes.split(","))
             if args.sizes else (SMOKE_SIZES if args.smoke else DEFAULT_SIZES))
    mode = args.mode
    if args.smoke:
        if mode == "real":
            ap.error("--smoke cannot use --mode real: a single unwarmed "
                     "sample is compile-dominated and would be persisted "
                     "as a measured crossover")
        mode = "simulated"
    existing = None
    if os.path.exists(args.table):
        try:
            existing = TuningCache.load(args.table)
        except (OSError, ValueError, TypeError, KeyError):
            existing = None             # corrupt: sweep from scratch
    cache, report = run_sweep(
        args.p, args.p_local, sizes=sizes,
        collectives=tuple(args.collectives.split(",")), dtype=args.dtype,
        mode=mode, machine=args.machine, hysteresis=args.hysteresis,
        iters=1 if args.smoke else 5, warmup=0 if args.smoke else 2,
        existing=existing, stale_after=args.stale_after)
    write_outputs(cache, report, table_path=args.table,
                  report_path=args.report, existing=existing)
    agg = report["winner_agreement"]
    print(f"tuning table: {args.table} ({len(cache)} entries at generation "
          f"{report['generation']}, {report['stale_skipped']} fresh cells "
          f"kept, fingerprint {report['fingerprint']})")
    print(f"report:       {args.report} "
          f"(model/measurement winner agreement {agg['matched']}/{agg['total']})")
    return cache, report


if __name__ == "__main__":
    main()
