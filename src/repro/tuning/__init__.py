"""Empirical collective autotuning (the measurement half of §4).

The paper selects among allgather algorithms purely from the postal model
(Eqs. 2-4), but its own measurements (Fig. 9) show the model mispredicts
crossover points on real networks. This package adds the measurement half:

  measure.py  micro-benchmark harness (wall-clock on a live mesh, or a
              deterministic schedule-simulated executor on CPU containers)
  cache.py    versioned, atomically-written JSON tuning table keyed by
              machine fingerprint x topology x collective x dtype x bytes
  policy.py   selection = measured crossover tables (with hysteresis)
              backed by the cost-model prior when no table exists
  sweep.py    offline sweep driver: builds the table + a Fig. 9-style
              measured-vs-modeled report

``core/autotune.pick_allgather`` and ``core/collectives.allgather(...,
algorithm="auto")`` resolve through :mod:`repro.tuning.policy`.
"""
from . import cache, measure, policy, sweep  # noqa: F401 (submodule access)
from .cache import SCHEMA_VERSION, SchemaVersionError, TuningCache, make_key
from .measure import Fingerprint
from .policy import Policy, Selection, default_policy, resolve, set_default_policy

__all__ = [
    "cache", "measure", "policy", "sweep",
    "SCHEMA_VERSION", "SchemaVersionError", "TuningCache", "make_key",
    "Fingerprint",
    "Policy", "Selection", "default_policy", "resolve", "set_default_policy",
]
