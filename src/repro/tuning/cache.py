"""Persistent tuning table: versioned JSON, atomic writes, schema migration.

One file holds every measured entry for one machine (or several — entries
are namespaced by fingerprint). Keys are flat strings so the table stays
human-diffable and mergeable::

    <fingerprint>|p<P>xl<PL>|<collective>|<dtype>|b<bucket_bytes>

``p<P>xl<PL>`` is the region-major topology of the measured shard_map —
``P`` total ranks split as ``P/PL`` outer (region) ranks x ``PL`` local
ranks — i.e. the mesh shape with the outer/local axis split applied.
Message sizes are bucketed to powers of two (one entry per octave): the
postal model is piecewise log-linear in bytes, so octave resolution locates
crossovers to within the model's own noise.

Writes go through a tempfile + ``os.replace`` so a crashed sweep can never
leave a torn table, and every file carries ``schema_version``: older known
versions are migrated forward at load, newer (or unknown) versions raise
``SchemaVersionError`` rather than being silently misread.

Staleness (schema v3): every entry is stamped with the measurement
``generation`` — the sweep counter at the time it was measured. A sweep run
against an existing table writes at ``max_generation() + 1``; buckets whose
generation lags the table maximum by ``max_age`` or more are *stale* and
``stale_keys()`` / ``Policy.stale_buckets()`` surface them so the next sweep
re-measures exactly those cells instead of the full grid.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Any, Callable, Iterator

SCHEMA_VERSION = 3


class SchemaVersionError(RuntimeError):
    """Tuning table file has an unknown or future schema version."""


def bucket_bytes(nbytes: float) -> int:
    """Power-of-two byte bucket (>= 1) containing ``nbytes``."""
    b = 1
    while b < nbytes:
        b <<= 1
    return b


def make_key(fingerprint: str, p: int, p_local: int, collective: str,
             dtype: str, bucket: int) -> str:
    return f"{fingerprint}|p{p}xl{p_local}|{collective}|{dtype}|b{bucket}"


@dataclasses.dataclass
class Entry:
    """One measured byte-bucket: per-algorithm cost + the winner."""

    collective: str
    p: int
    p_local: int
    dtype: str
    bucket: int                    # bytes-per-rank bucket (power of two)
    costs: dict[str, float]        # algorithm -> seconds (median)
    source: str                    # "measured" | "simulated"
    generation: int = 0            # sweep counter when this cell was measured

    @property
    def best(self) -> str:
        return min(self.costs, key=self.costs.get)

    def to_json(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict[str, Any]) -> "Entry":
        return cls(**d)


# ---------------------------------------------------------------------------
# schema migrations: version -> fn(raw_dict) -> raw_dict at version+1
# ---------------------------------------------------------------------------
def _migrate_v1(raw: dict[str, Any]) -> dict[str, Any]:
    """v1 lacked per-entry ``source`` (everything was wall-clock measured)."""
    for e in raw.get("entries", {}).values():
        e.setdefault("source", "measured")
    raw["schema_version"] = 2
    return raw


def _migrate_v2(raw: dict[str, Any]) -> dict[str, Any]:
    """v2 lacked per-entry ``generation`` (no staleness tracking)."""
    for e in raw.get("entries", {}).values():
        e.setdefault("generation", 0)
    raw["schema_version"] = 3
    return raw


_MIGRATIONS: dict[int, Callable[[dict[str, Any]], dict[str, Any]]] = {
    1: _migrate_v1,
    2: _migrate_v2,
}


class TuningCache:
    """In-memory view of one tuning table file."""

    def __init__(self, entries: dict[str, Entry] | None = None):
        self.entries: dict[str, Entry] = dict(entries or {})

    # ---- access ----------------------------------------------------------
    def put(self, fingerprint: str, entry: Entry) -> None:
        key = make_key(fingerprint, entry.p, entry.p_local, entry.collective,
                       entry.dtype, entry.bucket)
        self.entries[key] = entry

    def get(self, fingerprint: str, p: int, p_local: int, collective: str,
            dtype: str, bucket: int) -> Entry | None:
        return self.entries.get(
            make_key(fingerprint, p, p_local, collective, dtype, bucket))

    def group(self, fingerprint: str, p: int, p_local: int, collective: str,
              dtype: str) -> list[Entry]:
        """All buckets for one (topology, collective, dtype), ascending."""
        prefix = make_key(fingerprint, p, p_local, collective, dtype, 0)
        prefix = prefix.rsplit("|", 1)[0] + "|b"
        found = [e for k, e in self.entries.items() if k.startswith(prefix)]
        return sorted(found, key=lambda e: e.bucket)

    def __len__(self) -> int:
        return len(self.entries)

    def __iter__(self) -> Iterator[Entry]:
        return iter(self.entries.values())

    # ---- staleness -------------------------------------------------------
    def max_generation(self) -> int:
        """Latest sweep generation present (0 for an empty table)."""
        return max((e.generation for e in self.entries.values()), default=0)

    def stale_keys(self, max_age: int) -> list[str]:
        """Keys whose measurement lags the newest sweep by >= max_age
        generations — the re-measure set for the next sweep."""
        if max_age < 1:
            raise ValueError(f"max_age must be >= 1, got {max_age}")
        cur = self.max_generation()
        return [k for k, e in sorted(self.entries.items())
                if cur - e.generation >= max_age]

    # ---- persistence -----------------------------------------------------
    def save(self, path: str) -> None:
        """Atomic write (tempfile in the target dir + os.replace)."""
        payload = {
            "schema_version": SCHEMA_VERSION,
            "entries": {k: e.to_json() for k, e in sorted(self.entries.items())},
        }
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, prefix=".tuning_", suffix=".json")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    @classmethod
    def load(cls, path: str) -> "TuningCache":
        with open(path) as f:
            raw = json.load(f)
        version = raw.get("schema_version")
        if not isinstance(version, int) or version < 1:
            raise SchemaVersionError(
                f"{path}: missing/invalid schema_version {version!r}")
        while version < SCHEMA_VERSION:
            migrate = _MIGRATIONS.get(version)
            if migrate is None:
                raise SchemaVersionError(
                    f"{path}: no migration from schema v{version}")
            raw = migrate(raw)
            version = raw["schema_version"]
        if version != SCHEMA_VERSION:
            raise SchemaVersionError(
                f"{path}: schema v{version} is newer than supported "
                f"v{SCHEMA_VERSION} — refusing to guess")
        entries = {k: Entry.from_json(d) for k, d in raw["entries"].items()}
        return cls(entries)
