from .harness import (FaultHarness, FaultSpec, ProcessKilled, guard,
                      write_bytes)
