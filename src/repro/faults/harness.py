"""Deterministic fault injection for durability code paths.

The checkpoint store routes every durable mutation (chunk write, manifest
write, commit rename, LATEST replace) through a narrow waist that consults a
:class:`FaultHarness` before touching the filesystem. Tests arm the harness
with :class:`FaultSpec`s to make a *specific* byte hit the disk torn, an
*exact* rename die, or a randomly-chosen write kill the process — and
because the harness is seeded, a failing schedule replays bit-for-bit from
its seed alone (the property tests print the seed on failure).

Three failure modes:

``io_error``
    The write raises :class:`OSError` before any byte lands — the transient
    class (full disk, flaky NFS) the store's bounded retry absorbs.
``torn``
    Half the payload lands, then :class:`ProcessKilled` — the crash window
    the atomic-commit protocol (tmp dir + rename) must make invisible.
``kill``
    :class:`ProcessKilled` before any byte lands — SIGKILL between
    syscalls.

``ProcessKilled`` subclasses ``BaseException`` deliberately: a real SIGKILL
is not an application error, so no ``except Exception`` recovery path
(retry loops, the Trainer's fault recovery) may swallow it. Only top-level
test drivers catch it.
"""
from __future__ import annotations

import dataclasses
import fnmatch

import numpy as np


class ProcessKilled(BaseException):
    """Simulated hard kill (SIGKILL / preemption without grace).

    BaseException on purpose: recovery code that catches ``Exception``
    must not survive it — the process is gone; only the harness driver
    (the test) observes it.
    """


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One armed failure. Matches a fault ``point`` (glob ok) and fires
    either at an exact hit count (``at``, 0-based per point) or at random
    with probability ``rate`` per hit; ``times`` bounds total firings."""

    point: str                 # e.g. "checkpoint/chunk_write", "checkpoint/*"
    mode: str = "io_error"     # io_error | torn | kill
    at: int | None = None      # fire on the at-th hit of a matching point
    rate: float = 0.0          # else: fire with this probability per hit
    times: int = 1             # firings before the spec disarms

    def __post_init__(self):
        if self.mode not in ("io_error", "torn", "kill"):
            raise ValueError(f"unknown fault mode {self.mode!r}")


class FaultHarness:
    """Seeded decision point: ``check(point)`` returns the failure mode to
    apply right now, or None. Hit counters are global across the harness's
    lifetime (a retried write is a *new* hit — an ``at=0`` io_error fires
    once and the retry goes through, exactly the transient-fault shape)."""

    def __init__(self, specs: tuple[FaultSpec, ...] | list[FaultSpec] = (),
                 seed: int = 0):
        self.specs = tuple(specs)
        self.seed = seed
        self._rng = np.random.Generator(np.random.Philox(key=seed))
        self._hits: dict[str, int] = {}
        self._fired: dict[int, int] = {i: 0 for i in range(len(self.specs))}
        self.log: list[tuple[str, str, int]] = []   # (point, mode, hit)

    def hits(self, point: str) -> int:
        return self._hits.get(point, 0)

    def check(self, point: str) -> str | None:
        """Record one hit of ``point``; return the armed mode if a spec
        fires (first matching spec wins), else None."""
        n = self._hits.get(point, 0)
        self._hits[point] = n + 1
        for i, spec in enumerate(self.specs):
            if self._fired[i] >= spec.times:
                continue
            if not fnmatch.fnmatch(point, spec.point):
                continue
            fire = (n == spec.at) if spec.at is not None else (
                spec.rate > 0 and self._rng.random() < spec.rate)
            if fire:
                self._fired[i] += 1
                self.log.append((point, spec.mode, n))
                return spec.mode
        return None


def write_bytes(path: str, data: bytes, *, faults: FaultHarness | None,
                point: str) -> None:
    """The injection waist for payload writes: apply the armed failure
    mode, else write ``data`` to ``path`` in full."""
    mode = faults.check(point) if faults is not None else None
    if mode == "io_error":
        raise OSError(f"injected io_error at {point} ({path})")
    if mode == "kill":
        raise ProcessKilled(f"injected kill at {point} ({path})")
    if mode == "torn":
        with open(path, "wb") as f:        # half the payload lands, then die
            f.write(data[: len(data) // 2])
            f.flush()
        raise ProcessKilled(f"injected torn write at {point} ({path})")
    with open(path, "wb") as f:
        f.write(data)


def guard(point: str, faults: FaultHarness | None) -> None:
    """The injection waist for non-payload mutations (renames): io_error
    raises OSError, torn/kill raise ProcessKilled *before* the mutation —
    a rename is atomic, so its only failure shapes are "didn't happen"."""
    mode = faults.check(point) if faults is not None else None
    if mode == "io_error":
        raise OSError(f"injected io_error at {point}")
    if mode in ("torn", "kill"):
        raise ProcessKilled(f"injected {mode} at {point}")
