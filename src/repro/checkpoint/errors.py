"""Typed checkpoint failures.

Restore-side validation raises :class:`CheckpointError` naming the leaf
path (and chunk, where applicable) instead of bare ``assert`` — callers
can distinguish "no checkpoint" (restore returns None) from "checkpoint
present but unusable" (raises) and report *which* tensor broke.
"""
from __future__ import annotations


class CheckpointError(RuntimeError):
    """A checkpoint exists but cannot be used: architecture mismatch,
    shape/dtype mismatch on a named leaf, or unrecoverable chunk loss
    (every replica missing or hash-mismatched)."""

    def __init__(self, message: str, *, leaf: str | None = None,
                 step: int | None = None):
        self.leaf = leaf
        self.step = step
        where = []
        if step is not None:
            where.append(f"step {step}")
        if leaf:
            where.append(f"leaf {leaf!r}")
        suffix = f" [{', '.join(where)}]" if where else ""
        super().__init__(message + suffix)
