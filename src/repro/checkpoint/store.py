"""Locality-aware sharded checkpointing: atomic commit, replication, reshard.

Layout v2 (``manifest.json`` carries ``"schema": 2``)::

    <dir>/step_<N>/
        manifest.json           step, per-leaf chunk layout, mesh, replication
        leaf_<i>_c<j>.npy       one file per DISTINCT device shard of leaf i
        leaf_<i>_c<j>.r<k>.npy  k-th inter-pod replica of that chunk
    <dir>/LATEST                text file: committed step number (os.replace)

Save is *sharded*: each leaf is written as its deduplicated
``addressable_shards`` — one chunk file per distinct shard slice, tagged
with the owning pod (``topology.device_pod_map``) and content-hashed
(sha256). No full-leaf host gather ever happens for a sharded leaf; the
largest host allocation is one shard (``checkpoint/max_chunk_bytes`` gauge
— the per-process-bytes test pins this). Inter-pod replication (factor
priced by ``cost_model.checkpoint_replication_model`` — the degenerate
one-round outer phase of the locality-Bruck schedule, each pod's shards
mirrored to pod ``(p+k) mod q``) makes any single lost pod recoverable:
restore fails over home → replica per chunk, hash-verifying each read.

Restore reshards between arbitrary layouts (2×16 → 3×8 → flat, q arbitrary
— the PR 5 allgatherv adaptation keeps every target layout expressible):
``jax.make_array_from_callback`` asks for exactly each device's slice, which
is assembled from the intersecting chunks — never the full leaf on host,
never a cross-host gather. Step resolution prefers the committed ``LATEST``
pointer, falls back to a directory scan (``checkpoint/latest_fallbacks``)
when it is missing or dangling, and a corrupt/partial step falls back to
the previous complete one (``checkpoint/manifest_fallbacks``). Validation
raises typed :class:`CheckpointError` naming the leaf path.

Durability: every write lands in ``step_<N>.tmp/`` and is renamed into
place only when complete; every durable mutation routes through the
``repro.faults`` injection waist (points ``checkpoint/chunk_write``,
``manifest_write``, ``commit_rename``, ``latest_write``, ``latest_rename``)
so the crash-recovery property tests can tear or kill any byte of the
protocol. The async :class:`CheckpointManager` snapshots shard-wise,
retries transient ``OSError`` with bounded exponential backoff, and
surfaces a structured :class:`CheckpointHealth` instead of deferring
exceptions to the next ``save()``.

v1 manifests (no ``"schema"`` key, ``leaf_<i>.npy`` files) restore
unchanged — old run directories stay readable.
"""
from __future__ import annotations

import dataclasses
import hashlib
import io
import json
import os
import shutil
import threading
import time

import jax
import numpy as np

from repro import telemetry
from repro.faults import FaultHarness, guard, write_bytes
from .errors import CheckpointError

SCHEMA_VERSION = 2

# fault-injection points (repro.faults), in protocol order
POINT_CHUNK = "checkpoint/chunk_write"
POINT_MANIFEST = "checkpoint/manifest_write"
POINT_COMMIT = "checkpoint/commit_rename"
POINT_LATEST = "checkpoint/latest_write"
POINT_LATEST_RENAME = "checkpoint/latest_rename"
FAULT_POINTS = (POINT_CHUNK, POINT_MANIFEST, POINT_COMMIT, POINT_LATEST,
                POINT_LATEST_RENAME)


class CheckpointDataError(CheckpointError):
    """A step's data is partial/corrupt (missing chunk, hash mismatch on
    every replica, truncated file). Restore treats it as fall-back-able —
    unlike a structural :class:`CheckpointError` (architecture mismatch),
    which always raises."""


# ---------------------------------------------------------------------------
# shard-wise extraction (the device→host half of a save)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _Chunk:
    index: list          # [[start, stop], ...] per dim (== [] for scalars)
    pod: int
    data: np.ndarray


@dataclasses.dataclass
class _LeafRecord:
    name: str
    shape: tuple
    dtype: str
    sharded: bool
    chunks: list


@dataclasses.dataclass
class Snapshot:
    """Host-side shard-wise copy of one pytree — what the async writer
    thread consumes after the train loop has moved on."""

    step: int
    records: list
    treedef_str: str
    mesh: dict | None
    extra: dict


def _path_name(path) -> str:
    parts = []
    for p in path:
        for attr in ("key", "idx", "name"):
            v = getattr(p, attr, None)
            if v is not None:
                parts.append(str(v))
                break
        else:                                       # pragma: no cover
            parts.append(str(p))
    return "/".join(parts) or "<root>"


def _norm_index(index, shape) -> list:
    out = []
    for sl, dim in zip(index, shape):
        start = 0 if sl.start is None else int(sl.start)
        stop = int(dim) if sl.stop is None else int(sl.stop)
        out.append([start, stop])
    return out


def _mesh_info(leaves) -> dict | None:
    for leaf in leaves:
        mesh = getattr(getattr(leaf, "sharding", None), "mesh", None)
        names = tuple(getattr(mesh, "axis_names", ()) or ())
        if mesh is not None and names:
            shape = [int(s) for s in np.asarray(mesh.devices).shape]
            n_pods = shape[names.index("pod")] if "pod" in names else 1
            return {"axes": list(names), "shape": shape, "n_pods": n_pods}
    return None


def _extract_leaf(path, leaf) -> _LeafRecord:
    name = _path_name(path)
    shards = getattr(leaf, "addressable_shards", None)
    if isinstance(leaf, jax.Array) and shards:
        podmap = None
        mesh = getattr(leaf.sharding, "mesh", None)
        if mesh is not None and "pod" in tuple(getattr(mesh, "axis_names",
                                                       ()) or ()):
            from repro.core.topology import device_pod_map
            podmap = device_pod_map(mesh, ("pod",))
        seen: dict[tuple, _Chunk] = {}
        for s in shards:
            key = tuple((sl.start, sl.stop) for sl in s.index)
            if key in seen:
                continue
            pod = podmap.get(s.device.id, 0) if podmap else 0
            # np.asarray(shard.data) is the ONLY device→host copy: one
            # shard, never the assembled leaf
            seen[key] = _Chunk(_norm_index(s.index, leaf.shape), pod,
                               np.asarray(s.data))
        chunks = list(seen.values())
        return _LeafRecord(name, tuple(int(d) for d in leaf.shape),
                           str(leaf.dtype), len(chunks) > 1, chunks)
    arr = np.asarray(jax.device_get(leaf))
    return _LeafRecord(name, tuple(arr.shape), str(arr.dtype), False,
                       [_Chunk([[0, int(d)] for d in arr.shape], 0, arr)])


def extract_snapshot(step: int, tree, extra: dict | None = None) -> Snapshot:
    """Shard-wise host snapshot (the caller may then donate/overwrite the
    device buffers; the writer thread works from this copy alone)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    records = [_extract_leaf(path, leaf) for path, leaf in flat]
    return Snapshot(step=step, records=records, treedef_str=str(treedef),
                    mesh=_mesh_info([l for _, l in flat]), extra=extra or {})


# ---------------------------------------------------------------------------
# write path (atomic commit + replication + fault points)
# ---------------------------------------------------------------------------
def _npy_bytes(arr: np.ndarray) -> bytes:
    buf = io.BytesIO()
    np.save(buf, arr, allow_pickle=False)
    return buf.getvalue()


def _resolve_replication(replication, q: int, shard_bytes: int,
                         machine: str) -> int:
    from repro.core.cost_model import choose_replication
    if replication == "auto":
        rf = choose_replication(q, float(shard_bytes), machine)
    else:
        rf = 1 if replication in (None, 0) else int(replication)
    return max(1, min(rf, max(q, 1)))


def write_snapshot(ckpt_dir: str, snap: Snapshot, *, keep_last: int = 3,
                   replication="auto", faults: FaultHarness | None = None,
                   machine: str = "tpu_multipod") -> str:
    from repro.core.cost_model import checkpoint_replication_model
    reg = telemetry.get_registry()
    os.makedirs(ckpt_dir, exist_ok=True)
    step = snap.step
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    q = (snap.mesh or {}).get("n_pods", 1)
    max_chunk = max((c.data.nbytes for r in snap.records for c in r.chunks),
                    default=0)
    rf = _resolve_replication(replication, q, max_chunk, machine)

    leaves_meta = []
    total_bytes = replica_bytes = tree_bytes = 0
    for i, rec in enumerate(snap.records):
        chunk_meta = []
        for ci, chunk in enumerate(rec.chunks):
            data = _npy_bytes(chunk.data)
            digest = hashlib.sha256(data).hexdigest()
            files = []
            for r in range(rf):
                fname = (f"leaf_{i:04d}_c{ci}.npy" if r == 0
                         else f"leaf_{i:04d}_c{ci}.r{r}.npy")
                write_bytes(os.path.join(tmp, fname), data, faults=faults,
                            point=POINT_CHUNK)
                files.append({"file": fname,
                              "pod": (chunk.pod + r) % max(q, 1),
                              "sha256": digest})
                if r:
                    replica_bytes += len(data)
            total_bytes += len(data)
            chunk_meta.append({"index": chunk.index, "files": files})
        tree_bytes += int(np.prod(rec.shape, dtype=np.int64)
                          if rec.shape else 1) * rec.chunks[0].data.itemsize
        leaves_meta.append({"path": rec.name, "shape": list(rec.shape),
                            "dtype": rec.dtype, "sharded": rec.sharded,
                            "chunks": chunk_meta})
    manifest = {"schema": SCHEMA_VERSION, "step": step,
                "n_leaves": len(snap.records), "treedef": snap.treedef_str,
                "mesh": snap.mesh, "replication": rf,
                "leaves": leaves_meta, "extra": snap.extra or {}}
    write_bytes(os.path.join(tmp, "manifest.json"),
                json.dumps(manifest).encode(), faults=faults,
                point=POINT_MANIFEST)
    if os.path.exists(final):
        shutil.rmtree(final)
    guard(POINT_COMMIT, faults)
    os.rename(tmp, final)                            # atomic commit
    ltmp = os.path.join(ckpt_dir, "LATEST.tmp")
    write_bytes(ltmp, str(step).encode(), faults=faults, point=POINT_LATEST)
    guard(POINT_LATEST_RENAME, faults)
    os.replace(ltmp, os.path.join(ckpt_dir, "LATEST"))
    _gc(ckpt_dir, keep_last)

    reg.gauge("checkpoint/save_bytes").set(float(total_bytes))
    reg.gauge("checkpoint/replica_bytes").set(float(replica_bytes))
    reg.gauge("checkpoint/max_chunk_bytes").set(float(max_chunk))
    reg.gauge("checkpoint/tree_bytes").set(float(tree_bytes))
    reg.gauge("checkpoint/replication").set(float(rf))
    if rf > 1:
        reg.gauge("checkpoint/replication_model_s").set(
            checkpoint_replication_model(q, float(max_chunk), machine, rf=rf))
    return final


def save_checkpoint(ckpt_dir: str, step: int, tree, *,
                    extra: dict | None = None, keep_last: int = 3,
                    replication="auto", faults: FaultHarness | None = None,
                    machine: str = "tpu_multipod") -> str:
    snap = extract_snapshot(step, tree, extra)
    return write_snapshot(ckpt_dir, snap, keep_last=keep_last,
                          replication=replication, faults=faults,
                          machine=machine)


def _gc(ckpt_dir: str, keep_last: int) -> None:
    """Delete all but the newest ``keep_last`` steps — but never the step
    ``LATEST`` points at (the old _gc could unlink the committed pointer's
    target, leaving restore a dangling LATEST)."""
    if not keep_last:
        return
    steps = sorted(_all_steps(ckpt_dir))
    keep = set(steps[-keep_last:])
    pinned = _read_latest(ckpt_dir)
    if pinned is not None:
        keep.add(pinned)
    for s in steps:
        if s not in keep:
            shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)


# ---------------------------------------------------------------------------
# step resolution
# ---------------------------------------------------------------------------
def _all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                out.append(int(name.split("_")[1]))
    return out


def _read_latest(ckpt_dir: str) -> int | None:
    try:
        with open(os.path.join(ckpt_dir, "LATEST")) as f:
            return int(f.read().strip())
    except (OSError, ValueError):
        return None


def latest_step(ckpt_dir: str) -> int | None:
    """Newest step with a manifest on disk (directory scan — see
    :func:`committed_step` for the LATEST-preferring resolution)."""
    steps = _all_steps(ckpt_dir)
    return max(steps) if steps else None


def committed_step(ckpt_dir: str) -> int | None:
    """The step restore should load: the committed ``LATEST`` pointer when
    it is readable and its target exists; otherwise fall back to the
    directory scan and count ``checkpoint/latest_fallbacks`` (a fallback
    means a crash landed between commit and pointer update, or a pre-v2
    directory)."""
    pinned = _read_latest(ckpt_dir)
    if pinned is not None and os.path.exists(
            os.path.join(ckpt_dir, f"step_{pinned:08d}", "manifest.json")):
        return pinned
    steps = _all_steps(ckpt_dir)
    if pinned is not None or steps:
        telemetry.get_registry().count("checkpoint/latest_fallbacks")
    return max(steps) if steps else None


def _load_manifest(d: str, step: int) -> dict:
    try:
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError, UnicodeDecodeError) as e:
        raise CheckpointDataError(f"manifest unreadable: {e}", step=step)
    if not isinstance(manifest, dict) or "n_leaves" not in manifest:
        raise CheckpointDataError("manifest missing required keys", step=step)
    if manifest.get("schema", 1) >= 2 and "leaves" not in manifest:
        raise CheckpointDataError("v2 manifest missing leaf table", step=step)
    return manifest


def read_manifest(ckpt_dir: str, *, step: int | None = None
                  ) -> tuple[int, dict] | None:
    """(step, manifest) for the committed (or explicit) step; None when the
    directory holds no complete checkpoint. Used by consumers that need the
    ``extra`` metadata before deciding what to restore (serve resume)."""
    step = step if step is not None else committed_step(ckpt_dir)
    if step is None:
        return None
    return step, _load_manifest(os.path.join(ckpt_dir, f"step_{step:08d}"),
                                step)


# ---------------------------------------------------------------------------
# restore path (reshard via per-device chunk assembly)
# ---------------------------------------------------------------------------
def _read_chunk(d: str, meta: dict, ci: int, step: int) -> np.ndarray:
    """One chunk, failing over home → replicas with hash verification."""
    reg = telemetry.get_registry()
    errs = []
    for fi, finfo in enumerate(meta["chunks"][ci]["files"]):
        path = os.path.join(d, finfo["file"])
        try:
            with open(path, "rb") as f:
                raw = f.read()
        except OSError as e:
            errs.append(f"{finfo['file']}: {e}")
            continue
        if hashlib.sha256(raw).hexdigest() != finfo["sha256"]:
            reg.count("checkpoint/hash_failures")
            errs.append(f"{finfo['file']}: sha256 mismatch")
            continue
        if fi:
            reg.count("checkpoint/replica_reads")
        return np.load(io.BytesIO(raw), allow_pickle=False)
    raise CheckpointDataError(
        f"chunk {ci} unrecoverable from any replica ({'; '.join(errs)})",
        leaf=meta["path"], step=step)


def _assemble(d: str, meta: dict, index, cache: dict, step: int
              ) -> np.ndarray:
    """The slice ``index`` of a leaf, copied out of intersecting chunks —
    the host allocation is the requested slice, not the leaf."""
    shape = tuple(meta["shape"])
    tgt = _norm_index(index, shape)
    out = None
    covered = 0
    for ci, cm in enumerate(meta["chunks"]):
        src = cm["index"]
        inter = [[max(a1, a2), min(b1, b2)]
                 for (a1, b1), (a2, b2) in zip(src, tgt)]
        if any(a >= b for a, b in inter):
            continue
        data = _read_chunk(d, meta, ci, step) if ci not in cache \
            else cache[ci]
        cache[ci] = data
        if out is None:
            out = np.empty([b - a for a, b in tgt], dtype=data.dtype)
        sl_src = tuple(slice(a - s[0], b - s[0])
                       for (a, b), s in zip(inter, src))
        sl_dst = tuple(slice(a - t[0], b - t[0])
                       for (a, b), t in zip(inter, tgt))
        out[sl_dst] = data[sl_src]
        covered += int(np.prod([b - a for a, b in inter], dtype=np.int64)
                       if inter else 1)
    want = int(np.prod([b - a for a, b in tgt], dtype=np.int64)
               if tgt else 1)
    if out is None or covered != want:
        raise CheckpointDataError(
            f"chunks cover {covered}/{want} elements of slice {tgt}",
            leaf=meta["path"], step=step)
    return out


def _load_leaf_v2(d: str, meta: dict, like, sharding, step: int):
    shape = tuple(meta["shape"])
    if tuple(like.shape) != shape:
        raise CheckpointError(
            f"checkpoint shape {list(shape)} != expected {list(like.shape)}",
            leaf=meta["path"], step=step)
    cache: dict[int, np.ndarray] = {}
    if sharding is not None and getattr(sharding, "mesh", None) is not None:
        # reshard-on-read: each device's callback assembles exactly its
        # slice under the TARGET layout from the stored chunks — a 2×16
        # save restores onto 3×8 or flat without the full leaf ever
        # existing on host
        return jax.make_array_from_callback(
            shape, sharding,
            lambda index: _assemble(d, meta, index, cache, step))
    full = _assemble(d, meta, tuple(slice(0, s) for s in shape), cache, step)
    return jax.device_put(full, sharding) if sharding is not None \
        else jax.device_put(full)


def _load_leaf_v1(d: str, i: int, like, sharding, step: int):
    arr = np.load(os.path.join(d, f"leaf_{i}.npy"))
    if tuple(arr.shape) != tuple(like.shape):
        raise CheckpointError(
            f"checkpoint shape {list(arr.shape)} != expected "
            f"{list(like.shape)}", leaf=f"leaf_{i}", step=step)
    return jax.device_put(arr, sharding) if sharding is not None \
        else jax.device_put(arr)


def _materialize(d: str, manifest: dict, like, shardings, step: int):
    leaves_like, treedef = jax.tree.flatten(like)
    if manifest["n_leaves"] != len(leaves_like):
        raise CheckpointError(
            f"checkpoint has {manifest['n_leaves']} leaves, expected "
            f"{len(leaves_like)} — architecture mismatch", step=step)
    shard_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                    else [None] * len(leaves_like))
    v2 = manifest.get("schema", 1) >= 2
    out = []
    for i, (lk, sh) in enumerate(zip(leaves_like, shard_leaves)):
        if v2:
            out.append(_load_leaf_v2(d, manifest["leaves"][i], lk, sh, step))
        else:
            out.append(_load_leaf_v1(d, i, lk, sh, step))
    return jax.tree.unflatten(treedef, out)


def restore_checkpoint(ckpt_dir: str, like, *, step: int | None = None,
                       shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs); ``shardings``: matching pytree of Shardings for
    elastic placement on the *current* mesh (arbitrary layout — restore
    reshards chunk-wise). Prefers the committed ``LATEST`` step; a
    corrupt/partial step falls back to the previous complete one
    (``checkpoint/manifest_fallbacks``). Returns ``(step, tree)`` or None
    when no checkpoint exists; raises :class:`CheckpointError` on
    architecture mismatch or when every candidate step is unusable.
    """
    reg = telemetry.get_registry()
    explicit = step is not None
    if explicit:
        candidates = [step]
    else:
        head = committed_step(ckpt_dir)
        if head is None:
            return None
        candidates = [head] + sorted(
            (s for s in _all_steps(ckpt_dir) if s != head), reverse=True)
    last_err: CheckpointDataError | None = None
    for s in candidates:
        d = os.path.join(ckpt_dir, f"step_{s:08d}")
        try:
            manifest = _load_manifest(d, s)
            return s, _materialize(d, manifest, like, shardings, s)
        except CheckpointDataError as e:
            if explicit:
                raise
            # partial/corrupt step: fall back to the previous complete one
            reg.count("checkpoint/manifest_fallbacks")
            last_err = e
    raise CheckpointError(
        f"no usable checkpoint under {ckpt_dir}: {last_err}")


# ---------------------------------------------------------------------------
# async manager
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CheckpointHealth:
    """Structured writer health — what the Trainer inspects *between*
    saves instead of discovering a stale failure inside the next one.

    state: "ok" (every save committed cleanly), "degraded" (committed, but
    a retry fired or an earlier save failed), "failed" (the most recent
    attempt failed — the newest snapshot is NOT on disk)."""

    state: str = "ok"
    last_saved_step: int | None = None
    last_error: str | None = None
    failures: int = 0
    retries: int = 0
    pending: bool = False


class CheckpointManager:
    """Async checkpointing: ``save`` snapshots shard-wise and returns; a
    daemon thread serializes writes with bounded retry-with-backoff on
    transient ``OSError``. A previous save's failure never aborts the next
    ``save()`` (it lands in :attr:`health` / ``healthy()``); ``wait()``
    still blocks and raises the latest error — the end-of-run contract."""

    def __init__(self, ckpt_dir: str, keep_last: int = 3, *,
                 replication="auto", retries: int = 3,
                 backoff_s: float = 0.05,
                 faults: FaultHarness | None = None,
                 machine: str = "tpu_multipod"):
        self.ckpt_dir = ckpt_dir
        self.keep_last = keep_last
        self.replication = replication
        self.retries = retries
        self.backoff_s = backoff_s
        self.faults = faults
        self.machine = machine
        self.health = CheckpointHealth()
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def healthy(self) -> bool:
        return self.health.state != "failed"

    def save(self, step: int, tree, *, extra: dict | None = None,
             blocking: bool = False) -> None:
        tracer = telemetry.get_tracer()
        reg = telemetry.get_registry()
        with tracer.span("checkpoint/save", step=step):
            # shard-sized host copies (device→host DMA of each shard, never
            # an assembled leaf) — the loop may then donate the buffers
            snap = extract_snapshot(step, tree, extra)

        def work():
            t0 = time.perf_counter()
            attempt = 0
            try:
                while True:
                    try:
                        with tracer.span("checkpoint/write", step=step):
                            write_snapshot(
                                self.ckpt_dir, snap,
                                keep_last=self.keep_last,
                                replication=self.replication,
                                faults=self.faults, machine=self.machine)
                        break
                    except OSError:
                        if attempt >= self.retries:
                            raise
                        delay = self.backoff_s * (2 ** attempt)
                        attempt += 1
                        self.health.retries += 1
                        reg.count("checkpoint/retries")
                        time.sleep(delay)
            except BaseException as e:
                self._error = e
                self.health.failures += 1
                self.health.state = "failed"
                self.health.last_error = f"{type(e).__name__}: {e}"
                self.health.pending = False
                reg.count("checkpoint/save_failures")
                return
            self.health.last_saved_step = step
            self.health.state = ("degraded" if attempt or self.health.failures
                                 else "ok")
            self.health.pending = False
            reg.count("checkpoint/saves")
            reg.observe("checkpoint/save_s", time.perf_counter() - t0)

        # join (never raise): surfacing the PREVIOUS save's failure here
        # used to abort before the new writer started, losing THIS snapshot
        self._join()
        self.health.pending = True
        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def _join(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def wait(self) -> None:
        """Block until the queue drains; raise the pending error, if any."""
        self._join()
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore(self, like, *, shardings=None):
        reg = telemetry.get_registry()
        t0 = time.perf_counter()
        with telemetry.get_tracer().span("checkpoint/restore"):
            out = restore_checkpoint(self.ckpt_dir, like,
                                     shardings=shardings)
        if out is not None:
            reg.count("checkpoint/restores")
            reg.observe("checkpoint/restore_s", time.perf_counter() - t0)
        return out
