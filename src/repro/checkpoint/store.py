"""Sharded checkpointing with atomic commit, async writes and elastic restore.

Layout per checkpoint::

    <dir>/step_<N>/
        manifest.json     step, leaf index, mesh shape, extra metadata
        leaf_<i>.npy      one file per pytree leaf (global array)
    <dir>/LATEST          text file: committed step number (atomic rename)

Writes go to ``step_<N>.tmp/`` and are renamed only after every leaf and the
manifest are on disk — a crash mid-write never corrupts the newest complete
checkpoint. Restore re-shards leaves onto the *current* mesh via
``jax.device_put``, so a run checkpointed on 512 chips restarts unchanged on
256 (elastic: the data-parallel axis size is free to change; manifest records
the original mesh for audit). Async mode pushes the device→host copy and file
I/O to a daemon thread so the train loop never blocks on storage.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

from repro import telemetry


def _leaf_paths(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree, *, extra: dict | None = None,
                    keep_last: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves, treedef = _leaf_paths(tree)
    host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]

    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    for i, arr in enumerate(host_leaves):
        np.save(os.path.join(tmp, f"leaf_{i}.npy"), arr)
    manifest = {
        "step": step,
        "n_leaves": len(host_leaves),
        "treedef": str(treedef),
        "dtypes": [str(a.dtype) for a in host_leaves],
        "shapes": [list(a.shape) for a in host_leaves],
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                       # atomic commit
    with open(os.path.join(ckpt_dir, "LATEST.tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(ckpt_dir, "LATEST.tmp"),
               os.path.join(ckpt_dir, "LATEST"))
    _gc(ckpt_dir, keep_last)
    return final


def _gc(ckpt_dir: str, keep_last: int) -> None:
    steps = sorted(_all_steps(ckpt_dir))
    for s in steps[:-keep_last] if keep_last else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


def _all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                out.append(int(name.split("_")[1]))
    return out


def latest_step(ckpt_dir: str) -> int | None:
    steps = _all_steps(ckpt_dir)
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, like, *, step: int | None = None,
                       shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: matching pytree of Shardings for
    elastic placement on the current mesh; None → default placement.

    Returns (step, tree) or None if no complete checkpoint exists.
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        return None
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_like, treedef = jax.tree.flatten(like)
    assert manifest["n_leaves"] == len(leaves_like), (
        f"checkpoint has {manifest['n_leaves']} leaves, expected "
        f"{len(leaves_like)} — architecture mismatch")
    shard_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                    else [None] * len(leaves_like))
    out = []
    for i, (lk, sh) in enumerate(zip(leaves_like, shard_leaves)):
        arr = np.load(os.path.join(d, f"leaf_{i}.npy"))
        assert tuple(arr.shape) == tuple(lk.shape), (
            f"leaf {i}: ckpt shape {arr.shape} != expected {lk.shape}")
        out.append(jax.device_put(arr, sh) if sh is not None else
                   jax.device_put(arr))
    return step, jax.tree.unflatten(treedef, out)


class CheckpointManager:
    """Async checkpointing: ``save`` returns immediately; a daemon thread
    serializes writes. ``wait()`` blocks until the queue drains (used before
    shutdown and in tests)."""

    def __init__(self, ckpt_dir: str, keep_last: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep_last = keep_last
        self._lock = threading.Lock()
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, step: int, tree, *, extra: dict | None = None,
             blocking: bool = False) -> None:
        # snapshot to host synchronously (cheap on CPU; on TPU this is the
        # device->host DMA) so the train loop may donate/overwrite buffers.
        tracer = telemetry.get_tracer()
        with tracer.span("checkpoint/save", step=step):
            leaves, treedef = jax.tree.flatten(tree)
            host = [np.asarray(jax.device_get(l)) for l in leaves]
            snapshot = jax.tree.unflatten(treedef, host)

        def work():
            try:
                # the writer thread's spans land in their own trace lane
                with tracer.span("checkpoint/write", step=step):
                    save_checkpoint(self.ckpt_dir, step, snapshot,
                                    extra=extra, keep_last=self.keep_last)
                telemetry.get_registry().count("checkpoint/saves")
            except BaseException as e:       # surfaced on next wait()
                self._error = e

        self.wait()
        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore(self, like, *, shardings=None):
        with telemetry.get_tracer().span("checkpoint/restore"):
            return restore_checkpoint(self.ckpt_dir, like,
                                      shardings=shardings)
