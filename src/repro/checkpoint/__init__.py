from .errors import CheckpointError
from .store import (CheckpointDataError, CheckpointHealth, CheckpointManager,
                    FAULT_POINTS, Snapshot, committed_step, extract_snapshot,
                    latest_step, read_manifest, restore_checkpoint,
                    save_checkpoint, write_snapshot)
