"""Seeded chaos schedule: the disturbance half of the convergence proof.

One Philox-keyed draw (same generator discipline as
``repro.faults.FaultHarness``) fixes WHICH steps get hard kills, graceful
preemptions and injected stragglers, plus an explicit capacity timeline
(step -> devices offered). The controller re-arms each episode's injector
and preemption signal from the schedule's *unfired* view: a kill consumed
in episode N must not re-fire when episode N+1 replays the same step from
the commit, while straggler delays stay armed per episode (a replayed
delayed step is delayed again — determinism over cleverness).

Everything is derived from ``(seed, steps, counts)``: two soak runs with
the same arguments see byte-identical disturbance timelines.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.runtime import FaultInjector, PreemptionSignal


@dataclasses.dataclass(frozen=True)
class ChaosSpec:
    """Counts + bounds for the random draw."""

    steps: int                       # schedule horizon (trainer steps)
    seed: int = 0
    kills: int = 1
    preempts: int = 1
    straggles: int = 1
    first_step: int = 3              # no chaos during compile/warmup steps
    delay_s: float = 0.25            # minimum injected straggler sleep
    #: explicit capacity timeline: ((step, devices), ...) — capacity
    #: changes are operator/scheduler actions, not random noise
    capacity: tuple[tuple[int, int], ...] = ()


class ChaosSchedule:
    def __init__(self, spec: ChaosSpec):
        self.spec = spec
        lo = spec.first_step
        hi = max(lo + 1, spec.steps)
        want = spec.kills + spec.preempts + spec.straggles
        if want > hi - lo:
            raise ValueError(f"{want} events do not fit in steps "
                             f"[{lo}, {hi})")
        rng = np.random.Generator(np.random.Philox(key=spec.seed))
        # distinct steps so one step never carries two event kinds (a
        # kill and a preemption at the same step would be order-defined
        # by trainer internals, not by the schedule)
        picks = rng.choice(np.arange(lo, hi), size=want, replace=False)
        k, p = spec.kills, spec.preempts
        self.kills = tuple(sorted(int(s) for s in picks[:k]))
        self.preempts = tuple(sorted(int(s) for s in picks[k:k + p]))
        self.straggles = tuple(sorted(int(s) for s in picks[k + p:]))
        self.capacity = tuple(sorted(spec.capacity))
        self._fired_kills: set[int] = set()
        self._fired_preempts: set[int] = set()

    # -- per-episode arming --------------------------------------------
    def fault_injector(self) -> FaultInjector:
        return FaultInjector(
            kill_at_steps=tuple(s for s in self.kills
                                if s not in self._fired_kills),
            delay_at_steps=self.straggles,
            delay_s=self.spec.delay_s)

    def preemption_signal(self) -> PreemptionSignal:
        return PreemptionSignal(
            at_steps=tuple(s for s in self.preempts
                           if s not in self._fired_preempts))

    # -- controller feedback -------------------------------------------
    def observe_kill(self, step: int) -> None:
        self._fired_kills.add(step)

    def observe_preempt(self, step: int) -> None:
        self._fired_preempts.add(step)

    def capacity_at(self, step: int, default: int) -> int:
        cap = default
        for s, v in self.capacity:
            if s <= step:
                cap = v
        return cap

    def pending(self) -> dict:
        return {"kills": [s for s in self.kills
                          if s not in self._fired_kills],
                "preempts": [s for s in self.preempts
                             if s not in self._fired_preempts]}

    def describe(self) -> dict:
        return {"seed": self.spec.seed, "kills": list(self.kills),
                "preempts": list(self.preempts),
                "straggles": list(self.straggles),
                "capacity": [list(c) for c in self.capacity]}
