"""Pod-aligned target layouts, priced by the postal cost model.

A resize that ignores pod boundaries destroys the two-tier schedule's
locality advantage: a mesh row that straddles a physical pod turns ICI
hops into what the runtime schedules as DCN rounds. So every candidate
layout here keeps each mesh row INSIDE one physical pod — ``per_pod``
divides ``pod_size`` — and :func:`choose_layout` ranks candidates by

1. devices utilized (never leave a whole pod idle), then
2. the modeled two-tier allgather time (:func:`cost_model
   .locality_bruck_model` — Eq. 4, which handles the arbitrary/non-power
   region counts a shrink naturally produces via the allgatherv
   adaptation).

Splitting pods into more, smaller mesh rows (e.g. (6,2) instead of (3,4)
on three 4-chip pods) keeps alignment but multiplies the inter-region
round count, so the cost model rejects it whenever the non-local tier is
the expensive one — exactly the paper's argument, applied to layout
selection instead of schedule selection.

jax is imported lazily (inside :func:`layout_mesh` only): importing this
module never touches jax device state.
"""
from __future__ import annotations

import dataclasses

from repro.core import cost_model


class FleetLayoutError(RuntimeError):
    """A layout could not be built or failed its locality assertion."""


@dataclasses.dataclass(frozen=True, order=True)
class Layout:
    """``pods`` mesh rows of ``per_pod`` devices: mesh shape (q, d)."""

    pods: int
    per_pod: int

    @property
    def total(self) -> int:
        return self.pods * self.per_pod

    @property
    def shape(self) -> tuple[int, int]:
        return (self.pods, self.per_pod)

    def __str__(self) -> str:
        return f"({self.pods}x{self.per_pod})"


def pod_aligned_layouts(capacity: int, pod_size: int) -> list[Layout]:
    """Every layout whose mesh rows nest inside physical ``pod_size``-chip
    pods, using at most ``capacity`` devices. Each whole available pod may
    be split into ``pod_size/d`` rows of ``d`` devices for any divisor
    ``d``; a capacity below one pod degenerates to the flat single-row
    layout (the only shape that wastes nothing)."""
    if capacity < 1 or pod_size < 1:
        return []
    whole_pods = capacity // pod_size
    out = set()
    for q_phys in range(1, whole_pods + 1):
        for d in range(1, pod_size + 1):
            if pod_size % d == 0:
                out.add(Layout(q_phys * (pod_size // d), d))
    if not out:
        out.add(Layout(1, capacity))
    return sorted(out)


def layout_price_s(layout: Layout, *, machine: str = "tpu_multipod",
                   block_bytes: float = 1 << 20) -> float:
    """Modeled worst-rank allgather time for one ``block_bytes`` block per
    rank on this layout (Eq. 4; arbitrary region counts supported)."""
    m = cost_model.MACHINES[machine]
    if layout.pods <= 1:
        return (cost_model.bruck_model(layout.per_pod, block_bytes, m)
                if layout.per_pod > 1 else 0.0)
    if layout.per_pod <= 1:
        # one device per mesh row: no local tier at all — every hop is a
        # non-local round, i.e. the flat Bruck (Eq. 3). (Eq. 4's round
        # simulation needs p_local >= 2 to make progress.)
        return cost_model.bruck_model(layout.total, block_bytes, m)
    return cost_model.locality_bruck_model(
        layout.total, layout.per_pod, block_bytes, m)


def choose_layout(capacity: int, pod_size: int, *,
                  machine: str = "tpu_multipod",
                  block_bytes: float = 1 << 20) -> Layout:
    """The cheapest maximal pod-aligned layout for ``capacity`` devices.

    Utilization dominates (idling a whole pod is never worth a cheaper
    schedule); the cost model breaks ties between equal-device
    arrangements of the same pods. Deterministic: ties after price fall
    back to the fewest mesh rows, then the dataclass order."""
    cands = pod_aligned_layouts(capacity, pod_size)
    if not cands:
        raise FleetLayoutError(
            f"no pod-aligned layout for capacity={capacity} "
            f"pod_size={pod_size}")
    best_total = max(c.total for c in cands)
    maximal = [c for c in cands if c.total == best_total]
    return min(maximal, key=lambda c: (
        layout_price_s(c, machine=machine, block_bytes=block_bytes),
        c.pods, c))


def layout_mesh(layout: Layout, devices=None):
    """Materialize the layout as a ('pod','data') Mesh over the FIRST
    ``layout.total`` devices (devices are pod-major in this simulated
    fleet, so consecutive runs of ``pod_size`` share a pod and each mesh
    row stays pod-local by construction)."""
    import jax
    import numpy as np

    devs = list(jax.devices() if devices is None else devices)
    if layout.total > len(devs):
        raise FleetLayoutError(
            f"layout {layout} needs {layout.total} devices, "
            f"have {len(devs)}")
    arr = np.array(devs[:layout.total],
                   dtype=object).reshape(layout.pods, layout.per_pod)
    return jax.sharding.Mesh(arr, ("pod", "data"))
