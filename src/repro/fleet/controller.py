"""FleetController: the autoscaling shrink/grow orchestration loop.

PR 8 built the elastic *mechanisms* — resharding restore, preemption
drain, fault injection. This is the *decider* on top: an episode loop
that builds a Trainer on the current pod-aligned layout, runs
``fit(resume="auto")``, and converts whatever ends the episode (a hard
kill, a drain, completion) into the next action through the pure
:class:`~repro.fleet.policy.FleetPolicy`.

Signals consumed, all pre-existing surfaces:

* ``runtime/stragglers`` counter (StepMonitor pressure),
* serve scheduler queue depth (``Engine.scheduler.stats()``),
* ``PreemptionSignal`` drains (chaos- or SIGTERM-triggered),
* ``CheckpointManager`` health + ``committed_step``,
* ``repro.faults`` kills (``ProcessKilled``).

``ProcessKilled`` is a BaseException precisely so no recovery path inside
the stack may swallow it; the controller is the documented exception —
it IS the top-level restart driver the ``repro.faults`` contract refers
to, standing in for the external daemon (borg/k8s) of a real fleet.

Every decision lands as a structured ``TelemetryEvent`` plus ``fleet/*``
counters (``fleet/decisions`` must equal the sum of the per-action
counters — ``scripts/check_metrics_schema.py`` enforces it), decision
latency and post-failure recovery wall-clock go to histograms, and —
when ``assert_locality`` is on — every multi-pod layout's compiled step
must show a locality schedule in its HLO (``CommReport
.has_locality_schedule``) or the build fails loudly.

Zero-data-loss is asserted structurally: every episode must resume
exactly at the committed step (:class:`FleetDataLossError` otherwise),
and per-step losses are folded into ``loss_by_step`` so a soak can
compare the disturbed trajectory bitwise against an undisturbed run.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

from repro import telemetry
from repro.checkpoint import committed_step
from repro.faults import ProcessKilled
from repro.runtime import PreemptionSignal
from repro.telemetry import TelemetryEvent

from .chaos import ChaosSchedule
from .layout import (FleetLayoutError, Layout, choose_layout, layout_mesh,
                     layout_price_s)
from .policy import Decision, FleetPolicy, FleetSignals

#: decision action -> metrics counter suffix
ACTION_COUNTERS = {"none": "noops", "retry": "retries", "shrink": "shrinks",
                   "grow": "grows", "halt": "halts"}

_HALT = "halt"                  # pending-resize sentinel for a tick halt


class FleetDataLossError(RuntimeError):
    """An episode resumed somewhere other than the committed step."""


@dataclasses.dataclass
class FleetReport:
    status: str                         # "complete" | "halted-degraded"
    steps: int                          # final trainer step
    episodes: list[dict]
    decisions: list[Decision]
    final_layout: tuple[int, int]
    loss_by_step: dict[int, float]      # step -> loss, replays folded in
    chaos: dict | None = None


class FleetController:
    """Drives ``make_trainer(mesh)`` episodes until complete or halted.

    ``make_trainer`` must return a fresh :class:`repro.train.Trainer` for
    the given mesh, pointed at ONE checkpoint directory across calls (the
    resume chain lives there). When ``chaos`` is set the controller owns
    the trainer's fault injector and preemption signal (re-armed from the
    schedule's unfired view each episode); otherwise a trainer-provided
    ``preemption`` is respected and only created when absent.
    """

    def __init__(self, make_trainer: Callable[[Any], Any], *,
                 pod_size: int,
                 policy: FleetPolicy | None = None,
                 capacity_fn: Callable[[int], int] | None = None,
                 chaos: ChaosSchedule | None = None,
                 devices: int | None = None,
                 machine: str = "tpu_multipod",
                 block_bytes: float = 1 << 20,
                 assert_locality: bool = False,
                 poll_every: int = 1,
                 max_episodes: int = 32,
                 engine_factory: Callable[[Any], Any] | None = None,
                 serve_ckpt_dir: str | None = None,
                 log: Callable[[str], None] = print,
                 tracer: telemetry.Tracer | None = None,
                 registry: telemetry.MetricsRegistry | None = None):
        self.make_trainer = make_trainer
        self.pod_size = pod_size
        self.policy = policy or FleetPolicy()
        self.capacity_fn = capacity_fn
        self.chaos = chaos
        self._devices = devices
        self.machine = machine
        self.block_bytes = block_bytes
        self.assert_locality = assert_locality
        self.poll_every = max(1, poll_every)
        self.max_episodes = max_episodes
        self.engine_factory = engine_factory
        self.serve_ckpt_dir = serve_ckpt_dir
        if engine_factory is not None and serve_ckpt_dir is None:
            raise ValueError("engine_factory needs serve_ckpt_dir for "
                             "suspend/resume across resizes")
        self.log = log
        self.tracer = tracer or telemetry.get_tracer()
        self.registry = registry or telemetry.get_registry()
        self.events: list[TelemetryEvent] = []
        self.episodes: list[dict] = []
        self.loss_by_step: dict[int, float] = {}
        self.engine = None
        self._engine_suspended = False
        self._pending: Layout | str | None = None

    # -- signal assembly -----------------------------------------------
    def _capacity(self, step: int, fallback: int) -> int:
        return (self.capacity_fn(step) if self.capacity_fn is not None
                else fallback)

    def _queue_depth(self) -> int:
        if self.engine is None:
            return 0
        s = self.engine.scheduler.stats()
        return int(s.get("active", 0)) + int(s.get("queued", 0))

    def _signals(self, kind: str, tr) -> FleetSignals:
        counters = self.registry.snapshot().get("counters", {})
        live = int(tr.mesh.devices.size)
        return FleetSignals(
            kind=kind, step=tr.step,
            committed_step=committed_step(tr.tcfg.ckpt_dir) or 0,
            stragglers=int(counters.get("runtime/stragglers", 0)),
            queue_depth=self._queue_depth(),
            ckpt_state=tr.ckpt.health.state,
            devices=live,
            capacity=self._capacity(tr.step, live))

    # -- decision plumbing ---------------------------------------------
    def _decide(self, kind: str, tr) -> Decision:
        sig = self._signals(kind, tr)
        t0 = time.perf_counter()
        d = self.policy.decide(sig)
        latency = time.perf_counter() - t0
        reg = self.registry
        reg.observe("fleet/decision_latency_s", latency)
        reg.count("fleet/decisions")
        reg.count(f"fleet/{ACTION_COUNTERS[d.action]}")
        ev = TelemetryEvent(
            f"fleet decision: {d.action} — {d.reason}", kind="fleet",
            step=sig.step,
            attrs={"action": d.action, "reason": d.reason,
                   "escalation": d.escalation,
                   "target_devices": d.target_devices,
                   "signal": dataclasses.asdict(sig)})
        self.events.append(ev)
        if d.action != "none":
            self.log(f"[fleet] {ev}")
        return d

    # -- layout / serve helpers ----------------------------------------
    def _choose(self, capacity: int) -> Layout:
        return choose_layout(capacity, self.pod_size, machine=self.machine,
                             block_bytes=self.block_bytes)

    def _target_layout(self, d: Decision, current: Layout) -> Layout:
        if d.target_devices is not None:
            return self._choose(d.target_devices)
        # default escalation shrink: one pod fewer, never below one pod
        return self._choose(max(self.pod_size,
                                current.total - self.pod_size))

    def _suspend_serve(self) -> None:
        """Graceful serve drain ahead of a layout change: on a real fleet
        the resize notice reaches the serve tier too, so in-flight decode
        state is checkpointed rather than lost."""
        if self.engine is None:
            return
        with self.tracer.span("fleet/serve_suspend"):
            self.engine.suspend(self.serve_ckpt_dir)
        self.engine = None
        self._engine_suspended = True
        self.registry.count("fleet/serve_suspends")

    def _resume_serve(self, mesh) -> None:
        if self.engine_factory is None or self.engine is not None:
            return
        with self.tracer.span("fleet/serve_resume"):
            self.engine = self.engine_factory(mesh)
            if self._engine_suspended:
                n = self.engine.resume(self.serve_ckpt_dir)
                self._engine_suspended = False
                self.registry.count("fleet/serve_resumes")
                self.log(f"[fleet] serve engine resumed "
                         f"{n} request(s) on {mesh.devices.shape}")

    # -- episode construction ------------------------------------------
    def _hook(self, tr) -> None:
        """The per-step tick, installed as ``Trainer.step_hook``."""
        if self._pending is not None or tr.step % self.poll_every:
            return
        d = self._decide("tick", tr)
        if d.action == "halt":
            self._pending = _HALT
            tr.preemption.trigger()         # drain with a final save
        elif d.action in ("shrink", "grow"):
            target = self._target_layout(d, self._layout)
            if target == self._layout:
                return                      # already there: nothing to do
            self._pending = target
            tr.preemption.trigger()

    def _build(self, layout: Layout):
        import jax

        reg = self.registry
        mesh = layout_mesh(
            layout, None if self._devices is None
            else jax.devices()[:self._devices])
        jax.set_mesh(mesh)
        price = layout_price_s(layout, machine=self.machine,
                               block_bytes=self.block_bytes)
        reg.count("fleet/episodes")
        reg.gauge("fleet/devices").set(float(layout.total))
        reg.gauge("fleet/pods").set(float(layout.pods))
        reg.gauge("fleet/layout_price_s").set(price)
        with self.tracer.span("fleet/build", layout=str(layout)):
            tr = self.make_trainer(mesh)
        if self.chaos is not None:
            tr.faults = self.chaos.fault_injector()
            tr.preemption = self.chaos.preemption_signal()
        elif tr.preemption is None:
            tr.preemption = PreemptionSignal()
        tr.step_hook = self._hook
        # zero-data-loss, structurally: the trainer must sit exactly on
        # the committed step — anything else means a commit was dropped
        # (or a stale one resurrected) across the restart
        commit = committed_step(tr.tcfg.ckpt_dir) or 0
        if tr.step != commit:
            raise FleetDataLossError(
                f"episode resumed at step {tr.step}, committed step is "
                f"{commit} ({tr.tcfg.ckpt_dir})")
        if self.assert_locality and layout.pods > 1:
            rep = tr.comm_report
            if rep is None:
                raise FleetLayoutError(
                    f"layout {layout}: no CommReport to assert locality "
                    f"on (enable comm_telemetry)")
            if not rep.has_locality_schedule:
                raise FleetLayoutError(
                    f"layout {layout}: compiled step has NO pod-crossing "
                    f"locality schedule (grad_sync="
                    f"{tr.artifacts.grad_sync})")
            reg.count("fleet/layout_asserts")
        self._resume_serve(mesh)
        return tr

    def _fold_losses(self, tr) -> None:
        for m in tr.metrics_history:
            self.loss_by_step[m["step"]] = m["loss"]

    def _record_episode(self, n: int, layout: Layout, resumed: int,
                        tr, outcome: str) -> None:
        self.episodes.append({
            "episode": n, "layout": layout.shape, "resumed_step": resumed,
            "end_step": tr.step, "outcome": outcome})

    # -- the loop -------------------------------------------------------
    def run(self) -> FleetReport:
        reg = self.registry
        cap0 = self._capacity(0, self._devices or 0)
        if cap0 <= 0:
            import jax
            cap0 = len(jax.devices())
        layout = self._choose(cap0)
        self._layout = layout
        status = None
        t_fail: float | None = None
        episode = 0
        tr = None
        while status is None:
            episode += 1
            if episode > self.max_episodes:
                status = "halted-degraded"
                self.events.append(TelemetryEvent(
                    f"fleet: episode budget ({self.max_episodes}) "
                    f"exhausted", kind="fleet"))
                self.log(f"[fleet] {self.events[-1]}")
                break
            self._layout = layout
            self._pending = None
            tr = self._build(layout)
            if t_fail is not None:
                reg.observe("fleet/recovery_s", time.perf_counter() - t_fail)
                t_fail = None
            resumed = tr.step
            try:
                out = tr.fit(resume="auto")
            except ProcessKilled as e:
                # top-level restart driver: the one sanctioned catch —
                # see repro.faults and the module docstring
                t_fail = time.perf_counter()
                try:
                    # fence the dead incarnation's async writer before any
                    # restart: a save still in flight would race the next
                    # episode's committed-step read (the simulated-kill
                    # analogue of waiting out the old process's lease)
                    tr.ckpt.wait()
                except Exception as werr:       # noqa: BLE001
                    # a failed in-flight save is the writer's problem, not
                    # the restart's: health lands in the next signals read
                    self.log(f"[fleet] killed episode's writer errored "
                             f"while draining: {werr}")
                self._fold_losses(tr)
                self._record_episode(episode, layout, resumed, tr, "killed")
                if self.chaos is not None:
                    self.chaos.observe_kill(tr.step)
                self.log(f"[fleet] episode {episode} killed at step "
                         f"{tr.step}: {e}")
                d = self._decide("kill", tr)
                if d.action == "halt":
                    status = "halted-degraded"
                elif d.action == "shrink":
                    self._suspend_serve()
                    layout = self._target_layout(d, layout)
                continue
            self._fold_losses(tr)
            if out["status"] == "preempted":
                t_fail = time.perf_counter()
                if self._pending is not None:
                    # our own resize drain coming back around
                    target = self._pending
                    self._pending = None
                    outcome = ("halting" if target is _HALT else
                               f"resizing -> {target}")
                    self._record_episode(episode, layout, resumed, tr,
                                         outcome)
                    if target is _HALT:
                        status = "halted-degraded"
                    else:
                        self._suspend_serve()
                        layout = target
                    continue
                self._record_episode(episode, layout, resumed, tr,
                                     "preempted")
                if self.chaos is not None:
                    self.chaos.observe_preempt(tr.step)
                d = self._decide("preemption", tr)
                if d.action == "halt":
                    status = "halted-degraded"
                elif d.action == "shrink":
                    self._suspend_serve()
                    layout = self._target_layout(d, layout)
                continue
            self._record_episode(episode, layout, resumed, tr, "complete")
            status = "complete"
        healthy = (status == "complete"
                   and (tr is None or tr.ckpt.healthy()))
        reg.gauge("fleet/healthy").set(1.0 if healthy else 0.0)
        ev = TelemetryEvent(
            f"fleet run {status}: {episode} episode(s), final layout "
            f"{layout}", kind="fleet",
            attrs={"status": status, "episodes": episode,
                   "layout": layout.shape, "healthy": healthy})
        self.events.append(ev)
        self.log(f"[fleet] {ev}")
        return FleetReport(
            status=status, steps=tr.step if tr is not None else 0,
            episodes=self.episodes, decisions=list(self.policy.history),
            final_layout=layout.shape, loss_by_step=dict(self.loss_by_step),
            chaos=self.chaos.describe() if self.chaos else None)
