"""Fleet controller: autoscaling shrink/grow orchestration (DESIGN.md §11).

The decision layer over PR 8's elastic mechanisms — a pure, bounded
policy (:mod:`.policy`), cost-model-priced pod-aligned layout selection
(:mod:`.layout`), a seeded disturbance schedule (:mod:`.chaos`) and the
episode loop that ties them to ``Trainer.fit(resume=...)`` and
``Engine.suspend/resume`` (:mod:`.controller`).
"""
from .chaos import ChaosSchedule, ChaosSpec
from .controller import (ACTION_COUNTERS, FleetController, FleetDataLossError,
                         FleetReport)
from .layout import (FleetLayoutError, Layout, choose_layout, layout_mesh,
                     layout_price_s, pod_aligned_layouts)
from .policy import (ACTIONS, ESCALATION, Decision, FleetPolicy,
                     FleetSignals, PolicyConfig)

__all__ = [
    "ACTIONS", "ACTION_COUNTERS", "ChaosSchedule", "ChaosSpec", "Decision",
    "ESCALATION", "FleetController", "FleetDataLossError", "FleetLayoutError",
    "FleetPolicy", "FleetReport", "FleetSignals", "Layout", "PolicyConfig",
    "choose_layout", "layout_mesh", "layout_price_s", "pod_aligned_layouts",
]
