"""Fleet decision policy: signals in, one bounded action out.

Pure Python and fully deterministic — no jax, no clocks, no randomness —
so the hysteresis/cooldown/escalation invariants are property-testable
(tests/test_fleet.py drives it through hypothesis).

The controller feeds every observation through :meth:`FleetPolicy.decide`
as a :class:`FleetSignals` and executes the returned :class:`Decision`:

========== =====================================================
signal     response
========== =====================================================
kill/fault open (or continue) an *incident*: ``retry`` up to
           ``max_retries`` times, then ``shrink`` (one pod fewer),
           then ``halt`` — the bounded escalation ladder. Committed
           progress since the incident opened closes it (the crash
           is new, not a loop) and restarts the retry budget, as
           does a shrink (the ladder restarts on the new layout).
preemption ``retry`` — the drain already committed a blocking save,
           so resuming at the commit is free.
tick       capacity below the live layout forces a ``shrink`` to
           capacity (cooldown does not apply: the devices are
           gone); sustained straggler pressure (>= ``straggler_high``
           flags inside ``straggler_window`` steps) shrinks after
           the cooldown; spare capacity grows back only when the
           cooldown has passed AND straggler pressure is at or
           under ``straggler_low`` AND the checkpoint writer is
           healthy (hysteresis: the grow watermark sits strictly
           below the shrink watermark, so a marginal fleet cannot
           oscillate).
========== =====================================================

``halt`` is absorbing: once the policy halts, every later signal gets
``halt`` back — the controller parks the fleet degraded instead of
burning restarts.
"""
from __future__ import annotations

import dataclasses

#: action -> escalation rank. ``grow`` is capacity-seeking, not an
#: escalation, and shares rank 0 with ``none``.
ESCALATION = {"none": 0, "grow": 0, "retry": 1, "shrink": 2, "halt": 3}

ACTIONS = tuple(ESCALATION)


@dataclasses.dataclass(frozen=True)
class FleetSignals:
    """One observation of the fleet, as the controller sees it."""

    kind: str = "tick"          # "tick" | "kill" | "fault" | "preemption"
    step: int = 0               # trainer step the signal was taken at
    committed_step: int = 0     # last durably committed checkpoint step
    stragglers: int = 0         # CUMULATIVE runtime/stragglers counter
    queue_depth: int = 0        # serve backlog (active + queued requests)
    ckpt_state: str = "ok"      # CheckpointManager health: ok|degraded|failed
    devices: int = 0            # devices in the live layout
    capacity: int = 0           # devices the fleet scheduler currently offers


@dataclasses.dataclass(frozen=True)
class Decision:
    action: str                 # one of ACTIONS
    reason: str
    step: int
    escalation: int             # ESCALATION[action]
    #: shrink/grow sizing hint: device count to relayout to, or None for
    #: the default shrink of one pod (the controller owns pod geometry)
    target_devices: int | None = None


@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    max_retries: int = 2        # per incident
    max_shrinks: int = 2        # escalation shrinks per run (capacity-
    #                             forced shrinks are mandatory, not counted)
    cooldown_steps: int = 8     # no grow (or straggler-shrink) within this
    #                             many steps of the last resize
    straggler_window: int = 8   # trailing steps the pressure is read over
    straggler_high: int = 2     # shrink watermark (flags in window)
    straggler_low: int = 0      # grow watermark — strictly below high
    queue_grow_depth: int | None = None   # serve backlog that motivates a
    #                             grow; None = grow on any spare capacity
    min_devices: int = 1

    def __post_init__(self):
        if self.straggler_low >= self.straggler_high:
            raise ValueError(
                f"hysteresis gap inverted: straggler_low "
                f"{self.straggler_low} >= straggler_high "
                f"{self.straggler_high}")


class FleetPolicy:
    """The state machine. One instance per controller run."""

    def __init__(self, cfg: PolicyConfig | None = None):
        self.cfg = cfg or PolicyConfig()
        self.history: list[Decision] = []
        self._halted = False
        self._retries = 0               # within the open incident
        self._shrinks = 0               # escalation shrinks, whole run
        self._incident_commit: int | None = None   # None = no open incident
        self._last_resize_step: int | None = None
        self._marks: list[tuple[int, int]] = []    # (step, cum. stragglers)

    # -- observability -------------------------------------------------
    @property
    def halted(self) -> bool:
        return self._halted

    @property
    def shrinks(self) -> int:
        return self._shrinks

    # -- internals -----------------------------------------------------
    def _mk(self, action: str, reason: str, sig: FleetSignals,
            target: int | None = None) -> Decision:
        return Decision(action=action, reason=reason, step=sig.step,
                        escalation=ESCALATION[action], target_devices=target)

    def _cooldown_ok(self, step: int) -> bool:
        lr = self._last_resize_step
        return lr is None or step - lr >= self.cfg.cooldown_steps

    def _note_resize(self, step: int) -> None:
        # max(): under out-of-order steps the cooldown must anchor to the
        # LATEST resize ever seen, or a stale low step would reopen the
        # grow gate early (the hypothesis oscillation property)
        lr = self._last_resize_step
        self._last_resize_step = step if lr is None else max(lr, step)

    def _stragglers_in_window(self, sig: FleetSignals) -> int:
        """Delta of the cumulative straggler counter over the trailing
        window. Before a mark old enough to anchor the window exists, the
        earliest mark is the baseline (undercounts — conservative against
        a spurious shrink); the very first signal reports 0, so counter
        state carried in from an earlier run never reads as pressure."""
        cutoff = sig.step - self.cfg.straggler_window
        base = self._marks[0][1] if self._marks else sig.stragglers
        for s, c in self._marks:
            if s <= cutoff:
                base = c
            else:
                break
        self._marks.append((sig.step, sig.stragglers))
        while len(self._marks) >= 2 and self._marks[1][0] <= cutoff:
            self._marks.pop(0)
        return max(0, sig.stragglers - base)

    def _shrink(self, sig: FleetSignals, reason: str, *,
                target: int | None = None, count: bool = True) -> Decision:
        if count:
            self._shrinks += 1
        self._note_resize(sig.step)
        # a resize closes the incident: the ladder restarts on the new
        # layout instead of inheriting a stale retry budget
        self._retries = 0
        self._incident_commit = None
        return self._mk("shrink", reason, sig, target=target)

    def _halt(self, sig: FleetSignals, reason: str) -> Decision:
        self._halted = True
        return self._mk("halt", reason, sig)

    def _incident(self, sig: FleetSignals) -> Decision:
        cfg = self.cfg
        if self._incident_commit is None:
            self._incident_commit = sig.committed_step
        elif sig.committed_step > self._incident_commit:
            # real progress since the incident opened: a NEW incident,
            # not a crash loop — the retry budget resets
            self._incident_commit = sig.committed_step
            self._retries = 0
        if self._retries < cfg.max_retries:
            self._retries += 1
            return self._mk(
                "retry", f"incident retry {self._retries}/{cfg.max_retries} "
                f"(commit {sig.committed_step})", sig)
        if self._shrinks < cfg.max_shrinks and sig.devices > cfg.min_devices:
            return self._shrink(sig, "crash loop: retry budget exhausted")
        return self._halt(sig, "retries and shrinks exhausted")

    # -- the entry point -----------------------------------------------
    def decide(self, sig: FleetSignals) -> Decision:
        d = self._decide(sig)
        self.history.append(d)
        return d

    def _decide(self, sig: FleetSignals) -> Decision:
        cfg = self.cfg
        if self._halted:
            return self._mk("halt", "halted-degraded is absorbing", sig)
        pressure = self._stragglers_in_window(sig)
        if sig.kind in ("kill", "fault"):
            return self._incident(sig)
        if sig.kind == "preemption":
            return self._mk("retry",
                            "preemption drained at a commit; resume", sig)
        # ---- tick ----------------------------------------------------
        if sig.ckpt_state == "failed":
            # the checkpoint writer is dead: progress cannot commit, so
            # this is an incident even though the step loop still runs
            return self._incident(sig)
        if 0 < sig.capacity < cfg.min_devices:
            return self._halt(sig, f"capacity {sig.capacity} below "
                                   f"min_devices {cfg.min_devices}")
        if sig.capacity and sig.capacity < sig.devices:
            # revoked capacity: mandatory, exempt from cooldown and from
            # the escalation shrink budget (the devices are simply gone)
            return self._shrink(
                sig, f"capacity revoked: {sig.capacity} < {sig.devices}",
                target=sig.capacity, count=False)
        if (pressure >= cfg.straggler_high and self._cooldown_ok(sig.step)
                and sig.devices > cfg.min_devices
                and self._shrinks < cfg.max_shrinks):
            return self._shrink(
                sig, f"straggler pressure: {pressure} flag(s) in "
                f"{cfg.straggler_window} steps")
        if (sig.capacity > sig.devices and self._cooldown_ok(sig.step)
                and pressure <= cfg.straggler_low
                and sig.ckpt_state == "ok"
                and (cfg.queue_grow_depth is None
                     or sig.queue_depth >= cfg.queue_grow_depth)):
            self._note_resize(sig.step)
            return self._mk(
                "grow", f"capacity {sig.capacity} > live {sig.devices}, "
                f"cooldown passed, pressure {pressure}", sig,
                target=sig.capacity)
        return self._mk("none", "healthy", sig)
