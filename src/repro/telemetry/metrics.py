"""Counter/gauge/histogram registry + the comm predicted-vs-actual ledger.

The registry is deliberately small (no label vectors, no exposition
formats): names are flat strings ("train/step_time_s"), values are numbers,
``snapshot()`` is a JSON-ready dict and ``dump(path)`` persists it — the
``results/metrics.json`` artifact CI uploads and ``scripts/bench_trend.py``
ingests alongside the BENCH_*.json files.

The communication half implements the reconciliation contract of
DESIGN.md §8: a compiled step attaches its :class:`~repro.telemetry.comm.
CommReport` (HLO ground truth, per invocation) under a label; the runtime
path calls ``record_comm(label)`` once per executed invocation; and
``reconcile(label)`` checks that the bytes/msgs accumulated at runtime
equal ``invocations × report`` exactly. A path that executes steps without
publishing, publishes against a stale report after a rebuild, or serves
traffic from a different compiled fn than the one that was stamped, shows
up as a mismatch — the runtime analogue of the multipod HLO gate.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import threading
from typing import Any

from .comm import CommReport

_P_KEEP = 512          # bounded reservoir for histogram percentiles


class Counter:
    """Monotonic accumulator."""

    def __init__(self) -> None:
        self.value: float = 0

    def inc(self, n: float = 1) -> None:
        if n < 0:
            raise ValueError(f"counter increment must be >= 0, got {n}")
        self.value += n


class Gauge:
    """Last-write-wins scalar."""

    def __init__(self) -> None:
        self.value: float | None = None

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Running count/total/min/max plus a bounded sample for percentiles."""

    def __init__(self) -> None:
        self.count = 0
        self.total = 0.0
        self.min: float | None = None
        self.max: float | None = None
        self._sample: list[float] = []

    def record(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)
        if len(self._sample) < _P_KEEP:
            self._sample.append(v)
        else:                       # keep a deterministic striding reservoir
            idx = self.count % _P_KEEP
            self._sample[idx] = v

    def percentile(self, q: float) -> float | None:
        if not self._sample:
            return None
        s = sorted(self._sample)
        k = min(len(s) - 1, max(0, int(round(q * (len(s) - 1)))))
        return s[k]

    def snapshot(self) -> dict:
        mean = self.total / self.count if self.count else None
        return {"count": self.count, "total": self.total, "mean": mean,
                "min": self.min, "max": self.max,
                "p50": self.percentile(0.50), "p95": self.percentile(0.95)}


@dataclasses.dataclass
class _CommEpoch:
    """One build's ledger: the stamped report + runtime accumulation."""

    report: CommReport
    invocations: int = 0
    actual_nonlocal_bytes: float = 0.0
    actual_nonlocal_msgs: float = 0.0
    actual_dp_bytes: float = 0.0

    def record(self, n: int = 1) -> None:
        self.invocations += n
        self.actual_nonlocal_bytes += n * self.report.nonlocal_bytes
        self.actual_nonlocal_msgs += n * self.report.nonlocal_msgs
        self.actual_dp_bytes += n * self.report.dp_bytes

    def reconcile(self) -> dict:
        pred_b = self.invocations * self.report.nonlocal_bytes
        pred_m = self.invocations * self.report.nonlocal_msgs
        return {
            "label": self.report.label,
            "invocations": self.invocations,
            "predicted_nonlocal_bytes": pred_b,
            "predicted_nonlocal_msgs": pred_m,
            "actual_nonlocal_bytes": self.actual_nonlocal_bytes,
            "actual_nonlocal_msgs": self.actual_nonlocal_msgs,
            "actual_dp_bytes": self.actual_dp_bytes,
            "match": (math.isclose(pred_b, self.actual_nonlocal_bytes,
                                   rel_tol=0, abs_tol=1e-6)
                      and math.isclose(pred_m, self.actual_nonlocal_msgs,
                                       rel_tol=0, abs_tol=1e-6)),
        }

    def snapshot(self) -> dict:
        out = self.reconcile()
        out["report"] = self.report.asdict()
        # trend-tracked leaves (scripts/bench_trend.py keys on these names):
        out["comm_nonlocal_bytes_per_step"] = self.report.nonlocal_bytes
        out["comm_nonlocal_msgs_per_step"] = self.report.nonlocal_msgs
        return out


class MetricsRegistry:
    """Thread-safe named metrics + the per-label comm ledger."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}
        self._comm: dict[str, _CommEpoch] = {}
        self._comm_archive: dict[str, list[dict]] = {}

    # -- plain metrics -------------------------------------------------------
    def counter(self, name: str) -> Counter:
        with self._lock:
            return self._counters.setdefault(name, Counter())

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            return self._gauges.setdefault(name, Gauge())

    def histogram(self, name: str) -> Histogram:
        with self._lock:
            return self._histograms.setdefault(name, Histogram())

    def count(self, name: str, n: float = 1) -> None:
        self.counter(name).inc(n)

    def observe(self, name: str, v: float) -> None:
        self.histogram(name).record(v)

    # -- comm ledger ---------------------------------------------------------
    def attach_comm_report(self, label: str, report: CommReport) -> None:
        """Stamp a label with a freshly-compiled step's report. An earlier
        epoch under the same label (elastic restart, re-resolved layout) is
        archived with its final reconciliation, so a rebuild never mixes two
        builds' accounting in one ledger."""
        with self._lock:
            old = self._comm.get(label)
            if old is not None:
                self._comm_archive.setdefault(label, []).append(
                    old.snapshot())
            self._comm[label] = _CommEpoch(report=report)

    def comm_report(self, label: str) -> CommReport | None:
        epoch = self._comm.get(label)
        return epoch.report if epoch else None

    def record_comm(self, label: str, n: int = 1) -> None:
        """Account ``n`` executed invocations of the compiled step stamped
        under ``label``. Raises if nothing was stamped — running a step the
        telemetry layer never saw compiled is exactly the bug this catches."""
        epoch = self._comm.get(label)
        if epoch is None:
            raise KeyError(f"no CommReport attached under {label!r} — "
                           f"stamp the compiled step before recording runs")
        epoch.record(n)

    def reconcile(self, label: str) -> dict:
        epoch = self._comm.get(label)
        if epoch is None:
            raise KeyError(f"no CommReport attached under {label!r}")
        return epoch.reconcile()

    def reconcile_all(self) -> dict[str, dict]:
        return {label: e.reconcile() for label, e in self._comm.items()}

    # -- export --------------------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-ready view. Histogram means are mirrored as
        ``gauges["<name>_mean"]`` so the trend gate's suffix matching
        (``step_time_s_mean`` etc.) sees them without schema knowledge."""
        with self._lock:
            gauges: dict[str, Any] = {k: g.value
                                      for k, g in self._gauges.items()}
            hists = {k: h.snapshot() for k, h in self._histograms.items()}
            for k, snap in hists.items():
                if snap["mean"] is not None:
                    gauges[f"{k}_mean"] = snap["mean"]
            return {
                "counters": {k: c.value for k, c in self._counters.items()},
                "gauges": gauges,
                "histograms": hists,
                "comm": {label: e.snapshot()
                         for label, e in self._comm.items()},
                "comm_archive": dict(self._comm_archive),
            }

    def dump(self, path: str, *, meta: dict | None = None,
             merge: bool = True) -> dict:
        """Persist ``snapshot()`` to ``path``. With ``merge`` (default) an
        existing file's sections are updated key-by-key instead of replaced,
        so benchmark subprocesses invoked one after another compose a single
        ``results/metrics.json``."""
        snap = self.snapshot()
        if meta is not None:
            snap["meta"] = meta
        if merge and os.path.exists(path):
            try:
                with open(path) as f:
                    old = json.load(f)
            except (OSError, ValueError):
                old = {}
            for section, vals in snap.items():
                if isinstance(vals, dict) and isinstance(old.get(section),
                                                         dict):
                    old[section].update(vals)
                else:
                    old[section] = vals
            snap = old
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(snap, f, indent=1, sort_keys=True)
        return snap


# ---------------------------------------------------------------------------
# process-global registry
# ---------------------------------------------------------------------------
_default = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return _default


def set_registry(registry: MetricsRegistry) -> MetricsRegistry:
    """Swap the process-global registry (tests); returns the previous one."""
    global _default
    prev, _default = _default, registry
    return prev
