"""CommReport: the compile-time communication ground truth per invocation.

One report summarizes what ONE invocation of a compiled step moves over the
wire, derived from the compiled HLO via ``core.hlo_analysis.collective_stats``
under two device groupings:

* **pod grouping** (``device_pod_map(mesh, ("pod",))``) — the paper's axis:
  traffic crossing a pod boundary is DCN (``nonlocal_bytes``/
  ``nonlocal_msgs``). Meshes without a 'pod' axis report zeros here.
* **DP grouping** (``dp_group_map``) — devices sharing their data-parallel
  coordinates (same 'pod' AND 'data' position, any 'model' position) form
  one group, so an edge is "nonlocal" under this map exactly when it crosses
  the DP sharding domain. That isolates the *data-parallel* collectives (the
  FSDP gather + grad sync in train, the decode cache-combine in serve) from
  tensor-parallel traffic without any hand-maintained layer counts:
  ``dp_bytes``/``dp_msgs`` ARE the per-step combine/sync traffic, read off
  the artifact.

``permute_edges_nonlocal > 0`` on a multi-pod mesh is the signature of the
explicit locality schedule (the Bruck rounds lower to collective-permutes);
a locality-configured path whose report shows none has silently regressed
to flat XLA — the dryrun assert and ``Engine``/``Trainer`` telemetry both
key off this.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class CommReport:
    """Per-invocation expected communication of one compiled step."""

    label: str
    # inter-pod (DCN) tier — zeros on single-pod meshes
    nonlocal_bytes: float = 0.0
    nonlocal_msgs: float = 0.0
    local_bytes: float = 0.0
    local_msgs: float = 0.0
    permute_edges_nonlocal: int = 0
    # traffic crossing the DP sharding domain (gather/sync/combine),
    # regardless of pod structure
    dp_bytes: float = 0.0
    dp_msgs: float = 0.0
    # raw inventory
    total_bytes: int = 0
    op_counts: dict = dataclasses.field(default_factory=dict)

    @property
    def has_locality_schedule(self) -> bool:
        """True iff the compiled artifact carries explicit pod-crossing
        permute edges — the locality collectives' lowering signature."""
        return self.permute_edges_nonlocal > 0

    def asdict(self) -> dict:
        d = dataclasses.asdict(self)
        d["has_locality_schedule"] = self.has_locality_schedule
        return d


def dp_group_map(mesh, dp_axes: tuple[str, ...]) -> dict[int, int] | None:
    """device.id -> flat DP coordinate: devices sharing every DP-axis
    position (i.e. tensor-parallel peers) share a group, so collective
    traffic classified "nonlocal" under this map is exactly the traffic
    crossing the data-parallel domain. None when the mesh has no DP axis
    wider than one device (nothing to cross)."""
    import numpy as np
    from repro.core.topology import device_pod_map
    axes = tuple(a for a in dp_axes if a in mesh.axis_names)
    if not axes:
        return None
    names = list(mesh.axis_names)
    if all(np.asarray(mesh.devices).shape[names.index(a)] <= 1
           for a in axes):
        return None
    return device_pod_map(mesh, axes)


def comm_report(hlo_text: str, mesh, *, label: str = "") -> CommReport:
    """Build the report for one compiled step's HLO on ``mesh``."""
    from repro.core.hlo_analysis import collective_stats
    from repro.core.topology import device_pod_map
    from repro.train.sharding import dp_axes

    pod_map = (device_pod_map(mesh, ("pod",))
               if "pod" in mesh.axis_names else None)
    st = collective_stats(hlo_text, pod_map)
    dp_map = dp_group_map(mesh, dp_axes(mesh))
    dp_bytes = dp_msgs = 0.0
    if dp_map is not None:
        dp_st = collective_stats(hlo_text, dp_map)
        dp_bytes, dp_msgs = dp_st.nonlocal_bytes, dp_st.nonlocal_msgs
    return CommReport(
        label=label,
        nonlocal_bytes=float(st.nonlocal_bytes),
        nonlocal_msgs=float(st.nonlocal_msgs),
        local_bytes=float(st.permute_bytes_local + st.group_bytes_local),
        local_msgs=float(st.permute_edges_local + st.group_msgs_local),
        permute_edges_nonlocal=st.permute_edges_nonlocal,
        dp_bytes=float(dp_bytes),
        dp_msgs=float(dp_msgs),
        total_bytes=st.total_bytes,
        op_counts=dict(st.counts),
    )
