"""Span tracer exporting Chrome/Perfetto trace-event JSON.

Lightweight and dependency-free: a :class:`Tracer` records begin/end ("B"/
"E") duration events and instant ("i") events into an in-process buffer;
``dump(path)`` writes the standard trace-event container
(``{"traceEvents": [...], "displayTimeUnit": "ms"}``) that chrome://tracing
and https://ui.perfetto.dev load directly.

Nesting is tracked with a ``contextvars`` stack, so spans opened in
``async``/generator code attribute to the right parent, and each OS thread
gets its own lane (``tid``) — the checkpoint writer thread's spans land in
their own track. When the running JAX exposes
``jax.profiler.TraceAnnotation`` (≥0.4.x), every span also enters a profiler
annotation of the same name, so an XLA/Perfetto device profile carries the
paper's phase names next to the HLO ops they bracket.

``validate_trace_events`` is the schema half the tests and
``scripts/check_metrics_schema.py`` share: per-thread monotonic ``ts`` and
strictly matched (LIFO, same-name) B/E pairs.
"""
from __future__ import annotations

import contextlib
import contextvars
import json
import os
import threading
import time

_SPAN_STACK: contextvars.ContextVar[tuple] = contextvars.ContextVar(
    "repro_span_stack", default=())


def _jax_annotation(name: str):
    """Best-effort jax.profiler annotation for a span (None when absent)."""
    try:
        import jax.profiler
        return jax.profiler.TraceAnnotation(name)
    except Exception:
        return None


class Tracer:
    """Collects trace events; thread-safe; one per process by default."""

    def __init__(self, process_name: str = "repro",
                 jax_annotations: bool = True):
        self.process_name = process_name
        self.jax_annotations = jax_annotations
        self._lock = threading.Lock()
        self._events: list[dict] = []
        self._t0 = time.perf_counter()
        self._pid = os.getpid()

    # -- core ----------------------------------------------------------------
    def _now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    def _emit(self, ev: dict) -> None:
        with self._lock:
            self._events.append(ev)

    @contextlib.contextmanager
    def span(self, name: str, **args):
        """Context manager recording one B/E pair (plus a jax.profiler
        annotation when enabled). ``args`` become the event's ``args`` dict
        and must be JSON-serializable."""
        tid = threading.get_ident()
        stack = _SPAN_STACK.get()
        token = _SPAN_STACK.set(stack + (name,))
        ev = {"ph": "B", "name": name, "cat": "repro", "ts": self._now_us(),
              "pid": self._pid, "tid": tid}
        if args:
            ev["args"] = args
        if stack:
            ev.setdefault("args", {})["parent"] = stack[-1]
        self._emit(ev)
        ann = _jax_annotation(name) if self.jax_annotations else None
        if ann is not None:
            try:
                ann.__enter__()
            except Exception:
                ann = None
        try:
            yield self
        finally:
            if ann is not None:
                try:
                    ann.__exit__(None, None, None)
                except Exception:
                    pass
            self._emit({"ph": "E", "name": name, "cat": "repro",
                        "ts": self._now_us(), "pid": self._pid, "tid": tid})
            _SPAN_STACK.reset(token)

    def instant(self, name: str, **args) -> None:
        ev = {"ph": "i", "name": name, "cat": "repro", "s": "t",
              "ts": self._now_us(), "pid": self._pid,
              "tid": threading.get_ident()}
        if args:
            ev["args"] = args
        self._emit(ev)

    def current_span(self) -> str | None:
        stack = _SPAN_STACK.get()
        return stack[-1] if stack else None

    # -- export --------------------------------------------------------------
    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
        self._t0 = time.perf_counter()

    def dump(self, path: str) -> dict:
        """Write the Chrome trace-event JSON container; returns it."""
        meta = [{"ph": "M", "name": "process_name", "pid": self._pid,
                 "tid": 0, "ts": 0,
                 "args": {"name": self.process_name}}]
        doc = {"traceEvents": meta + self.events(),
               "displayTimeUnit": "ms"}
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
        return doc


def validate_trace_events(events: list[dict]) -> list[str]:
    """Schema check for a trace-event list; returns problems ([] = valid).

    Enforced invariants (the ones Perfetto silently mis-renders when
    broken): every event has a known ``ph`` and numeric ``ts`` (metadata
    "M" events excepted), ``ts`` is non-decreasing per (pid, tid) lane, and
    B/E events form matched LIFO pairs with identical names per lane.
    """
    problems: list[str] = []
    last_ts: dict[tuple, float] = {}
    stacks: dict[tuple, list[tuple[str, float]]] = {}
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph not in ("B", "E", "i", "I", "X", "M", "C"):
            problems.append(f"event {i}: unknown ph {ph!r}")
            continue
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)):
            problems.append(f"event {i}: non-numeric ts {ts!r}")
            continue
        lane = (ev.get("pid"), ev.get("tid"))
        if ts < last_ts.get(lane, float("-inf")):
            problems.append(
                f"event {i}: ts {ts} decreases on lane {lane} "
                f"(prev {last_ts[lane]})")
        last_ts[lane] = ts
        if ph == "B":
            stacks.setdefault(lane, []).append((ev.get("name", ""), ts))
        elif ph == "E":
            stack = stacks.get(lane) or []
            if not stack:
                problems.append(f"event {i}: E {ev.get('name')!r} with no "
                                f"open B on lane {lane}")
                continue
            name, b_ts = stack.pop()
            if ev.get("name") != name:
                problems.append(
                    f"event {i}: E {ev.get('name')!r} closes B {name!r} "
                    f"on lane {lane} (not LIFO-matched)")
            if ts < b_ts:
                problems.append(f"event {i}: E.ts {ts} < B.ts {b_ts} "
                                f"for span {name!r}")
    for lane, stack in stacks.items():
        for name, _ in stack:
            problems.append(f"unclosed span {name!r} on lane {lane}")
    return problems


# ---------------------------------------------------------------------------
# process-global tracer
# ---------------------------------------------------------------------------
_default = Tracer()


def get_tracer() -> Tracer:
    return _default


def set_tracer(tracer: Tracer) -> Tracer:
    """Swap the process-global tracer (tests); returns the previous one."""
    global _default
    prev, _default = _default, tracer
    return prev


def span(name: str, **args):
    """``with telemetry.span("train/step"): ...`` on the global tracer."""
    return _default.span(name, **args)


def dump_trace(path: str) -> dict:
    """Export the global tracer's buffer as Chrome trace-event JSON."""
    return _default.dump(path)
