"""repro.telemetry — structured tracing + a comm-metrics registry.

The runtime half of the paper's cost accounting (DESIGN.md §8). Two parts:

* ``trace`` — a zero-dependency span tracer (context-manager API, nested via
  contextvars) exporting Chrome/Perfetto trace-event JSON. Spans mirror onto
  ``jax.profiler`` annotations when available, so XLA profiles carry the
  paper's phase names (prefetch / gather / compute / grad-sync / combine).
* ``metrics`` — a counter/gauge/histogram registry whose communication
  counters are stamped *at lowering time*: every compiled step runs through
  ``core.hlo_analysis.collective_stats`` and attaches a :class:`CommReport`
  (expected inter-pod bytes/msgs per invocation), so the registry reports
  predicted-vs-actual comm per step and ``reconcile`` catches any path whose
  runtime accounting drifts from the HLO ground truth.

Module-level ``get_tracer()`` / ``get_registry()`` return process-global
instances (the Trainer, serve Engine and benchmarks publish into them by
default); tests construct private ones.
"""
from .comm import CommReport, comm_report, dp_group_map
from .events import TelemetryEvent
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      get_registry, set_registry)
from .trace import (Tracer, dump_trace, get_tracer, set_tracer, span,
                    validate_trace_events)

__all__ = [
    "CommReport", "comm_report", "dp_group_map",
    "TelemetryEvent",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "get_registry", "set_registry",
    "Tracer", "dump_trace", "get_tracer", "set_tracer", "span",
    "validate_trace_events",
]
