"""Structured runtime events with a string back-compat view.

``Trainer.events`` and ``StepMonitor.record`` historically produced raw
strings; every consumer (tests, log scrapers) matches on substrings. The
structured event is therefore a ``str`` *subclass*: the message IS the
string value (``in``, ``startswith``, ``==`` and json-as-string all keep
working), while ``kind`` / ``step`` / ``t`` / ``attrs`` carry the machine-
readable half that the telemetry registry and the trace export consume.
"""
from __future__ import annotations

import time


class TelemetryEvent(str):
    """One structured event: a message string + typed metadata.

    kind:  event taxonomy — "straggler" | "collective" | "fault" |
           "restore" | "checkpoint" | "comm" | "warning" | "info".
    step:  the trainer/engine step the event belongs to (None if n/a).
    t:     wall-clock epoch seconds when the event was created.
    attrs: free-form structured payload (e.g. {"dt": 0.41, "ewma": 0.12}).
    """

    kind: str
    step: int | None
    t: float
    attrs: dict

    def __new__(cls, message: str, *, kind: str = "info",
                step: int | None = None, t: float | None = None,
                attrs: dict | None = None):
        self = super().__new__(cls, message)
        self.kind = kind
        self.step = step
        self.t = time.time() if t is None else t
        self.attrs = dict(attrs or {})
        return self

    @property
    def message(self) -> str:
        return str(self)

    def asdict(self) -> dict:
        return {"message": str(self), "kind": self.kind, "step": self.step,
                "t": self.t, "attrs": dict(self.attrs)}

    def __repr__(self) -> str:  # distinguishable from a bare str in dumps
        return (f"TelemetryEvent({str(self)!r}, kind={self.kind!r}, "
                f"step={self.step!r})")
