"""TPU-native allgather: the paper's schedule as Pallas remote DMAs.

Each device runs one kernel instance (inside ``shard_map``); round r issues a
single ``pltpu.make_async_remote_copy`` moving the scheduled contiguous slice
of its HBM-resident output buffer directly into the destination device's
buffer (RDMA put), synchronized with DMA semaphores. Because the whole
exchange is one kernel, a fused consumer can overlap the non-local rounds
with compute — the capability XLA's monolithic all-gather op lacks.

Locality-awareness is inherited from the compiled schedule
(kernels/dma_allgather/schedule_compile.py): with ``locality_bruck`` the
kernel performs exactly Algorithm 2's rounds — local Bruck, one remote
exchange per lane, local redistribution.

Validated with the Pallas TPU *interpret* backend (cross-device DMAs
emulated on CPU) against ``lax.all_gather``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import schedules as S
from repro.kernels import _pallas_compat
from .schedule_compile import DmaSchedule, compile_schedule


def _ag_kernel(sched_ref, x_ref, o_ref, send_sem, recv_sem, *,
               n: int, sizes: tuple[int, ...], axes: tuple[str, ...],
               axis_sizes: tuple[int, ...]):
    o_ref[pl.ds(0, n)] = x_ref[...]

    def unflatten(rank):
        """flat gather-rank -> per-axis mesh coordinates (row-major)."""
        coords = []
        rem = rank
        for sz in reversed(axis_sizes):
            coords.append(rem % sz)
            rem = rem // sz
        return tuple(reversed(coords))

    for r, size in enumerate(sizes):
        tgt = sched_ref[r, 0]
        soff = sched_ref[r, 1] * n
        roff = sched_ref[r, 2] * n
        sflag = sched_ref[r, 3]
        rflag = sched_ref[r, 4]
        device_id = dict(zip(axes, unflatten(tgt)))
        # per-round semaphores: a shared counting semaphore would let an
        # early round-(r+1) arrival satisfy the round-r wait, and a device
        # could forward a slice whose round-r data has not landed yet (a
        # real race caught by the TPU interpret backend).
        copy = pltpu.make_async_remote_copy(
            src_ref=o_ref.at[pl.ds(soff, size * n)],
            dst_ref=o_ref.at[pl.ds(roff, size * n)],
            send_sem=send_sem.at[r], recv_sem=recv_sem.at[r],
            device_id=device_id,
            device_id_type=pltpu.DeviceIdType.MESH)

        @pl.when(sflag == 1)
        def _start():
            copy.start()

        @pl.when(sflag == 1)
        def _wait_send():
            copy.wait_send()

        @pl.when(rflag == 1)
        def _wait_recv():
            copy.wait_recv()


def dma_allgather(x: jax.Array, axes, dma_sched: DmaSchedule, perm: jax.Array,
                  *, axis_sizes: tuple[int, ...], interpret=None) -> jax.Array:
    """Per-device body (call inside shard_map over ``axes``).

    x: this device's shard, any shape — flattened to (n,).
    perm: (p, p) canonicalization table (global, replicated).
    Returns (p, *x.shape): all shards in canonical order.
    """
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    p = dma_sched.p
    cap = dma_sched.capacity
    n = x.size
    xf = x.reshape(-1)

    # my row of the schedule table / perm
    idx = lax.axis_index(axes)
    table = jnp.asarray(dma_sched.table)             # (p, R, 5)
    my_sched = lax.dynamic_index_in_dim(table, idx, 0, keepdims=False)

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    kernel = functools.partial(
        _ag_kernel, n=n, sizes=dma_sched.sizes, axes=axes,
        axis_sizes=axis_sizes)
    out = pl.pallas_call(
        kernel,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct(
            (cap * n,), x.dtype,
            vma=frozenset(axes) | getattr(jax.typeof(xf), "vma", frozenset())),
        scratch_shapes=[pltpu.SemaphoreType.DMA((max(len(dma_sched.sizes), 1),)),
                        pltpu.SemaphoreType.DMA((max(len(dma_sched.sizes), 1),))],
        compiler_params=_pallas_compat.CompilerParams(
            collective_id=7,  # same logical collective across devices
        ),
        interpret=(_pallas_compat.interpret_params() if interpret else False),
    )(my_sched, xf)

    buf = out.reshape(cap, *x.shape)
    my_perm = lax.dynamic_index_in_dim(perm, idx, 0, keepdims=False)
    return jnp.take(buf, my_perm, axis=0)


@functools.lru_cache(maxsize=64)
def build_schedule(algorithm: str, p: int, p_local: int | None) -> DmaSchedule:
    if algorithm == "locality_bruck":
        from .schedule_compile import locality_bruck_raw
        return compile_schedule(locality_bruck_raw(p, p_local))
    if algorithm == "hierarchical":
        raise NotImplementedError(
            "hierarchical's master broadcast is not raw-contiguous; use the "
            "XLA/ppermute path (core/collectives.py) for it")
    gen = S.ALGORITHMS[algorithm]
    sched = gen(p, p_local) if p_local else gen(p)
    return compile_schedule(sched)
