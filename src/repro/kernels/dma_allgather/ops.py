"""Public op: locality-aware DMA allgather over mesh axes.

Usage (inside shard_map over ``outer + local`` axes)::

    out = dma_locality_allgather(x, outer=("pod",), local=("data",), mesh=mesh)
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .dma_ag import build_schedule, dma_allgather


def _sizes(mesh, axes):
    names = list(mesh.axis_names)
    return tuple(mesh.devices.shape[names.index(a)] for a in axes)


def dma_locality_allgather(x, outer, local, mesh, *, algorithm="locality_bruck",
                           interpret=None):
    outer = (outer,) if isinstance(outer, str) else tuple(outer)
    local = (local,) if isinstance(local, str) else tuple(local)
    axes = outer + local
    axis_sizes = _sizes(mesh, axes)
    p = math.prod(axis_sizes)
    pl_ = math.prod(_sizes(mesh, local))
    if algorithm in ("bruck", "ring"):
        sched = build_schedule(algorithm, p, None)
    else:
        sched = build_schedule(algorithm, p, pl_)
    perm = jnp.asarray(sched.perm)
    return dma_allgather(x, axes, sched, perm, axis_sizes=axis_sizes,
                         interpret=interpret)
