"""Oracle for the DMA allgather: lax.all_gather (canonical order)."""
from jax import lax


def allgather_ref(x, axes):
    return lax.all_gather(x, axes)
