"""Compile a ``core.schedules.Schedule`` into dense per-device DMA rounds.

The Pallas kernel executes R static rounds; in round r every device reads its
row of the schedule table: [target_rank, send_off, recv_off, send_flag,
recv_flag] (offsets in blocks). Sizes are uniform per round (asserted), so
slice shapes stay static. A final per-device permutation restores canonical
block order (the Bruck rotation, generalized).

Unlike the message-level simulator (core/schedules.py), a DMA engine cannot
deduplicate on receive: every received slice is appended verbatim. Rounds
that re-send already-held blocks (the paper's "lane 0 re-contributes its
data for simplicity", and the broadcast to idle lanes) therefore grow the
buffer past p blocks; the capacity is the max over ranks of the final append
count and the canonicalization perm picks the first occurrence of each
origin block.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.schedules import Schedule


@dataclasses.dataclass(frozen=True)
class DmaSchedule:
    table: np.ndarray        # (p, R, 5) int32
    sizes: tuple[int, ...]   # blocks per round (static)
    perm: np.ndarray         # (p, p) int32: canonical[j] = buf[perm[i, j]]
    p: int
    capacity: int            # buffer slots (blocks) needed per device

    def nonlocal_stats(self, region) -> tuple[int, int]:
        """(max msgs, max blocks) crossing region boundaries per rank."""
        msgs = np.zeros(self.p, int)
        blocks = np.zeros(self.p, int)
        for r, size in enumerate(self.sizes):
            for i in range(self.p):
                if self.table[i, r, 3] and not region.is_local(
                        i, int(self.table[i, r, 0])):
                    msgs[i] += 1
                    blocks[i] += size
        return int(msgs.max()), int(blocks.max())


def compile_schedule(sched: Schedule) -> DmaSchedule:
    p = sched.p
    bufs: list[list[int]] = [[r] for r in range(p)]   # raw append order
    rounds = []
    sizes = []
    for rnd in sched.rounds:
        if not rnd.sends:
            continue
        row = np.zeros((p, 5), np.int32)
        size = None
        incoming: dict[int, tuple[int, ...]] = {}
        for s in rnd.sends:
            if size is None:
                size = len(s.blocks)
            assert len(s.blocks) == size, "non-uniform round size"
            buf = bufs[s.src]
            # locate the send as a contiguous slice of the raw buffer
            off = _find_slice(buf, s.blocks)
            assert row[s.src, 3] == 0, "multiple sends per rank per round"
            row[s.src, 0] = s.dst
            row[s.src, 1] = off
            # the DMA writes into the *receiver's* buffer — the sender's row
            # carries the receiver's append offset (per-device, not uniform:
            # idle lanes have shorter buffers).
            row[s.src, 2] = len(bufs[s.dst])
            row[s.src, 3] = 1
            assert s.dst not in incoming, "multiple receives per rank"
            incoming[s.dst] = s.blocks
        for dst, blocks in incoming.items():
            row[dst, 4] = 1
            bufs[dst].extend(blocks)                  # verbatim append
        rounds.append(row)
        sizes.append(size)

    capacity = max(len(b) for b in bufs)
    perm = np.zeros((p, p), np.int32)
    for i in range(p):
        first = {}
        for j, origin in enumerate(bufs[i]):
            first.setdefault(origin, j)
        missing = set(range(p)) - set(first)
        assert not missing, f"rank {i} never received blocks {sorted(missing)[:8]}"
        for origin, j in first.items():
            perm[i, origin] = j
    table = (np.stack(rounds, axis=1) if rounds
             else np.zeros((p, 0, 5), np.int32))
    return DmaSchedule(table=table.astype(np.int32), sizes=tuple(sizes),
                       perm=perm, p=p, capacity=capacity)


def locality_bruck_raw(p: int, p_local: int) -> Schedule:
    """Raw-append (DMA-clean) variant of paper Algorithm 2.

    The generator in core/schedules.py follows the paper's "lane 0
    re-contributes its data for simplicity" — which makes receivers
    deduplicate, something a DMA engine cannot do. This variant implements
    the paper's stated alternative (§3: "the first local process
    contributing no data", the MPI_Allgatherv route): the redistribution
    allgather runs among the ``active-1`` lanes that actually received a
    chunk, then lane 1 forwards the chunk area to lane 0 (+1 local message
    — local messages are exactly what the paper trades for) and a binomial
    broadcast fills lanes ≥ active. Every message is a contiguous slice of
    the sender's raw buffer and no block is ever received twice for
    power-of-p_ℓ region counts. Non-local traffic is identical to Alg. 2.
    """
    from repro.core.schedules import Round, Send
    from repro.core.topology import RegionMap

    region = RegionMap(p=p, p_local=p_local)
    pl, r = p_local, region.n_regions
    bufs: list[list[int]] = [[rank] for rank in range(p)]
    rounds: list[Round] = []

    def apply_round(sends, phase):
        if not sends:
            return
        incoming = {}
        for s in sends:
            assert s.dst not in incoming
            incoming[s.dst] = s.blocks
        for dst, blocks in incoming.items():
            bufs[dst].extend(blocks)
        rounds.append(Round(sends=tuple(sends), phase=phase))

    def slice_of(rank, off, ln):
        return tuple(bufs[rank][off:off + ln])

    # ---- initial local allgather (bruck over lanes, unit = 1 block) -----
    d = 1
    while d < pl:
        cnt = min(d, pl - d)
        sends = []
        for rank in range(p):
            R, l = region.region_of(rank), region.local_rank_of(rank)
            dst = region.rank_of(R, (l - d) % pl)
            sends.append(Send(src=rank, dst=dst,
                              blocks=slice_of(rank, 0, cnt)))
        apply_round(sends, f"raw-init-d{d}")
        d *= 2

    group = 1
    step = 0
    while group < r:
        n_groups = -(-r // group)
        active = min(pl, n_groups)
        L0 = group * pl                     # buffer length entering the round
        u = group * pl                      # chunk (unit) length
        # ---- non-local exchange: lanes 1..active-1, entire buffer -------
        sends = []
        for rank in range(p):
            R, l = region.region_of(rank), region.local_rank_of(rank)
            if l == 0 or l >= active:
                continue
            dst = region.rank_of((R - l * group) % r, l)
            sends.append(Send(src=rank, dst=dst, blocks=slice_of(rank, 0, L0)))
        apply_round(sends, f"raw-nonlocal-{step}")

        g2 = active - 1                      # chunk holders: lanes 1..active-1
        # ---- unit bruck among the holders --------------------------------
        d = 1
        while d < g2:
            cnt = min(d, g2 - d)
            sends = []
            for rank in range(p):
                R, l = region.region_of(rank), region.local_rank_of(rank)
                if not (1 <= l <= g2):
                    continue
                j = l - 1
                dst = region.rank_of(R, 1 + (j - d) % g2)
                sends.append(Send(src=rank, dst=dst,
                                  blocks=slice_of(rank, L0, cnt * u)))
            apply_round(sends, f"raw-redist{step}-d{d}")
            d *= 2
        # ---- lane 1 forwards the chunk area to lane 0 ---------------------
        if g2 >= 1:
            sends = []
            for R in range(r):
                src = region.rank_of(R, 1)
                sends.append(Send(src=src, dst=region.rank_of(R, 0),
                                  blocks=slice_of(src, L0, g2 * u)))
            apply_round(sends, f"raw-fill0-{step}")
        # ---- binomial broadcast to idle lanes ≥ active ---------------------
        have = active
        while have < pl:
            sends = []
            for R in range(r):
                for l in range(min(have, pl - have)):
                    src = region.rank_of(R, l)
                    sends.append(Send(src=src, dst=region.rank_of(R, l + have),
                                      blocks=slice_of(src, L0, g2 * u)))
            apply_round(sends, f"raw-bcast{step}-{have}")
            have *= 2
        group *= active
        step += 1

    final = [sorted(set(b)) for b in bufs]
    for i, b in enumerate(final):
        assert b == list(range(p)), f"rank {i} incomplete"
    return Schedule(p=p, rounds=rounds, buffers=final,
                    algorithm="locality_bruck_raw", region=region)


def _find_slice(buf: list[int], blocks: tuple[int, ...]) -> int:
    """First offset where ``blocks`` appears as a contiguous slice."""
    n = len(blocks)
    for off in range(len(buf) - n + 1):
        if tuple(buf[off:off + n]) == blocks:
            return off
    raise AssertionError(f"send {blocks[:6]}... not contiguous in buffer")


def execute_table(dma: DmaSchedule) -> np.ndarray:
    """Pure-python executor of the compiled table (kernel-free oracle).

    Returns (p, p) int: row i = origin ids in canonical order — must equal
    arange(p) per row for a correct schedule.
    """
    p, cap = dma.p, dma.capacity
    bufs = -np.ones((p, cap), np.int64)
    bufs[:, 0] = np.arange(p)
    lens = np.ones(p, np.int64)
    for r, size in enumerate(dma.sizes):
        writes = []
        for i in range(p):
            tgt, soff, roff, sflag, rflag = dma.table[i, r]
            if sflag:
                writes.append((int(tgt), bufs[i, soff:soff + size].copy(),
                               int(roff)))
        for tgt, data, roff in writes:
            assert dma.table[tgt, r, 4] == 1, "send to non-receiving rank"
            bufs[tgt, roff:roff + size] = data
            lens[tgt] = max(lens[tgt], roff + size)
    out = np.empty((p, p), np.int64)
    for i in range(p):
        out[i] = bufs[i, dma.perm[i]]
    return out
