"""Oracle: the model's chunked SSD in full fp32 (matches the kernel's
VMEM-resident fp32 math; the model's default jnp path uses the bf16 data
path documented in models/ssm.py)."""
from repro.models.ssm import ssd_chunked


def ssd_ref(x, dt, A, B, C, *, Q: int = 256):
    return ssd_chunked(x, dt, A, B, C, min(Q, x.shape[1]), precise=True)
