"""Public op: Pallas kernel on TPU, interpret mode elsewhere."""
import jax

from .ref import ssd_ref
from .ssd import ssd_pallas


def ssd(x, dt, A, B, C, *, Q: int = 256):
    on_tpu = jax.default_backend() == "tpu"
    return ssd_pallas(x, dt, A, B, C, Q=Q, interpret=not on_tpu)
