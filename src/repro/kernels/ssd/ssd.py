"""Mamba2 SSD chunked-scan Pallas TPU kernel.

Grid: (batch·heads, num_chunks), chunk axis sequential ("arbitrary"): the
running inter-chunk state h ∈ R^{N×P} lives in VMEM scratch and is carried
across the chunk steps of one (batch, head) program — the HBM-resident
(nc, N, P) state tensor of the jnp path (``models/ssm.ssd_chunked``, the
oracle) never exists.

Per chunk (all fp32, in VMEM):
    da   = dt·A;  cum = cumsum(da);  seg = cum[Q-1]
    y    = ((C Bᵀ) ⊙ tril(exp(cum_i − cum_j))) (dt ⊙ x)      intra-chunk
         + exp(cum) ⊙ (C h)                                    inter-chunk
    h   ←  exp(seg) h + Bᵀ (exp(seg − cum) dt ⊙ x)            state update
Tiling: x (Q,P), B/C (Q,N), score (Q,Q) — Q=256, N≤128, P=64 keeps every
matmul MXU-aligned and the working set ≈ (Q² + 2QN + 2QP + NP)·4B ≈ 0.5 MB.
Multi-group (G>1) maps head → group through the B/C index maps (GQA-style).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _pallas_compat


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_scr, *,
                Q: int):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)                  # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)                # (Q, 1)
    A = a_ref[0].astype(jnp.float32)                  # (1,) per-head scalar
    Bm = b_ref[0].astype(jnp.float32)                 # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)                 # (Q, N)

    da = dt * A                                       # (Q, 1)
    cum = jnp.cumsum(da, axis=0)                      # (Q, 1)
    seg = cum[Q - 1]                                  # (1,)

    # intra-chunk dual form
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (Q, Q)
    decay = jnp.exp(cum - cum.T)                      # exp(cum_i - cum_j)
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(ii >= jj, CB * decay, 0.0)
    dtx = dt * x                                      # (Q, P)
    y = jax.lax.dot(L, dtx, preferred_element_type=jnp.float32)

    # inter-chunk contribution from the carried state
    h = h_scr[...]                                    # (N, P)
    y = y + jnp.exp(cum) * jax.lax.dot(
        Cm, h, preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)

    # state update
    w = jnp.exp(seg - cum) * dt                       # (Q, 1)
    h_new = jnp.exp(seg)[0] * h + jax.lax.dot_general(
        Bm, w * x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)           # (N, P)
    h_scr[...] = h_new

    @pl.when(ci == nc - 1)
    def _emit_state():
        hout_ref[0] = h_new


@functools.partial(jax.jit, static_argnames=("Q", "interpret"))
def ssd_pallas(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
               C: jax.Array, *, Q: int = 256, interpret: bool = False):
    """x: (Bt,S,H,P); dt: (Bt,S,H); A: (H,); B/C: (Bt,S,G,N).

    Returns (y (Bt,S,H,P) fp32, h_final (Bt,H,N,P) fp32) — same contract as
    ``models.ssm.ssd_chunked`` (the oracle).
    """
    Bt, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    q = min(Q, S)
    if S % q:
        q = S
    nc = S // q

    xf = x.transpose(0, 2, 1, 3).reshape(Bt * H, S, P)
    dtf = dt.transpose(0, 2, 1).reshape(Bt * H, S, 1)
    af = jnp.broadcast_to(A[None, :], (Bt, H)).reshape(Bt * H, 1)
    bf = B.transpose(0, 2, 1, 3).reshape(Bt * G, S, N)
    cf = C.transpose(0, 2, 1, 3).reshape(Bt * G, S, N)
    Hg = H // G

    def bc_map(b, c, G=G, H=H, Hg=Hg):
        return ((b // H) * G + (b % H) // Hg, c, 0)

    y, h_fin = pl.pallas_call(
        functools.partial(_ssd_kernel, Q=q),
        grid=(Bt * H, nc),
        in_specs=[
            pl.BlockSpec((1, q, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, q, 1), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1), lambda b, c: (b, 0)),
            pl.BlockSpec((1, q, N), bc_map),
            pl.BlockSpec((1, q, N), bc_map),
        ],
        out_specs=[
            pl.BlockSpec((1, q, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, N, P), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bt * H, S, P), jnp.float32),
            jax.ShapeDtypeStruct((Bt * H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=_pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xf, dtf, af, bf, cf)
    y = y.reshape(Bt, H, S, P).transpose(0, 2, 1, 3)
    h_fin = h_fin.reshape(Bt, H, N, P)
    return y, h_fin
