"""Flash attention (tiled online softmax) Pallas TPU kernel.

Grid: (batch·heads, num_q_blocks, num_kv_blocks) with the KV axis innermost
and sequential ("arbitrary" dimension semantics): scratch accumulators
(m, l, acc) persist across the KV steps of one Q block and the output is
written on the last KV step — the standard TPU flash schedule.

Tiling: q block (block_q, D), k/v blocks (block_k, D) in VMEM. Defaults
512/512 keep every matmul dim a multiple of the 128×128 MXU tile. GQA is
expressed through the KV index map (q head h reads kv head h // G) — the
grouped KV blocks are never materialized per-head in HBM.

Variants: causal, sliding window, chunked-local (llama4), logit softcap
(gemma2) — same mask set as ``models/attention.py`` (the oracle, ref.py).
Fully-masked KV blocks short-circuit via ``pl.when`` (no MXU work), matching
the exact-FLOPs accounting of the q-chunked jnp path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _pallas_compat

NEG_INF = -2.0 ** 30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, causal: bool, window: int,
                  chunk: int, cap: float, scale: float, kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    # block-level reachability: fully-masked KV blocks do no MXU work
    reachable = jnp.asarray(True)
    if causal:
        reachable = k_start <= q_start + block_q - 1
    if window:
        reachable = jnp.logical_and(
            reachable, k_start + block_k - 1 >= q_start - (window - 1))
    if chunk:
        reachable = jnp.logical_and(
            reachable, (q_start // chunk) * chunk <= k_start + block_k - 1)
        reachable = jnp.logical_and(reachable, k_start <= q_start + block_q - 1)

    @pl.when(reachable)
    def _compute():
        q = q_ref[0].astype(jnp.float32)             # (block_q, D)
        k = k_ref[0].astype(jnp.float32)             # (block_k, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if cap:
            s = cap * jnp.tanh(s / cap)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_pos < kv_len
        if causal:
            mask = jnp.logical_and(mask, q_pos >= k_pos)
        if window:
            mask = jnp.logical_and(mask, q_pos - k_pos < window)
        if chunk:
            mask = jnp.logical_and(mask, q_pos // chunk == k_pos // chunk)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                          # (block_q, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        acc_scr[...] = alpha * acc_scr[...] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)              # fully-masked rows
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "chunk", "cap", "block_q",
                              "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0, chunk: int = 0,
                    cap: float = 0.0, block_q: int = 512, block_k: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q: (B, S, H, D); k/v: (B, T, KV, D), H % KV == 0. Returns (B,S,H,D)."""
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = D ** -0.5
    bq = min(block_q, S)
    bk = min(block_k, T)
    if S % bq:
        bq = S
    if T % bk:
        bk = T

    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, T, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, T, D)

    grid = (B * H, S // bq, T // bk)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, block_q=bq, block_k=bk, causal=causal,
                          window=window, chunk=chunk, cap=cap, scale=scale,
                          kv_len=T),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            # GQA: q head (b % H) reads kv head (b % H) // G of batch b // H
            pl.BlockSpec((1, bk, D),
                         lambda b, i, j, G=G, H=H, KV=KV:
                         ((b // H) * KV + (b % H) // G, j, 0)),
            pl.BlockSpec((1, bk, D),
                         lambda b, i, j, G=G, H=H, KV=KV:
                         ((b // H) * KV + (b % H) // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        compiler_params=_pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)
