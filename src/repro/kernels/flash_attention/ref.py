"""Pure-jnp oracle for the flash attention kernel (exact masked softmax)."""
import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def attention_ref(q, k, v, *, causal=True, window=0, chunk=0, cap=0.0):
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bikgd,bjkd->bkgij", qg, kf) * (D ** -0.5)
    if cap:
        s = cap * jnp.tanh(s / cap)
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= qp >= kp
    if window:
        mask &= (qp - kp) < window
    if chunk:
        mask &= (qp // chunk) == (kp // chunk)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgij,bjkd->bikgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, D).astype(q.dtype)
