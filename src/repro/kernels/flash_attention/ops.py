"""Public op: Pallas kernel on TPU, interpret mode elsewhere."""
import jax

from .flash import flash_attention
from .ref import attention_ref


def attention(q, k, v, **kw):
    on_tpu = jax.default_backend() == "tpu"
    return flash_attention(q, k, v, interpret=not on_tpu, **kw)
