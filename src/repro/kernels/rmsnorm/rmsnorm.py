"""Fused RMSNorm Pallas TPU kernel.

Tiling: rows are processed in blocks of ``block_rows`` (grid dim 0); the full
feature dim lives in VMEM per block (d ≤ 16k → ≤ 64 KB·block_rows at fp32,
well inside the ~16 MB VMEM budget). The reduction + rsqrt + scale fuse into
one VMEM pass instead of the 3 HBM round-trips of the unfused lowering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * (1.0 + scale_ref[...].astype(jnp.float32))
                  ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "eps", "interpret"))
def rmsnorm_pallas(x: jax.Array, scale: jax.Array, *, eps: float = 1e-5,
                   block_rows: int = 256, interpret: bool = False) -> jax.Array:
    """x: (..., d); scale: (d,). Returns same shape/dtype as x."""
    shape = x.shape
    d = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    if rows % br:
        br = rows                      # odd row counts: single block
    grid = (rows // br,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out.reshape(shape)
