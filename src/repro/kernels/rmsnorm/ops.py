"""Public op: Pallas on TPU, interpret-mode Pallas for CPU validation."""
import jax

from .ref import rmsnorm_ref
from .rmsnorm import rmsnorm_pallas


def rmsnorm(x, scale, *, eps: float = 1e-5):
    on_tpu = jax.default_backend() == "tpu"
    return rmsnorm_pallas(x, scale, eps=eps, interpret=not on_tpu)
