"""Pallas-TPU API compatibility across JAX versions.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` (and
``TPUInterpretParams`` to ``InterpretParams``) in newer JAX releases. The
kernels target the new names; this shim resolves whichever the installed
JAX provides so the same kernel source compiles on both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

_InterpretParams = getattr(pltpu, "InterpretParams", None) \
    or getattr(pltpu, "TPUInterpretParams", None)


def interpret_params():
    """Value for ``pallas_call(interpret=...)`` requesting TPU-interpret mode.

    Newer JAX takes an ``InterpretParams`` instance (enables the
    cross-device DMA interpreter); older JAX only supports the boolean
    single-device interpreter.
    """
    return _InterpretParams() if _InterpretParams is not None else True
