"""Dispatch layer for the fused decode-stat accumulation.

``resolve_impl("auto")`` picks the Pallas kernel on real TPU backends and
the jnp path elsewhere (the kernel only runs interpreted on CPU — correct
but slow, so CPU serving keeps the fused-by-XLA jnp ops). The serve engine
threads the resolved impl into its per-layer combine region.
"""
from __future__ import annotations

import jax

from .stats import decode_stats_accumulate_pallas

IMPLS = ("auto", "jnp", "pallas", "pallas_interpret")


def resolve_impl(impl: str = "auto") -> str:
    if impl not in IMPLS:
        raise ValueError(f"unknown decode-stats impl {impl!r}; known: {IMPLS}")
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return impl


def accumulate(s, mask, m, v_cache, *, impl: str = "jnp"):
    """(o, l) from masked scores — impl must already be resolved."""
    if impl in ("pallas", "pallas_interpret"):
        return decode_stats_accumulate_pallas(
            s, m, v_cache, interpret=(impl == "pallas_interpret"))
    if impl != "jnp":
        raise ValueError(f"unresolved decode-stats impl {impl!r} "
                         "(call resolve_impl first)")
    from repro.models.attention import decode_stats_accumulate
    return decode_stats_accumulate(s, mask, m, v_cache)
