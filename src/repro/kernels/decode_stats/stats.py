"""Fused decode partial-stat accumulation (Pallas TPU kernel).

The serve engine's locality decode region splits one-token attention into
(1) masked scores + running max — cheap, feeds the max-allreduce that is
issued immediately — and (2) this kernel: exp(s − m), the row sums l, and
the P·V contraction o, blocked over the local cache length with scratch
accumulators (the ``kernels/flash_attention`` schedule minus the online
max, which the combine already owns). Fusing (2) keeps it one VMEM-resident
op — the "real compute" the in-flight max-allreduce hides behind
(DESIGN.md §5).

Grid: (B·KV, num_kv_blocks), KV axis innermost and sequential
("arbitrary"): acc/lsum scratch persist across the KV steps of one row
group and the outputs are written on the last step.

Masking needs no position logic here: the scores arrive already
NEG_INF-masked (models/attention.decode_stats_scores), so ``s ≤ NEG_INF/2``
identifies masked slots — exact for every pattern including the
fully-masked shard (m = NEG_INF would make exp(s − m) = 1 there).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _pallas_compat

NEG_INF = -2.0 ** 30


def _stats_kernel(s_ref, m_ref, v_ref, o_ref, l_ref, acc_scr, lsum_scr, *,
                  block_k: int):
    ki = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)
        lsum_scr[...] = jnp.zeros_like(lsum_scr)

    s = s_ref[0]                                   # (G, block_k) fp32
    m = m_ref[0]                                   # (G, 1) fp32
    p = jnp.where(s > NEG_INF * 0.5, jnp.exp(s - m), 0.0)
    lsum_scr[...] += jnp.sum(p, axis=-1, keepdims=True)
    v = v_ref[0].astype(jnp.float32)               # (block_k, D)
    acc_scr[...] += jax.lax.dot(p, v, preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = acc_scr[...]
        l_ref[0] = lsum_scr[...]


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_stats_accumulate_pallas(s: jax.Array, m: jax.Array,
                                   v_cache: jax.Array, *, block_k: int = 512,
                                   interpret: bool = False
                                   ) -> tuple[jax.Array, jax.Array]:
    """s (B,KV,G,L) masked fp32 scores, m (B,KV,G) running max,
    v_cache (B,L,KV,D). Returns fp32 (o (B,1,H,D), l (B,1,H)), H = KV·G.
    fp32 accumulation throughout (the jnp oracle contracts P·V in the cache
    dtype — identical for fp32 caches, tighter for bf16)."""
    B, KV, G, L = s.shape
    D = v_cache.shape[-1]
    bk = min(block_k, L)
    if L % bk:
        bk = L                                     # odd lengths: one block
    sf = s.reshape(B * KV, G, L)
    mf = m.reshape(B * KV, G, 1)
    vf = v_cache.transpose(0, 2, 1, 3).reshape(B * KV, L, D)

    grid = (B * KV, L // bk)
    o, l = pl.pallas_call(
        functools.partial(_stats_kernel, block_k=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, G, bk), lambda b, j: (b, 0, j)),
            pl.BlockSpec((1, G, 1), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, bk, D), lambda b, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, G, D), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, G, 1), lambda b, j: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * KV, G, D), jnp.float32),
            jax.ShapeDtypeStruct((B * KV, G, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
        compiler_params=_pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(sf, mf, vf)
    return o.reshape(B, 1, KV * G, D), l.reshape(B, 1, KV * G)
