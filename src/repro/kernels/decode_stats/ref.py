"""Pure-jnp oracle for the fused decode-stat accumulation kernel.

Mirrors ``models/attention.decode_stats_accumulate`` with fp32 P·V
accumulation (what the Pallas kernel computes); for fp32 caches the two are
identical.
"""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def decode_stats_accumulate_ref(s, m, v_cache):
    """s (B,KV,G,L) masked fp32, m (B,KV,G), v (B,L,KV,D) ->
    (o (B,1,H,D) fp32, l (B,1,H) fp32)."""
    B, KV, G, _ = s.shape
    D = v_cache.shape[-1]
    p = jnp.where(s > NEG_INF * 0.5, jnp.exp(s - m[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgj,bjkd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, KV * G, D), l.reshape(B, 1, KV * G)
