#!/usr/bin/env python
"""Perf-trend gate: diff BENCH_*.json artifacts against the previous run.

``python scripts/bench_trend.py --prev prev-bench/ --cur . [--threshold 0.10]``

Walks every ``BENCH_*.json`` present in BOTH directories, compares each
known metric at the same JSON path, and exits non-zero when any regresses
by more than the threshold (>10% by default — the nightly CI gate). Files
whose ``meta`` stamp (jax version / backend / device count, see
``benchmarks.common.bench_metadata``) differs between the runs are skipped
with a notice: a jax upgrade or runner change is not a code regression and
must not be graded as one.

Metric direction is keyed by name: ``*_us``/``us_per_step`` and the modeled
``*_s``/fractions regress UP, ``tokens_per_s`` regresses DOWN. Wall-clock
metrics on shared CI runners are noisy, so they take
``max(threshold, --wall-threshold)`` (default 0.30) while deterministic
modeled/simulated metrics use the strict threshold.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

#: metric-name -> direction ("lower" is better / "higher" is better),
#: wall-clock flag (noisy on shared runners)
METRICS: dict[str, tuple[str, bool]] = {
    "us_per_step": ("lower", True),
    "us_per_call": ("lower", True),
    "tokens_per_s": ("higher", True),
    "exposed_comm_s": ("lower", False),
    "exposed_comm_fraction": ("lower", False),
    "modeled_step_s": ("lower", False),
    "hidden_s_per_layer": ("higher", False),
}


def _walk(node, path=()):
    if isinstance(node, dict):
        for k, v in node.items():
            yield from _walk(v, path + (k,))
    elif isinstance(node, list):
        for i, v in enumerate(node):
            yield from _walk(v, path + (str(i),))
    elif isinstance(node, (int, float)) and not isinstance(node, bool):
        yield path, float(node)


def compare_file(name: str, prev: dict, cur: dict, threshold: float,
                 wall_threshold: float) -> list[str]:
    """Returns the list of regression messages for one artifact."""
    if prev.get("meta") != cur.get("meta"):
        print(f"{name}: SKIP — meta stamp changed "
              f"({prev.get('meta')} -> {cur.get('meta')}); not comparable")
        return []
    prev_vals = dict(_walk(prev))
    regressions = []
    compared = 0
    for path, cur_v in _walk(cur):
        metric = path[-1]
        spec = METRICS.get(metric)
        if spec is None or path not in prev_vals:
            continue
        direction, wall = spec
        prev_v = prev_vals[path]
        if prev_v <= 0:
            continue
        change = (cur_v - prev_v) / prev_v
        if direction == "higher":
            change = -change            # normalized: positive == worse
        compared += 1
        limit = max(threshold, wall_threshold) if wall else threshold
        tag = ".".join(path)
        if change > limit:
            regressions.append(
                f"{name}: {tag} regressed {change * 100:.1f}% "
                f"({prev_v:.6g} -> {cur_v:.6g}, limit {limit * 100:.0f}%)")
        elif change < -threshold:
            print(f"{name}: {tag} improved {-change * 100:.1f}% "
                  f"({prev_v:.6g} -> {cur_v:.6g})")
    print(f"{name}: compared {compared} metrics, "
          f"{len(regressions)} regression(s)")
    return regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--prev", required=True,
                    help="directory holding the previous run's artifacts")
    ap.add_argument("--cur", default=".",
                    help="directory holding this run's artifacts")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="relative regression that fails the gate")
    ap.add_argument("--wall-threshold", type=float, default=0.30,
                    help="noise floor for wall-clock metrics on shared "
                         "runners (the larger of this and --threshold)")
    ap.add_argument("--pattern", default="BENCH_*.json")
    args = ap.parse_args(argv)

    if not os.path.isdir(args.prev):
        print(f"no previous artifacts at {args.prev!r} — first run, "
              "nothing to diff")
        return 0
    cur_files = sorted(glob.glob(os.path.join(args.cur, args.pattern)))
    if not cur_files:
        print(f"FAIL: no {args.pattern} in {args.cur!r} — the bench step "
              "produced nothing to track")
        return 1
    regressions: list[str] = []
    for cur_path in cur_files:
        name = os.path.basename(cur_path)
        prev_path = os.path.join(args.prev, name)
        if not os.path.exists(prev_path):
            print(f"{name}: SKIP — no previous artifact (new benchmark)")
            continue
        try:
            with open(prev_path) as f:
                prev = json.load(f)
            with open(cur_path) as f:
                cur = json.load(f)
        except (OSError, ValueError) as e:
            print(f"{name}: SKIP — unreadable ({e})")
            continue
        regressions += compare_file(name, prev, cur, args.threshold,
                                    args.wall_threshold)
    for r in regressions:
        print("REGRESSION:", r, file=sys.stderr)
    return 1 if regressions else 0


if __name__ == "__main__":
    raise SystemExit(main())
