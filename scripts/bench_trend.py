#!/usr/bin/env python
"""Perf-trend gate: diff BENCH_*.json artifacts against previous runs.

``python scripts/bench_trend.py --prev prev-bench/ --cur . [--threshold 0.10]``

``--prev`` holds the baseline in one of two layouts:

* a single run's artifacts directly (``prev-bench/BENCH_*.json``) —
  the original previous-run-only diff;
* one subdirectory per previous run (``prev-bench/<run-id>/BENCH_*.json``,
  what the nightly CI fetch step downloads) — the baseline for each metric
  is then the MEDIAN over the last K runs whose ``meta`` stamp matches the
  current one (``--k``, default 5, newest first by mtime). A single noisy
  or lucky previous nightly can no longer move the gate by itself.

Walks every ``BENCH_*.json`` present in the current directory and at least
one baseline run, compares each known metric at the same JSON path, and
exits non-zero when any regresses by more than the threshold (>10% by
default — the nightly CI gate). Baseline runs whose ``meta`` stamp (jax
version / backend / device count, see ``benchmarks.common.bench_metadata``)
differs from the current run are skipped with a notice: a jax upgrade or
runner change is not a code regression and must not be graded as one.

Metric direction is keyed by name: ``*_us``/``us_per_step`` and the modeled
``*_s``/fractions regress UP, ``tokens_per_s`` regresses DOWN. Wall-clock
metrics on shared CI runners are noisy, so they take
``max(threshold, --wall-threshold)`` (default 0.30) while deterministic
modeled/simulated metrics use the strict threshold.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import statistics
import sys

#: metric-name -> direction ("lower" is better / "higher" is better),
#: wall-clock flag (noisy on shared runners)
METRICS: dict[str, tuple[str, bool]] = {
    "us_per_step": ("lower", True),
    "us_per_call": ("lower", True),
    "tokens_per_s": ("higher", True),
    "exposed_comm_s": ("lower", False),
    "exposed_comm_fraction": ("lower", False),
    "modeled_step_s": ("lower", False),
    "hidden_s_per_layer": ("higher", False),
}


def _walk(node, path=()):
    if isinstance(node, dict):
        for k, v in node.items():
            yield from _walk(v, path + (k,))
    elif isinstance(node, list):
        for i, v in enumerate(node):
            yield from _walk(v, path + (str(i),))
    elif isinstance(node, (int, float)) and not isinstance(node, bool):
        yield path, float(node)


def compare_file(name: str, prevs: list[dict], cur: dict, threshold: float,
                 wall_threshold: float) -> list[str]:
    """Regression messages for one artifact vs the median-of-K baseline.

    ``prevs`` holds one dict per previous run (newest first); runs with a
    non-matching meta stamp are dropped here, and each metric's baseline is
    the median of the values the surviving runs recorded at that path.
    """
    matching = [p for p in prevs if p.get("meta") == cur.get("meta")]
    if not matching:
        stamps = {json.dumps(p.get("meta"), sort_keys=True) for p in prevs}
        print(f"{name}: SKIP — no baseline run with a matching meta stamp "
              f"({len(prevs)} run(s), stamps {sorted(stamps)} vs "
              f"{json.dumps(cur.get('meta'), sort_keys=True)})")
        return []
    prev_series: dict[tuple, list[float]] = {}
    for p in matching:
        for path, v in _walk(p):
            prev_series.setdefault(path, []).append(v)
    regressions = []
    compared = 0
    for path, cur_v in _walk(cur):
        metric = path[-1]
        spec = METRICS.get(metric)
        series = prev_series.get(path)
        if spec is None or not series:
            continue
        direction, wall = spec
        prev_v = statistics.median(series)
        if prev_v <= 0:
            continue
        change = (cur_v - prev_v) / prev_v
        if direction == "higher":
            change = -change            # normalized: positive == worse
        compared += 1
        limit = max(threshold, wall_threshold) if wall else threshold
        tag = ".".join(path)
        if change > limit:
            regressions.append(
                f"{name}: {tag} regressed {change * 100:.1f}% "
                f"(median-of-{len(series)} {prev_v:.6g} -> {cur_v:.6g}, "
                f"limit {limit * 100:.0f}%)")
        elif change < -threshold:
            print(f"{name}: {tag} improved {-change * 100:.1f}% "
                  f"(median-of-{len(series)} {prev_v:.6g} -> {cur_v:.6g})")
    print(f"{name}: compared {compared} metrics over {len(matching)} "
          f"baseline run(s), {len(regressions)} regression(s)")
    return regressions


def baseline_dirs(prev_root: str, pattern: str, k: int) -> list[str]:
    """Baseline run directories under ``prev_root``, newest run first,
    capped at K: the root itself when it directly holds artifacts
    (single-run layout) plus any per-run subdirectory holding artifacts.

    Recency ordering: all-numeric subdirectory names are GitHub run ids
    (monotonically increasing — what the CI fetch step creates), sorted
    descending; otherwise directory mtime is the fallback. The fetch loop
    downloads newest runs FIRST, so mtime of the download is inverted
    relative to run recency and must not be trusted when run ids are
    available."""
    subs = []
    root_holds = bool(glob.glob(os.path.join(prev_root, pattern)))
    for sub in os.listdir(prev_root):
        d = os.path.join(prev_root, sub)
        if os.path.isdir(d) and glob.glob(os.path.join(d, pattern)):
            subs.append((sub, d))
    if subs and all(name.isdigit() for name, _ in subs):
        subs.sort(key=lambda x: int(x[0]), reverse=True)
    else:
        subs.sort(key=lambda x: os.path.getmtime(x[1]), reverse=True)
    dirs = ([prev_root] if root_holds else []) + [d for _, d in subs]
    return dirs[:k]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--prev", required=True,
                    help="directory holding the previous run's artifacts")
    ap.add_argument("--cur", default=".",
                    help="directory holding this run's artifacts")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="relative regression that fails the gate")
    ap.add_argument("--wall-threshold", type=float, default=0.30,
                    help="noise floor for wall-clock metrics on shared "
                         "runners (the larger of this and --threshold)")
    ap.add_argument("--k", type=int, default=5,
                    help="max previous runs forming the median baseline")
    ap.add_argument("--pattern", default="BENCH_*.json")
    args = ap.parse_args(argv)

    if not os.path.isdir(args.prev):
        print(f"no previous artifacts at {args.prev!r} — first run, "
              "nothing to diff")
        return 0
    cur_files = sorted(glob.glob(os.path.join(args.cur, args.pattern)))
    if not cur_files:
        print(f"FAIL: no {args.pattern} in {args.cur!r} — the bench step "
              "produced nothing to track")
        return 1
    run_dirs = baseline_dirs(args.prev, args.pattern, args.k)
    if not run_dirs:
        print(f"no previous artifacts under {args.prev!r} — first run, "
              "nothing to diff")
        return 0
    print(f"baseline: {len(run_dirs)} run(s): "
          + ", ".join(os.path.relpath(d, args.prev) or "." for d in run_dirs))
    regressions: list[str] = []
    for cur_path in cur_files:
        name = os.path.basename(cur_path)
        prevs = []
        for d in run_dirs:
            prev_path = os.path.join(d, name)
            if not os.path.exists(prev_path):
                continue
            try:
                with open(prev_path) as f:
                    prevs.append(json.load(f))
            except (OSError, ValueError) as e:
                print(f"{name}: skipping unreadable baseline "
                      f"{prev_path!r} ({e})")
        if not prevs:
            print(f"{name}: SKIP — no previous artifact (new benchmark)")
            continue
        try:
            with open(cur_path) as f:
                cur = json.load(f)
        except (OSError, ValueError) as e:
            print(f"{name}: SKIP — unreadable ({e})")
            continue
        regressions += compare_file(name, prevs, cur, args.threshold,
                                    args.wall_threshold)
    for r in regressions:
        print("REGRESSION:", r, file=sys.stderr)
    return 1 if regressions else 0


if __name__ == "__main__":
    raise SystemExit(main())
