#!/usr/bin/env python
"""Perf-trend gate: diff BENCH_*.json artifacts against previous runs.

``python scripts/bench_trend.py --prev prev-bench/ --cur . [--threshold 0.10]``

``--prev`` holds the baseline in one of two layouts:

* a single run's artifacts directly (``prev-bench/BENCH_*.json``) —
  the original previous-run-only diff;
* one subdirectory per previous run (``prev-bench/<run-id>/BENCH_*.json``,
  what the nightly CI fetch step downloads) — the baseline for each metric
  is then the MEDIAN over the last K runs whose ``meta`` stamp matches the
  current one (``--k``, default 5, newest first by mtime). A single noisy
  or lucky previous nightly can no longer move the gate by itself.

Walks every ``BENCH_*.json`` present in the current directory and at least
one baseline run, compares each known metric at the same JSON path, and
exits non-zero when any regresses by more than the threshold (>10% by
default — the nightly CI gate). Baseline runs whose ``meta`` stamp (jax
version / backend / device count, see ``benchmarks.common.bench_metadata``)
differs from the current run are skipped with a notice: a jax upgrade or
runner change is not a code regression and must not be graded as one.

Metric direction is keyed by name: ``*_us``/``us_per_step`` and the modeled
``*_s``/fractions regress UP, ``tokens_per_s`` regresses DOWN. Wall-clock
metrics on shared CI runners are noisy, so they take
``max(threshold, --wall-threshold)`` (default 0.30) while deterministic
modeled/simulated metrics use the strict threshold.

``--plot DIR`` additionally renders the per-metric HISTORY the K-run fetch
already downloads: for every tracked metric of every artifact, a
small-multiples SVG sparkline panel (baseline runs oldest→newest plus the
current value, dependency-free hand-rolled SVG) in ``DIR/<artifact>.svg``
and a markdown table in ``DIR/history.md`` — appended to
``$GITHUB_STEP_SUMMARY`` when set, so the trend is readable from the run
page without downloading the ``bench-history`` artifact.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import statistics
import sys

#: metric-name -> direction ("lower" is better / "higher" is better),
#: wall-clock flag (noisy on shared runners)
METRICS: dict[str, tuple[str, bool]] = {
    "us_per_step": ("lower", True),
    "us_per_call": ("lower", True),
    "tokens_per_s": ("higher", True),
    "exposed_comm_s": ("lower", False),
    "exposed_comm_fraction": ("lower", False),
    "modeled_step_s": ("lower", False),
    "hidden_s_per_layer": ("higher", False),
    # multipod HLO ground truth: locality/flat inter-pod traffic ratios —
    # deterministic compile artifacts; a ratio drifting UP means the
    # locality schedule is losing its DCN edge
    "nonlocal_bytes_ratio": ("lower", False),
    "nonlocal_msgs_ratio": ("lower", False),
    # serve-traffic virtual-clock trace metrics (BENCH_serve_traffic.json):
    # deterministic functions of the trace and the schedule, strict gate
    "p50_latency_ticks": ("lower", False),
    "p99_latency_ticks": ("lower", False),
    "slo_goodput_tokens_per_tick": ("higher", False),
    # results/metrics.json (repro.telemetry registry snapshot): gauge names
    # are slash-qualified ("train/step_time_s_mean") — matching is on the
    # name's last segment, see the rsplit in compare_file/write_history
    "step_time_s_mean": ("lower", True),
    "decode_step_s_mean": ("lower", True),
    "compile_time_s": ("lower", True),
    # per-step DCN prediction from the compiled step's CommReport —
    # deterministic compile artifact, strict threshold
    "comm_nonlocal_bytes_per_step": ("lower", False),
    "comm_nonlocal_msgs_per_step": ("lower", False),
    # distributed checkpoint (BENCH_checkpoint.json): save/restore/reshard
    # wall-clock plus deterministic byte accounting — max_chunk_bytes
    # drifting UP means save started gathering more than the shard
    "save_wall_s": ("lower", True),
    "restore_wall_s": ("lower", True),
    "reshard_wall_s": ("lower", True),
    "max_chunk_bytes": ("lower", False),
    "replica_bytes": ("lower", False),
    # fleet controller (BENCH_fleet.json + registry histogram-mean
    # mirrors): per-tick decision cost and failure-to-resumed wall-clock
    "decision_latency_s": ("lower", True),
    "recovery_wall_s": ("lower", True),
    "decision_latency_s_mean": ("lower", True),
    "recovery_s_mean": ("lower", True),
}

#: extra artifacts tracked alongside the BENCH_*.json pattern (relative to
#: --cur; same relative path looked up in every baseline run)
EXTRA_ARTIFACTS = ("results/metrics.json",)


def _walk(node, path=()):
    if isinstance(node, dict):
        for k, v in node.items():
            yield from _walk(v, path + (k,))
    elif isinstance(node, list):
        for i, v in enumerate(node):
            yield from _walk(v, path + (str(i),))
    elif isinstance(node, (int, float)) and not isinstance(node, bool):
        yield path, float(node)


def compare_file(name: str, prevs: list[dict], cur: dict, threshold: float,
                 wall_threshold: float) -> list[str]:
    """Regression messages for one artifact vs the median-of-K baseline.

    ``prevs`` holds one dict per previous run (newest first); runs with a
    non-matching meta stamp are dropped here, and each metric's baseline is
    the median of the values the surviving runs recorded at that path.
    """
    matching = [p for p in prevs if p.get("meta") == cur.get("meta")]
    if not matching:
        stamps = {json.dumps(p.get("meta"), sort_keys=True) for p in prevs}
        print(f"{name}: SKIP — no baseline run with a matching meta stamp "
              f"({len(prevs)} run(s), stamps {sorted(stamps)} vs "
              f"{json.dumps(cur.get('meta'), sort_keys=True)})")
        return []
    prev_series: dict[tuple, list[float]] = {}
    for p in matching:
        for path, v in _walk(p):
            prev_series.setdefault(path, []).append(v)
    regressions = []
    compared = 0
    for path, cur_v in _walk(cur):
        # registry gauges are slash-qualified ("train/step_time_s_mean"):
        # the metric name is the last segment
        metric = path[-1].rsplit("/", 1)[-1]
        spec = METRICS.get(metric)
        series = prev_series.get(path)
        if spec is None or not series:
            continue
        direction, wall = spec
        prev_v = statistics.median(series)
        if prev_v <= 0:
            continue
        change = (cur_v - prev_v) / prev_v
        if direction == "higher":
            change = -change            # normalized: positive == worse
        compared += 1
        limit = max(threshold, wall_threshold) if wall else threshold
        tag = ".".join(path)
        if change > limit:
            regressions.append(
                f"{name}: {tag} regressed {change * 100:.1f}% "
                f"(median-of-{len(series)} {prev_v:.6g} -> {cur_v:.6g}, "
                f"limit {limit * 100:.0f}%)")
        elif change < -threshold:
            print(f"{name}: {tag} improved {-change * 100:.1f}% "
                  f"(median-of-{len(series)} {prev_v:.6g} -> {cur_v:.6g})")
    print(f"{name}: compared {compared} metrics over {len(matching)} "
          f"baseline run(s), {len(regressions)} regression(s)")
    return regressions


# ---------------------------------------------------------------------------
# --plot: per-metric history sparklines (SVG) + markdown table
# ---------------------------------------------------------------------------
# Single-series panels on a light surface; values from the documented
# data-viz palette (categorical slot 1 for the series, text/grid tokens for
# everything else — text never wears the data color).
_SERIES = "#2a78d6"
_SURFACE = "#fcfcfb"
_TEXT = "#0b0b0b"
_TEXT_2 = "#52514e"
_GRID = "#e8e7e4"
_PANEL_W, _PANEL_H, _COLS = 340, 130, 2
_MAX_PANELS = 24            # per artifact; overflow is logged, never silent


def _fmt(v: float) -> str:
    return f"{v:,.4g}"


def _panel(x0: float, y0: float, title: str, series: list[float],
           labels: list[str]) -> str:
    """One metric's sparkline panel at (x0, y0): hairline grid, 2px line,
    surface-ringed markers, direct labels on the endpoints only (the
    markdown table carries every value), <title> tooltips per point.
    ``labels`` names each point's run (a baseline run that lacks this
    metric contributes no point, so attribution comes from the caller)."""
    pad_l, pad_r, pad_t, pad_b = 12, 64, 26, 12
    w = _PANEL_W - pad_l - pad_r
    h = _PANEL_H - pad_t - pad_b
    lo, hi = min(series), max(series)
    span = (hi - lo) or max(abs(hi), 1e-12)
    lo, hi = lo - 0.08 * span, hi + 0.08 * span
    n = len(series)
    xs = [x0 + pad_l + (w / 2 if n == 1 else i * w / (n - 1))
          for i in range(n)]
    ys = [y0 + pad_t + h - (v - lo) / (hi - lo) * h for v in series]
    out = [f'<text x="{x0 + pad_l}" y="{y0 + 15}" class="t1">'
           f'{title}</text>']
    for frac in (0.0, 0.5, 1.0):                      # recessive grid
        gy = y0 + pad_t + h * frac
        out.append(f'<line x1="{x0 + pad_l}" y1="{gy:.1f}" '
                   f'x2="{x0 + pad_l + w}" y2="{gy:.1f}" class="grid"/>')
    if n > 1:
        pts = " ".join(f"{x:.1f},{y:.1f}" for x, y in zip(xs, ys))
        out.append(f'<polyline points="{pts}" class="line"/>')
    for i, (x, y, v) in enumerate(zip(xs, ys, series)):
        r = 4.5 if i == n - 1 else 3.0
        out.append(f'<circle cx="{x:.1f}" cy="{y:.1f}" r="{r}" class="pt">'
                   f'<title>{labels[i]}: {_fmt(v)}</title></circle>')
    # direct labels: first and last only, value text in ink (never clipped —
    # the reserved right pad is sized for them)
    if n > 1:
        out.append(f'<text x="{xs[0] + 6:.1f}" y="{ys[0] - 7:.1f}" '
                   f'class="t2">{_fmt(series[0])}</text>')
    out.append(f'<text x="{xs[-1] + 8:.1f}" y="{ys[-1] + 4:.1f}" '
               f'class="t1">{_fmt(series[-1])}</text>')
    return "\n".join(out)


def render_history_svg(path: str, name: str,
                       metrics: list[tuple[str, list[float], list[str]]],
                       n_runs: int) -> None:
    """Small-multiples SVG: one single-series panel per tracked metric."""
    shown = metrics[:_MAX_PANELS]
    if len(metrics) > len(shown):
        print(f"{name}: plotting first {_MAX_PANELS} of {len(metrics)} "
              f"metrics (rest in the markdown table)")
    cols = min(_COLS, max(len(shown), 1))
    rows = -(-max(len(shown), 1) // cols)
    W, H = cols * _PANEL_W + 16, rows * _PANEL_H + 40
    body = [f'<text x="12" y="22" class="hdr">{name} — last '
            f'{n_runs} baseline run(s) + current</text>']
    for i, (tag, series, labels) in enumerate(shown):
        x0 = 8 + (i % cols) * _PANEL_W
        y0 = 32 + (i // cols) * _PANEL_H
        body.append(_panel(x0, y0, tag, series, labels))
    svg = (
        f'<svg xmlns="http://www.w3.org/2000/svg" width="{W}" height="{H}" '
        f'viewBox="0 0 {W} {H}" role="img">\n'
        f'<style>text{{font-family:system-ui,sans-serif}}'
        f'.hdr{{font-size:13px;font-weight:600;fill:{_TEXT}}}'
        f'.t1{{font-size:11px;font-weight:600;fill:{_TEXT}}}'
        f'.t2{{font-size:10px;fill:{_TEXT_2}}}'
        f'.grid{{stroke:{_GRID};stroke-width:1}}'
        f'.line{{fill:none;stroke:{_SERIES};stroke-width:2;'
        f'stroke-linejoin:round;stroke-linecap:round}}'
        f'.pt{{fill:{_SERIES};stroke:{_SURFACE};stroke-width:2}}</style>\n'
        f'<rect width="{W}" height="{H}" fill="{_SURFACE}"/>\n'
        + "\n".join(body) + "\n</svg>\n")
    with open(path, "w") as f:
        f.write(svg)


def write_history(plot_dir: str, name: str, prevs_old_first: list[dict],
                  cur: dict) -> list[str]:
    """Render one artifact's history (SVG + markdown rows). ``prevs``
    oldest-first and already meta-matched; the current run is the last
    point of every series. A baseline run missing a metric (e.g. the
    metric was added between nightlies) contributes no point, and the
    surviving points keep their true run attribution."""
    n_runs = len(prevs_old_first)
    prev_series: dict[tuple, list[tuple[int, float]]] = {}
    for i, p in enumerate(prevs_old_first):
        for path, v in _walk(p):
            prev_series.setdefault(path, []).append((i, v))
    metrics: list[tuple[str, list[float], list[str]]] = []
    md: list[str] = []
    for path, cur_v in sorted(_walk(cur)):
        spec = METRICS.get(path[-1].rsplit("/", 1)[-1])
        if spec is None:
            continue
        pts = prev_series.get(path, [])
        series = [v for _, v in pts] + [cur_v]
        labels = [f"baseline {i + 1}/{n_runs}" for i, _ in pts] + ["current"]
        tag = ".".join(path)
        metrics.append((tag, series, labels))
        base = series[:-1]
        med = statistics.median(base) if base else None
        delta = ("" if not med else
                 f"{(cur_v - med) / med * 100:+.1f}%")
        hist = " → ".join(_fmt(v) for v in base) or "—"
        md.append(f"| `{tag}` | {spec[0]} | {hist} | "
                  f"{_fmt(med) if med is not None else '—'} | "
                  f"**{_fmt(cur_v)}** | {delta} |")
    if not metrics:
        return []
    stem = os.path.splitext(name)[0].replace(os.sep, "_").replace("/", "_")
    render_history_svg(os.path.join(plot_dir, f"{stem}.svg"), name, metrics,
                       n_runs)
    header = [f"### {name}", "",
              "| metric | better | history (oldest → newest) | median | "
              "current | Δ vs median |",
              "|---|---|---|---|---|---|"]
    return header + md + [""]


def baseline_dirs(prev_root: str, pattern: str, k: int) -> list[str]:
    """Baseline run directories under ``prev_root``, newest run first,
    capped at K: the root itself when it directly holds artifacts
    (single-run layout) plus any per-run subdirectory holding artifacts.

    Recency ordering: all-numeric subdirectory names are GitHub run ids
    (monotonically increasing — what the CI fetch step creates), sorted
    descending; otherwise directory mtime is the fallback. The fetch loop
    downloads newest runs FIRST, so mtime of the download is inverted
    relative to run recency and must not be trusted when run ids are
    available."""
    subs = []
    root_holds = bool(glob.glob(os.path.join(prev_root, pattern)))
    for sub in os.listdir(prev_root):
        d = os.path.join(prev_root, sub)
        if os.path.isdir(d) and glob.glob(os.path.join(d, pattern)):
            subs.append((sub, d))
    if subs and all(name.isdigit() for name, _ in subs):
        subs.sort(key=lambda x: int(x[0]), reverse=True)
    else:
        subs.sort(key=lambda x: os.path.getmtime(x[1]), reverse=True)
    dirs = ([prev_root] if root_holds else []) + [d for _, d in subs]
    return dirs[:k]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--prev", required=True,
                    help="directory holding the previous run's artifacts")
    ap.add_argument("--cur", default=".",
                    help="directory holding this run's artifacts")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="relative regression that fails the gate")
    ap.add_argument("--wall-threshold", type=float, default=0.30,
                    help="noise floor for wall-clock metrics on shared "
                         "runners (the larger of this and --threshold)")
    ap.add_argument("--k", type=int, default=5,
                    help="max previous runs forming the median baseline")
    ap.add_argument("--pattern", default="BENCH_*.json")
    ap.add_argument("--plot", metavar="DIR", default=None,
                    help="render per-metric history (SVG + markdown) into "
                         "DIR; appended to $GITHUB_STEP_SUMMARY when set")
    args = ap.parse_args(argv)

    cur_files = sorted(glob.glob(os.path.join(args.cur, args.pattern)))
    for rel in EXTRA_ARTIFACTS:
        p = os.path.join(args.cur, rel)
        if os.path.exists(p):
            cur_files.append(p)
    if not cur_files:
        print(f"FAIL: no {args.pattern} in {args.cur!r} — the bench step "
              "produced nothing to track")
        return 1
    run_dirs = (baseline_dirs(args.prev, args.pattern, args.k)
                if os.path.isdir(args.prev) else [])
    if run_dirs:
        print(f"baseline: {len(run_dirs)} run(s): "
              + ", ".join(os.path.relpath(d, args.prev) or "."
                          for d in run_dirs))
    else:
        print(f"no previous artifacts under {args.prev!r} — first run, "
              "nothing to diff")
    if args.plot:
        os.makedirs(args.plot, exist_ok=True)
    regressions: list[str] = []
    plot_md: list[str] = []
    for cur_path in cur_files:
        # relative path, not basename: results/metrics.json must look up
        # the same relative location inside each baseline run's artifact
        name = os.path.relpath(cur_path, args.cur)
        try:
            with open(cur_path) as f:
                cur = json.load(f)
        except (OSError, ValueError) as e:
            print(f"{name}: SKIP — unreadable ({e})")
            continue
        prevs = []
        for d in run_dirs:
            prev_path = os.path.join(d, name)
            if not os.path.exists(prev_path):
                continue
            try:
                with open(prev_path) as f:
                    prevs.append(json.load(f))
            except (OSError, ValueError) as e:
                print(f"{name}: skipping unreadable baseline "
                      f"{prev_path!r} ({e})")
        if args.plot:
            matched_old_first = [p for p in prevs
                                 if p.get("meta") == cur.get("meta")][::-1]
            plot_md += write_history(args.plot, name, matched_old_first, cur)
        if not prevs:
            print(f"{name}: SKIP — no previous artifact (new benchmark)")
            continue
        regressions += compare_file(name, prevs, cur, args.threshold,
                                    args.wall_threshold)
    if args.plot and plot_md:
        doc = "\n".join(["## Benchmark history (median-of-K gate inputs)", ""]
                        + plot_md)
        with open(os.path.join(args.plot, "history.md"), "w") as f:
            f.write(doc + "\n")
        summary = os.environ.get("GITHUB_STEP_SUMMARY")
        if summary:
            with open(summary, "a") as f:
                f.write(doc + "\n")
        print(f"history: {len(plot_md)} markdown row(s) + SVG panels "
              f"in {args.plot!r}")
    for r in regressions:
        print("REGRESSION:", r, file=sys.stderr)
    return 1 if regressions else 0


if __name__ == "__main__":
    raise SystemExit(main())
