#!/usr/bin/env python
"""Validate telemetry artifacts (CI schema + reconciliation gate).

``python scripts/check_metrics_schema.py results/metrics.json results/trace_*.json``

Two artifact kinds, auto-detected by shape:

* **metrics snapshots** (``repro.telemetry.MetricsRegistry.dump``): the
  ``counters`` / ``gauges`` / ``histograms`` / ``comm`` sections must hold
  finite numbers (counters non-negative, histogram count/total/min/max/mean
  coherent) — and, the actual gate, every ``comm`` entry's runtime
  accumulation must reconcile against its compile-time CommReport
  prediction (``match: true``). A step path that executed without being
  accounted, or accounted against a stale report, fails CI here.
* **trace dumps** (``repro.telemetry.dump_trace``): a Chrome trace-event
  container whose ``traceEvents`` pass
  :func:`repro.telemetry.validate_trace_events` (known phases, numeric
  monotonic ``ts`` per lane, LIFO-matched B/E span pairs) and hold at least
  one span.

Exits non-zero with a per-file diagnostic on the first violation.
"""
from __future__ import annotations

import json
import math
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                os.pardir, "src"))

from repro.telemetry import validate_trace_events           # noqa: E402

METRIC_SECTIONS = ("counters", "gauges", "histograms", "comm")


def _finite(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool) \
        and math.isfinite(v)


def check_metrics(path: str, doc: dict) -> int:
    for section in METRIC_SECTIONS:
        if not isinstance(doc.get(section, {}), dict):
            print(f"{path}: FAIL — section {section!r} is not a mapping")
            return 1
    for name, v in doc.get("counters", {}).items():
        if not _finite(v) or v < 0:
            print(f"{path}: FAIL — counter {name!r} = {v!r} "
                  f"(must be a finite number >= 0)")
            return 1
    for name, v in doc.get("gauges", {}).items():
        if v is not None and not _finite(v):
            print(f"{path}: FAIL — gauge {name!r} = {v!r} (must be finite)")
            return 1
    for name, h in doc.get("histograms", {}).items():
        ctx = f"{path}: histogram {name!r}"
        if not isinstance(h, dict) or not _finite(h.get("count")) \
                or h["count"] < 0:
            print(f"{ctx}: FAIL — bad count {h!r}")
            return 1
        if h["count"] > 0:
            for k in ("total", "mean", "min", "max"):
                if not _finite(h.get(k)):
                    print(f"{ctx}: FAIL — non-finite {k} {h.get(k)!r}")
                    return 1
            if not (h["min"] <= h["mean"] <= h["max"]):
                print(f"{ctx}: FAIL — mean {h['mean']} outside "
                      f"[min {h['min']}, max {h['max']}]")
                return 1
    # checkpoint health invariants (DESIGN.md §10): any snapshot that did
    # checkpoint I/O must show a clean writer — a failed save or a restore
    # that had to fall back past a dangling LATEST is a CI failure even if
    # the run itself "passed".
    counters = doc.get("counters", {})
    if counters.get("checkpoint/saves", 0) > 0 \
            or counters.get("checkpoint/restores", 0) > 0:
        for bad in ("checkpoint/save_failures", "checkpoint/latest_fallbacks",
                    "checkpoint/manifest_fallbacks",
                    "checkpoint/hash_failures"):
            if counters.get(bad, 0) != 0:
                print(f"{path}: FAIL — {bad} = {counters[bad]} after "
                      f"{counters.get('checkpoint/saves', 0)} save(s) / "
                      f"{counters.get('checkpoint/restores', 0)} restore(s) "
                      f"(checkpoint I/O must be clean in CI)")
                return 1
        gauges = doc.get("gauges", {})
        if counters.get("checkpoint/saves", 0) > 0:
            mc = gauges.get("checkpoint/max_chunk_bytes")
            tb = gauges.get("checkpoint/tree_bytes")
            if not _finite(mc) or mc <= 0:
                print(f"{path}: FAIL — checkpoint saves recorded but "
                      f"checkpoint/max_chunk_bytes gauge is {mc!r}")
                return 1
            if _finite(tb) and mc > tb:
                print(f"{path}: FAIL — max chunk ({mc:.0f} B) exceeds the "
                      f"whole tree ({tb:.0f} B): save gathered more than "
                      f"a shard")
                return 1
    # fleet controller invariants (DESIGN.md §11): the decision ledger must
    # be self-consistent — every decision is exactly one action, a healthy
    # run never halted, and the controller's straggler view (the runtime
    # counter it polls) can never lag the trainer's own surfaced count.
    if "fleet/decisions" in counters:
        actions = sum(counters.get(f"fleet/{k}", 0)
                      for k in ("noops", "retries", "shrinks", "grows",
                                "halts"))
        if counters["fleet/decisions"] != actions:
            print(f"{path}: FAIL — fleet/decisions = "
                  f"{counters['fleet/decisions']} but per-action counters "
                  f"sum to {actions} (a decision was recorded without its "
                  f"action, or vice versa)")
            return 1
        if counters.get("fleet/episodes", 0) < 1:
            print(f"{path}: FAIL — fleet decisions recorded without a "
                  f"single fleet/episodes build")
            return 1
        if doc.get("gauges", {}).get("fleet/healthy") == 1 \
                and counters.get("fleet/halts", 0) != 0:
            print(f"{path}: FAIL — fleet/healthy gauge is 1 but "
                  f"{counters['fleet/halts']} halt decision(s) were taken")
            return 1
    if "train/stragglers" in counters and "runtime/stragglers" in counters \
            and counters["runtime/stragglers"] < counters["train/stragglers"]:
        print(f"{path}: FAIL — runtime/stragglers "
              f"({counters['runtime/stragglers']}) < train/stragglers "
              f"({counters['train/stragglers']}): the monitor surfaced "
              f"events it never counted")
        return 1
    n_comm = 0
    for label, c in doc.get("comm", {}).items():
        ctx = f"{path}: comm {label!r}"
        for k in ("invocations", "predicted_nonlocal_bytes",
                  "predicted_nonlocal_msgs", "actual_nonlocal_bytes",
                  "actual_nonlocal_msgs"):
            if not _finite(c.get(k)):
                print(f"{ctx}: FAIL — non-finite {k} {c.get(k)!r}")
                return 1
        if not isinstance(c.get("report"), dict):
            print(f"{ctx}: FAIL — missing compile-time report")
            return 1
        # THE gate: runtime accumulation == invocations × compile-time
        # prediction. False means a step executed outside the telemetry
        # accounting, or against a stale report.
        if c.get("match") is not True:
            print(f"{ctx}: FAIL — predicted vs actual comm mismatch: "
                  f"predicted {c['predicted_nonlocal_bytes']:.0f} B / "
                  f"{c['predicted_nonlocal_msgs']:.0f} msgs, actual "
                  f"{c['actual_nonlocal_bytes']:.0f} B / "
                  f"{c['actual_nonlocal_msgs']:.0f} msgs over "
                  f"{c['invocations']} invocation(s)")
            return 1
        n_comm += 1
    print(f"{path}: OK (metrics snapshot: "
          f"{len(doc.get('counters', {}))} counters, "
          f"{len(doc.get('gauges', {}))} gauges, "
          f"{len(doc.get('histograms', {}))} histograms, "
          f"{n_comm} reconciled comm label(s))")
    return 0


def check_trace(path: str, doc: dict) -> int:
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        print(f"{path}: FAIL — no traceEvents list")
        return 1
    problems = validate_trace_events(events)
    if problems:
        for p in problems[:10]:
            print(f"{path}: FAIL — {p}")
        return 1
    spans = sum(1 for e in events if e.get("ph") == "B")
    if spans == 0:
        print(f"{path}: FAIL — trace holds no spans (instrumentation "
              f"produced nothing)")
        return 1
    print(f"{path}: OK (trace: {len(events)} events, {spans} spans)")
    return 0


def check_file(path: str) -> int:
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        print(f"{path}: FAIL — unreadable ({e})")
        return 1
    if not isinstance(doc, dict):
        print(f"{path}: FAIL — top level is not an object")
        return 1
    if "traceEvents" in doc:
        return check_trace(path, doc)
    if any(s in doc for s in METRIC_SECTIONS):
        return check_metrics(path, doc)
    print(f"{path}: FAIL — neither a trace dump nor a metrics snapshot")
    return 1


def main(argv: list[str]) -> int:
    paths = argv or [os.path.join("results", "metrics.json")]
    rc = 0
    for path in paths:
        if not os.path.exists(path):
            print(f"{path}: FAIL — file does not exist")
            return 1
        rc |= check_file(path)
    return rc


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
