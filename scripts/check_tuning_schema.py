#!/usr/bin/env python
"""Validate a persisted tuning table (CI schema gate).

``python scripts/check_tuning_schema.py [results/tuning_table.json ...]``

Loads each table through :class:`repro.tuning.cache.TuningCache` (which
enforces ``schema_version`` and runs migrations) and then checks every
entry invariant the policy layer depends on:

* key format ``<fingerprint>|p<P>xl<PL>|<collective>|<dtype>|b<bucket>``
  consistent with the entry's own fields;
* bucket is a power of two; p divisible by p_local;
* costs: non-empty map of known algorithm names to positive finite floats;
* source is "measured" or "simulated".

Exits non-zero with a per-entry diagnostic on the first violation, so a
sweep refactor can never silently persist a table the policy would misread.
"""
from __future__ import annotations

import math
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                os.pardir, "src"))

from repro.tuning.cache import TuningCache, make_key           # noqa: E402
from repro.tuning.measure import (ALLGATHER_ALGORITHMS,        # noqa: E402
                                  ALLREDUCE_ALGORITHMS,
                                  LOGSUMEXP_ALGORITHMS,
                                  OVERLAP_ALGORITHMS)

KNOWN_ALGORITHMS = {
    "allgather": set(ALLGATHER_ALGORITHMS) | {"xla"},
    "allreduce": set(ALLREDUCE_ALGORITHMS),
    "logsumexp_combine": set(LOGSUMEXP_ALGORITHMS),
}


def _known_algorithms(collective: str):
    if collective.startswith("overlap:i"):
        # intensity-octave overlap cells: "overlap:i<k>" with integer k
        try:
            int(collective.split(":i", 1)[1])
        except ValueError:
            return None
        return set(OVERLAP_ALGORITHMS)
    return KNOWN_ALGORITHMS.get(collective)


def check_table(path: str) -> int:
    cache = TuningCache.load(path)          # schema_version enforced here
    if not len(cache):
        print(f"{path}: FAIL — table has no entries")
        return 1
    for key, e in cache.entries.items():
        ctx = f"{path}: entry {key!r}"
        fingerprint = key.split("|", 1)[0]
        expect = make_key(fingerprint, e.p, e.p_local, e.collective, e.dtype,
                          e.bucket)
        if key != expect:
            print(f"{ctx}: FAIL — key disagrees with fields ({expect!r})")
            return 1
        if e.bucket < 1 or (e.bucket & (e.bucket - 1)) != 0:
            print(f"{ctx}: FAIL — bucket {e.bucket} is not a power of two")
            return 1
        if e.p_local < 1 or e.p % e.p_local != 0:
            print(f"{ctx}: FAIL — p={e.p} not divisible by p_local={e.p_local}")
            return 1
        algs = _known_algorithms(e.collective)
        if algs is None:
            print(f"{ctx}: FAIL — unknown collective {e.collective!r}")
            return 1
        if not isinstance(e.generation, int) or e.generation < 0:
            print(f"{ctx}: FAIL — invalid generation {e.generation!r}")
            return 1
        if not e.costs:
            print(f"{ctx}: FAIL — empty costs map")
            return 1
        for alg, cost in e.costs.items():
            if alg not in algs:
                print(f"{ctx}: FAIL — unknown algorithm {alg!r} "
                      f"for {e.collective}")
                return 1
            if not isinstance(cost, (int, float)) or not math.isfinite(cost) \
                    or cost <= 0:
                print(f"{ctx}: FAIL — non-positive/non-finite cost "
                      f"{alg}={cost!r}")
                return 1
        if e.source not in ("measured", "simulated"):
            print(f"{ctx}: FAIL — unknown source {e.source!r}")
            return 1
    print(f"{path}: OK ({len(cache)} entries)")
    return 0


def main(argv: list[str]) -> int:
    paths = argv or [os.path.join("results", "tuning_table.json")]
    rc = 0
    for path in paths:
        if not os.path.exists(path):
            print(f"{path}: FAIL — file does not exist")
            return 1
        rc |= check_table(path)
    return rc


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
