#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md).
#
# Single-process smoke tests deliberately run on the one real CPU device
# (tests/conftest.py); multi-device tests and the benchmarks spawn
# subprocesses that force their own host device count via
# --xla_force_host_platform_device_count, overriding whatever XLA_FLAGS we
# export here. We therefore only propagate the caller's XLA_FLAGS and keep
# the flag available for ad-hoc runs:
#
#   XLA_FLAGS=--xla_force_host_platform_device_count=8 scripts/verify.sh
#
# Modes:
#   scripts/verify.sh            full tier-1 suite
#   scripts/verify.sh --smoke    CI pre-merge subset: deselects the heavy
#                                multi-device subprocess suites (-m slow)
#                                and the hypothesis property suites
#                                (-m hypothesis); extra args pass through
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

SMOKE=0
args=()
for a in "$@"; do
  case "$a" in
    --smoke) SMOKE=1 ;;
    *) args+=("$a") ;;
  esac
done

# Preflight (full mode only): the multi-device tests force 8 host devices
# in their subprocesses. If this environment cannot actually deliver them
# (XLA_FLAGS stripped by a wrapper, exotic platform), those tests would
# silently build degenerate 1-device meshes and pass vacuously — fail
# loudly instead. Smoke mode deselects every multi-device suite (-m slow),
# so it skips the preflight and stays runnable in constrained containers.
[ "$SMOKE" = 1 ] || python - <<'EOF'
import os, subprocess, sys
env = dict(os.environ)
env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
out = subprocess.run(
    [sys.executable, "-c", "import jax; print(len(jax.devices()))"],
    env=env, capture_output=True, text=True)
n = int(out.stdout.strip() or 0) if out.returncode == 0 else 0
if n < 8:
    sys.stderr.write(
        f"FATAL: forcing 8 host devices yielded {n}; the multi-device "
        "tier-1 tests would silently run single-device meshes.\n"
        f"{out.stderr[-2000:]}\n")
    sys.exit(1)
EOF

if [ "$SMOKE" = 1 ]; then
  python -m pytest -x -q -m "not slow and not hypothesis" \
    ${args[@]+"${args[@]}"}
else
  python -m pytest -x -q ${args[@]+"${args[@]}"}
fi
