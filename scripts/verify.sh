#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md).
#
# Single-process smoke tests deliberately run on the one real CPU device
# (tests/conftest.py); multi-device tests and the benchmarks spawn
# subprocesses that force their own host device count via
# --xla_force_host_platform_device_count, overriding whatever XLA_FLAGS we
# export here. We therefore only propagate the caller's XLA_FLAGS and keep
# the flag available for ad-hoc runs:
#
#   XLA_FLAGS=--xla_force_host_platform_device_count=8 scripts/verify.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q "$@"
