"""Paper Figs. 9-10 analogue: measured allgather comparison.

The paper measures wall-time on Quartz/Lassen; the TPU-adapted equivalent
here has two parts:

  1. MEASURED: wall-clock of the five allgather algorithms (shard_map +
     ppermute) on a 16-device host mesh (4 regions × 4) — the CPU backend's
     inter-process costs are uniform, so this checks overhead/correctness
     rather than locality gains.
  2. STRUCTURAL (the TPU-relevant reproduction): compiled-HLO non-local
     edge/byte counts on the production mesh — see collective_hlo_audit.
"""
from __future__ import annotations

from .common import emit, run_multidevice

CODE = r"""
import jax, jax.numpy as jnp, time
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import collectives as C

mesh = jax.make_mesh((4, 4), ("r", "l"))
x = jnp.ones((16, 1024), jnp.float32)   # 4 KiB per rank
def make(alg):
    def body(s):
        return C.allgather(s, "r", "l", algorithm=alg, tiled=True)
    return jax.jit(jax.shard_map(body, mesh=mesh, in_specs=P(("r","l")),
                                  out_specs=P(("r","l"))))
for alg in ["xla", "bruck", "ring", "hierarchical", "multilane",
            "locality_bruck"]:
    f = make(alg)
    out = f(x); out.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(50):
        out = f(x)
    out.block_until_ready()
    us = (time.perf_counter() - t0) / 50 * 1e6
    print(f"RESULT {alg} {us:.1f}")
"""


def main() -> list[tuple]:
    out = run_multidevice(CODE, devices=16)
    rows = []
    for line in out.splitlines():
        if line.startswith("RESULT"):
            _, alg, us = line.split()
            rows.append((f"fig9/measured_allgather_{alg}_16dev_4KiB",
                         float(us), "host-CPU wall time"))
    assert len(rows) == 6
    return emit(rows)


if __name__ == "__main__":
    main()
