"""Paper Fig. 3: modeled single ping-pong cost by message class on Lassen.

Reproduces the three-way split (intra-socket / inter-socket / inter-node)
using the Bienz-et-al. parameter fits behind core/cost_model.py; the paper's
qualitative claims asserted: inter-node ≫ inter-socket ≫ intra-socket for
small messages, with the eager→rendezvous jump at 8 KiB.
"""
from __future__ import annotations

from repro.core.cost_model import LinkParams, ProtocolParams, _p

from .common import emit

INTRA_SOCKET = ProtocolParams(eager=_p(0.45, 20.0), rendezvous=_p(1.3, 38.0))
INTER_SOCKET = ProtocolParams(eager=_p(0.9, 9.0), rendezvous=_p(2.4, 20.0))
INTER_NODE = ProtocolParams(eager=_p(1.8, 5.0), rendezvous=_p(5.2, 11.5))

SIZES = [8, 64, 512, 4096, 8192, 65536, 1 << 20]


def main() -> list[tuple]:
    rows = []
    for nbytes in SIZES:
        a = INTRA_SOCKET.msg_cost(nbytes) * 1e6
        b = INTER_SOCKET.msg_cost(nbytes) * 1e6
        c = INTER_NODE.msg_cost(nbytes) * 1e6
        assert c > b > a, "locality ordering must hold"
        rows.append((f"fig3/pingpong_{nbytes}B_intra_socket", round(a, 3),
                     f"ratio_internode={c / a:.1f}x"))
        rows.append((f"fig3/pingpong_{nbytes}B_inter_socket", round(b, 3), ""))
        rows.append((f"fig3/pingpong_{nbytes}B_inter_node", round(c, 3), ""))
    return emit(rows)


if __name__ == "__main__":
    main()
