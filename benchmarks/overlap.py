"""overlap: eager vs double-buffered prefetch for the FSDP train pipeline.

Per model size, spawns an 8-device (2 pods × 4) subprocess that builds the
paper-mode FSDP train step twice — ``prefetch_depth=0`` (eager: the whole
stacked param gather serialized in front of the forward) and
``prefetch_depth=1`` (layer i+1's gather issued inside the scan before
layer i's compute) — asserts EXACT loss/metric equality between the two,
and reports wall-clock step time + tokens/s.

Host-CPU wall clock cannot show the overlap win (there is no real network
to hide), so the exposed-communication split additionally comes from the
simulated backend: the cost-model overlap term
(``cost_model.overlap_model``) prices each layer's gather bytes against its
matmul window on the tpu_v5e parameter set — the same term
``prefetch_depth="auto"`` resolves through. The prefetched exposed-comm
numbers must come out strictly below the eager ones; the acceptance gate of
the overlap subsystem.

Wall clock is gated (prefetched strictly faster) ONLY on accelerator
backends; on the CPU harness it is reported, and the gate is instead that
``prefetch_depth="auto"`` resolves to eager via the measured-dispatch
guard (``Policy.select_overlap(dispatch_overhead_s=...)``) — a host with
no wire must never be told to prefetch. Writes ``BENCH_overlap.json``.
"""
from __future__ import annotations

import json
import os

from .common import REPO, emit, run_multidevice, write_bench_json

OUT = os.path.join(REPO, "BENCH_overlap.json")

#: (name, smoke config, n_layers) — three sizes, one windowed-ring plan
SIZES = (("llama3b_2L", "llama3.2-3b", 2),
         ("llama3b_6L", "llama3.2-3b", 6),
         ("gemma9b_4L", "gemma2-9b", 4))

STEPS = 3
BATCH, SEQ = 8, 64

CODE_TMPL = r"""
import json, time
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro import configs
from repro.core import cost_model
from repro.train.sharding import fsdp_param_dims
from repro.train.step import make_train_step, init_state, custom_batch_specs
from repro.data import SyntheticLM

ARCH, NL, BATCH, SEQ, STEPS = %r, %d, %d, %d, %d

mesh = jax.make_mesh((2, 4), ("pod", "data"))
jax.set_mesh(mesh)
cfg = dataclasses.replace(configs.get_smoke(ARCH), n_layers=NL)
data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=SEQ,
                   global_batch=BATCH, seed=0)
bspec = custom_batch_specs(cfg, BATCH, SEQ)
out = {}
metrics_by_depth = {}
for depth in (0, 1):
    art = make_train_step(cfg, mesh, grad_sync="locality", fsdp=True,
                          shape=bspec, donate=False, prefetch_depth=depth)
    assert art.prefetch_depth == depth, art
    state = init_state(cfg, mesh, art)
    batch = {k: jax.device_put(v, art.batch_shardings[k])
             for k, v in data.batch(0).items()}
    state2, metrics = art.step_fn(state, batch)        # compile + warm
    jax.block_until_ready(metrics["loss"])
    t0 = time.perf_counter()
    for _ in range(STEPS):
        state2, metrics = art.step_fn(state, batch)
    jax.block_until_ready(metrics["loss"])
    us = (time.perf_counter() - t0) / STEPS * 1e6
    metrics_by_depth[depth] = float(metrics["loss"])
    out["prefetched" if depth else "eager"] = {
        "us_per_step": us,
        "tokens_per_s": BATCH * SEQ / (us / 1e6),
        "loss": float(metrics["loss"]),
    }
assert metrics_by_depth[0] == metrics_by_depth[1], metrics_by_depth

# prefetch_depth="auto" through the tuning policy + the measured-dispatch
# guard: on a host-CPU harness (no wire to hide, real per-dispatch cost)
# it must resolve to the eager schedule
art_auto = make_train_step(cfg, mesh, grad_sync="locality", fsdp=True,
                           shape=bspec, donate=False, prefetch_depth="auto")
out["auto"] = {"depth": art_auto.prefetch_depth,
               "source": art_auto.prefetch_source,
               "backend": jax.default_backend()}

# --- simulated backend: the cost-model overlap term on this topology -------
from repro.models import transformer
a_params = jax.eval_shape(lambda k: transformer.init_params(k, cfg),
                          jax.random.PRNGKey(0))
from repro.train.sharding import param_specs
pspecs = param_specs(a_params, mesh, fsdp=True)
dims = fsdp_param_dims(pspecs)["blocks"]
blk = jax.tree.leaves(a_params["blocks"])
dlv = jax.tree.leaves(dims)
reps = blk[0].shape[0]
itemsize = jnp.dtype(cfg.dtype).itemsize
sharded = sum(int(np.prod(l.shape[1:])) for l, k in zip(blk, dlv) if k >= 0)
total = sum(int(np.prod(l.shape[1:])) for l in blk)
d_size = 4
gather_bytes = sharded * itemsize / d_size            # per-rank shard/layer
tokens_per_dev = BATCH * SEQ // 8
layer_flops = 2.0 * total * tokens_per_dev
oc = cost_model.overlap_model(d_size, d_size, gather_bytes, layer_flops,
                              cost_model.MACHINES["tpu_v5e"])
n_layers_scanned = reps
sim = {}
for name, exposed in (("eager", oc.exposed_eager),
                      ("prefetched", oc.exposed_prefetch)):
    comm = exposed * n_layers_scanned
    comp = oc.t_compute * n_layers_scanned
    sim[name] = {
        "exposed_comm_s": comm,
        "exposed_comm_fraction": comm / (comm + comp),
        "modeled_step_s": comm + comp,
    }
out["simulated"] = {
    "machine": "tpu_v5e", "per_layer_gather_bytes": gather_bytes,
    "per_layer_flops": layer_flops, "layers": n_layers_scanned,
    "hidden_s_per_layer": oc.hidden,
    **{k: v for k, v in sim.items()},
}

# same layer geometry at a production token batch (4k tokens/device): the
# smoke shapes are latency-toys, so also report the window the pipeline is
# built for — where the matmuls are big enough to hide most of the gather
prod_flops = 2.0 * total * 4096
ocp = cost_model.overlap_model(d_size, d_size, gather_bytes, prod_flops,
                               cost_model.MACHINES["tpu_v5e"])
out["simulated_production_batch"] = {
    "tokens_per_device": 4096,
    "eager": {"exposed_comm_s": ocp.exposed_eager * n_layers_scanned},
    "prefetched": {"exposed_comm_s": ocp.exposed_prefetch * n_layers_scanned},
    "hidden_fraction": (ocp.hidden / ocp.exposed_eager
                        if ocp.exposed_eager else 0.0),
}
print("JSON" + json.dumps(out))
"""


def main() -> list[tuple]:
    results = {}
    for name, arch, n_layers in SIZES:
        code = CODE_TMPL % (arch, n_layers, BATCH, SEQ, STEPS)
        stdout = run_multidevice(code, devices=8, timeout=1800)
        line = [l for l in stdout.splitlines() if l.startswith("JSON")][0]
        results[name] = json.loads(line[4:])
    write_bench_json(OUT, results, devices=8)

    rows = []
    for name, r in results.items():
        sim = r["simulated"]
        for mode in ("eager", "prefetched"):
            rows.append((
                f"overlap/{name}/{mode}", r[mode]["us_per_step"],
                f"tokens_per_s={r[mode]['tokens_per_s']:.0f} "
                f"exposed_comm_fraction={sim[mode]['exposed_comm_fraction']:.4f}"))
        e, p = (sim["eager"]["exposed_comm_s"],
                sim["prefetched"]["exposed_comm_s"])
        rows.append((f"overlap/{name}/exposed_reduction", None,
                     f"eager_s={e:.3e} prefetched_s={p:.3e} "
                     f"hidden_fraction={(e - p) / e if e else 0.0:.4f}"))
        prod = r["simulated_production_batch"]
        rows.append((f"overlap/{name}/exposed_reduction_prod_batch", None,
                     f"eager_s={prod['eager']['exposed_comm_s']:.3e} "
                     f"prefetched_s={prod['prefetched']['exposed_comm_s']:.3e} "
                     f"hidden_fraction={prod['hidden_fraction']:.4f}"))
        assert (prod["prefetched"]["exposed_comm_s"]
                < prod["eager"]["exposed_comm_s"]), name
        # the acceptance gate: the prefetched pipeline must expose strictly
        # less modeled communication time than the eager baseline
        assert p < e, (name, e, p)
        assert r["eager"]["loss"] == r["prefetched"]["loss"], name
        # wall clock: REPORTED everywhere, GATED only on accelerator
        # backends — a host-CPU harness has no network to hide, so the
        # pipeline's dispatch overhead legitimately makes prefetched
        # slower there (the recorded gemma9b_4L 463.7ms vs 377.5ms); on
        # CPU the policy fix is the gate instead: "auto" must resolve to
        # eager (depth 0, source "dispatch" when the guard fired)
        wall_e, wall_p = r["eager"]["us_per_step"], r["prefetched"]["us_per_step"]
        auto = r["auto"]
        rows.append((f"overlap/{name}/wall_clock_gate", None,
                     f"prefetched_faster={wall_p < wall_e} "
                     f"auto_depth={auto['depth']} "
                     f"auto_source={auto['source']} "
                     f"backend={auto['backend']}"))
        if auto["backend"] == "cpu":
            assert auto["depth"] == 0, (name, auto)
        else:
            assert wall_p < wall_e, (name, wall_e, wall_p)
    return emit(rows)


if __name__ == "__main__":
    main()
