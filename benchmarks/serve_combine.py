"""serve-combine: xla vs locality decode cache-combine, per decode step.

Spawns an 8-device subprocess, builds the serve engine twice over a
sequence-sharded KV cache — once with GSPMD's implicit combine ("xla"),
once with the manual shard_map + ``locality_logsumexp_combine`` path — and
reports wall-clock per decode step plus the compiled collective inventory
of each decode_fn. Writes ``BENCH_serve_combine.json`` so the perf
trajectory of the §Perf serve hook is a tracked artifact, not hand-curated
numbers.
"""
from __future__ import annotations

import json
import os

from .common import REPO, emit, run_multidevice, write_bench_json

OUT = os.path.join(REPO, "BENCH_serve_combine.json")

CODE = r"""
import json, time
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro import configs
from repro.models import transformer
from repro.serve.engine import make_serve_fns, resolve_cache_combine
from repro.serve.spec import ServeSpec
from repro.core.hlo_analysis import (allreduce_combiners, collective_stats,
                                     op_payloads)

mesh = jax.make_mesh((8,), ("data",))
jax.set_mesh(mesh)
cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=2)
B, CL, STEPS = 1, 128, 32
params = transformer.init_params(jax.random.PRNGKey(0), cfg)
prompts = jnp.asarray(
    np.random.default_rng(0).integers(0, cfg.vocab_size, (B, 8), np.int32))
choice = resolve_cache_combine(cfg, mesh, B, CL)
cache_sds = transformer.cache_specs(cfg, B, CL)
tok_sds = jax.ShapeDtypeStruct((B, 1), jnp.int32)

out = {"payload_bytes": choice.nbytes, "p": choice.p,
       "o_bytes": B * cfg.n_heads * cfg.head_dim_ * 4,
       "auto_resolution": {"algorithm": choice.algorithm,
                           "source": choice.source}}
for alg in ("xla", "locality"):
    art = make_serve_fns(cfg, mesh, ServeSpec(batch=B, cache_len=CL,
                                          combine=alg))
    fn = art.decode_fn
    hlo = fn.lower(art.abstract_params, cache_sds, tok_sds).compile().as_text()
    st = collective_stats(hlo)
    p16 = jax.tree.map(
        lambda p: p.astype(cfg.dtype) if p.dtype == jnp.float32 else p, params)
    p16 = jax.device_put(p16, art.param_shardings)
    logits, cache = art.prefill_fn(p16, {"tokens": prompts})
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    logits, cache = fn(p16, cache, tok)         # compile + warm
    jax.block_until_ready(logits)
    t0 = time.perf_counter()
    for _ in range(STEPS):
        logits, cache = fn(p16, cache, tok)
    jax.block_until_ready(logits)
    out[alg] = {
        "us_per_step": (time.perf_counter() - t0) / STEPS * 1e6,
        "collectives": {"counts": dict(st.counts), "bytes": dict(st.bytes_)},
        "allreduce_payloads": op_payloads(hlo, "all-reduce"),
        "allreduce_combiners": allreduce_combiners(hlo),
    }
print("JSON" + json.dumps(out))
"""


def main() -> list[tuple]:
    stdout = run_multidevice(CODE, devices=8, timeout=1800)
    line = [l for l in stdout.splitlines() if l.startswith("JSON")][0]
    out = json.loads(line[4:])
    write_bench_json(OUT, out, devices=8)

    rows = []
    for alg in ("xla", "locality"):
        st = out[alg]["collectives"]["counts"]
        rows.append((f"serve_combine/{alg}", out[alg]["us_per_step"],
                     f"collectives={st}"))
    ratio = out["xla"]["us_per_step"] / max(out["locality"]["us_per_step"], 1e-9)
    rows.append(("serve_combine/xla_over_locality", None,
                 f"ratio={ratio:.3f} payload={out['payload_bytes']}B "
                 f"auto={out['auto_resolution']['algorithm']}"))
    # the manual path must not run the stat combine through all-reduce: no
    # max-combiner all-reduce (implicit sharded-softmax signature) and the
    # explicit permute/reduce-scatter schedule must be present instead
    combiners = out["locality"]["allreduce_combiners"]
    bad = [c for c in combiners if c in ("maximum", "minimum")]
    assert not bad, f"locality decode still all-reduces softmax stats: {bad}"
    assert out["locality"]["collectives"]["counts"].get("reduce-scatter", 0), \
        "locality decode lost its explicit combine (no reduce-scatter)"
    return emit(rows)


if __name__ == "__main__":
    main()
