"""Paper Fig. 8: modeled cost vs data size (1024 regions × 16 ranks)."""
from __future__ import annotations

from repro.core import cost_model as CM

from .common import emit


def main() -> list[tuple]:
    rows = []
    p_local = 16
    p = 1024 * p_local
    for block in (4, 16, 64, 256, 1024, 4096):
        std = CM.bruck_model(p, float(block), CM.LASSEN) * 1e6
        loc = CM.locality_bruck_model(p, p_local, float(block), CM.LASSEN) * 1e6
        rows.append((f"fig8/block{block}B_bruck", round(std, 3), ""))
        rows.append((f"fig8/block{block}B_locality", round(loc, 3),
                     f"speedup={std / loc:.2f}x"))
    return emit(rows)


if __name__ == "__main__":
    main()
