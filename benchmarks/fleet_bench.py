"""fleet: chaos mini-soak benchmark — decision latency + recovery wall-clock.

A seeded kill + preemption + straggler schedule hits a 12-device
(3x4 pod-aligned) flat-psum run under the :class:`repro.fleet
.FleetController`; the run must converge to ``complete``/healthy, and the
controller's overheads become the trended numbers:

* ``decision_latency_s`` — mean wall-clock of one ``FleetPolicy.decide``
  round trip including signal assembly (the per-step tick tax);
* ``recovery_wall_s`` — mean wall-clock from a failure (kill / drain) to
  the next episode's trainer standing on the committed step (rebuild +
  resharding restore + recompile).

Writes ``BENCH_fleet.json`` (trended via ``scripts/bench_trend.py
--pattern BENCH_fleet.json``); the subprocess dumps its own registry into
``results/metrics.json`` so the ``fleet/*`` counter invariants are
checkable by ``scripts/check_metrics_schema.py``.
"""
from __future__ import annotations

import json
import os

from .common import REPO, RESULTS, emit, run_multidevice, write_bench_json

OUT = os.path.join(REPO, "BENCH_fleet.json")
DEVICES = 12

SOAK_CODE = r"""
import dataclasses, json, os, tempfile
import jax, jax.numpy as jnp
from repro import configs, telemetry
from repro.fleet import (ChaosSchedule, ChaosSpec, FleetController,
                         FleetPolicy, PolicyConfig)
from repro.train import Trainer, TrainerConfig

STEPS = 8
ckdir = tempfile.mkdtemp(prefix="fleet_bench_")
cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=2,
                          d_model=96, d_ff=192, vocab_size=384,
                          dtype=jnp.float32)
tcfg = TrainerConfig(steps=STEPS, seq_len=32, global_batch=24, ckpt_every=2,
                     keep_last=6, log_every=100, grad_sync="flat_psum",
                     fsdp=False, lr=3e-3, comm_telemetry=False,
                     ckpt_dir=ckdir)

def make_trainer(mesh):
    return Trainer(cfg, mesh, tcfg, log=lambda s: None)

chaos = ChaosSchedule(ChaosSpec(steps=STEPS, seed=1, kills=1, preempts=1,
                                straggles=1, first_step=3, delay_s=0.2))
policy = FleetPolicy(PolicyConfig(max_retries=6, max_shrinks=0,
                                  straggler_high=99))
fc = FleetController(make_trainer, pod_size=4, devices=12, chaos=chaos,
                     policy=policy, log=lambda s: None)
report = fc.run()
assert report.status == "complete", report.status
assert chaos.pending() == {"kills": [], "preempts": []}, chaos.pending()

reg = telemetry.get_registry()
snap = reg.snapshot()
lat = snap["histograms"].get("fleet/decision_latency_s", {})
rec = snap["histograms"].get("fleet/recovery_s", {})
out = {
    "status": report.status,
    "steps": report.steps,
    "episodes": len(report.episodes),
    "final_layout": list(report.final_layout),
    "decisions": snap["counters"].get("fleet/decisions", 0),
    "decision_latency_s": lat.get("mean"),
    "decision_latency_max_s": lat.get("max"),
    "recovery_wall_s": rec.get("mean"),
    "recoveries": rec.get("count", 0),
    "healthy": snap["gauges"].get("fleet/healthy"),
}
print("RESULT " + json.dumps(out))
results = os.environ.get("FLEET_BENCH_RESULTS")
if results:
    # this subprocess owns the fleet/* counters — persist them itself so
    # results/metrics.json carries what the schema checker reconciles
    os.makedirs(results, exist_ok=True)
    meta = {"jax_version": jax.__version__,
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "device_kind": jax.devices()[0].device_kind}
    reg.dump(os.path.join(results, "metrics.json"), meta=meta)
    telemetry.dump_trace(os.path.join(results, "trace_fleet_soak.json"))
"""


def main() -> list[tuple]:
    os.environ["FLEET_BENCH_RESULTS"] = RESULTS
    stdout = run_multidevice(SOAK_CODE, DEVICES, timeout=1500)
    line = [ln for ln in stdout.splitlines() if ln.startswith("RESULT ")][-1]
    res = json.loads(line[len("RESULT "):])
    assert res["status"] == "complete", res
    assert res["healthy"] == 1.0, res
    assert res["decision_latency_s"] is not None, res
    assert res["recoveries"] >= 2 and res["recovery_wall_s"] is not None, res

    write_bench_json(OUT, {"fleet": res}, devices=DEVICES)
    return emit([
        ("fleet/decision_latency", res["decision_latency_s"] * 1e6,
         f"mean_s={res['decision_latency_s']:.2e} "
         f"max_s={res['decision_latency_max_s']:.2e} "
         f"decisions={res['decisions']}"),
        ("fleet/recovery_wall", res["recovery_wall_s"] * 1e6,
         f"mean_s={res['recovery_wall_s']:.3f} "
         f"recoveries={res['recoveries']}"),
        ("fleet/soak", None,
         f"status={res['status']} episodes={res['episodes']} "
         f"steps={res['steps']} layout={tuple(res['final_layout'])} "
         f"healthy={res['healthy']}"),
    ])


if __name__ == "__main__":
    main()
