"""Shared benchmark utilities: timing, subprocess multi-device runs, and the
environment metadata stamp every BENCH_*.json carries (the CI trend job only
diffs artifacts whose stamps match — like with like)."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
RESULTS = os.path.join(REPO, "results")


def bench_metadata(devices: int | None = None) -> dict:
    """jax version / backend / device identity of this benchmark run.

    ``devices`` overrides the live device count for benchmarks whose real
    work runs in a forced-host-device subprocess (the parent process only
    sees 1 CPU device).
    """
    import jax
    return {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": devices if devices is not None else jax.device_count(),
        "device_kind": jax.devices()[0].device_kind,
    }


def write_bench_json(path: str, payload: dict, *,
                     devices: int | None = None) -> None:
    """Persist one BENCH_*.json with the metadata stamp injected."""
    payload = dict(payload)
    payload.setdefault("meta", bench_metadata(devices))
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)


def time_us(fn, *, warmup: int = 3, iters: int = 20) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6


def run_multidevice(code: str, devices: int, timeout: int = 1200) -> str:
    """Run code in a subprocess with N forced host devices; returns stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise RuntimeError(f"bench subprocess failed:\n{proc.stderr[-3000:]}")
    return proc.stdout


def emit(rows: list[tuple]) -> list[tuple]:
    """Print the CSV rows AND publish them into the metrics registry
    (``bench/<name>_us`` gauges), so ``results/metrics.json`` carries the
    same numbers the BENCH_*.json artifacts do."""
    from repro import telemetry
    reg = telemetry.get_registry()
    for name, us, derived in rows:
        print(f"{name},{us if us is not None else ''},{derived}")
        if us is not None:
            reg.gauge(f"bench/{name}_us").set(float(us))
    return rows


def telemetry_artifacts(name: str, *, devices: int | None = None) -> None:
    """Persist this process's telemetry: the global tracer's span buffer to
    ``results/trace_<name>.json`` (Chrome/Perfetto trace-event JSON) and the
    global registry snapshot merged into ``results/metrics.json`` (stamped
    with the same metadata BENCH_*.json carries, so the trend job matches
    like with like)."""
    from repro import telemetry
    os.makedirs(RESULTS, exist_ok=True)
    telemetry.dump_trace(os.path.join(RESULTS, f"trace_{name}.json"))
    telemetry.get_registry().dump(os.path.join(RESULTS, "metrics.json"),
                                  meta=bench_metadata(devices))
