"""Shared benchmark utilities: timing + subprocess multi-device runs."""
from __future__ import annotations

import os
import subprocess
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
RESULTS = os.path.join(REPO, "results")


def time_us(fn, *, warmup: int = 3, iters: int = 20) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6


def run_multidevice(code: str, devices: int, timeout: int = 1200) -> str:
    """Run code in a subprocess with N forced host devices; returns stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise RuntimeError(f"bench subprocess failed:\n{proc.stderr[-3000:]}")
    return proc.stdout


def emit(rows: list[tuple]) -> list[tuple]:
    for name, us, derived in rows:
        print(f"{name},{us if us is not None else ''},{derived}")
    return rows
